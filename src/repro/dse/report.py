"""Host-side result reporting for repro.dse (tables + JSON).

Two granularities:

* :func:`result_rows` — one row per candidate (label, portfolio cost,
  per-SKU unit costs, risk stats when present), for ranking tables.
* :func:`detail_rows` — one row per SKU of a single candidate with the
  full itemized breakdown, produced by ``CostEngine.as_rows`` on the
  candidate's own batch, so the columns are exactly the engine's
  (``raw_chips`` ... ``nre_total`` / ``total``).

Everything returns plain lists of dicts of Python floats — json.dumps
ready — plus a minimal fixed-width :func:`format_table` for terminals.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

from ..core.batch import SystemBatch
from ..core.engine import CostEngine
from .evaluate import CandidateResult
from .search import SearchResult
from .space import Candidate, DesignSpace, candidate_systems


def result_rows(results: Sequence[CandidateResult],
                top: Optional[int] = None) -> List[Dict]:
    """Per-candidate summary rows (input order preserved)."""
    rows = []
    for r in results[:top] if top is not None else results:
        row = {"candidate": r.label, "reuse": r.candidate.is_reuse,
               "portfolio_cost": float(r.portfolio_cost)}
        for name, u, re_u, nre_u in zip(r.sku_names, r.sku_unit_total,
                                        r.sku_unit_re, r.sku_unit_nre):
            row[f"{name}:unit"] = float(u)
            row[f"{name}:re"] = float(re_u)
            row[f"{name}:nre"] = float(nre_u)
        if r.risk:
            row.update({f"risk:{k}": float(v) for k, v in r.risk.items()})
        rows.append(row)
    return rows


def detail_rows(space: DesignSpace, cand: Candidate,
                engine: Optional[CostEngine] = None,
                flow: str = "chip-last") -> List[Dict]:
    """Engine-itemized per-SKU rows for one candidate
    (``CostEngine.as_rows`` column contract)."""
    engine = engine or CostEngine()
    batch = SystemBatch.from_systems(candidate_systems(space, cand),
                                     share_nre=True)
    return engine.as_rows(batch, flow=flow)


def search_summary(res: SearchResult, top: int = 5) -> Dict:
    """JSON-ready digest of a search run."""
    return {
        "objective": res.objective_key,
        "best": {"candidate": res.best.label,
                 "portfolio_cost": float(res.best.portfolio_cost),
                 "objective": float(res.best.objective(res.objective_key)),
                 "risk": ({k: float(v) for k, v in res.best.risk.items()}
                          if res.best.risk else None)},
        "top": result_rows(res.top(top)),
        "pareto": [{k: (v if isinstance(v, str) else float(v))
                    for k, v in p.items() if k != "candidate"}
                   for p in res.pareto],
        "n_evaluated": res.n_evaluated,
        "history": res.history,
    }


def format_table(rows: Sequence[Dict],
                 columns: Optional[Sequence[str]] = None) -> str:
    """Fixed-width text table; floats >= 1000 rendered with separators."""
    if not rows:
        return "(no rows)"
    cols = list(columns) if columns else list(rows[0].keys())

    def fmt(v):
        if isinstance(v, bool) or v is None:
            return str(v)
        if isinstance(v, float):
            return f"{v:,.0f}" if abs(v) >= 1000 else f"{v:.4g}"
        return str(v)

    table = [[fmt(r.get(c, "")) for c in cols] for r in rows]
    widths = [max(len(c), *(len(t[i]) for t in table))
              for i, c in enumerate(cols)]
    lines = ["  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    lines.append("  ".join("-" * w for w in widths))
    for t in table:
        lines.append("  ".join(v.rjust(w) for v, w in zip(t, widths)))
    return "\n".join(lines)


def to_json(obj, indent: int = 2) -> str:
    """json.dumps with a default that copes with numpy scalars/arrays."""
    def default(o):
        if hasattr(o, "tolist"):
            return o.tolist()
        if hasattr(o, "item"):
            return o.item()
        return str(o)
    return json.dumps(obj, indent=indent, default=default)
