"""Three-term roofline from the dry-run's compiled artifact.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HBM_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

(The analyzer reports per-device numbers, so no further division by chip
count is needed; multiplying back by `chips` gives the global figures
the brief's formulas express.)

Hardware constants: TPU v5e-class per the brief.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .hlo import HLOCostReport


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e-class"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # B/s per chip
    ici_bw_per_link: float = 50e9        # B/s per link
    ici_links: int = 4                   # usable links per chip (2D torus)
    hbm_gb: float = 16.0


HW = Hardware()


@dataclasses.dataclass
class RooflineTerms:
    t_compute: float
    t_memory: float              # Pallas-kernel path (flash tiles in VMEM)
    t_collective: float
    flops: float                 # per device
    hbm_bytes: float             # per device (kernel path)
    collective_bytes: float      # per device
    model_flops: float = 0.0     # global useful FLOPs (6ND-style)
    chips: int = 1
    t_memory_xla_path: float = 0.0   # score tiles materialized to HBM

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of peak at the bound step time.

        MODEL_FLOPS/(chips · peak · t_bound): the MFU-style score the
        perf loop is hill-climbing.
        """
        if self.t_bound <= 0:
            return 0.0
        return (self.model_flops / self.chips / HW.peak_flops_bf16
                / self.t_bound)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs (remat/redundancy waste)."""
        total = self.flops * self.chips
        return self.model_flops / total if total > 0 else 0.0

    def as_dict(self) -> Dict:
        return {
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bound": self.bound,
            "t_bound": self.t_bound, "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.collective_bytes,
            "model_flops": self.model_flops, "chips": self.chips,
            "roofline_fraction": self.roofline_fraction,
            "useful_flops_ratio": self.useful_flops_ratio,
            "t_memory_xla_path": self.t_memory_xla_path,
        }


def roofline_from_report(report: HLOCostReport, *, chips: int,
                         model_flops: float = 0.0,
                         hw: Hardware = HW) -> RooflineTerms:
    ici_bw = hw.ici_bw_per_link * hw.ici_links
    return RooflineTerms(
        t_compute=report.flops / hw.peak_flops_bf16,
        t_memory=report.hbm_bytes_kernel_path / hw.hbm_bw,
        t_collective=report.total_collective_bytes / ici_bw,
        flops=report.flops,
        hbm_bytes=report.hbm_bytes_kernel_path,
        collective_bytes=report.total_collective_bytes,
        model_flops=model_flops,
        chips=chips,
        t_memory_xla_path=report.hbm_bytes / hw.hbm_bw,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS (6ND-style useful flops) per (arch x shape)
# ---------------------------------------------------------------------------


def active_params(cfg) -> float:
    """Active parameters per token (MoE counts shared + top-k experts)."""
    from ..models import api
    from ..models.common import count_params, is_spec
    import jax

    spec = api.param_spec(cfg)
    if cfg.family != "moe":
        return float(count_params(spec))
    # replace the full expert count by (shared + top_k) experts
    total = float(count_params(spec))
    import math
    expert_params = 3 * cfg.d_model * cfg.d_ff_expert
    moe_layers = cfg.n_layers - cfg.first_dense
    routed_all = moe_layers * cfg.n_experts * expert_params
    routed_active = moe_layers * cfg.top_k * expert_params
    return total - routed_all + routed_active


def model_flops(cfg, shape) -> float:
    """Useful FLOPs of one step: 6·N_active·D (train) / 2·N_active·D (fwd).

    decode shapes process global_batch tokens; prefill/train process
    global_batch·seq tokens.  Attention FLOPs beyond the 6ND rule are
    intentionally excluded (the brief's definition).
    """
    n_act = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens = shape.global_batch * (shape.seq_len + cfg.dec_len)
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch
