"""CostEngine / SystemBatch: parity with the scalar reference paths,
jit single-trace behaviour, grad/vmap compatibility, spec builder, and
the deterministic pareto_front contract."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import (CostEngine, SystemBatch, amortized_costs,
                        pareto_front, re_cost, soc_system, spec,
                        split_system)
from repro.core.engine import TRACE_COUNTS, _re_impl

ENGINE = CostEngine()

RE_FIELDS = ("raw_chips", "chip_defects", "raw_package", "package_defects",
             "wasted_kgd")


def _hetero_group():
    """SoC / MCM / InFO / 2.5D group, incl. mixed-node unequal slices."""
    return [
        soc_system("soc", 800.0, "5nm", quantity=1e6),
        split_system("mcm", 800.0, "5nm", 3, "MCM", quantity=1e6),
        split_system("info", 600.0, "7nm", 2, "InFO", quantity=5e5),
        split_system("d25", 600.0, "5nm", 4, "2.5D", quantity=1e6),
        spec({"kind": "split", "name": "het", "area": 700.0,
              "fractions": [0.5, 0.3, 0.2],
              "processes": ["5nm", "7nm", "12nm"],
              "integration": "2.5D", "quantity": 1e6}),
        spec({"kind": "chips", "name": "forced_pkg",
              "chips": [{"area": 150.0, "process": "7nm"},
                        {"area": 90.0, "process": "12nm"}],
              "integration": "MCM", "quantity": 2e5,
              "package_area": 1200.0}),
    ]


@pytest.mark.parametrize("flow", ["chip-last", "chip-first"])
def test_re_parity_with_scalar_reference(flow):
    systems = _hetero_group()
    br = ENGINE.re(SystemBatch.from_systems(systems), flow=flow)
    for i, s in enumerate(systems):
        ref = re_cost(s, flow=flow)
        for f in RE_FIELDS:
            assert float(getattr(br, f)[i]) == pytest.approx(
                getattr(ref, f), rel=1e-5, abs=1e-8), (s.name, f)
        assert float(br.total[i]) == pytest.approx(ref.total, rel=1e-5)


def test_nre_and_total_parity_with_amortized_costs():
    systems = _hetero_group()
    tc = ENGINE.total(SystemBatch.from_systems(systems))
    ref = amortized_costs(systems)
    for i, s in enumerate(systems):
        r = ref[s.name]
        assert float(tc.nre.modules[i]) == pytest.approx(r.nre_modules,
                                                         rel=1e-5)
        assert float(tc.nre.chips[i]) == pytest.approx(r.nre_chips, rel=1e-5)
        assert float(tc.nre.packages[i]) == pytest.approx(r.nre_packages,
                                                          rel=1e-5)
        assert float(tc.nre.d2d[i]) == pytest.approx(r.nre_d2d, rel=1e-5,
                                                     abs=1e-6)
        assert float(tc.total[i]) == pytest.approx(r.total, rel=1e-5)


def test_package_reuse_group_parity():
    from repro.core import scms_systems
    grp = scms_systems(integration="2.5D", package_reuse=True)
    tc = ENGINE.total(SystemBatch.from_systems(grp))
    ref = amortized_costs(grp)
    for i, s in enumerate(grp):
        assert float(tc.total[i]) == pytest.approx(ref[s.name].total,
                                                   rel=1e-5)


def test_share_nre_false_prices_standalone_groups():
    s1 = split_system("a", 400.0, "7nm", 2, "MCM", quantity=1e6)
    s2 = split_system("b", 400.0, "7nm", 2, "MCM", quantity=1e6)
    alone = SystemBatch.from_systems([s1, s2], share_nre=False)
    tc = ENGINE.total(alone)
    for i, s in enumerate((s1, s2)):
        assert float(tc.total[i]) == pytest.approx(
            amortized_costs([s])[s.name].total, rel=1e-5)
    # group mode pools cross-system entities (here: the shared 7nm D2D
    # interface design), matching the legacy group reference — and is
    # therefore cheaper per unit than standalone pricing
    shared = SystemBatch.from_systems([s1, s2], share_nre=True)
    ref = amortized_costs([s1, s2])
    ts = ENGINE.total(shared)
    for i, s in enumerate((s1, s2)):
        assert float(ts.total[i]) == pytest.approx(ref[s.name].total,
                                                   rel=1e-5)
    assert float(ts.total[0]) < float(tc.total[0])


def test_shared_nre_batch_requires_unique_names():
    s = soc_system("dup", 300.0, "7nm")
    with pytest.raises(ValueError):
        SystemBatch.from_systems([s, s], share_nre=True)
    SystemBatch.from_systems([s, s], share_nre=False)  # fine standalone


def test_wafer_yield_threaded_from_node():
    """The engine must use the per-node wafer yield (the old re_cost_split
    hardcoded 0.99) — perturbing it must move the engine's answer."""
    import repro.core.technology as tech_mod
    s = soc_system("s", 500.0, "5nm")
    base = float(ENGINE.re(SystemBatch.from_systems([s])).total[0])
    node5 = tech_mod.PROCESS_NODES["5nm"]
    try:
        tech_mod.PROCESS_NODES["5nm"] = dataclasses.replace(
            node5, wafer_yield=0.5)
        bumped = float(ENGINE.re(SystemBatch.from_systems([s])).total[0])
        ref = re_cost(soc_system("s", 500.0, "5nm")).total
    finally:
        tech_mod.PROCESS_NODES["5nm"] = node5
    assert bumped > 1.5 * base                 # halving yield ~doubles KGD
    assert bumped == pytest.approx(ref, rel=1e-5)   # and matches reference


def test_single_trace_across_same_shape_batches():
    systems = [split_system(f"s{i}", 300.0 + i, "7nm", 2, "MCM")
               for i in range(4)]
    b1 = SystemBatch.from_systems(systems[:2], share_nre=False)
    b2 = SystemBatch.from_systems(systems[2:], share_nre=False)
    ENGINE.total(b1)
    before = dict(TRACE_COUNTS)
    ENGINE.total(b2)   # same shapes, different data + names -> no retrace
    assert dict(TRACE_COUNTS) == before


def test_grad_and_vmap_through_engine():
    batch = SystemBatch.from_systems(
        [split_system("m", 800.0, "5nm", 3, "MCM")])

    def total(areas):
        return _re_impl(batch.replace(chip_area=areas), "chip-last").total.sum()

    g = jax.jit(jax.grad(total))(batch.chip_area)
    assert g.shape == batch.chip_area.shape
    assert bool(jnp.all(jnp.isfinite(g)))
    assert bool(jnp.all(g > 0.0))       # more silicon always costs more

    sweep = jnp.stack([batch.chip_area * s for s in (0.5, 1.0, 2.0)])
    totals = jax.vmap(total)(sweep)
    assert totals.shape == (3,)
    assert float(totals[0]) < float(totals[1]) < float(totals[2])


def test_spec_wrappers_equivalent():
    a = soc_system("x", 640.0, "7nm", quantity=2e5)
    b = spec({"kind": "soc", "name": "x", "area": 640.0, "process": "7nm",
              "quantity": 2e5})
    assert a == b
    c = split_system("y", 640.0, "7nm", 4, "InFO", quantity=2e5)
    d = spec({"name": "y", "area": 640.0, "process": "7nm", "n": 4,
              "integration": "InFO", "quantity": 2e5})
    assert c == d


def test_spec_rejects_unknown_keys_and_bad_fractions():
    with pytest.raises(ValueError):
        spec({"kind": "soc", "area": 100.0, "process": "7nm", "typo": 1})
    with pytest.raises(ValueError):
        spec({"kind": "split", "area": 100.0, "process": "7nm", "n": 3,
              "fractions": [0.5, 0.5], "integration": "MCM"})


def test_re_cost_split_deprecated_but_working():
    from repro.core import node, re_cost_split, tech
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        r = re_cost_split(800.0, 3.0, wafer_cost=node("5nm").wafer_cost,
                          defect_density=0.11, cluster=3.0,
                          tech_params=tech("MCM"))
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    assert float(r["total"]) > 0.0
    assert float(r["total"]) == pytest.approx(
        sum(float(r[k]) for k in RE_FIELDS), rel=1e-6)


def test_pareto_front_deterministic_ties():
    pts = [{"x": 1.0, "y": 5.0, "tag": "keep-first"},
           {"x": 1.0, "y": 5.0, "tag": "dup-dropped"},
           {"x": 2.0, "y": 5.0, "tag": "ytie-dropped"},
           {"x": 2.0, "y": 3.0, "tag": "keep"},
           {"x": 3.0, "y": 4.0, "tag": "dominated"}]
    front = pareto_front(pts, "x", "y")
    assert [p["tag"] for p in front] == ["keep-first", "keep"]
    # deterministic under input permutation of the non-duplicate points
    front2 = pareto_front(list(reversed(pts[2:])) + pts[:2], "x", "y")
    assert [(p["x"], p["y"]) for p in front2] == [(1.0, 5.0), (2.0, 3.0)]
