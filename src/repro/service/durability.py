"""Durable admission journal for crash-safe serving.

The missing robustness layer under :class:`~repro.service.server.
PricingService`: PR 8 made the tick loop survive faults *inside* the
process; this module makes admitted work survive the process itself.

Three pieces:

* **Wire codec** — :func:`request_to_wire` / :func:`request_from_wire`
  turn every typed request kind into a JSON-safe dict and back.
  ``Candidate`` objects are resolved to space indices at encode time, so
  a journal record never depends on pickling; a decoded request prices
  identically to the original (same indices, same seeds, same sigmas).
* **:class:`RequestJournal`** — an append-only, fsync-batched,
  segment-rotated write-ahead log of admitted requests.  One JSON record
  per line with a CRC32 field; every append is ``flush()``-ed to the OS
  (SIGKILL-safe) and batches of ``fsync_every`` appends are ``fsync``-ed
  (power-loss exposure is bounded).  A torn trailing record (crash mid
  ``write``) is detected and ignored on scan, never raised through.
  Segments rotate at ``segment_max_records`` records; rotation
  carries every still-open admit record forward into the fresh segment
  (fsync-ed before anything is dropped) and then garbage-collects ALL
  older segments — the journal's steady-state size is proportional to
  *open* work, not traffic history, and no kept segment ever depends on
  a record in a dropped one.
* **Replay** — :meth:`RequestJournal.replay` returns every admitted
  request without a terminal record, in admission order, as
  :class:`JournalEntry` rows carrying the request's stable ``origin``
  id.  ``PricingService.start()`` re-admits them with explicit
  ``replayed`` provenance on the responses (see README "Durability &
  restart").

The journal is deliberately service-agnostic below the codec: records
are ``(uid, wire-dict)`` pairs, terminality is a status string, and the
``stats_hook`` lets the owner mirror journal counters into its metrics
registry (the service wires :class:`~repro.service.metrics.
DurabilityStats` in).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import os
import zlib
from typing import Any, Callable, Dict, List, Optional

from ..dse.search import RiskConfig
from ..dse.uncertainty import Uncertainty
from .protocol import (McSpec, MCRiskRequest, PriceRequest,
                       PriceSystemsRequest, RankRequest, Request,
                       SearchRequest, WhatIfRequest)

_SEGMENT_PREFIX = "journal_"
_SEGMENT_SUFFIX = ".log"
_WIRE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class DurabilityConfig:
    """Where and how the service persists admitted work.

    ``directory`` holds the request journal segments plus one
    ``checkpoints/search_<origin>/`` tree per in-flight search.  The
    fsync batch and segment sizes trade write amplification against
    power-loss exposure; process kills (SIGKILL) lose nothing regardless
    because every append reaches the OS page cache before admission is
    acknowledged."""

    directory: pathlib.Path
    fsync_every: int = 8               # appends per fsync batch
    segment_max_records: int = 4096    # records per journal segment
    checkpoint_every: int = 4          # generations between search snaps
    checkpoint_keep: int = 3           # retained checkpoint steps

    def __post_init__(self):
        object.__setattr__(self, "directory",
                           pathlib.Path(self.directory))

    @property
    def journal_dir(self) -> pathlib.Path:
        return self.directory / "journal"

    def checkpoint_dir(self, origin: int) -> pathlib.Path:
        return self.directory / "checkpoints" / f"search_{origin:08d}"


# ---------------------------------------------------------------------------
# Request wire codec
# ---------------------------------------------------------------------------


def _wire_mc(mc: Optional[McSpec]) -> Optional[Dict]:
    if mc is None:
        return None
    return {"draws": int(mc.draws),
            "quantiles": [float(q) for q in mc.quantiles],
            "seed": int(mc.seed),
            "sigmas": _wire_sigmas(mc.sigmas)}


def _wire_sigmas(u: Uncertainty) -> List[float]:
    return [float(u.defect_sigma), float(u.wafer_cost_sigma),
            float(u.bond_sigma), float(u.interposer_sigma)]


def _unwire_sigmas(xs) -> Uncertainty:
    d, w, b, i = (float(x) for x in xs)
    return Uncertainty(defect_sigma=d, wafer_cost_sigma=w, bond_sigma=b,
                       interposer_sigma=i)


def _unwire_mc(d: Optional[Dict]) -> Optional[McSpec]:
    if d is None:
        return None
    return McSpec(draws=int(d["draws"]),
                  quantiles=tuple(float(q) for q in d["quantiles"]),
                  seed=int(d["seed"]), sigmas=_unwire_sigmas(d["sigmas"]))


def _wire_risk(r: Optional[RiskConfig]) -> Optional[Dict]:
    if r is None:
        return None
    return {"n_draws": int(r.n_draws), "quantile": float(r.quantile),
            "sigmas": _wire_sigmas(r.sigmas)}


def _unwire_risk(d: Optional[Dict]) -> Optional[RiskConfig]:
    if d is None:
        return None
    return RiskConfig(n_draws=int(d["n_draws"]),
                      quantile=float(d["quantile"]),
                      sigmas=_unwire_sigmas(d["sigmas"]))


def _indices(req, space) -> Optional[List[int]]:
    """Resolve a request's candidate selection to plain index lists.
    ``None`` stays ``None`` (rank-the-whole-space)."""
    if getattr(req, "candidates", ()) and req.indices is None:
        if space is None:
            raise ValueError(
                "journaling Candidate objects needs the DesignSpace")
        return [int(space.index_of(c)) for c in req.candidates]
    if req.indices is None:
        return None
    return [int(i) for i in req.indices]


def request_to_wire(req: Request, space=None) -> Dict:
    """One typed request -> a JSON-safe dict (inverse:
    :func:`request_from_wire`).  ``Candidate`` objects are resolved to
    indices through ``space`` so the wire form is self-contained."""
    kind = getattr(req, "kind", None)
    d: Dict[str, Any] = {"v": _WIRE_VERSION, "kind": kind,
                         "flow": req.flow}
    deadline = getattr(req, "deadline_ms", None)
    if deadline is not None:
        d["deadline_ms"] = float(deadline)
    if kind in ("price", "rank", "mc_risk"):
        d["indices"] = _indices(req, space)
        d["mc"] = _wire_mc(req.mc)
        if kind == "rank":
            d["top_k"] = int(req.top_k)
            d["objective"] = req.objective
    elif kind == "what_if":
        base = req.base
        if not isinstance(base, int):
            if space is None:
                raise ValueError(
                    "journaling a Candidate base needs the DesignSpace")
            base = int(space.index_of(base))
        d["base"] = int(base)
        d["processes"] = list(req.processes)
        d["integrations"] = list(req.integrations)
    elif kind == "search":
        d.update(seed=int(req.seed), population=int(req.population),
                 generations=int(req.generations), elite=int(req.elite),
                 jump_prob=float(req.jump_prob),
                 risk=_wire_risk(req.risk))
    elif kind == "price_systems":
        d["specs"] = [dict(s) for s in req.specs]
    else:
        raise ValueError(f"unknown request kind {kind!r}")
    return d


def request_from_wire(d: Dict) -> Request:
    """Inverse of :func:`request_to_wire`."""
    kind = d.get("kind")
    deadline = d.get("deadline_ms")
    if kind == "price":
        return PriceRequest(indices=d["indices"], flow=d["flow"],
                            mc=_unwire_mc(d.get("mc")),
                            deadline_ms=deadline)
    if kind == "rank":
        return RankRequest(indices=d["indices"], top_k=int(d["top_k"]),
                           flow=d["flow"], mc=_unwire_mc(d.get("mc")),
                           objective=d["objective"], deadline_ms=deadline)
    if kind == "mc_risk":
        return MCRiskRequest(indices=d["indices"],
                             mc=_unwire_mc(d["mc"]), flow=d["flow"],
                             deadline_ms=deadline)
    if kind == "what_if":
        return WhatIfRequest(base=int(d["base"]),
                             processes=tuple(d["processes"]),
                             integrations=tuple(d["integrations"]),
                             flow=d["flow"], deadline_ms=deadline)
    if kind == "search":
        return SearchRequest(seed=int(d["seed"]),
                             population=int(d["population"]),
                             generations=int(d["generations"]),
                             elite=int(d["elite"]),
                             jump_prob=float(d["jump_prob"]),
                             risk=_unwire_risk(d.get("risk")),
                             flow=d["flow"], deadline_ms=deadline)
    if kind == "price_systems":
        return PriceSystemsRequest(specs=tuple(dict(s)
                                               for s in d["specs"]),
                                   flow=d["flow"], deadline_ms=deadline)
    raise ValueError(f"unknown wire request kind {kind!r}")


# ---------------------------------------------------------------------------
# The journal
# ---------------------------------------------------------------------------


def _crc(payload: Dict) -> int:
    return zlib.crc32(
        json.dumps(payload, sort_keys=True, default=float).encode())


@dataclasses.dataclass
class JournalEntry:
    """One admitted-but-unfinished request, ready for replay."""

    uid: int                       # uid the request was admitted under
    origin: int                    # stable id across replay chains
    request: Request
    wire: Dict
    trace_id: str = ""             # durable trace id (stable across replay)


class RequestJournal:
    """Append-only, fsync-batched, segment-rotated admission WAL
    (see module docstring).

    Record grammar (one JSON object per line, ``crc`` = CRC32 of the
    record without its ``crc`` field)::

        {"rec": "meta",  "seq": n, "fingerprint": ..., "crc": ...}
        {"rec": "admit", "seq": n, "uid": u, "origin": o,
         "trace": t, "req": <wire>, "crc": ...}
        {"rec": "done",  "seq": n, "uid": u, "status": "ok"|<code>,
         "crc": ...}

    ``status`` is ``"ok"``, a typed error code, ``"cancelled"``, or
    ``"replayed"`` (the request was re-admitted under a new uid whose
    admit record precedes this terminal — so a crash between the two
    can only *duplicate* work, never lose it).
    """

    def __init__(self, directory, fsync_every: int = 8,
                 segment_max_records: int = 4096,
                 fingerprint: str = "",
                 stats_hook: Optional[Callable[[str, int], None]] = None):
        self.directory = pathlib.Path(directory)
        self.directory.mkdir(parents=True, exist_ok=True)
        self.fsync_every = max(1, int(fsync_every))
        self.segment_max_records = max(2, int(segment_max_records))
        self.fingerprint = fingerprint
        self._hook = stats_hook
        # live index, rebuilt by scan(): open admits + per-segment uids
        self._open: "Dict[int, Dict]" = {}      # uid -> admit record
        self._terminal: set = set()             # uids with a done record
        self._segment_uids: Dict[int, set] = {}  # seg no -> admitted uids
        self.max_uid = 0
        self.seq = 0
        self.torn_records = 0
        self.appends = 0
        self.fsyncs = 0
        self.rotations = 0
        self._pending_sync = 0
        self._fh = None
        self._segment_no = 0
        self._segment_records = 0
        self._rotating = False
        self._scan()
        self._open_segment(new=True)

    # -- scan / replay -------------------------------------------------------

    def _segments(self) -> List[int]:
        out = []
        for p in self.directory.iterdir():
            name = p.name
            if name.startswith(_SEGMENT_PREFIX) \
                    and name.endswith(_SEGMENT_SUFFIX):
                out.append(int(name[len(_SEGMENT_PREFIX):
                                    -len(_SEGMENT_SUFFIX)]))
        return sorted(out)

    def _segment_path(self, no: int) -> pathlib.Path:
        return self.directory / \
            f"{_SEGMENT_PREFIX}{no:08d}{_SEGMENT_SUFFIX}"

    def _scan(self):
        """Rebuild the open-request index from every on-disk segment.
        A line that fails to parse or fails its CRC is a torn write:
        counted, skipped, never raised."""
        for no in self._segments():
            uids = self._segment_uids.setdefault(no, set())
            for line in self._segment_path(no).read_text().splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                    crc = rec.pop("crc")
                    if crc != _crc(rec):
                        raise ValueError("crc mismatch")
                except (ValueError, KeyError, TypeError):
                    self.torn_records += 1
                    continue
                self.seq = max(self.seq, int(rec.get("seq", 0)))
                kind = rec.get("rec")
                if kind == "admit":
                    uid = int(rec["uid"])
                    self.max_uid = max(self.max_uid, uid)
                    uids.add(uid)
                    self._open[uid] = rec
                elif kind == "done":
                    uid = int(rec["uid"])
                    self._terminal.add(uid)
                    self._open.pop(uid, None)
            self._segment_no = max(self._segment_no, no)

    def replay(self) -> List[JournalEntry]:
        """Every admitted request without a terminal record, oldest
        first.  Undecodable wire payloads are skipped and counted as
        torn (a corrupt record must not poison the whole recovery)."""
        out = []
        for rec in sorted(self._open.values(),
                          key=lambda r: int(r["seq"])):
            try:
                req = request_from_wire(rec["req"])
            except (ValueError, KeyError, TypeError):
                self.torn_records += 1
                continue
            uid = int(rec["uid"])
            out.append(JournalEntry(uid=uid,
                                    origin=int(rec.get("origin", uid)),
                                    request=req, wire=rec["req"],
                                    trace_id=str(rec.get("trace", ""))))
        return out

    @property
    def open_count(self) -> int:
        return len(self._open)

    # -- append path ---------------------------------------------------------

    def _open_segment(self, new: bool):
        if new:
            self._segment_no += 1
            self._segment_records = 0
            self._segment_uids.setdefault(self._segment_no, set())
        path = self._segment_path(self._segment_no)
        self._fh = open(path, "a", encoding="utf-8")
        if new and self.fingerprint:
            self._write({"rec": "meta", "fingerprint": self.fingerprint})

    def _bump(self, name: str, n: int = 1):
        if self._hook is not None:
            self._hook(name, n)

    def _write(self, payload: Dict):
        self.seq += 1
        payload = {"seq": self.seq, **payload}
        payload["crc"] = _crc({k: v for k, v in payload.items()
                               if k != "crc"})
        self._fh.write(json.dumps(payload, default=float) + "\n")
        # flush to the OS on every record: admission acknowledged =>
        # SIGKILL-safe.  fsync (power-loss durability) is batched.
        self._fh.flush()
        self.appends += 1
        self._bump("journal_appends")
        self._pending_sync += 1
        if self._pending_sync >= self.fsync_every:
            self.sync()
        self._segment_records += 1
        if self._segment_records >= self.segment_max_records \
                and not self._rotating:
            self._rotate()

    def sync(self):
        if self._fh is None or self._pending_sync == 0:
            return
        os.fsync(self._fh.fileno())
        self.fsyncs += 1
        self._bump("journal_fsyncs")
        self._pending_sync = 0

    def _rotate(self):
        """Close the full segment, carry every still-open admit forward
        into a fresh one, then drop all older segments.

        Carry-forward is what makes the aggressive GC sound: after it,
        no kept segment's open admit (nor any done record that matters)
        lives in a dropped segment — dropping a closed segment can
        orphan ``done`` records only for admits dropped with it, which
        the scan ignores harmlessly.  The carried copies are fsync-ed
        BEFORE the originals are unlinked, so a crash anywhere in
        rotation can at worst duplicate admit records (the scan
        de-duplicates by uid), never lose one.  If open work exceeds
        ``segment_max_records`` the new segment simply runs oversized
        until some of it terminates."""
        self.sync()
        self._fh.close()
        self.rotations += 1
        self._bump("journal_rotations")
        carried = sorted(self._open.values(), key=lambda r: int(r["seq"]))
        self._open_segment(new=True)
        self._rotating = True
        try:
            for rec in carried:
                self.admit(int(rec["uid"]), rec["req"],
                           origin=int(rec.get("origin", rec["uid"])),
                           trace_id=str(rec.get("trace", "")))
        finally:
            self._rotating = False
        self.sync()
        self._gc()

    def _gc(self):
        """Drop every closed segment (rotation just carried all open
        admits into the current one)."""
        for no in self._segments():
            if no == self._segment_no:
                continue
            self._segment_path(no).unlink(missing_ok=True)
            uids = self._segment_uids.pop(no, set())
            self._terminal -= uids

    # -- the service-facing API ---------------------------------------------

    def admit(self, uid: int, wire: Dict, origin: Optional[int] = None,
              trace_id: str = ""):
        """Journal one admission (the WAL write that makes the request
        crash-safe).  Must be called before the admission is
        acknowledged to the client.  ``trace_id`` rides the record so a
        replay after a crash reconstructs the SAME request trace."""
        uid = int(uid)
        rec = {"rec": "admit", "uid": uid,
               "origin": int(origin if origin is not None else uid),
               "trace": str(trace_id),
               "req": wire}
        self._open[uid] = {**rec, "seq": self.seq + 1}
        self._segment_uids[self._segment_no].add(uid)
        self.max_uid = max(self.max_uid, uid)
        self._write(rec)

    def done(self, uid: int, status: str):
        """Journal a terminal outcome (``ok`` / typed error code /
        ``cancelled`` / ``replayed``): the request will not be replayed."""
        uid = int(uid)
        if uid not in self._open:
            return
        self._open.pop(uid, None)
        self._terminal.add(uid)
        self._write({"rec": "done", "uid": uid, "status": str(status)})

    def close(self):
        if self._fh is not None:
            self.sync()
            self._fh.close()
            self._fh = None

    def stats(self) -> Dict[str, int]:
        return {"segments": len(self._segments()),
                "open": self.open_count,
                "appends": self.appends,
                "fsyncs": self.fsyncs,
                "rotations": self.rotations,
                "torn_records": self.torn_records,
                "max_uid": self.max_uid}
