from .adamw import (OptState, adamw_init, adamw_init_spec, adamw_update,
                    clip_by_global_norm)
from .schedule import cosine_schedule, linear_warmup_cosine
from .compression import (compress_topk_int8, decompress_topk_int8,
                          error_feedback_update)
