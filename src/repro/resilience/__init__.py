"""repro.resilience — failure handling for the pricing stack.

Four small, dependency-light building blocks (stdlib + numpy only, no
jax, no imports from the rest of ``repro`` — every other layer may
import this one without cycles):

* :mod:`~repro.resilience.faults` — deterministic, seed-keyed fault
  injection behind the ``REPRO_FAULTS`` env var (disabled injectors are
  falsy, so production hot paths pay one truthiness check).
* :mod:`~repro.resilience.retry` — retry-with-backoff and a
  closed/open/half-open :class:`CircuitBreaker` for the fused-dispatch
  degradation path.
* :mod:`~repro.resilience.guards` — host-side numerical validation:
  non-finite walks over request objects and range checks over packed
  system arrays (NaN/Inf anywhere, negative areas/costs, yields outside
  (0, 1]).
* :mod:`~repro.resilience.watchdog` — a heartbeat thread that detects a
  stuck service tick and fires a one-per-stall callback (the server uses
  it to auto-dump the flight recorder).

How the service composes them is documented in the README "Failure
handling" section and :mod:`repro.service.server`.
"""
from .faults import (FAULT_KINDS, FaultInjector, FaultRule, InjectedFault,
                     parse_fault_spec)
from .guards import nonfinite_paths, validate_packed_arrays
from .retry import CircuitBreaker, RetryPolicy, call_with_retry
from .watchdog import Watchdog

__all__ = [
    "FAULT_KINDS", "FaultInjector", "FaultRule", "InjectedFault",
    "parse_fault_spec",
    "nonfinite_paths", "validate_packed_arrays",
    "CircuitBreaker", "RetryPolicy", "call_with_retry",
    "Watchdog",
]
