"""Shared helpers for the benchmark suite (CSV emission, timing, and the
BENCH_*.json perf-trajectory files CI tracks)."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Iterable

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def write_bench_json(name: str, summary: dict) -> pathlib.Path:
    """Persist a benchmark summary as ``BENCH_<name>.json`` at the repo
    root.  CI uploads these as artifacts and
    ``scripts/check_bench_regression.py`` guards them against the
    committed baselines in ``benchmarks/baselines/``."""
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(summary, indent=2, sort_keys=True,
                               default=float) + "\n")
    print(f"# wrote {path}")
    return path


def emit(section: str, rows: Iterable[dict]):
    rows = list(rows)
    if not rows:
        print(f"# {section}: (no rows)")
        return
    cols = list(rows[0].keys())
    print(f"# {section}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))
    print()


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def timed(fn: Callable, *args, repeat: int = 3):
    fn(*args)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6           # us per call
