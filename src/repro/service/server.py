"""Actuary-as-a-service: the continuous-batching cost-query server.

The same slot/pad idiom as :mod:`repro.serving.engine` (vLLM-style
continuous batching), but the "decode step" is the fused DSE chunk
kernel: concurrent clients submit typed pricing requests
(:mod:`repro.service.protocol`), an async scheduler
(:mod:`repro.service.scheduler`) coalesces heterogeneous pending work
into the constant ``chunk_shape`` signatures of
:class:`~repro.dse.evaluate.ChunkedEvaluator` / ``portfolio_search``,
dispatches ONE device tick, and streams per-request results back with
exactly one ``jax.device_get`` per tick.

Because ticks call the very same module-level jits the direct APIs use
(``_CHUNK_JIT`` / ``_CHUNK_MC_JIT`` / the search generation step), and
because every per-candidate value in those kernels depends only on its
own row (padding is cost-neutral by construction), a coalesced response
is **bit-exact** against the equivalent single-request
``ChunkedEvaluator.evaluate_indices`` / ``portfolio_search`` call — the
hard parity oracle ``tests/test_service.py`` pins with 0 relative error.

Lifecycle::

    svc = PricingService(space, ServiceConfig(chunk=128))
    await svc.start()            # pre-warms every configured jit trace
    resp = await svc.submit(PriceRequest(indices=[3, 17, 912]))
    resp.result.portfolio_cost   # EvalArrays, bit-exact vs direct call
    await svc.stop()

or synchronously: ``responses, svc = serve(space, requests, config)``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import shutil
import signal
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.store import CheckpointManager
from ..core.batch import SystemBatch, pad_batch
from ..core.engine import _TOTAL_JIT
from ..core.system import System, spec
from ..dse.evaluate import _CHUNK_JIT, _CHUNK_MC_JIT, ChunkedEvaluator, \
    EvalArrays
from ..dse.search import SearchResult, SearchState, _default_mc_key, \
    _front, _gen_step, _rank
from ..dse.space import ArchChoice, Candidate, DesignSpace
from ..obs import jaxhooks
from ..obs.flight import FlightRecorder
from ..obs.ledger import Bill, Ledger
from ..obs.slo import SLObjective, SLOTracker
from ..obs.trace import TRACER as _TRACER
from ..resilience import CircuitBreaker, FaultInjector, InjectedFault, \
    Watchdog
from .cache import LaneSignature, ResultCache, TraceCache, space_fingerprint
from .durability import DurabilityConfig, RequestJournal, request_to_wire
from .metrics import DurabilityStats, RequestRecord, ResilienceStats, \
    ServiceMetrics
from .protocol import DEADLINE_EXCEEDED, INTERNAL_ERROR, INVALID_REQUEST, \
    NUMERICAL_ERROR, QUEUE_FULL, SHUTTING_DOWN, McSpec, \
    MCRiskRequest, PriceRequest, PriceSystemsRequest, RankRequest, Request, \
    RequestLog, Response, SearchRequest, SystemsResult, Timing, \
    WhatIfRequest, WhatIfResult, RankResult, error_response, \
    mint_trace_id, validate_request
from .scheduler import Assignment, GenWork, GroupWork, Lane, Scheduler, \
    SpanWork, TickPlan


class ServiceError(Exception):
    """Admission-time rejection; becomes a typed error envelope."""

    def __init__(self, code: str, message: str):
        super().__init__(message)
        self.code = code


class SimulatedCrash(RuntimeError):
    """Raised by the injected ``crash`` fault kind: the moral equivalent
    of SIGKILL at a tick boundary — in-flight futures get typed
    ``shutting_down`` envelopes so test clients unblock, but NO journal
    terminals are written, so a subsequent :meth:`PricingService.start`
    must replay the journal exactly as after a real process death."""


@dataclasses.dataclass(frozen=True)
class SearchWarmup:
    """One gen-step jit signature to pre-compile at startup."""

    population: int = 32
    elite: int = 6
    jump_prob: float = 0.15
    n_draws: int = 0          # 0 = nominal objective
    quantile: float = 0.5


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving shape + warmup menu.  ``chunk`` and the warm lists are jit
    signature components: requests outside the warmed menu still work,
    but compile at admission time (never inside a tick)."""

    chunk: int = 64                    # candidate slots per device tick
    split: Optional[int] = None        # max slots one request takes per pass
    flows: Tuple[str, ...] = ("chip-last",)
    max_pending: int = 1_000_000       # queued-row budget (backpressure)
    raw_slots: int = 16                # system slots of the raw spec lane
    raw_max_chips: Optional[int] = None
    result_cache_entries: int = 256
    result_cache_max_rows: int = 65536
    warm_mc: Tuple[Tuple[int, Tuple[float, ...]], ...] = ((128, (0.5, 0.9)),)
    warm_search: Tuple[SearchWarmup, ...] = ()
    log_keep: int = 1024
    flight_capacity: int = 2048        # flight-recorder ring (always on)
    # -- failure handling (see README "Failure handling") ------------------
    tick_retries: int = 1              # fused re-dispatch attempts per tick
    retry_backoff_s: float = 0.005     # linear backoff between attempts
    fallback: bool = True              # degrade to the legacy host path
    breaker_threshold: int = 1         # consecutive failures that open it
    breaker_cooldown_s: float = 2.0    # open -> half_open re-probe delay
    watchdog_timeout_s: Optional[float] = None   # None = no watchdog
    # -- durability / lifecycle (see README "Durability & restart") --------
    durability: Optional[DurabilityConfig] = None  # None = no journal
    drain_timeout_s: Optional[float] = None  # stop(): None = unbounded drain
    sigterm_drain: bool = False        # SIGTERM -> bounded-drain stop()
    # -- SLOs (see README "Observability") ---------------------------------
    # Declarative latency/availability objectives per request kind; empty
    # tuple = no SLO tracking (default, zero overhead).  A burn-rate
    # excursion past an objective's alert threshold records a flight
    # event and auto-dumps context when REPRO_FLIGHT_DIR is set.
    slos: Tuple[SLObjective, ...] = ()


@dataclasses.dataclass(eq=False)
class _Active:
    """Server-side state of one in-flight request."""

    uid: int
    kind: str
    request: Request
    rec: RequestRecord
    future: asyncio.Future
    cost: int = 0                      # admitted row budget (released at end)
    n_rows: int = 0
    rows_done: int = 0
    idx: Optional[np.ndarray] = None
    accum: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    risk_keys: Tuple[str, ...] = ()
    payload_fn: Optional[Callable] = None    # EvalArrays -> result payload
    cache_key: Optional[Tuple] = None
    on_partial: Optional[Callable] = None
    task: Optional["SearchTask"] = None
    failed: bool = False
    deadline_t: Optional[float] = None       # absolute perf_counter deadline
    degraded: bool = False                   # any row via legacy fallback
    degraded_rows: Optional[np.ndarray] = None   # (n,) provenance mask
    # Replay provenance: set when this admission re-plays a journaled
    # request; ``origin`` is the stable id across replay chains (= uid
    # for fresh admissions) and keys the search checkpoint directory.
    replayed_from: Optional[int] = None
    origin: int = 0
    # Request-scoped trace id (minted at admission, durable across
    # crash replay) and the request's open serving-cost bill.
    trace_id: str = ""
    bill: Optional[Bill] = None


def _risk_keys(quantiles: Tuple[float, ...]) -> Tuple[str, ...]:
    return ("mean", "std") + tuple(f"q{int(round(q * 100))}"
                                   for q in quantiles)


class SearchTask:
    """Device-side state of one evolutionary search, advanced one jitted
    generation per tick.  The loop state is a
    :class:`~repro.dse.search.SearchState` — the same carrier
    ``portfolio_search`` checkpoints — so the key schedule, generation
    step, history, final ranking, AND checkpoint/restore semantics
    replicate the direct call exactly: a served (or resumed) search is
    bit-exact against ``portfolio_search``."""

    def __init__(self, svc: "PricingService", active: _Active,
                 sr: SearchRequest):
        self.svc = svc
        self.active = active
        self.sr = sr
        self.obj = "cost"
        self.n_draws, self.quantile = 0, 0.5
        if sr.risk is not None:
            self.obj = sr.risk.objective_key
            self.n_draws = int(sr.risk.n_draws)
            self.quantile = float(sr.risk.quantile)
        self.state = SearchState.init(jax.random.PRNGKey(sr.seed),
                                      sr.population, svc.space.size(),
                                      sr.risk)
        # the trace id rides the checkpoint manifest, so a resumed
        # search continues the SAME request trace
        self.state.trace_id = active.trace_id

    @property
    def gen(self) -> int:
        return self.state.gen

    @property
    def mc_key(self):
        return self.state.mc_key

    def device_call(self):
        """Dispatch one generation; returns the arrays to fetch (the
        next population stays on device)."""
        st = self.state
        st.k_loop, k_gen = jax.random.split(st.k_loop)
        pop_out, pop_next, gen_idx, gen_obj = _gen_step()(
            self.svc.enc.tables, k_gen, st.pop, self.svc.qty,
            st.mc_key, st.sig, meta=self.svc.enc.meta,
            flow=self.sr.flow, population=self.sr.population,
            elite=self.sr.elite, jump_prob=float(self.sr.jump_prob),
            n_draws=self.n_draws, quantile=self.quantile)
        st.pop = pop_next
        return (pop_out, gen_idx, gen_obj)

    def consume(self, host) -> bool:
        """Fold one generation's host results in; True when the
        generation budget is spent (ranking sweep comes next)."""
        self.state.consume(
            host, lambda i: self.svc.space.candidate_at(i).label())
        return self.state.gen >= self.sr.generations

    def uniq_indices(self) -> np.ndarray:
        return np.asarray(sorted(self.state.seen), np.int64)

    def finalize(self, arrays: EvalArrays) -> SearchResult:
        results = self.svc.ev.results_from_arrays(arrays)
        ranked = _rank(results, self.obj)
        return SearchResult(best=ranked[0], ranked=ranked,
                            pareto=_front(ranked, self.obj),
                            history=self.state.history,
                            n_evaluated=len(results),
                            objective_key=self.obj)


class PricingService:
    """The continuous-batching pricing server for one
    :class:`~repro.dse.space.DesignSpace`."""

    def __init__(self, space: DesignSpace,
                 config: Optional[ServiceConfig] = None,
                 log: Optional[RequestLog] = None):
        self.space = space
        self.cfg = config or ServiceConfig()
        if not self.cfg.flows:
            raise ValueError("service needs at least one flow")
        self.enc = space.encoder()
        self.qty = jnp.asarray([sk.quantity for sk in space.skus],
                               jnp.float32)
        self.n_skus = len(space.skus)
        # direct-API twin: shares the module-level jits (and therefore the
        # compiled traces) with every tick; also the host-side
        # results_from_arrays helper.
        self.ev = ChunkedEvaluator(space, candidates_per_chunk=self.cfg.chunk,
                                   flow=self.cfg.flows[0])
        self.fingerprint = space_fingerprint(space)
        self.sched = Scheduler(slots=self.cfg.chunk, split=self.cfg.split,
                               raw_slots=self.cfg.raw_slots,
                               max_pending=self.cfg.max_pending)
        self.metrics = ServiceMetrics()
        self.flight = FlightRecorder(capacity=self.cfg.flight_capacity)
        self.log = log or RequestLog(keep=self.cfg.log_keep)
        self.traces = TraceCache()
        self.results = ResultCache(self.cfg.result_cache_entries,
                                   self.cfg.result_cache_max_rows)
        self.raw_max_chips = (self.cfg.raw_max_chips
                              or max(space.max_chips(), 4))
        r, c = self.cfg.raw_slots, self.raw_max_chips
        self.raw_pad = dict(n_systems=r, max_chips=c,
                            chip_entities=r * c + 1, pkg_entities=r + 1,
                            mod_entities=2 * r * c + 1,
                            mod_instances=2 * r * c,
                            d2d_entities=r * c + 1, d2d_instances=r * c)
        self._lane_args: Dict[Lane, Tuple] = {}
        self._active: Dict[int, _Active] = {}
        self._uid = 0
        self._task: Optional[asyncio.Task] = None
        self._wake: Optional[asyncio.Event] = None
        self._running = False
        self.warmed = False
        # -- failure handling (repro.resilience) ------------------------
        self.faults = FaultInjector.from_env()
        self.res = ResilienceStats()
        self.breaker = CircuitBreaker(
            threshold=self.cfg.breaker_threshold,
            cooldown_s=self.cfg.breaker_cooldown_s,
            on_event=self._on_breaker_event)
        self.watchdog = (Watchdog(self.cfg.watchdog_timeout_s,
                                  self._on_stall)
                         if self.cfg.watchdog_timeout_s else None)
        self._deadline_count = 0       # admitted requests with deadlines
        self._fb_evs: Dict[str, ChunkedEvaluator] = {}   # per-flow legacy
        # -- serving-cost ledger + SLO tracking (repro.obs) --------------
        self.ledger = Ledger()
        self.slo: Optional[SLOTracker] = (
            SLOTracker(self.cfg.slos, on_burn=self._on_slo_burn)
            if self.cfg.slos else None)
        # completions found during a tick are deferred until after the
        # tick's wall is measured and billed, so a finishing request's
        # bill includes its final tick's share (see _tick)
        self._tick_done: List[Callable] = []
        self._raw_parts: Optional[List[GroupWork]] = None
        # -- durability (repro.service.durability) ----------------------
        self.dur = DurabilityStats()
        self.dcfg = self.cfg.durability
        self.journal: Optional[RequestJournal] = None
        self._ckpt_mgrs: Dict[int, CheckpointManager] = {}
        self._accepting = True         # False while draining/crashed
        self._sigterm_installed = False
        self.replayed_tasks: List[asyncio.Task] = []

    # ------------------------------------------------------------------
    # Failure handling (repro.resilience glue)
    # ------------------------------------------------------------------

    def _fire(self, kind: str):
        """Check the fault injector at one call site.  Costs a single
        truthiness check when ``REPRO_FAULTS`` is unset."""
        if not self.faults:
            return None
        rule = self.faults.fire(kind)
        if rule is not None:
            self.res.bump("faults_injected")
            self.flight.record("fault", kind=kind)
        return rule

    def _on_breaker_event(self, event: str):
        self.res.bump(f"breaker_{event}s")
        self.log.event(-1, f"breaker_{event}")
        self.flight.record("breaker", transition=event,
                           state=self.breaker.state)

    def _on_slo_burn(self, kind: str, dimension: str, burn: float,
                     trace_id: str):
        """An error-budget burn rate crossed its alert threshold (latched
        once per excursion by the tracker): record the event with the
        offending trace id and auto-dump the flight recorder so the
        context around the burn is preserved."""
        self.log.event(-1, "slo_burn", kind=kind, dimension=dimension,
                       burn=round(burn, 3), trace_id=trace_id)
        self.flight.record("slo_burn", kind=kind, dimension=dimension,
                           burn=burn, trace_id=trace_id)
        if FlightRecorder.auto_dump_dir() is not None:
            try:
                self.dump_flight_recorder()
            except OSError:
                pass                  # never let a dump break serving

    def _on_stall(self, elapsed: float):
        """Watchdog callback — runs on the watchdog thread, so: evidence
        only (counter bumps are GIL-atomic, the flight ring is append-
        only).  The stuck tick itself cannot be preempted; recovery is
        the loop guard in :meth:`_run` plus :meth:`_ensure_loop`."""
        self.res.bump("watchdog_trips")
        self.flight.record("watchdog_trip", busy_s=elapsed)
        path = None
        if FlightRecorder.auto_dump_dir() is not None:
            try:
                path = self.dump_flight_recorder()
                self.res.bump("watchdog_dumps")
            except OSError:
                path = None
        self.log.event(-1, "watchdog_trip", busy_s=elapsed,
                       dump=str(path) if path else None)

    def _ensure_loop(self):
        """Relaunch the tick-loop task if it died (it should not — the
        loop guard contains per-tick exceptions — but a dead loop must
        never strand admitted work)."""
        if self._running and self._task is not None and self._task.done():
            self.res.bump("loop_restarts")
            self.log.event(-1, "loop_restart")
            self.flight.record("loop_restart")
            self._task = asyncio.get_running_loop().create_task(self._run())

    def _close_bill(self, req: _Active, ok: bool, status: str,
                    cache_hit: bool = False,
                    observe_slo: bool = True) -> Optional[Dict]:
        """Finalize a request's cost bill and feed the SLO tracker —
        the one terminal-accounting path every outcome goes through.
        Returns the bill as a JSON-ready dict for the response envelope.
        """
        degraded = 0
        if isinstance(req.degraded_rows, np.ndarray):
            degraded = int(req.degraded_rows.sum())
        if req.bill is not None:
            self.ledger.close(req.bill, status=status, cache_hit=cache_hit,
                              degraded_rows=degraded,
                              latency_s=req.rec.latency_s)
        if self.slo is not None and observe_slo:
            self.slo.observe(req.kind, req.rec.latency_s, ok,
                             trace_id=req.trace_id)
        return req.bill.as_dict() if req.bill is not None else None

    def _cancel(self, req: _Active):
        """Client abandoned an admitted request (awaiter cancelled):
        drop its queued work, release its row budget, count it.  No
        envelope — there is nobody left to receive one."""
        if req.failed or req.uid not in self._active:
            return
        req.failed = True
        if req.deadline_t is not None:
            self._deadline_count -= 1
        self.sched.drop_owned_by(req)
        self.sched.release(req.cost)
        self.metrics.finish_request(req.rec, ok=False)
        # a cancellation is the client's doing, not the service's: close
        # the bill but keep it out of the availability error budget
        self._close_bill(req, ok=False, status="cancelled",
                         observe_slo=False)
        self._active.pop(req.uid, None)
        if self.journal is not None:
            self.journal.done(req.uid, "cancelled")
        self.res.bump("cancelled")
        self.log.event(req.uid, "cancelled")
        self.flight.record("request_cancelled", uid=req.uid, kind=req.kind,
                           trace_id=req.trace_id)

    def _fallback_evaluator(self, flow: str) -> ChunkedEvaluator:
        """The legacy host-packing evaluator degraded ticks price
        through (the parity oracle: float32 casts of its float64s)."""
        if flow == self.ev.flow:
            return self.ev
        ev = self._fb_evs.get(flow)
        if ev is None:
            ev = ChunkedEvaluator(self.space,
                                  candidates_per_chunk=self.cfg.chunk,
                                  flow=flow, fused=False)
            self._fb_evs[flow] = ev
        return ev

    # ------------------------------------------------------------------
    # Warmup: compile every configured lane signature before serving
    # ------------------------------------------------------------------

    def warmup(self):
        """Pre-compile the trace cache so no cold request ever recompiles
        on the hot path.  Idempotent; called by :meth:`start`."""
        for flow in self.cfg.flows:
            self._ensure_chunk(flow)
            for draws, quantiles in self.cfg.warm_mc:
                self._ensure_mc(flow, int(draws), tuple(quantiles))
            if self.cfg.raw_slots > 0:
                self._ensure_raw(flow)
            for w in self.cfg.warm_search:
                self._ensure_gen(flow, w)
        self.warmed = True

    def _ensure_chunk(self, flow: str, trace_id: str = ""):
        sig = LaneSignature("chunk", flow)
        dev0 = jnp.zeros((self.cfg.chunk,), jnp.int32)
        self.traces.ensure(sig, lambda: jax.device_get(_CHUNK_JIT(
            self.enc.tables, dev0, self.qty, meta=self.enc.meta,
            flow=flow)), trace_id=trace_id)
        if self.cfg.fallback:
            # warm the degraded path's engine trace too, so a tick that
            # falls back never compiles mid-tick (the fallback always
            # prices a full, padded chunk — one constant signature).
            idx0 = np.zeros((self.cfg.chunk,), np.int64)
            self.traces.ensure(
                LaneSignature("fallback", flow),
                lambda: self._fallback_evaluator(flow)
                .evaluate_indices_legacy(idx0))

    def _ensure_mc(self, flow: str, draws: int, quantiles: Tuple[float, ...],
                   trace_id: str = ""):
        sig = LaneSignature("mc", flow, (draws, quantiles))
        dev0 = jnp.zeros((self.cfg.chunk,), jnp.int32)
        key0 = jax.random.PRNGKey(0)
        sig0 = jnp.zeros((4,), jnp.float32)
        self.traces.ensure(sig, lambda: jax.device_get(_CHUNK_MC_JIT(
            self.enc.tables, dev0, self.qty, key0, sig0, meta=self.enc.meta,
            flow=flow, n_draws=draws, quantiles=quantiles)),
            trace_id=trace_id)
        if self.cfg.fallback:
            # sigmas are traced (not signature components) — warming
            # with the defaults covers every sigma set at this shape.
            idx0 = np.zeros((self.cfg.chunk,), np.int64)
            self.traces.ensure(
                LaneSignature("fallback_mc", flow, (draws, quantiles)),
                lambda: self._fallback_evaluator(flow)
                .evaluate_indices_legacy(idx0, mc_key=jax.random.PRNGKey(0),
                                         mc_draws=draws,
                                         mc_quantiles=quantiles))

    def _ensure_gen(self, flow: str, w: SearchWarmup, trace_id: str = ""):
        sig = LaneSignature("gen", flow, (w.population, w.elite,
                                          float(w.jump_prob), w.n_draws,
                                          float(w.quantile)))
        key0 = jax.random.PRNGKey(0)
        # the task's own key schedule also jits (randint/split/fold_in) —
        # run it once here so admission stays compile-free too
        k_init, _ = jax.random.split(key0)
        _default_mc_key(key0)
        pop0 = jax.random.randint(k_init, (w.population,), 0,
                                  self.space.size(), dtype=jnp.int32)
        self.traces.ensure(sig, lambda: jax.device_get(_gen_step()(
            self.enc.tables, key0, pop0, self.qty, key0,
            jnp.zeros((4,), jnp.float32), meta=self.enc.meta, flow=flow,
            population=w.population, elite=w.elite,
            jump_prob=float(w.jump_prob), n_draws=w.n_draws,
            quantile=float(w.quantile))[2:]), trace_id=trace_id)

    def _ensure_raw(self, flow: str, trace_id: str = ""):
        sig = LaneSignature("raw", flow)

        def compile_raw():
            s = spec({"kind": "soc", "name": "__warm", "area": 100.0,
                      "process": self.space.processes[0], "quantity": 1.0})
            b = SystemBatch.from_systems([s], share_nre=[0],
                                         max_chips=self.raw_max_chips)
            jax.device_get(_TOTAL_JIT(pad_batch(b, **self.raw_pad), flow))

        self.traces.ensure(sig, compile_raw, trace_id=trace_id)

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------

    async def start(self):
        if self._task is not None:
            return
        if not self.warmed:
            self.warmup()
        if self.watchdog is not None:
            self.watchdog.start()
        self._wake = asyncio.Event()
        self._running = True
        self._accepting = True
        if self.dcfg is not None and self.journal is None:
            self.journal = RequestJournal(
                self.dcfg.journal_dir,
                fsync_every=self.dcfg.fsync_every,
                segment_max_records=self.dcfg.segment_max_records,
                fingerprint=self.fingerprint, stats_hook=self.dur.bump)
            # uid continuity: new admissions must never collide with
            # uids still open in the journal from a previous process.
            self._uid = max(self._uid, self.journal.max_uid)
        if self.cfg.sigterm_drain:
            try:
                asyncio.get_running_loop().add_signal_handler(
                    signal.SIGTERM, self._on_sigterm)
                self._sigterm_installed = True
            except (NotImplementedError, RuntimeError, ValueError):
                self._sigterm_installed = False
        self._task = asyncio.get_running_loop().create_task(self._run())
        if self.journal is not None:
            self._replay_journal()

    def _on_sigterm(self):
        """SIGTERM = graceful shutdown request: bounded drain with the
        configured ``drain_timeout_s`` (in-flight searches checkpoint at
        the deadline; unfinished work gets typed ``shutting_down``)."""
        self.log.event(-1, "sigterm")
        self.flight.record("sigterm")
        asyncio.get_running_loop().create_task(self.stop())

    def _replay_journal(self):
        """Re-admit every journaled request without a terminal record.
        Each replay admits under a NEW uid (with ``origin`` preserved)
        *before* the old uid's ``replayed`` terminal is written, so a
        crash mid-replay can only duplicate work, never lose it."""
        loop = asyncio.get_running_loop()
        for e in self.journal.replay():
            self.dur.bump("journal_replayed")
            self.log.event(e.uid, "replay", origin=e.origin,
                           kind=e.request.kind)
            self.flight.record("request_replayed", uid=e.uid,
                               origin=e.origin, kind=e.request.kind)
            self.replayed_tasks.append(loop.create_task(
                self.submit(e.request, replayed_from=e.origin,
                            _replaces=e.uid,
                            _trace_id=(e.trace_id or None))))

    async def drain_replayed(self) -> List[Response]:
        """Await every journal-replayed request's response (envelopes,
        never exceptions)."""
        if not self.replayed_tasks:
            return []
        out = await asyncio.gather(*self.replayed_tasks)
        return list(out)

    async def stop(self, drain_timeout_s: Optional[float] = None):
        """Drain remaining work, then stop the tick loop.

        ``drain_timeout_s`` (argument, falling back to
        ``ServiceConfig.drain_timeout_s``) bounds the drain: admission
        stops immediately, in-flight work gets the deadline to finish,
        and at the deadline unfinished searches are checkpointed and
        every unfinished request is failed with a typed
        ``shutting_down`` envelope.  ``None`` (the default) preserves
        the original unbounded drain."""
        timeout = (drain_timeout_s if drain_timeout_s is not None
                   else self.cfg.drain_timeout_s)
        self._accepting = False
        self._running = False
        if self._wake is not None:
            self._wake.set()
        if self._task is not None:
            if timeout is None:
                await self._task
            else:
                self.dur.bump("drain_calls")
                try:
                    await asyncio.wait_for(asyncio.shield(self._task),
                                           timeout)
                except asyncio.TimeoutError:
                    self.dur.bump("drain_timeouts")
                    self._drain_abort()
                    await self._task
            self._task = None
        if self._sigterm_installed:
            try:
                asyncio.get_running_loop().remove_signal_handler(
                    signal.SIGTERM)
            except (NotImplementedError, RuntimeError, ValueError):
                pass
            self._sigterm_installed = False
        if self.journal is not None:
            self.journal.close()
            self.journal = None
        if self.watchdog is not None:
            self.watchdog.stop()

    def _drain_abort(self):
        """The drain deadline passed: checkpoint unfinished searches,
        give every unfinished request a typed ``shutting_down``
        envelope (journaled as terminal — the client was answered, so
        the work will NOT replay), drop the queue, dump the flight
        recorder when ``REPRO_FLIGHT_DIR`` is set."""
        for req in list(self._active.values()):
            if req.failed:
                continue
            if req.kind == "search" and req.task is not None \
                    and self.dcfg is not None:
                try:
                    req.task.state.save(self._ckpt_manager(req.origin))
                    self.dur.bump("checkpoints_written")
                    self.dur.bump("drain_checkpointed")
                except OSError:
                    pass
            self.dur.bump("drain_rejected")
            self._fail(req, SHUTTING_DOWN,
                       f"drain deadline passed with "
                       f"{req.rows_done}/{req.n_rows} rows done")
        self.sched.clear()
        self.flight.record("drain_abort")
        if FlightRecorder.auto_dump_dir() is not None:
            try:
                self.dump_flight_recorder()
            except OSError:
                pass

    def _ckpt_manager(self, origin: int) -> CheckpointManager:
        m = self._ckpt_mgrs.get(origin)
        if m is None:
            m = CheckpointManager(self.dcfg.checkpoint_dir(origin),
                                  keep=self.dcfg.checkpoint_keep)
            self._ckpt_mgrs[origin] = m
        return m

    def _drop_checkpoints(self, origin: int):
        """A search finished ok: its checkpoint tree is dead weight."""
        if self.dcfg is None:
            return
        self._ckpt_mgrs.pop(origin, None)
        d = self.dcfg.checkpoint_dir(origin)
        if d.exists():
            shutil.rmtree(d, ignore_errors=True)
            self.dur.bump("checkpoints_removed")

    def _hard_crash(self):
        """Enact an injected ``crash`` fault: SIGKILL semantics at a
        tick boundary.  In-flight futures resolve with typed
        ``shutting_down`` envelopes (in-process test clients unblock),
        but — deliberately — NO journal terminals are written and the
        journal file handle stays untouched: open admits stay open on
        disk, exactly as after a real process death, and the next
        :meth:`start` replays them."""
        self.dur.bump("crashes")
        self.log.event(-1, "crash")
        self.flight.record("crash", active=len(self._active))
        self._running = False
        self._accepting = False
        for req in list(self._active.values()):
            req.failed = True
            self.metrics.finish_request(req.rec, ok=False)
            bill_dict = self._close_bill(req, ok=False,
                                         status=SHUTTING_DOWN,
                                         observe_slo=False)
            if not req.future.done():
                resp = error_response(
                    req.uid, req.kind, SHUTTING_DOWN,
                    "simulated crash (injected fault)", req.rec.t_submit,
                    trace_id=req.trace_id)
                resp.replayed = req.replayed_from is not None
                resp.replayed_from = req.replayed_from
                resp.bill = bill_dict
                req.future.set_result(resp)
        self._active.clear()
        self._deadline_count = 0
        self.sched.clear()

    async def _run(self):
        while True:
            if not self.sched.has_work():
                if not self._running:
                    break
                self._wake.clear()
                if not self.sched.has_work():        # re-check after clear
                    await self._wake.wait()
                continue
            try:
                self._tick()
            except Exception as e:  # noqa: BLE001 - the loop must survive
                # _tick already fails the tick's owners per request; an
                # exception reaching here is a bug in the failure path
                # itself.  Contain it: count, record, keep serving.
                self.res.bump("loop_errors")
                self.log.event(-1, "loop_error",
                               error=f"{type(e).__name__}: {e}")
                self.flight.record("loop_error",
                                   error=f"{type(e).__name__}: {e}")
            await asyncio.sleep(0)   # let clients submit between ticks

    # ------------------------------------------------------------------
    # Submission
    # ------------------------------------------------------------------

    def _journal_replaced(self, replaces: Optional[int], status: str):
        """A replayed request reached a terminal outcome at admission
        time (cache hit / typed rejection): close out the journaled uid
        it replaces so it does not replay again."""
        if replaces is not None and self.journal is not None:
            self.journal.done(replaces, status)

    def _reject(self, uid: int, request: Request, t_submit: float,
                trace_id: str, code: str, message: str,
                replayed_from: Optional[int] = None,
                rec=None, bill: Optional[Bill] = None) -> Response:
        """Admission-time typed rejection: every rejection still gets a
        trace_id, a closed ledger bill and an SLO availability sample —
        rejected work is spent error budget, not a blind spot."""
        if rec is None:
            rec = self.metrics.start_request(request.kind, 0, t_submit,
                                             trace_id=trace_id)
        if bill is None:
            bill = self.ledger.open(trace_id, uid, request.kind,
                                    replayed=replayed_from is not None)
        self.metrics.finish_request(rec, ok=False)
        self.ledger.close(bill, status=code, latency_s=rec.latency_s)
        if self.slo is not None:
            self.slo.observe(request.kind, rec.latency_s, False,
                             trace_id=trace_id)
        _TRACER.instant("request_error", trace_id=trace_id, uid=uid,
                        kind=request.kind, code=code)
        self.log.event(uid, "rejected", code=code, message=message)
        resp = error_response(uid, request.kind, code, message, t_submit,
                              trace_id=trace_id)
        resp.bill = bill.as_dict()
        return resp

    async def submit(self, request: Request,
                     on_partial: Optional[Callable] = None, *,
                     replayed_from: Optional[int] = None,
                     _replaces: Optional[int] = None,
                     _trace_id: Optional[str] = None) -> Response:
        """Submit one typed request; always returns a Response envelope
        (typed error inside on rejection — never an exception).

        ``on_partial(rows_done, n_rows)`` streams coalesced progress as
        the scheduler ticks through the request.  ``replayed_from`` /
        ``_replaces`` / ``_trace_id`` are the journal-replay path's
        internals (see :meth:`_replay_journal`); client code never
        passes them."""
        self._uid += 1
        uid = self._uid
        # the request-scoped correlation id: minted here at admission,
        # preserved verbatim across journal replay so one logical request
        # keeps ONE trace across process restarts.
        trace_id = _trace_id or mint_trace_id()
        t_submit = time.perf_counter()
        self.log.event(uid, "submit", kind=request.kind,
                       trace_id=trace_id)
        _TRACER.instant("request_admit", trace_id=trace_id, uid=uid,
                        kind=request.kind)
        if not self._accepting:
            self._journal_replaced(_replaces, SHUTTING_DOWN)
            return self._reject(uid, request, t_submit, trace_id,
                                SHUTTING_DOWN, "service is shutting down",
                                replayed_from)
        self._ensure_loop()
        try:
            active, items, cached = self._lower(uid, request, t_submit,
                                                on_partial, replayed_from,
                                                trace_id)
        except ServiceError as e:
            self._journal_replaced(_replaces, e.code)
            return self._reject(uid, request, t_submit, trace_id,
                                e.code, str(e), replayed_from)
        if cached is not None:
            self.metrics.finish_request(active.rec, ok=True, cached=True)
            bill_dict = self._close_bill(active, ok=True, status="ok",
                                         cache_hit=True)
            _TRACER.instant("request_done", trace_id=trace_id, uid=uid,
                            kind=request.kind, cached=True)
            self.log.event(uid, "cache_hit")
            self._journal_replaced(_replaces, "ok")
            now = time.perf_counter()
            return Response(request_id=uid, kind=request.kind, ok=True,
                            result=cached, cached=True,
                            timing=Timing(t_submit, now - t_submit,
                                          now - t_submit),
                            replayed=replayed_from is not None,
                            replayed_from=replayed_from,
                            trace_id=trace_id, bill=bill_dict)
        flood = self._fire("flood")
        if flood is not None or not self.sched.admit(items, active.cost):
            self.metrics.reject()
            self._journal_replaced(_replaces, QUEUE_FULL)
            return self._reject(
                uid, request, t_submit, trace_id, QUEUE_FULL,
                "pending row budget exhausted (injected flood)"
                if flood is not None else
                f"pending row budget exhausted "
                f"({self.sched.pending_rows}/{self.sched.max_pending} used, "
                f"request needs {active.cost})",
                replayed_from, rec=active.rec, bill=active.bill)
        for it in items:
            it.deadline_t = active.deadline_t
            it.trace_id = trace_id
        self._active[uid] = active
        if active.deadline_t is not None:
            self._deadline_count += 1
        if self.journal is not None:
            # the WAL write that makes this admission crash-safe — and
            # only AFTER it lands does the uid it replaces (if any) get
            # its "replayed" terminal: a crash between the two
            # duplicates work, never loses it.
            self.journal.admit(uid, request_to_wire(request, self.space),
                               origin=active.origin, trace_id=trace_id)
            if _replaces is not None:
                self.journal.done(_replaces, "replayed")
        self.log.event(uid, "admitted", rows=active.n_rows)
        if self._wake is not None:
            self._wake.set()
        try:
            return await active.future
        except asyncio.CancelledError:
            self._cancel(active)
            raise

    # ------------------------------------------------------------------
    # Lowering: request -> lane + work items + finalizers
    # ------------------------------------------------------------------

    def _mc_lane(self, flow: str, mc: McSpec, key,
                 trace_id: str = "") -> Lane:
        quantiles = tuple(float(q) for q in mc.quantiles)
        draws = int(mc.draws)
        # admission-time compile (span labelled with the forcing request)
        self._ensure_mc(flow, draws, quantiles, trace_id=trace_id)
        key_t = tuple(int(x) for x in np.asarray(key).ravel())
        sig_t = (mc.sigmas.defect_sigma, mc.sigmas.wafer_cost_sigma,
                 mc.sigmas.bond_sigma, mc.sigmas.interposer_sigma)
        lane = Lane(kind="mc", flow=flow, mc=(draws, quantiles, key_t, sig_t))
        # (key, sigma array, draws, quantiles) feed the fused dispatch;
        # the trailing Uncertainty object is for the legacy fallback.
        self._lane_args.setdefault(
            lane, (key, mc.sigmas.as_array(), draws, quantiles, mc.sigmas))
        return lane

    def _check_flow(self, flow: str):
        if flow not in self.cfg.flows:
            raise ServiceError(
                INVALID_REQUEST,
                f"flow {flow!r} is not served (configured: {self.cfg.flows})")

    def _check_indices(self, indices, candidates=()) -> np.ndarray:
        if indices is None and candidates:
            try:
                indices = [self.space.index_of(c) for c in candidates]
            except ValueError as e:
                raise ServiceError(INVALID_REQUEST, str(e)) from None
        if indices is None:
            raise ServiceError(INVALID_REQUEST,
                               "request needs indices or candidates")
        idx = np.asarray(indices, np.int64)
        if idx.ndim != 1 or idx.size == 0:
            raise ServiceError(INVALID_REQUEST,
                               "need a 1-D, non-empty index vector")
        if idx.min() < 0 or idx.max() >= self.space.size():
            raise ServiceError(
                INVALID_REQUEST,
                f"candidate index out of range [0, {self.space.size()})")
        return idx

    def _alloc_sweep(self, active: _Active, idx: np.ndarray,
                     quantiles: Optional[Tuple[float, ...]]):
        n = int(idx.size)
        s = self.n_skus
        active.idx = idx
        active.n_rows = n
        active.cost = n
        active.accum = {"unit": np.empty((n, s), np.float32),
                        "re": np.empty((n, s), np.float32),
                        "nre": np.empty((n, s), np.float32),
                        "pf": np.empty((n,), np.float32)}
        active.degraded_rows = np.zeros((n,), bool)
        if quantiles is not None:
            active.risk_keys = _risk_keys(quantiles)
            for k in active.risk_keys:
                active.accum["risk:" + k] = np.empty((n,), np.float32)

    def _sweep_arrays(self, active: _Active) -> EvalArrays:
        risk = None
        if active.risk_keys:
            risk = {k: active.accum["risk:" + k] for k in active.risk_keys}
        return EvalArrays(idx=active.idx,
                          sku_unit_total=active.accum["unit"],
                          sku_unit_re=active.accum["re"],
                          sku_unit_nre=active.accum["nre"],
                          portfolio_cost=active.accum["pf"], risk=risk)

    def _lower(self, uid: int, request: Request, t_submit: float,
               on_partial, replayed_from: Optional[int] = None,
               trace_id: str = ""
               ) -> Tuple[_Active, List, Optional[object]]:
        kind = getattr(request, "kind", None)
        if kind is None:
            raise ServiceError(INVALID_REQUEST,
                               f"unknown request type {type(request)!r}")
        problem = validate_request(request)
        if problem is not None:
            raise ServiceError(INVALID_REQUEST, problem)
        self._check_flow(request.flow)
        fut = asyncio.get_running_loop().create_future()
        active = _Active(uid=uid, kind=kind, request=request,
                         rec=self.metrics.start_request(kind, 0, t_submit,
                                                        trace_id=trace_id),
                         future=fut, on_partial=on_partial,
                         replayed_from=replayed_from,
                         origin=(replayed_from if replayed_from is not None
                                 else uid))
        active.trace_id = trace_id
        active.bill = self.ledger.open(trace_id, uid, kind,
                                       replayed=replayed_from is not None)
        deadline_ms = getattr(request, "deadline_ms", None)
        if deadline_ms is not None:
            active.deadline_t = t_submit + float(deadline_ms) / 1e3

        if kind == "search":
            return self._lower_search(active, request)
        if kind == "price_systems":
            return self._lower_systems(active, request)

        # -- index-sweep family: price / rank / mc_risk / what_if ----------
        mc: Optional[McSpec] = getattr(request, "mc", None)
        if kind == "mc_risk":
            mc = request.mc
        grid_meta = None
        if kind == "what_if":
            idx, grid_meta, skipped = self._what_if_grid(request)
        elif kind == "rank" and request.indices is None:
            idx = np.arange(self.space.size(), dtype=np.int64)
        else:
            idx = self._check_indices(request.indices,
                                      getattr(request, "candidates", ()))
        quantiles = None
        if mc is not None:
            lane = self._mc_lane(request.flow,  mc,
                                 jax.random.PRNGKey(mc.seed),
                                 trace_id=trace_id)
            quantiles = tuple(float(q) for q in mc.quantiles)
        else:
            self._ensure_chunk(request.flow, trace_id=trace_id)
            lane = Lane(kind="chunk", flow=request.flow)

        objective = "cost"
        if kind == "rank":
            objective = request.objective
            if objective != "cost":
                if quantiles is None:
                    raise ServiceError(
                        INVALID_REQUEST,
                        f"objective {objective!r} needs an McSpec")
                if objective not in _risk_keys(quantiles):
                    raise ServiceError(
                        INVALID_REQUEST,
                        f"objective {objective!r} not among "
                        f"{_risk_keys(quantiles)}")

        self._alloc_sweep(active, idx, quantiles)
        active.rec.n_rows = active.n_rows

        if kind in ("price", "mc_risk"):
            active.payload_fn = lambda arrays: arrays
            active.cache_key = ResultCache.key(self.fingerprint,
                                               request.flow, lane.mc, idx)
        elif kind == "rank":
            top_k = int(request.top_k)
            active.payload_fn = \
                lambda arrays: self._rank_payload(arrays, objective, top_k)
            active.cache_key = ResultCache.key(self.fingerprint,
                                               request.flow, lane.mc, idx)
        else:  # what_if
            active.payload_fn = \
                lambda arrays, g=grid_meta, sk=skipped: \
                self._what_if_payload(arrays, g, sk)

        if active.cache_key is not None:
            hit = self.results.get(active.cache_key)
            if hit is not None:
                return active, [], active.payload_fn(hit)
        return active, [SpanWork(owner=active, lane=lane, idx=idx)], None

    def _rank_payload(self, arrays: EvalArrays, objective: str,
                      top_k: int) -> RankResult:
        obj = arrays.objective(objective)
        order = np.lexsort((arrays.idx, obj))   # index breaks exact ties
        top = order[:max(0, top_k)]
        risk = None
        if arrays.risk is not None:
            risk = {k: v[top] for k, v in arrays.risk.items()}
        top_arrays = EvalArrays(
            idx=arrays.idx[top], sku_unit_total=arrays.sku_unit_total[top],
            sku_unit_re=arrays.sku_unit_re[top],
            sku_unit_nre=arrays.sku_unit_nre[top],
            portfolio_cost=arrays.portfolio_cost[top], risk=risk)
        return RankResult(objective=objective,
                          order=arrays.idx[order], values=obj[order],
                          top=self.ev.results_from_arrays(top_arrays))

    # -- what-if -----------------------------------------------------------
    def _what_if_grid(self, request: WhatIfRequest):
        base = request.base
        if isinstance(base, (int, np.integer)):
            try:
                base = self.space.candidate_at(int(base))
            except IndexError as e:
                raise ServiceError(INVALID_REQUEST, str(e)) from None
        try:
            base_idx = self.space.index_of(base)
        except ValueError as e:
            raise ServiceError(INVALID_REQUEST, str(e)) from None
        procs = tuple(request.processes) or self.space.processes
        ints = tuple(request.integrations) or self.space.integrations
        grid, skipped = [], []
        for p in procs:
            for t in ints:
                try:
                    cand = self._swap_tech(base, p, t)
                    gi = self.space.index_of(cand)
                    grid.append((p, t, gi, cand.label()))
                except (ValueError, KeyError) as e:
                    skipped.append({"process": p, "integration": t,
                                    "reason": str(e)})
        if not grid:
            raise ServiceError(
                INVALID_REQUEST,
                f"no valid what-if combination (skipped {len(skipped)})")
        idx = np.asarray([base_idx] + [g[2] for g in grid], np.int64)
        return idx, (base.label(), grid), skipped

    @staticmethod
    def _swap_tech(cand: Candidate, process: str,
                   integration: str) -> Candidate:
        if cand.is_reuse:
            return Candidate(reuse=dataclasses.replace(
                cand.reuse, process=process, integration=integration))
        return Candidate(choices=tuple(
            ArchChoice(c.n_chiplets, process,
                       "SoC" if c.n_chiplets == 1 else integration)
            for c in cand.choices))

    def _what_if_payload(self, arrays: EvalArrays, grid_meta,
                         skipped) -> WhatIfResult:
        base_label, grid = grid_meta
        base_cost = float(arrays.portfolio_cost[0])
        rows = []
        for j, (p, t, gi, label) in enumerate(grid, start=1):
            cost = float(arrays.portfolio_cost[j])
            rows.append({"process": p, "integration": t, "candidate": label,
                         "portfolio_cost": cost,
                         "delta_vs_base": cost - base_cost,
                         "rel_delta": (cost - base_cost) / base_cost})
        return WhatIfResult(base_label=base_label, base_cost=base_cost,
                            rows=rows, skipped=list(skipped))

    # -- search ------------------------------------------------------------
    def _lower_search(self, active: _Active, sr: SearchRequest):
        if sr.population < 1 or not (1 <= sr.elite <= sr.population):
            raise ServiceError(INVALID_REQUEST,
                               "need 1 <= elite <= population")
        if sr.generations < 1:
            raise ServiceError(INVALID_REQUEST, "need generations >= 1")
        n_draws, quantile = 0, 0.5
        if sr.risk is not None:
            n_draws, quantile = int(sr.risk.n_draws), float(sr.risk.quantile)
        self._ensure_gen(sr.flow, SearchWarmup(
            population=sr.population, elite=sr.elite,
            jump_prob=float(sr.jump_prob), n_draws=n_draws,
            quantile=quantile), trace_id=active.trace_id)
        # the ranking sweep reuses the chunk/mc lane — make sure it's warm
        if sr.risk is not None:
            self._ensure_mc(sr.flow, n_draws, (0.5, quantile),
                            trace_id=active.trace_id)
        else:
            self._ensure_chunk(sr.flow, trace_id=active.trace_id)
        active.task = SearchTask(self, active, sr)
        if self.dcfg is not None and active.replayed_from is not None:
            # replayed search: continue from the newest readable
            # checkpoint (corrupt steps fall back; an unreadable tree
            # restarts from generation 0 — still bit-exact, just slower)
            mgr = self._ckpt_manager(active.origin)
            before = mgr.corrupt_fallbacks
            try:
                restored = SearchState.restore_latest(mgr, sr.population)
            except ValueError:
                restored = None
            if mgr.corrupt_fallbacks > before:
                self.dur.bump("checkpoint_corrupt_fallbacks",
                              mgr.corrupt_fallbacks - before)
            if restored is not None:
                if not restored.trace_id:
                    # pre-tracing checkpoint: adopt the replayed trace
                    restored.trace_id = active.trace_id
                active.task.state = restored
                self.dur.bump("checkpoints_restored")
                self.log.event(active.uid, "search_restored",
                               origin=active.origin, gen=restored.gen)
                self.flight.record("search_restored", uid=active.uid,
                                   origin=active.origin, gen=restored.gen)
        # budget: every generation prices `population` rows, and the final
        # ranking sweep at most everything the generations saw.
        active.cost = sr.population * (sr.generations + 1)
        active.n_rows = 0             # set when the ranking sweep enqueues
        active.rec.n_rows = sr.population * sr.generations
        lane = Lane(kind="gen", flow=sr.flow)
        return active, [GenWork(owner=active, lane=lane,
                                task=active.task)], None

    def _enqueue_search_rank(self, active: _Active):
        """Generations done: stream the distinct priced candidates through
        the coalescing chunk/mc lane, exactly like portfolio_search's
        final ``evaluate_indices(uniq)`` sweep."""
        task, sr = active.task, active.task.sr
        uniq = task.uniq_indices()
        if sr.risk is not None:
            quantiles = (0.5, float(sr.risk.quantile))
            mc = McSpec(draws=int(sr.risk.n_draws), quantiles=quantiles,
                        seed=0, sigmas=sr.risk.sigmas)
            lane = self._mc_lane(sr.flow, mc, task.mc_key,
                                 trace_id=active.trace_id)
        else:
            quantiles = None
            lane = Lane(kind="chunk", flow=sr.flow)
        self._alloc_sweep(active, uniq, quantiles)
        active.cost = sr.population * (sr.generations + 1)  # unchanged
        active.payload_fn = task.finalize
        self.sched.push(SpanWork(owner=active, lane=lane, idx=uniq,
                                 deadline_t=active.deadline_t,
                                 trace_id=active.trace_id))

    # -- raw spec lane ------------------------------------------------------
    def _lower_systems(self, active: _Active, req: PriceSystemsRequest):
        if self.cfg.raw_slots < 1:
            raise ServiceError(INVALID_REQUEST,
                               "raw system lane is disabled (raw_slots=0)")
        if not req.specs:
            raise ServiceError(INVALID_REQUEST, "empty spec list")
        if len(req.specs) > self.cfg.raw_slots:
            raise ServiceError(
                INVALID_REQUEST,
                f"group of {len(req.specs)} systems exceeds the raw lane "
                f"budget of {self.cfg.raw_slots}")
        try:
            systems = [spec(dict(d)) for d in req.specs]
            for s in systems:
                if s.n_chips > self.raw_max_chips:
                    raise ValueError(
                        f"system {s.name!r} has {s.n_chips} chips "
                        f"(raw lane limit {self.raw_max_chips})")
            # dry-run the solo pack: catches duplicate names, bad specs
            solo = SystemBatch.from_systems(
                systems, share_nre=[0] * len(systems),
                max_chips=self.raw_max_chips)
            if not self._raw_fits(solo):
                raise ValueError("group exceeds the raw lane entity budget")
        except (ValueError, KeyError, TypeError) as e:
            raise ServiceError(INVALID_REQUEST, str(e)) from None
        self._ensure_raw(req.flow, trace_id=active.trace_id)
        active.n_rows = len(systems)
        active.cost = len(systems)
        active.rec.n_rows = len(systems)
        lane = Lane(kind="raw", flow=req.flow)
        return active, [GroupWork(owner=active, lane=lane,
                                  systems=systems)], None

    def _raw_fits(self, batch: SystemBatch) -> bool:
        p = self.raw_pad
        return (len(batch) <= p["n_systems"]
                and batch.chip_area.shape[1] <= p["max_chips"]
                and batch.chip_entity_area.shape[0] <= p["chip_entities"]
                and batch.pkg_entity_area.shape[0] <= p["pkg_entities"]
                and batch.mod_entity_area.shape[0] <= p["mod_entities"]
                and batch.mod_sys.shape[0] <= p["mod_instances"]
                and batch.d2d_entity_nre.shape[0] <= p["d2d_entities"]
                and batch.d2d_sys.shape[0] <= p["d2d_instances"])

    # ------------------------------------------------------------------
    # The tick: one lane, one dispatch, ONE jax.device_get
    # ------------------------------------------------------------------

    def _tick(self) -> bool:
        if self.faults and self._fire("crash") is not None:
            self._hard_crash()
            return False
        if self._deadline_count:
            now = time.perf_counter()
            for w in self.sched.expire(now):
                owner: _Active = w.owner
                if owner.failed:
                    continue
                self.res.bump("deadline_rejected")
                self._fail(owner, DEADLINE_EXCEEDED,
                           f"deadline exceeded after "
                           f"{(now - owner.rec.t_submit) * 1e3:.1f} ms "
                           f"({owner.rows_done}/{owner.n_rows} rows done)")
        plan = self.sched.plan()
        if plan is None:
            return False
        # terminal completions discovered during the tick are DEFERRED to
        # after the wall clock stops and the ledger charges the tick, so
        # a finishing request's bill includes its final tick's share.
        self._tick_done = []
        self._raw_parts = None
        span_labels: Dict[str, object] = {"lane": plan.lane.kind}
        if _TRACER.enabled():
            tids, seen = [], set()
            for owner in self._owners(plan):
                if owner.trace_id and owner.trace_id not in seen:
                    seen.add(owner.trace_id)
                    tids.append(owner.trace_id)
            span_labels["trace_ids"] = tids
        t0 = time.perf_counter()
        before = self.traces.counts()
        retries_before = self.res.retries
        dispatch_before = (jaxhooks.total_dispatch_s()
                           if _TRACER.enabled() else 0.0)
        if self.watchdog is not None:
            self.watchdog.enter()
        try:
            with _TRACER.span("tick", **span_labels):
                stall = self._fire("stall")
                if stall is not None:
                    time.sleep(stall.ms / 1e3)
                try:
                    if plan.gen is not None:
                        rows = self._tick_gen(plan)
                    elif plan.lane.kind == "raw":
                        rows = self._tick_raw(plan)
                    else:
                        rows = self._tick_chunk(plan)
                except Exception as e:  # fail the owners, keep serving
                    self._fail_tick(plan, e)
                    rows = 0
        finally:
            if self.watchdog is not None:
                self.watchdog.exit()
        recompiled = self.traces.meter_tick(before)
        wall = time.perf_counter() - t0
        # gen lanes price their whole population every tick: count those
        # rows as fully-occupied slots so search work shows up in
        # occupancy instead of being excluded (see ServiceMetrics).
        slots, used = plan.slots, plan.used
        if plan.lane.kind == "gen":
            slots = used = rows
        dispatch_s = ((jaxhooks.total_dispatch_s() - dispatch_before)
                      if _TRACER.enabled() else 0.0)
        self.ledger.charge_tick(plan.lane.kind, wall,
                                self._tick_parts(plan),
                                slots or 1, used,
                                dispatch_s=dispatch_s,
                                retries=self.res.retries - retries_before)
        self.metrics.record_tick(plan.lane.kind, slots, used, rows, wall)
        self.flight.record("tick", lane=plan.lane.kind, slots=slots,
                           used=used, rows=rows, wall_s=wall,
                           recompiled=bool(recompiled))
        if recompiled:
            self.log.event(-1, "tick_recompile", lane=plan.lane.kind,
                           traces=recompiled)
        done, self._tick_done = self._tick_done, []
        for fin in done:
            fin()
        return True

    def _tick_parts(self, plan: TickPlan) -> List[Tuple[Bill, int]]:
        """(bill, rows contributed) per request for this tick — the
        pro-ration weights :meth:`Ledger.charge_tick` splits the wall
        over.  A coalesced owner with several assignments (multi-pass
        fill) appears once, with its rows summed."""
        if plan.gen is not None:
            owner = plan.gen.owner
            if owner.bill is None:
                return []
            return [(owner.bill, max(1, owner.task.sr.population))]
        if plan.lane.kind == "raw":
            groups = self._raw_parts if self._raw_parts is not None \
                else plan.groups
            return [(g.owner.bill, g.n_systems) for g in groups
                    if g.owner.bill is not None]
        parts: List[Tuple[Bill, int]] = []
        pos: Dict[int, int] = {}
        for a in plan.assignments:
            bill = a.item.owner.bill
            if bill is None:
                continue
            if id(bill) in pos:
                old_bill, old_n = parts[pos[id(bill)]]
                parts[pos[id(bill)]] = (old_bill, old_n + a.n)
            else:
                pos[id(bill)] = len(parts)
                parts.append((bill, a.n))
        return parts

    def _owners(self, plan: TickPlan) -> List[_Active]:
        owners = []
        if plan.gen is not None:
            owners.append(plan.gen.owner)
        owners += [a.item.owner for a in plan.assignments]
        owners += [g.owner for g in plan.groups]
        return owners

    def _fail_tick(self, plan: TickPlan, err: Exception):
        self.flight.record("tick_error", lane=plan.lane.kind,
                           error=f"{type(err).__name__}: {err}")
        if FlightRecorder.auto_dump_dir() is not None:
            try:
                self.dump_flight_recorder()
            except OSError:
                pass                      # never let a dump kill serving
        seen = set()
        for owner in self._owners(plan):
            if id(owner) in seen:
                continue
            seen.add(id(owner))
            self._fail(owner, INTERNAL_ERROR,
                       f"{type(err).__name__}: {err}")

    def _dispatch_fused(self, lane: Lane, dev):
        """One fused-kernel dispatch + host fetch (may raise)."""
        mc = lane.kind == "mc"
        if self.faults:
            if self._fire("recompile") is not None:
                # drop the fused jit's compiled traces: the dispatch
                # below survives, recompiles, and gets metered.
                fn = (_CHUNK_MC_JIT if mc else _CHUNK_JIT).fn
                clear = getattr(fn, "clear_cache", None)
                if clear is not None:
                    clear()
            if self._fire("dispatch_error") is not None:
                raise InjectedFault("dispatch_error")
        if mc:
            key, sig, draws, quantiles = self._lane_args[lane][:4]
            out = _CHUNK_MC_JIT(self.enc.tables, dev, self.qty, key, sig,
                                meta=self.enc.meta, flow=lane.flow,
                                n_draws=draws, quantiles=quantiles)
        else:
            out = _CHUNK_JIT(self.enc.tables, dev, self.qty,
                             meta=self.enc.meta, flow=lane.flow)
        return jax.device_get(out)                 # THE tick sync

    def _dispatch_fused_with_retry(self, lane: Lane, dev):
        """Returns ``(host, None)`` or, with the retry budget spent,
        ``(None, last_error)`` — the caller decides fallback vs raise."""
        last: Optional[Exception] = None
        for attempt in range(1 + max(0, self.cfg.tick_retries)):
            if attempt:
                self.res.bump("retries")
                time.sleep(self.cfg.retry_backoff_s * attempt)
            try:
                return self._dispatch_fused(lane, dev), None
            except Exception as e:  # noqa: BLE001 - retry any failure
                self.res.bump("fused_failures")
                last = e
                self.log.event(-1, "fused_dispatch_error", lane=lane.kind,
                               attempt=attempt,
                               error=f"{type(e).__name__}: {e}")
                self.flight.record("fused_dispatch_error", lane=lane.kind,
                                   attempt=attempt,
                                   error=f"{type(e).__name__}: {e}")
        return None, last

    def _fallback_chunk_host(self, lane: Lane, chunk_idx: np.ndarray):
        """Degraded-mode tick: price the (already padded) chunk through
        the legacy host-packing oracle.  Returns host arrays in the
        fused layout ``(unit, re, nre, pf[, risk], finite)`` — float32
        casts of the oracle's float64s, bit-exact vs ``_evaluate_legacy``
        by shared :meth:`ChunkedEvaluator._legacy_chunk_host` math."""
        ev = self._fallback_evaluator(lane.flow)
        with _TRACER.span("fallback", lane=lane.kind):
            if lane.kind == "mc":
                key, _, draws, quantiles, sigmas = self._lane_args[lane]
                arrays = ev.evaluate_indices_legacy(
                    chunk_idx, mc_key=key, mc_draws=draws,
                    mc_sigmas=sigmas, mc_quantiles=quantiles)
            else:
                arrays = ev.evaluate_indices_legacy(chunk_idx)
        out = [arrays.sku_unit_total, arrays.sku_unit_re,
               arrays.sku_unit_nre, arrays.portfolio_cost]
        if arrays.risk is not None:
            out.append(arrays.risk)
        out.append(arrays.finite)
        return tuple(out)

    def _tick_chunk(self, plan: TickPlan) -> int:
        k = self.cfg.chunk
        with _TRACER.span("pack", used=plan.used):
            chunk_idx = np.zeros((k,), np.int64)
            for a in plan.assignments:
                chunk_idx[a.slot:a.slot + a.n] = \
                    a.item.idx[a.start:a.start + a.n]
            if plan.used < k and plan.assignments:
                chunk_idx[plan.used:] = chunk_idx[0]  # cost-neutral padding
            dev = jnp.asarray(chunk_idx, jnp.int32)
        host = None
        degraded = False
        if self.breaker.allow():
            host, err = self._dispatch_fused_with_retry(plan.lane, dev)
            if host is None:
                self.breaker.record_failure()
                if not self.cfg.fallback:
                    raise err
            else:
                self.breaker.record_success()
        if host is None:
            # fused path down (or breaker open): slow-but-correct.
            t_fb = time.perf_counter()
            host = self._fallback_chunk_host(plan.lane, chunk_idx)
            degraded = True
            self.res.bump("fallback_ticks")
            self.res.bump("fallback_rows", plan.used)
            self.res.bump("fallback_busy_s", time.perf_counter() - t_fb)
        now = time.perf_counter()
        unit, re_t, nre_t, pf = host[0], host[1], host[2], host[3]
        risk = host[4] if plan.lane.kind == "mc" else None
        finite = np.asarray(host[-1])
        if self.faults and plan.used \
                and self._fire("poison") is not None:
            # host buffers from device_get may be read-only views
            unit = np.array(unit)
            finite = np.array(finite)
            row = self.faults.rng(
                "poison", self.faults.fired["poison"]).randrange(plan.used)
            unit[row] = np.nan
            finite[row] = False
        for a in plan.assignments:
            req: _Active = a.item.owner
            if req.failed:
                continue
            sl = slice(a.slot, a.slot + a.n)
            dst = slice(a.start, a.start + a.n)
            ok_rows = finite[sl]
            if not ok_rows.all():
                # a typed envelope for THIS request only; coalesced
                # siblings in the same chunk are untouched.
                bad = int(a.n - ok_rows.sum())
                self.res.bump("numerical_errors")
                self._fail(req, NUMERICAL_ERROR,
                           f"non-finite cost in {bad} of {a.n} rows "
                           f"(rows {a.start}..{a.start + a.n - 1})")
                continue
            req.accum["unit"][dst] = unit[sl]
            req.accum["re"][dst] = re_t[sl]
            req.accum["nre"][dst] = nre_t[sl]
            req.accum["pf"][dst] = pf[sl]
            if risk is not None:
                for kk in req.risk_keys:
                    req.accum["risk:" + kk][dst] = risk[kk][sl]
            if degraded:
                req.degraded = True
                req.degraded_rows[dst] = True
            if not req.rec.t_first:
                req.rec.t_first = now
            req.rows_done += a.n
            if req.on_partial is not None:
                req.on_partial(req.rows_done, req.n_rows)
            if req.rows_done >= req.n_rows:
                # defer past charge_tick so the final tick's share is on
                # the bill before the response envelope snapshots it
                self._tick_done.append(
                    lambda r=req: self._finish_sweep(r))
        if _TRACER.enabled():
            _TRACER.add_complete("scatter", time.perf_counter() - now)
        return plan.used

    def _tick_gen(self, plan: TickPlan) -> int:
        work: GenWork = plan.gen
        req: _Active = work.owner
        if req.failed:
            return 0
        task = work.task
        # a restored checkpoint may already have every generation done
        # (the crash hit between the last generation and the ranking
        # sweep): go straight to ranking.
        if task.gen >= task.sr.generations:
            self._enqueue_search_rank(req)
            return 0
        # checkpointed abort: a search checks its deadline between
        # generations (queue expiry catches it too once re-pushed, but
        # plan() may have popped this work before the deadline passed).
        if req.deadline_t is not None \
                and time.perf_counter() >= req.deadline_t:
            self.res.bump("deadline_rejected")
            self._fail(req, DEADLINE_EXCEEDED,
                       f"deadline exceeded after {task.gen}/"
                       f"{task.sr.generations} generations")
            return 0
        with _TRACER.span("generation", gen=task.gen):
            try:
                out = task.device_call()
                host = jax.device_get(out)         # THE tick sync
            except Exception as e:
                self._fail(req, INTERNAL_ERROR, f"{type(e).__name__}: {e}")
                return 0
            if not np.isfinite(np.asarray(host[2], np.float64)).all():
                self.res.bump("numerical_errors")
                self._fail(req, NUMERICAL_ERROR,
                           f"non-finite objective in generation {task.gen}")
                return 0
            if not req.rec.t_first:
                req.rec.t_first = time.perf_counter()
            done = task.consume(host)
            if self.dcfg is not None and not done \
                    and self.dcfg.checkpoint_every > 0 \
                    and task.gen % self.dcfg.checkpoint_every == 0:
                try:
                    task.state.save(self._ckpt_manager(req.origin))
                    self.dur.bump("checkpoints_written")
                except OSError as e:
                    self.log.event(req.uid, "checkpoint_error",
                                   error=str(e))
            if req.on_partial is not None:
                req.on_partial(task.gen, task.sr.generations)
            if done:
                self._enqueue_search_rank(req)
            else:
                self.sched.push(work)
        return task.sr.population

    def _tick_raw(self, plan: TickPlan) -> int:
        with _TRACER.span("pack", lane="raw"):
            groups = list(plan.groups)
            # combined entity tables must fit the padded signature; shed
            # the newest groups back to the queue head until they do.
            while groups:
                systems, gids = [], []
                for gi, g in enumerate(groups):
                    systems += g.systems
                    gids += [gi] * g.n_systems
                batch = SystemBatch.from_systems(
                    systems, share_nre=gids, max_chips=self.raw_max_chips)
                if self._raw_fits(batch):
                    break
                self.sched.queue.appendleft(groups.pop())
            if not groups:
                return 0
            self._raw_parts = list(groups)   # actual riders after shedding
            padded = pad_batch(batch, **self.raw_pad)
        host = jax.device_get(_TOTAL_JIT(padded, plan.lane.flow))  # THE sync
        now = time.perf_counter()
        total = np.asarray(host.total, np.float64)
        re_tot = np.asarray(host.re.total, np.float64)
        nre_tot = np.asarray(host.nre.total, np.float64)
        off = 0
        for g in groups:
            req: _Active = g.owner
            rows = []
            for i, s in enumerate(g.systems):
                j = off + i
                rows.append({"system": s.name, "quantity": s.quantity,
                             "re_total": float(re_tot[j]),
                             "nre_total": float(nre_tot[j]),
                             "total": float(total[j])})
            off += g.n_systems
            if req.failed:
                continue
            group_sl = slice(off - g.n_systems, off)
            if not (np.isfinite(total[group_sl]).all()
                    and np.isfinite(re_tot[group_sl]).all()
                    and np.isfinite(nre_tot[group_sl]).all()):
                self.res.bump("numerical_errors")
                self._fail(req, NUMERICAL_ERROR,
                           f"non-finite cost in the {g.n_systems}-system "
                           f"group")
                continue
            req.rec.t_first = req.rec.t_first or now
            req.rows_done = req.n_rows
            self._tick_done.append(
                lambda r=req, p=SystemsResult(rows=rows): self._finish(r, p))
        if _TRACER.enabled():
            _TRACER.add_complete("scatter", time.perf_counter() - now)
        return off

    # ------------------------------------------------------------------
    # Completion / failure
    # ------------------------------------------------------------------

    def _finish_sweep(self, req: _Active):
        try:
            arrays = self._sweep_arrays(req)
            # degraded (fallback-priced) values are correct but carry a
            # different provenance than fused ones — never cache them.
            if req.cache_key is not None and not req.degraded:
                self.results.put(req.cache_key, arrays)
            payload = req.payload_fn(arrays)
        except Exception as e:
            self._fail(req, INTERNAL_ERROR, f"{type(e).__name__}: {e}")
            return
        self._finish(req, payload)

    def _finish(self, req: _Active, payload):
        if req.deadline_t is not None:
            self._deadline_count -= 1
        self.metrics.finish_request(req.rec, ok=True)
        bill_dict = self._close_bill(req, ok=True, status="ok")
        self.sched.release(req.cost)
        self._active.pop(req.uid, None)
        if self.journal is not None:
            self.journal.done(req.uid, "ok")
        if req.kind == "search":
            self._drop_checkpoints(req.origin)
        _TRACER.instant("request_done", trace_id=req.trace_id,
                        uid=req.uid, kind=req.kind)
        self.log.event(req.uid, "done", rows=req.n_rows,
                       degraded=req.degraded)
        self.flight.record("request", uid=req.uid, kind=req.kind,
                           rows=req.n_rows, wall_s=req.rec.latency_s,
                           degraded=req.degraded, trace_id=req.trace_id)
        if not req.future.done():
            req.future.set_result(Response(
                request_id=req.uid, kind=req.kind, ok=True, result=payload,
                timing=Timing(req.rec.t_submit, req.rec.ttfr_s,
                              req.rec.latency_s),
                degraded=req.degraded,
                degraded_rows=(req.degraded_rows
                               if req.degraded
                               and req.kind in ("price", "mc_risk")
                               else None),
                replayed=req.replayed_from is not None,
                replayed_from=req.replayed_from,
                trace_id=req.trace_id, bill=bill_dict))

    def _fail(self, req: _Active, code: str, message: str):
        if req.failed:
            return
        req.failed = True
        if req.deadline_t is not None:
            self._deadline_count -= 1
        self.sched.drop_owned_by(req)
        self.sched.release(req.cost)
        self.metrics.finish_request(req.rec, ok=False)
        bill_dict = self._close_bill(req, ok=False, status=code)
        self._active.pop(req.uid, None)
        if self.journal is not None:
            # a typed failure IS an answer: terminal in the journal, so
            # the request will not replay.
            self.journal.done(req.uid, code)
        _TRACER.instant("request_error", trace_id=req.trace_id,
                        uid=req.uid, kind=req.kind, code=code)
        self.log.event(req.uid, "error", code=code, message=message)
        self.flight.record("request_error", uid=req.uid, kind=req.kind,
                           code=code, error=message, trace_id=req.trace_id)
        if not req.future.done():
            resp = error_response(req.uid, req.kind, code, message,
                                  req.rec.t_submit, trace_id=req.trace_id)
            resp.replayed = req.replayed_from is not None
            resp.replayed_from = req.replayed_from
            resp.bill = bill_dict
            req.future.set_result(resp)

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def snapshot(self) -> Dict:
        """JSON-ready metrics snapshot (latency, occupancy, caches,
        recompiles) — the surface the bench and CI assert on.  When
        tracing is on (``REPRO_TRACE=1`` / ``obs.enable()``) the snapshot
        also carries the per-phase wall table, per-jit compile/dispatch
        attribution and ``device_get`` stats."""
        snap = self.metrics.snapshot(trace_stats=self.traces.stats(),
                                     cache_stats=self.results.stats())
        snap["resilience"] = {
            **self.res.snapshot(),
            "breaker": self.breaker.snapshot(),
            "faults": self.faults.stats(),
            "deadlines_active": self._deadline_count,
            "watchdog": (self.watchdog.snapshot()
                         if self.watchdog is not None else None),
        }
        snap["durability"] = {
            **self.dur.snapshot(),
            "enabled": self.dcfg is not None,
            "accepting": self._accepting,
            "journal": (self.journal.stats()
                        if self.journal is not None else None),
        }
        snap["ledger"] = self.ledger.snapshot()
        snap["slo"] = ({"enabled": True, "objectives": self.slo.snapshot()}
                       if self.slo is not None else {"enabled": False})
        if _TRACER.enabled():
            snap["obs"] = {
                "phases": _TRACER.phase_table(),
                "tick_coverage": _TRACER.coverage("tick"),
                "jit": jaxhooks.stats(),
                "device_get": jaxhooks.device_get_stats(),
                "recompiles_in_ticks": (
                    _TRACER.count("jit_compile", parent="tick")
                    + _TRACER.count("jit_compile", parent="generation")
                    + _TRACER.count("jit_compile", parent="pack")),
            }
        return snap

    def dump_flight_recorder(self, path=None):
        """Dump the flight recorder — and, when tracing is on, every
        tracer span — as one Chrome/Perfetto ``trace_event`` JSON file.
        Called automatically on tick failure when ``REPRO_FLIGHT_DIR``
        is set; callable any time for a live look at recent ticks.
        Returns the written path."""
        extra = _TRACER.chrome_events() if _TRACER.enabled() else None
        return self.flight.dump(path, extra_events=extra)


def serve(space: DesignSpace, requests: Sequence[Request],
          config: Optional[ServiceConfig] = None,
          ) -> Tuple[List[Response], PricingService]:
    """One-shot convenience: start a service, submit ``requests``
    concurrently, drain, stop.  Returns (responses in request order,
    the stopped service for metrics inspection)."""
    svc = PricingService(space, config)

    async def _main():
        await svc.start()
        try:
            return await asyncio.gather(*(svc.submit(r) for r in requests))
        finally:
            await svc.stop()

    return asyncio.run(_main()), svc
