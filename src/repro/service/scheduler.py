"""The coalescing scheduler: pending request spans -> constant-shape ticks.

Policy only — no device work, no asyncio — so the scheduling behavior is
unit-testable in isolation.  The server owns the loop; the scheduler owns
*what runs next*:

* Work arrives as :class:`SpanWork` (an index sweep, divisible),
  :class:`GroupWork` (a raw ``share_nre`` system group, indivisible — its
  NRE amortization needs the whole group in one batch), or
  :class:`GenWork` (one evolutionary-search state, one generation per
  tick).
* Every tick serves exactly ONE lane (one jit signature): the lane of
  the oldest queued item.  Same-lane work anywhere in the queue is
  coalesced into the tick's fixed slot budget — that is the continuous
  batching.
* **Fairness** is FIFO with large-request splitting: one item
  contributes at most ``split`` candidates per pass, and items that
  still have work left after a tick are rotated to the back of the
  queue.  A 1M-candidate sweep therefore yields a slot share to every
  point query that arrives behind it instead of starving the queue.
* **Backpressure** is a bounded row budget: :meth:`admit` refuses work
  past ``max_pending`` rows (the server turns that refusal into a typed
  ``queue_full`` error envelope, never an OOM).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Lane:
    """One jit-signature equivalence class: requests in the same lane may
    share a device tick.

    ``mc`` folds in everything two Monte-Carlo sweeps must agree on to
    share a chunk: the static trace key (draws, quantiles) AND the traced
    per-chunk arguments (seed, sigmas) — a chunk has one key/sigma set,
    so requests under different scenarios must not coalesce."""

    kind: str                      # "chunk" | "mc" | "raw" | "gen"
    flow: str = "chip-last"
    mc: Optional[Tuple] = None     # (draws, quantiles, seed, sigmas)


@dataclasses.dataclass(eq=False)        # identity semantics: queue
class SpanWork:                          # membership must not compare arrays
    """A divisible index sweep owned by one request."""

    owner: Any                     # the server's ActiveRequest
    lane: Lane
    idx: np.ndarray                # (n,) candidate indices, request order
    cursor: int = 0                # next unscheduled position
    deadline_t: Optional[float] = None   # absolute perf_counter deadline
    trace_id: str = ""             # the owning request's trace id

    @property
    def remaining(self) -> int:
        return int(self.idx.shape[0]) - self.cursor


@dataclasses.dataclass(eq=False)
class GroupWork:
    """An indivisible raw system group (one share_nre group, one tick)."""

    owner: Any
    lane: Lane
    systems: List[Any]             # core.system.System objects
    deadline_t: Optional[float] = None
    trace_id: str = ""             # the owning request's trace id

    @property
    def n_systems(self) -> int:
        return len(self.systems)


@dataclasses.dataclass(eq=False)
class GenWork:
    """One in-flight evolutionary search; the server's SearchTask holds
    the device-side population state."""

    owner: Any
    lane: Lane
    task: Any                      # server.SearchTask
    deadline_t: Optional[float] = None
    trace_id: str = ""             # the owning request's trace id


@dataclasses.dataclass
class Assignment:
    """One contiguous span of a SpanWork mapped into tick slots."""

    item: SpanWork
    start: int                     # offset into the request's row space
    n: int
    slot: int                      # first slot in the tick's chunk


@dataclasses.dataclass
class TickPlan:
    """Everything the server needs to dispatch one device tick."""

    lane: Lane
    slots: int                     # the lane's fixed slot budget
    used: int
    assignments: List[Assignment] = dataclasses.field(default_factory=list)
    groups: List[GroupWork] = dataclasses.field(default_factory=list)
    gen: Optional[GenWork] = None


class Scheduler:
    def __init__(self, slots: int, split: Optional[int] = None,
                 raw_slots: int = 16, max_pending: int = 1_000_000):
        if slots < 1:
            raise ValueError("need at least one chunk slot")
        self.slots = int(slots)
        self.split = int(split) if split else int(slots)
        if self.split < 1:
            raise ValueError("split must be positive")
        self.raw_slots = int(raw_slots)
        self.max_pending = int(max_pending)
        self.queue: deque = deque()
        self.pending_rows = 0

    # -- admission / backpressure -------------------------------------------
    def admit(self, items: List[Any], cost_rows: int) -> bool:
        """Enqueue ``items`` if the row budget allows; False = reject
        (the caller owes the client a ``queue_full`` envelope)."""
        if self.pending_rows + cost_rows > self.max_pending:
            return False
        self.pending_rows += cost_rows
        self.queue.extend(items)
        return True

    def push(self, item: Any):
        """Re-enqueue follow-on work whose budget was charged at admit
        time (search rank sweeps, continuing generations)."""
        self.queue.append(item)

    def release(self, rows: int):
        self.pending_rows = max(0, self.pending_rows - rows)

    def has_work(self) -> bool:
        return bool(self.queue)

    def drop_owned_by(self, owner: Any):
        """Remove all queued work of a (failed) request."""
        self.queue = deque(w for w in self.queue if w.owner is not owner)

    def clear(self):
        """Drop everything (simulated crash / hard shutdown): the queue
        empties and the whole row budget is released in one stroke."""
        self.queue.clear()
        self.pending_rows = 0

    def expire(self, now: float) -> List[Any]:
        """Pop and return every queued item whose deadline has passed
        (the caller owes each owner a ``deadline_exceeded`` envelope).
        Policy only: the row budget stays charged until the server fails
        the owner and releases it."""
        expired = [w for w in self.queue
                   if w.deadline_t is not None and w.deadline_t <= now]
        if expired:
            dead = {id(w) for w in expired}
            self.queue = deque(w for w in self.queue if id(w) not in dead)
        return expired

    # -- tick planning -------------------------------------------------------
    def plan(self) -> Optional[TickPlan]:
        if not self.queue:
            return None
        lane = self.queue[0].lane
        if lane.kind == "gen":
            return TickPlan(lane=lane, slots=1, used=1,
                            gen=self.queue.popleft())
        if lane.kind == "raw":
            return self._plan_raw(lane)
        return self._plan_spans(lane)

    def _plan_raw(self, lane: Lane) -> TickPlan:
        groups, used = [], 0
        for item in list(self.queue):
            if item.lane != lane:
                continue
            if used + item.n_systems > self.raw_slots and groups:
                break
            groups.append(item)
            used += item.n_systems
            if used >= self.raw_slots:
                break
        for g in groups:
            self.queue.remove(g)
        return TickPlan(lane=lane, slots=self.raw_slots, used=used,
                        groups=groups)

    def _plan_spans(self, lane: Lane) -> TickPlan:
        assignments: List[Assignment] = []
        served: List[SpanWork] = []
        used = 0
        # multi-pass fill: each pass hands every same-lane item at most
        # `split` slots (fairness), and passes repeat until the chunk is
        # full or the lane is drained (occupancy).
        progress = True
        while used < self.slots and progress:
            progress = False
            for item in self.queue:
                if item.lane != lane or used >= self.slots:
                    continue
                take = min(self.split, item.remaining, self.slots - used)
                if take <= 0:
                    continue
                assignments.append(Assignment(item=item, start=item.cursor,
                                              n=take, slot=used))
                item.cursor += take
                used += take
                if item not in served:
                    served.append(item)
                progress = True
        # rotation: finished items leave; served-but-unfinished items go
        # to the back so queued neighbors (any lane) reach the head.
        for item in served:
            self.queue.remove(item)
        for item in served:
            if item.remaining > 0:
                self.queue.append(item)
        return TickPlan(lane=lane, slots=self.slots, used=used,
                        assignments=assignments)
