"""Per-architecture smoke tests (brief requirement): reduced config, one
forward/train step on CPU, output shapes + no NaNs; plus decode-vs-
prefill consistency for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.models.common import count_params, init_params
from repro.parallel import steps as st

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32):
    if cfg.family == "encdec":
        return {"frames": jnp.ones((b, s, cfg.d_model), jnp.float32),
                "dec_tokens": jnp.zeros((b, cfg.dec_len), jnp.int32),
                "labels": jnp.zeros((b, cfg.dec_len), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.n_img_patches
        return {"tokens": jnp.zeros((b, s - p), jnp.int32),
                "img_embeds": jnp.ones((b, p, cfg.d_model), jnp.float32),
                "labels": jnp.zeros((b, s - p), jnp.int32)}
    return {"tokens": jnp.zeros((b, s), jnp.int32),
            "labels": jnp.zeros((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(api.param_spec(cfg), KEY)
    batch = _batch(cfg)
    loss = api.loss_fn(cfg)(params, batch)
    assert jnp.isfinite(loss), arch

    state = st.init_train_state(cfg, KEY)
    step = jax.jit(st.make_train_step(cfg, total_steps=10))
    state2, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    for leaf in jax.tree_util.tree_leaves(state2.params):
        assert np.isfinite(np.asarray(leaf)).all(), arch
    assert int(metrics["step"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_spec_matches_brief(arch):
    cfg = get_config(arch)
    # exact assigned hyperparameters survive in the FULL config
    briefs = {
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
        "minicpm3_4b": (62, 2560, 40, 40, 6400, 73448),
        "glm4_9b": (40, 4096, 32, 2, 13696, 151552),
        "mistral_large_123b": (88, 12288, 96, 8, 28672, 32768),
        "deepseek_7b": (30, 4096, 32, 32, 11008, 102400),
        "deepseek_moe_16b": (28, 2048, 16, 16, None, 102400),
        "deepseek_v2_236b": (60, 5120, 128, 128, None, 102400),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32000),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
    }
    L, d, h, kv, dff, vocab = briefs[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if dff is not None:
        assert cfg.d_ff == dff
    assert cfg.vocab == vocab
    if arch == "deepseek_moe_16b":
        assert (cfg.n_experts, cfg.top_k, cfg.n_shared,
                cfg.d_ff_expert) == (64, 6, 2, 1408)
    if arch == "deepseek_v2_236b":
        assert (cfg.n_experts, cfg.top_k, cfg.kv_lora) == (160, 6, 512)
    if arch == "zamba2_7b":
        assert cfg.ssm_state == 64
    if arch == "whisper_medium":
        assert cfg.n_dec_layers == 24


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_consistent_with_forward(arch):
    """prefill(s tokens) + decode == forward(s+1 tokens) last logits."""
    cfg = get_config(arch).reduced()
    if cfg.family == "encdec":
        pytest.skip("separate encdec consistency test below")
    if cfg.family == "moe":
        # capacity dropping is sequence-global: give ample capacity so the
        # forward and decode paths see identical expert assignments
        cfg = cfg.replace(capacity_factor=16.0)
    params = init_params(api.param_spec(cfg), KEY)
    b, s = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s + 1)), jnp.int32)
    img = None
    n_img = 0
    if cfg.family == "vlm":
        n_img = cfg.n_img_patches
        img = jnp.asarray(rng.standard_normal(
            (b, n_img, cfg.d_model)), jnp.float32)

    from repro.models import transformer as tf
    full_logits = tf.lm_forward(cfg, params, toks, img)
    want = full_logits[:, -1]

    pre_logits, cache = tf.lm_prefill(cfg, params, toks[:, :s],
                                      s + n_img + 8, img_embeds=img)
    kv_len = jnp.full((b,), s + n_img, jnp.int32)
    got, _ = tf.lm_decode(cfg, params, toks[:, s:s + 1], cache, kv_len)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)
    # prefill's own last-token logits match forward at that position
    # (image patches shift text positions by n_img)
    np.testing.assert_allclose(np.asarray(pre_logits),
                               np.asarray(full_logits[:, n_img + s - 1]),
                               atol=2e-3, rtol=2e-3)


def test_encdec_decode_consistency():
    cfg = get_config("whisper_medium").reduced()
    params = init_params(api.param_spec(cfg), KEY)
    b, s_enc = 2, 16
    rng = np.random.default_rng(1)
    frames = jnp.asarray(rng.standard_normal((b, s_enc, cfg.d_model)),
                         jnp.float32)
    from repro.models import encdec as ed
    enc = ed.encode(cfg, params, frames)
    dec_toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, 4)), jnp.int32)
    full = ed.decode_train(cfg, params, enc, dec_toks)

    cache = ed.encdec_prefill(cfg, params, frames)
    kv = jnp.zeros((b,), jnp.int32)
    for t in range(4):
        got, cache = ed.encdec_decode(cfg, params, dec_toks[:, t:t + 1],
                                      cache, kv)
        kv = kv + 1
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(full[:, t]),
                                   atol=2e-3, rtol=2e-3)


def test_param_counts_in_expected_range():
    """FULL configs: parameter counts match the advertised model sizes."""
    expect = {
        "deepseek_7b": (6e9, 8e9),
        "mistral_large_123b": (115e9, 130e9),
        "deepseek_moe_16b": (14e9, 20e9),
        "deepseek_v2_236b": (200e9, 260e9),
        "glm4_9b": (8e9, 11e9),
        "minicpm3_4b": (3.4e9, 5e9),
        "zamba2_7b": (6e9, 9e9),
        # our backbone uses SwiGLU (3 FFN mats) vs whisper's GELU (2):
        # ~0.96B vs the official 0.77B — same class, documented in DESIGN
        "whisper_medium": (0.6e9, 1.1e9),
        "xlstm_125m": (0.06e9, 0.2e9),
        "llava_next_mistral_7b": (6.5e9, 8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(api.param_spec(get_config(arch)))
        assert lo <= n <= hi, f"{arch}: {n:,}"
