"""repro.service — Actuary-as-a-service.

A continuous-batching cost-query server over the fused ``repro.dse``
pipeline: concurrent clients submit typed pricing requests, an async
scheduler coalesces them into constant-shape device ticks, and every
response is bit-exact against the equivalent direct
:class:`~repro.dse.evaluate.ChunkedEvaluator` / ``portfolio_search``
call.  See :mod:`repro.service.server` for the tick loop.
"""
from .cache import LaneSignature, ResultCache, TraceCache, \
    index_digest, space_fingerprint
from .durability import DurabilityConfig, JournalEntry, RequestJournal, \
    request_from_wire, request_to_wire
from .metrics import DurabilityStats, RequestRecord, ResilienceStats, \
    ServiceMetrics
from .protocol import DEADLINE_EXCEEDED, ErrorInfo, INTERNAL_ERROR, \
    INVALID_REQUEST, \
    McSpec, MCRiskRequest, NUMERICAL_ERROR, PriceRequest, \
    PriceSystemsRequest, QUEUE_FULL, SHUTTING_DOWN, \
    RankRequest, RankResult, Request, RequestLog, Response, SearchRequest, \
    SystemsResult, Timing, WhatIfRequest, WhatIfResult, error_response, \
    validate_request
from .scheduler import Assignment, GenWork, GroupWork, Lane, Scheduler, \
    SpanWork, TickPlan
from .server import PricingService, SearchTask, SearchWarmup, \
    ServiceConfig, ServiceError, SimulatedCrash, serve

__all__ = [
    "DEADLINE_EXCEEDED", "ErrorInfo", "INTERNAL_ERROR", "INVALID_REQUEST",
    "NUMERICAL_ERROR", "QUEUE_FULL", "SHUTTING_DOWN",
    "McSpec", "MCRiskRequest", "PriceRequest", "PriceSystemsRequest",
    "RankRequest", "RankResult", "Request", "RequestLog", "Response",
    "SearchRequest", "SystemsResult", "Timing", "WhatIfRequest",
    "WhatIfResult", "error_response", "validate_request",
    "Lane", "Scheduler", "SpanWork", "GroupWork", "GenWork", "Assignment",
    "TickPlan",
    "LaneSignature", "ResultCache", "TraceCache", "index_digest",
    "space_fingerprint",
    "DurabilityConfig", "JournalEntry", "RequestJournal",
    "request_from_wire", "request_to_wire",
    "DurabilityStats", "RequestRecord", "ResilienceStats", "ServiceMetrics",
    "PricingService", "SearchTask", "SearchWarmup", "ServiceConfig",
    "ServiceError", "SimulatedCrash", "serve",
]
