"""§Roofline table: three roofline terms per (arch x shape) from the
multi-pod dry-run's compiled artifacts (results/dryrun.json).

  compute    = HLO_FLOPs / (chips x 197 TFLOP/s)
  memory     = HBM_bytes / (chips x 819 GB/s)
  collective = collective_bytes / (chips x 4 x 50 GB/s links)

plus MODEL_FLOPS (6·N·D / 2·N_active·D), the useful-compute ratio and
the MFU-style roofline fraction at the bound step time.
"""
import json
from pathlib import Path

from .common import emit

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results"
RESULTS = RESULTS_DIR / "dryrun_optimized.json"
FALLBACK = RESULTS_DIR / "dryrun.json"
BASELINE = RESULTS_DIR / "dryrun_baseline.json"


def rows_from(results: dict, mesh: str = "16x16", tag: str = ""):
    rows = []
    for key, v in sorted(results.items()):
        parts = key.split("|")
        if len(parts) == 4 and parts[3] != tag:
            continue
        if len(parts) == 3 and tag:
            continue
        if parts[2] != mesh:
            continue
        if v["status"] == "skip":
            rows.append({"arch": parts[0], "shape": parts[1],
                         "bound": "SKIP", "t_compute_s": 0.0,
                         "t_memory_s": 0.0, "t_collective_s": 0.0,
                         "t_bound_s": 0.0, "model_flops": 0,
                         "useful_ratio": 0.0, "roofline_frac": 0.0,
                         "mem_gb_per_dev": 0.0})
            continue
        if v["status"] != "ok":
            continue
        r = v["roofline"]
        rows.append({
            "arch": parts[0], "shape": parts[1], "bound": r["bound"],
            "t_compute_s": r["t_compute"], "t_memory_s": r["t_memory"],
            "t_collective_s": r["t_collective"], "t_bound_s": r["t_bound"],
            "model_flops": r["model_flops"],
            "useful_ratio": r["useful_flops_ratio"],
            "roofline_frac": r["roofline_fraction"],
            "mem_gb_per_dev": v["memory"]["peak_estimate_gb"],
        })
    return rows


def run():
    path = RESULTS if RESULTS.exists() else FALLBACK
    if not path.exists():
        print("# roofline: results/dryrun*.json missing — run "
              "`python -m repro.launch.dryrun --all --mesh both` first")
        return []
    results = json.loads(path.read_text())
    rows = rows_from(results, "16x16")
    emit("roofline_single_pod_16x16", rows)
    rows_mp = rows_from(results, "2x16x16")
    emit("roofline_two_pod_2x16x16", rows_mp)

    if BASELINE.exists() and path != BASELINE:
        base = json.loads(BASELINE.read_text())
        comp = []
        base_rows = {(r["arch"], r["shape"]): r
                     for r in rows_from(base, "16x16")}
        for r in rows:
            b = base_rows.get((r["arch"], r["shape"]))
            if not b or r["bound"] == "SKIP" or b["t_bound_s"] <= 0:
                continue
            comp.append({
                "arch": r["arch"], "shape": r["shape"],
                "baseline_t_s": b["t_bound_s"],
                "optimized_t_s": r["t_bound_s"],
                "speedup": b["t_bound_s"] / max(r["t_bound_s"], 1e-12),
                "baseline_frac": b["roofline_frac"],
                "optimized_frac": r["roofline_frac"],
            })
        emit("roofline_baseline_vs_optimized", comp)
    return rows


if __name__ == "__main__":
    run()
