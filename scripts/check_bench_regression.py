"""Guard the benchmark perf trajectory against the committed baselines.

  python scripts/check_bench_regression.py [--min-ratio 0.15] [name ...]

Compares the ``BENCH_<name>.json`` files the benchmarks write at the
repo root (see ``benchmarks/common.write_bench_json``) against the
committed ``benchmarks/baselines/BENCH_<name>.json``:

* throughput keys must stay within ``--min-ratio`` of the baseline
  (generous by default: CI boxes are noisy and shared, so the guard
  catches order-of-magnitude regressions, not jitter);
* absolute floors/ceilings (speedup ratios, parity errors, chaos
  survival invariants) are enforced exactly — these are
  correctness-adjacent and machine-independent.

Exit codes are typed so CI can tell "the code got slower" from "the
guard could not run":

* ``0`` — every rule passed;
* ``1`` — a rule failed (a real regression);
* ``2`` — infrastructure error: a BENCH/baseline file is missing,
  truncated, or unparseable, or an unknown benchmark name was given.
  Printed as a one-line ``MISSING``/``UNREADABLE`` diagnosis — never a
  traceback.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]

# Per-check outcome severities; main() exits with the worst one seen.
OK, FAIL, ERROR = 0, 1, 2

# (key, kind, threshold): kind "ratio" compares against min_ratio *
# baseline[key]; "min"/"max" are machine-independent absolute bounds.
# Keys may be dotted paths ("a.b.c") into nested JSON objects — the
# registry snapshot (BENCH_service_metrics.json) nests every instrument
# as {"kind": ..., "value": ...}.
RULES = {
    "dse": [
        ("candidates_per_sec", "ratio", None),
        ("fused_vs_legacy", "min", 10.0),
        ("parity_vs_legacy_rel", "max", 1e-6),
        ("parity_worst_rel", "max", 1e-5),
    ],
    "engine": [
        ("systems_per_sec", "ratio", None),
        ("worst_rel", "max", 1e-5),
    ],
    "service": [
        # ratio compares like-for-like: CI runs --fast and the committed
        # baseline is a --fast run.  The full bench additionally asserts
        # aggregate throughput >= 0.5x the single-client fused rate
        # in-process (mode-dependent, so not a baseline rule here).
        ("agg_candidates_per_sec", "ratio", None),
        ("recompiles_after_warmup", "max", 0.0),
        # serving-cost ledger invariants (also asserted in-process by the
        # bench; pinned here so a silent accounting regression cannot
        # slip through an artifact-only change)
        ("ledger_tick_residual_rel_max", "max", 0.05),
        ("ledger_unattributed_ms", "max", 0.0),
    ],
    "service_metrics": [
        # The registry scrape a traced `service_bench --slo` run writes
        # (observability-smoke CI job).  Dotted paths: every instrument
        # snapshots as {"kind": ..., "value"/"count": ...}.
        ("ledger_bills_closed.value", "min", 1.0),
        ("ledger_ticks_charged.value", "min", 1.0),
        ("ledger_request_device_ms.count", "min", 1.0),
        # per-tick bills must sum to the measured tick wall (float
        # rounding only) and never bill device time to nobody
        ("ledger_tick_residual_rel.value", "max", 0.05),
        ("ledger_unattributed_ms.value", "max", 0.0),
        # the smoke's generous SLOs must not be burning error budget
        ("slo_all_latency_burn.value", "max", 1.0),
        ("slo_all_availability_burn.value", "max", 1.0),
    ],
    "chaos": [
        # Survival invariants of the seeded fault schedule (see
        # benchmarks/chaos_bench.py): every induced fault must land as a
        # typed envelope or a correct degraded response, with zero
        # cross-request contamination and one flight recording per
        # induced stall.  All machine-independent.
        ("survived", "min", 1.0),
        ("loop_errors", "max", 0.0),
        ("contaminated_rows", "max", 0.0),
        ("untyped_errors", "max", 0.0),
        ("stall_dump_deficit", "max", 0.0),
        ("fault_kinds_injected", "min", 5.0),
        # crash/restore sub-run (see chaos_bench._crash_recovery): the
        # injected crash must fire, replay must answer every journaled
        # request, and the resumed search must be bit-exact.
        ("crash_recovered", "min", 1.0),
        ("crash_resume_bitexact", "min", 1.0),
        ("crash_replayed_lost", "max", 0.0),
        ("crash_untyped_errors", "max", 0.0),
    ],
    "restart": [
        # Recovery invariants of the SIGKILL-mid-search oracle
        # (benchmarks/restart_bench.py): a real process death, a resume
        # over the same durability directory, bit-exact parity and zero
        # lost admissions.  recovery_s is a boundedness invariant, not a
        # perf race — the ceiling is deliberately generous.
        ("survived", "min", 1.0),
        ("child_killed", "min", 1.0),
        ("checkpoints_at_kill", "min", 2.0),
        ("search_bitexact", "min", 1.0),
        ("lost_requests", "max", 0.0),
        ("recovery_s", "max", 300.0),
    ],
}


_MISSING = object()


def _lookup(payload: dict, key: str):
    """Resolve a possibly-dotted ``key`` in nested JSON; ``_MISSING``
    when any segment is absent or a non-dict is indexed further."""
    node = payload
    for part in key.split("."):
        if not isinstance(node, dict) or part not in node:
            return _MISSING
        node = node[part]
    return node


def _load(path: pathlib.Path, name: str, role: str):
    """Read one BENCH json; (payload, OK) or (None, ERROR) with a
    one-line diagnosis — a missing or truncated file must read as an
    infrastructure problem, not a traceback or a fake regression."""
    if not path.exists():
        hint = ("run the benchmark first" if role == "run"
                else f"commit one (copy a trusted BENCH_{name}.json there)")
        print(f"[{name}] MISSING {role} file {path} — {hint}")
        return None, ERROR
    try:
        payload = json.loads(path.read_text())
    except (OSError, UnicodeDecodeError, json.JSONDecodeError) as e:
        print(f"[{name}] UNREADABLE {role} file {path} — {e} "
              f"(truncated or corrupt? re-run the benchmark)")
        return None, ERROR
    if not isinstance(payload, dict):
        print(f"[{name}] UNREADABLE {role} file {path} — expected a JSON "
              f"object, got {type(payload).__name__}")
        return None, ERROR
    return payload, OK


def check(name: str, min_ratio: float, root: pathlib.Path) -> int:
    """Run one benchmark's rules; returns OK / FAIL / ERROR."""
    if name not in RULES:
        print(f"[{name}] UNKNOWN benchmark — known: {sorted(RULES)}")
        return ERROR
    cur, status = _load(root / f"BENCH_{name}.json", name, "run")
    if status:
        return status
    base, status = _load(root / "benchmarks" / "baselines" /
                         f"BENCH_{name}.json", name, "baseline")
    if status:
        return status
    worst = OK
    failures = []
    for key, kind, bound in RULES[name]:
        raw = _lookup(cur, key)
        if raw is _MISSING:
            print(f"[{name}] FAIL {key} MISSING from the current run "
                  f"(rule {kind}) — did the benchmark finish?")
            failures.append((key, "missing from current run"))
            worst = max(worst, FAIL)
            continue
        have = float(raw)
        if kind == "ratio":
            base_raw = _lookup(base, key)
            if base_raw is _MISSING:
                print(f"[{name}] FAIL {key} MISSING from baseline "
                      f"— re-commit the baseline")
                failures.append((key, "missing from baseline"))
                worst = max(worst, FAIL)
                continue
            want = min_ratio * float(base_raw)
            good = have >= want
            detail = (f">= {want:,.1f} ({min_ratio:g}x baseline "
                      f"{float(base_raw):,.1f})")
            miss = (f"short by {want - have:,.6g} "
                    f"({have / want:.2%} of the floor)" if not good else "")
        elif kind == "min":
            want = float(bound)
            good = have >= want
            detail = f">= {want:g}"
            miss = f"short by {want - have:,.6g}" if not good else ""
        else:
            want = float(bound)
            good = have <= want
            detail = f"<= {want:g}"
            miss = f"over by {have - want:,.6g}" if not good else ""
        print(f"[{name}] {'PASS' if good else 'FAIL'} {key} = {have:,.6g} "
              f"(need {detail})" + (f" — {miss}" if miss else ""))
        if not good:
            failures.append((key, miss))
            worst = max(worst, FAIL)
    for key, why in failures:
        print(f"[{name}] RULE FAILED: {key} — {why}")
    return worst


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*", default=list(RULES))
    ap.add_argument("--min-ratio", type=float, default=0.15,
                    help="throughput floor as a fraction of baseline")
    ap.add_argument("--root", type=pathlib.Path, default=ROOT,
                    help="tree holding BENCH_*.json + benchmarks/baselines/"
                         " (tests point this at a scratch dir)")
    args = ap.parse_args()
    worst = max(check(n, args.min_ratio, args.root)
                for n in (args.names or list(RULES)))
    if worst == FAIL:
        print("benchmark regression detected")
    elif worst == ERROR:
        print("benchmark guard could not run — see MISSING/UNREADABLE above")
    return worst


if __name__ == "__main__":
    sys.exit(main())
