"""Flash attention (forward) as a Pallas TPU kernel.

Dataflow: grid (B, H, nQ, nK) with the KV-block axis innermost; VMEM
scratch carries the online-softmax state (m, l, acc) across KV blocks,
so HBM traffic is O(S·D) per head instead of O(S²).  Tiling:

  q block   (1, 1, BQ, D)   BQ = 128 rows   (MXU-aligned)
  kv block  (1, 1, BK, D)   BK = 128 rows
  acc       (BQ, D) fp32 in VMEM; m/l (BQ, 1) fp32

GQA is native: the KV index map divides the head index by the group
size — no KV head duplication (the XLA fallback has to repeat KV to
keep GSPMD sharding happy; the kernel does not).

Causality skips whole blocks past the diagonal (the `pl.when` guard) —
~2x fewer FLOPs at long sequence.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, bq: int, bk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk
    # causal: a block strictly above the diagonal contributes nothing —
    # skip its compute (and its share of FLOPs) entirely.
    run = (k_start <= q_start + bq - 1) if causal else (ik >= 0)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (BK, Dv)
        s = (q @ k.T) * scale                          # (BQ, BK)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                            # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                 # (BQ, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ v
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, scale=None,
                        bq: int = 128, bk: int = 128,
                        interpret: bool = False):
    """q:(B,H,S,D) k/v:(B,Hkv,T,D) -> (B,H,S,Dv)."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    scale = d ** -0.5 if scale is None else scale
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)

    grid = (b, h, s // bq, t // bk)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda bb, hh, iq, ik, g=group: (bb, hh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dv),
                               lambda bb, hh, iq, ik: (bb, hh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # m
            pltpu.VMEM((bq, 1), jnp.float32),    # l
            pltpu.VMEM((bq, dv), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
