"""Fused sLSTM sequence kernel (Pallas).

The sLSTM recurrence is the latency wall of the xLSTM family: 4096+
sequential steps of tiny (B,H,Dh) state math.  Lowered naively (XLA
while loop) every step round-trips the state through HBM; this kernel
keeps (c, n, h, m) in VMEM scratch for the whole sequence and streams
only the precomputed gate inputs in / hidden states out:

  grid (B, nSeqChunks): seq chunk innermost, state scratch persists;
  per chunk a fori_loop walks the rows entirely in VMEM.

HBM traffic drops from ~40 ops x state-size x S to (xg in + h out) —
the justification for the analyzer's recurrent-state credit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(xg_ref, r_ref, b_ref, o_ref, c_ref, n_ref, h_ref, m_ref, *,
            lc: int, n_heads: int, dh: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        h_ref[...] = jnp.zeros_like(h_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    r = r_ref[...].astype(jnp.float32)                 # (4,H,Dh,Dh)
    bias = b_ref[...].astype(jnp.float32)              # (4,H,Dh)

    def step(t, _):
        xg = xg_ref[0, t].astype(jnp.float32)          # (4,H,Dh)
        hprev = h_ref[...]                             # (H,Dh)
        rec = jnp.einsum("hd,ghde->ghe", hprev, r,
                         preferred_element_type=jnp.float32)
        g = xg + rec + bias
        zt = jnp.tanh(g[0])
        it = g[1]
        ft = jax.nn.log_sigmoid(g[2])
        ot = jax.nn.sigmoid(g[3])
        m_new = jnp.maximum(ft + m_ref[...], it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m_ref[...] - m_new)
        c_new = f_ * c_ref[...] + i_ * zt
        n_new = f_ * n_ref[...] + i_
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        c_ref[...] = c_new
        n_ref[...] = n_new
        h_ref[...] = h_new
        m_ref[...] = m_new
        o_ref[0, t] = h_new.astype(o_ref.dtype)
        return _

    jax.lax.fori_loop(0, lc, step, 0)


def slstm_seq(xg, r, bias, *, seq_chunk: int = 256,
              interpret: bool = False):
    """xg:(B,S,4,H,Dh) precomputed input gates; r:(4,H,Dh,Dh) recurrent
    weights; bias:(4,H,Dh).  Returns hidden states (B,S,H,Dh)."""
    b, s, four, h, dh = xg.shape
    lc = min(seq_chunk, s)
    assert s % lc == 0, (s, lc)
    grid = (b, s // lc)
    kernel = functools.partial(_kernel, lc=lc, n_heads=h, dh=dh)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, lc, 4, h, dh), lambda bb, ic: (bb, ic, 0, 0, 0)),
            pl.BlockSpec((4, h, dh, dh), lambda bb, ic: (0, 0, 0, 0)),
            pl.BlockSpec((4, h, dh), lambda bb, ic: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lc, h, dh), lambda bb, ic: (bb, ic, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, dh), xg.dtype),
        scratch_shapes=[
            pltpu.VMEM((h, dh), jnp.float32),          # c
            pltpu.VMEM((h, dh), jnp.float32),          # n
            pltpu.VMEM((h, dh), jnp.float32),          # h
            pltpu.VMEM((h, dh), jnp.float32),          # m
        ],
        interpret=interpret,
    )(xg, r, bias)
