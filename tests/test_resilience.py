"""repro.resilience + the hardened service: deterministic fault
injection, deadline/cancellation semantics, retry -> breaker -> legacy
fallback (bit-exact vs the host-packing oracle), numerical guardrails
(typed ``numerical_error`` envelopes that never contaminate coalesced
siblings), watchdog evidence capture, backpressure recovery, and the
bench-guard's non-traceback failure modes."""
import asyncio
import dataclasses
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SystemBatch
from repro.core.engine import finite_rows
from repro.core.system import spec
from repro.dse import ChunkedEvaluator, DesignSpace, SKU, Uncertainty
from repro.resilience import (CircuitBreaker, FaultInjector, RetryPolicy,
                              Watchdog, call_with_retry, nonfinite_paths,
                              parse_fault_spec)
from repro.service import (DEADLINE_EXCEEDED, INVALID_REQUEST, Lane, McSpec,
                           MCRiskRequest, NUMERICAL_ERROR, PriceRequest,
                           PriceSystemsRequest, PricingService, QUEUE_FULL,
                           Scheduler, SearchRequest, SearchWarmup,
                           ServiceConfig, SpanWork, serve, validate_request)


def _space(**kw):
    d = dict(skus=(SKU("laptop", 200.0, 2e6), SKU("server", 400.0, 5e5)),
             processes=("7nm", "12nm"), integrations=("MCM",),
             chiplet_counts=(1, 2, 4), allow_reuse=True)
    d.update(kw)
    return DesignSpace(**d)


@pytest.fixture(scope="module")
def space():
    return _space()


@pytest.fixture(scope="module")
def evaluator(space):
    return ChunkedEvaluator(space, candidates_per_chunk=16)


@pytest.fixture(scope="module")
def oracle(space):
    # fused=False: the legacy host-packing parity oracle the degraded
    # service path must match bit-exactly (after the float32 cast)
    return ChunkedEvaluator(space, candidates_per_chunk=16, fused=False)


@pytest.fixture(autouse=True)
def _no_env_faults(monkeypatch):
    # a stray REPRO_FAULTS (e.g. the CI chaos job's) must not leak into
    # services these tests construct; faults are injected explicitly.
    monkeypatch.delenv("REPRO_FAULTS", raising=False)


CFG = ServiceConfig(chunk=16, split=4, warm_mc=((64, (0.5, 0.9)),))


def _f32_rows_equal(resp_arrays, j, cr):
    """One served row vs one oracle CandidateResult: exact f32 casts."""
    assert np.array_equal(resp_arrays.sku_unit_total[j],
                          np.float32(cr.sku_unit_total))
    assert np.array_equal(resp_arrays.sku_unit_re[j],
                          np.float32(cr.sku_unit_re))
    assert np.array_equal(resp_arrays.sku_unit_nre[j],
                          np.float32(cr.sku_unit_nre))
    assert resp_arrays.portfolio_cost[j] == np.float32(cr.portfolio_cost)


# ---------------------------------------------------------------------------
# Fault injector: deterministic schedules, gating, parse errors
# ---------------------------------------------------------------------------


def test_fault_spec_parse_and_grammar():
    seed, rules = parse_fault_spec(
        "seed=42; dispatch_error:p=0.3 ;stall:p=1.0,ms=1500,n=1")
    assert seed == 42
    assert rules["dispatch_error"].prob == 0.3
    assert rules["stall"].ms == 1500.0 and rules["stall"].max_fires == 1
    with pytest.raises(ValueError):
        parse_fault_spec("explode:p=1.0")          # unknown kind
    with pytest.raises(ValueError):
        parse_fault_spec("stall:ms=5")             # p= is required
    with pytest.raises(ValueError):
        parse_fault_spec("poison:p=1.5")           # p outside [0, 1]
    with pytest.raises(ValueError):
        parse_fault_spec("poison:p=0.5,zap=1")     # unknown option
    assert not FaultInjector("")                   # falsy when rule-free
    assert not FaultInjector("seed=7")
    assert FaultInjector("poison:p=0.0")           # enabled, never fires


def test_fault_schedule_deterministic_and_capped():
    spec_str = "seed=42;dispatch_error:p=0.5;stall:p=1.0,ms=250,n=2"
    a, b = FaultInjector(spec_str), FaultInjector(spec_str)
    seq_a = [a.fire("dispatch_error") is not None for _ in range(64)]
    seq_b = [b.fire("dispatch_error") is not None for _ in range(64)]
    assert seq_a == seq_b                  # a schedule, not a dice roll
    assert any(seq_a) and not all(seq_a)
    # lifetime cap: p=1.0 but n=2 -> exactly two fires ever
    assert sum(a.fire("stall") is not None for _ in range(10)) == 2
    assert a.stats()["fired"]["stall"] == 2
    # per-kind independent streams: checking other kinds in between must
    # not shift a kind's schedule
    c = FaultInjector("seed=42;dispatch_error:p=0.5;poison:p=0.5")
    seq_c = []
    for _ in range(64):
        c.fire("poison")
        seq_c.append(c.fire("dispatch_error") is not None)
    assert seq_c == seq_a
    # payload rng is deterministic too
    assert FaultInjector(spec_str).rng("poison", 3).randrange(100) == \
        FaultInjector(spec_str).rng("poison", 3).randrange(100)
    # unseeded kinds never fire and cost one dict lookup
    assert a.fire("flood") is None


# ---------------------------------------------------------------------------
# Retry + circuit breaker units
# ---------------------------------------------------------------------------


def test_call_with_retry():
    calls, slept, seen = [], [], []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("boom")
        return "ok"

    out = call_with_retry(flaky, RetryPolicy(retries=2, backoff_s=0.01),
                          on_retry=lambda n, e: seen.append(n),
                          sleep=slept.append)
    assert out == "ok" and len(calls) == 3
    assert slept == [0.01, 0.02]           # linear backoff
    assert seen == [1, 2]

    def always():
        raise ValueError("nope")

    with pytest.raises(ValueError):
        call_with_retry(always, RetryPolicy(retries=1), sleep=lambda s: None)


def test_circuit_breaker_lifecycle_and_cooldown_restart():
    t = [0.0]
    events = []
    br = CircuitBreaker(threshold=2, cooldown_s=1.0, clock=lambda: t[0],
                        on_event=events.append)
    assert br.allow()
    br.record_failure()                    # 1 of 2: still closed
    assert br.state == "closed" and br.allow()
    br.record_failure()                    # threshold -> open
    assert br.state == "open" and not br.allow()
    t[0] = 0.5
    assert not br.allow()                  # cooling down
    t[0] = 1.1
    assert br.allow() and br.state == "half_open"   # the probe
    br.record_failure()                    # failed probe -> re-open
    assert br.state == "open"
    t[0] = 1.5
    # the cool-down restarted at the FAILED PROBE (t=1.1), not at the
    # original open (t=0.0) — no instant re-probe loop
    assert not br.allow()
    t[0] = 2.2
    assert br.allow()
    br.record_success()
    assert br.state == "closed" and br.allow()
    assert events == ["open", "probe", "open", "probe", "close"]
    snap = br.snapshot()
    assert snap["opens"] == 2 and snap["closes"] == 1 and snap["probes"] == 2
    # open-duration accounting spans the failed probe: opened at 0.0,
    # recovered at 2.2
    assert snap["open_s_total"] == pytest.approx(2.2)


# ---------------------------------------------------------------------------
# Watchdog unit
# ---------------------------------------------------------------------------


def test_watchdog_one_trip_per_stall():
    stalls = []
    wd = Watchdog(timeout_s=0.05, on_stall=stalls.append, poll_s=0.01)
    wd.start()
    try:
        wd.enter()
        time.sleep(0.15)                   # one stuck "tick"
        assert wd.trips == 1               # latched: not once per poll
        assert len(stalls) == 1 and stalls[0] >= 0.05
        wd.exit()
        time.sleep(0.05)
        assert wd.trips == 1               # idle: no trips
        wd.enter()
        wd.exit()                          # fast tick: no trip
        time.sleep(0.03)
        assert wd.trips == 1
    finally:
        wd.stop()
    assert not wd.snapshot()["running"]
    with pytest.raises(ValueError):
        Watchdog(0.0, stalls.append)


# ---------------------------------------------------------------------------
# Numerical guardrails: walker, in-graph mask, packing validation
# ---------------------------------------------------------------------------


def test_nonfinite_paths_walker():
    assert nonfinite_paths({"a": 1.0, "b": [1, 2, "x"], "c": None}) == []
    out = nonfinite_paths({"a": 1.0, "b": float("nan")}, path="req")
    assert len(out) == 1 and "req" in out[0] and "'b'" in out[0]
    arr = np.ones((4,), np.float32)
    arr[2] = np.inf
    out = nonfinite_paths({"x": arr})
    assert out and "'x'" in out[0]
    assert nonfinite_paths(np.arange(5)) == []     # int arrays are exempt

    @dataclasses.dataclass
    class D:
        v: float

    assert nonfinite_paths(D(float("inf")))
    assert nonfinite_paths(D(3.0)) == []


def test_finite_rows_mask():
    a = jnp.asarray([[1.0, 2.0], [np.nan, 1.0], [3.0, 4.0]], jnp.float32)
    b = jnp.asarray([1.0, 2.0, np.inf], jnp.float32)
    assert np.asarray(finite_rows(a, b)).tolist() == [True, False, False]
    assert np.asarray(finite_rows(a)).tolist() == [True, False, True]


def test_from_systems_rejects_bad_parameters():
    good = spec({"kind": "soc", "name": "a", "area": 100.0,
                 "process": "7nm", "quantity": 1.0})
    nan_area = spec({"kind": "soc", "name": "b", "area": float("nan"),
                     "process": "7nm", "quantity": 1.0})
    neg_area = spec({"kind": "soc", "name": "c", "area": -50.0,
                     "process": "7nm", "quantity": 1.0})
    with pytest.raises(ValueError, match="invalid system parameters"):
        SystemBatch.from_systems([good, nan_area], share_nre=[0, 1])
    with pytest.raises(ValueError, match="invalid system parameters"):
        SystemBatch.from_systems([good, neg_area], share_nre=[0, 1])
    SystemBatch.from_systems([good], share_nre=[0])    # sane spec passes


def test_validate_request_rejects_nonfinite_fields():
    assert validate_request(PriceRequest(indices=[1, 2])) is None
    assert validate_request(MCRiskRequest(
        indices=[1], mc=McSpec(sigmas=Uncertainty(
            defect_sigma=float("nan"))))) is not None
    assert validate_request(SearchRequest(
        jump_prob=float("inf"))) is not None
    assert validate_request(PriceSystemsRequest(specs=(
        {"kind": "soc", "name": "x", "area": float("inf"),
         "process": "7nm", "quantity": 1.0},))) is not None
    # NaN deadlines are non-finite; non-positive ones can never be met
    assert validate_request(PriceRequest(
        indices=[1], deadline_ms=float("nan"))) is not None
    assert validate_request(PriceRequest(
        indices=[1], deadline_ms=-5.0)) is not None
    assert validate_request(PriceRequest(
        indices=[1], deadline_ms=25.0)) is None


def test_service_envelopes_nonfinite_requests(space):
    reqs = [
        MCRiskRequest(indices=[1], mc=McSpec(sigmas=Uncertainty(
            defect_sigma=float("nan")))),
        PriceRequest(indices=[1], deadline_ms=0.0),
        PriceSystemsRequest(specs=(
            {"kind": "soc", "name": "x", "area": float("inf"),
             "process": "7nm", "quantity": 1.0},)),
        PriceSystemsRequest(specs=(
            {"kind": "soc", "name": "y", "area": -120.0,
             "process": "7nm", "quantity": 1.0},)),
    ]
    resps, svc = serve(space, reqs, CFG)
    for r in resps:
        assert not r.ok and r.error.code == INVALID_REQUEST, r
    assert svc.snapshot()["ticks"] == 0    # rejected before the device


# ---------------------------------------------------------------------------
# Deadlines + cancellation
# ---------------------------------------------------------------------------


def test_scheduler_expire():
    sched = Scheduler(slots=8, max_pending=100)
    lane = Lane(kind="chunk")

    def span(deadline):
        return SpanWork(owner=object(), lane=lane,
                        idx=np.arange(3, dtype=np.int64),
                        deadline_t=deadline)

    w1, w2, w3 = span(1.0), span(None), span(5.0)
    assert sched.admit([w1, w2, w3], 9)
    assert sched.expire(0.5) == []
    assert sched.expire(2.0) == [w1]
    assert list(sched.queue) == [w2, w3]
    assert sched.expire(10.0) == [w3]
    assert list(sched.queue) == [w2]       # no-deadline work never expires
    assert sched.pending_rows == 9         # policy only: budget untouched


def test_deadline_exceeded_in_queue(space):
    """An in-queue request whose deadline passes before its first tick is
    rejected with a typed envelope; its sibling is untouched and the row
    budget is fully released."""

    async def _main():
        svc = PricingService(space, CFG)
        doomed = asyncio.ensure_future(svc.submit(
            PriceRequest(indices=[0, 1, 2], deadline_ms=10.0)))
        sibling = asyncio.ensure_future(svc.submit(
            PriceRequest(indices=[3, 4])))
        await asyncio.sleep(0.05)          # both admitted; deadline passes
        await svc.start()                  # first tick expires the doomed
        r_doomed, r_sib = await asyncio.gather(doomed, sibling)
        await svc.stop()
        return svc, r_doomed, r_sib

    svc, r_doomed, r_sib = asyncio.run(_main())
    assert not r_doomed.ok
    assert r_doomed.error.code == DEADLINE_EXCEEDED
    assert "0/3 rows" in r_doomed.error.message
    assert r_sib.ok
    assert svc.res.deadline_rejected == 1
    assert svc.snapshot()["resilience"]["deadline_rejected"] == 1
    assert svc.sched.pending_rows == 0
    assert svc._deadline_count == 0


def test_search_deadline_checkpoints_between_generations(space):
    """A mid-flight search aborts cleanly at a generation boundary: some
    generations tick, then the deadline wins — never a hung request."""
    cfg = dataclasses.replace(
        CFG, warm_search=(SearchWarmup(population=8, elite=2),))

    async def _main():
        svc = PricingService(space, cfg)
        await svc.start()
        r = await svc.submit(SearchRequest(
            seed=1, population=8, generations=5000, elite=2,
            deadline_ms=250.0))
        await svc.stop()
        return svc, r

    svc, r = asyncio.run(_main())
    assert not r.ok and r.error.code == DEADLINE_EXCEEDED
    assert svc.snapshot()["ticks_by_lane"].get("gen", 0) >= 1
    assert svc.sched.pending_rows == 0     # budget released on abort


def test_cancel_in_queue_releases_budget(space):
    async def _main():
        svc = PricingService(space, CFG)
        task = asyncio.ensure_future(
            svc.submit(PriceRequest(indices=[0, 1, 2])))
        await asyncio.sleep(0)             # admitted; loop not started yet
        assert svc.sched.pending_rows == 3
        task.cancel()
        with pytest.raises(asyncio.CancelledError):
            await task
        assert svc.sched.pending_rows == 0
        assert not svc.sched.has_work()
        await svc.start()                  # the service still serves
        r = await svc.submit(PriceRequest(indices=[5, 6]))
        await svc.stop()
        return svc, r

    svc, r = asyncio.run(_main())
    assert r.ok
    assert svc.res.cancelled == 1
    assert svc.snapshot()["resilience"]["cancelled"] == 1


# ---------------------------------------------------------------------------
# Retry -> breaker -> legacy fallback (degraded mode) -> recovery
# ---------------------------------------------------------------------------


def test_fused_failure_degrades_to_oracle_then_recovers(space, evaluator,
                                                        oracle):
    """With the fused path hard-down, responses degrade to the legacy
    host-packing evaluator — float32 casts of the oracle's float64s,
    bit for bit — and once the fault clears, a half-open probe restores
    the fused path bit-exactly."""
    cfg = dataclasses.replace(CFG, breaker_cooldown_s=60.0)
    p_idx = [0, 1, 2, 3, 4]
    m_idx = [1, 2, 3]

    async def _main():
        svc = PricingService(space, cfg)
        svc.faults = FaultInjector("seed=1;dispatch_error:p=1.0")
        await svc.start()
        r1 = await svc.submit(PriceRequest(indices=p_idx))
        r2 = await svc.submit(MCRiskRequest(
            indices=m_idx, mc=McSpec(draws=64, quantiles=(0.5, 0.9),
                                     seed=7)))
        # the fault clears; drop the cool-down so the next tick probes
        svc.faults = FaultInjector("")
        svc.breaker.cooldown_s = 0.0
        r3 = await svc.submit(PriceRequest(indices=p_idx))
        await svc.stop()
        return svc, r1, r2, r3

    svc, r1, r2, r3 = asyncio.run(_main())
    assert r1.ok and r2.ok and r3.ok

    # r1: fully degraded, every row flagged, values == f32(oracle f64)
    assert r1.degraded and r1.degraded_rows.all()
    legacy = oracle.evaluate([space.candidate_at(i) for i in p_idx])
    for j, cr in enumerate(legacy):
        _f32_rows_equal(r1.result, j, cr)

    # r2: breaker already open -> straight to fallback (no new attempts);
    # risk stats equal f32 casts of the oracle's, despite the service
    # chunk holding different padding than the oracle's own chunk
    assert r2.degraded and r2.degraded_rows.all()
    legacy_mc = oracle.evaluate(
        [space.candidate_at(i) for i in m_idx],
        mc_key=jax.random.PRNGKey(7), mc_draws=64,
        mc_quantiles=(0.5, 0.9))
    for j, cr in enumerate(legacy_mc):
        _f32_rows_equal(r2.result, j, cr)
        for k, v in cr.risk.items():
            assert r2.result.risk[k][j] == np.float32(v), k

    # r3: recovered — fused again, bit-exact vs the direct call, and the
    # degraded r1 result was never cached
    assert not r3.degraded and not r3.cached
    direct = evaluator.evaluate_indices(np.asarray(p_idx))
    assert np.array_equal(r3.result.sku_unit_total, direct.sku_unit_total)
    assert np.array_equal(r3.result.portfolio_cost, direct.portfolio_cost)

    res = svc.snapshot()["resilience"]
    assert res["fallback_ticks"] == 2
    assert res["fallback_rows"] == len(p_idx) + len(m_idx)
    assert res["retries"] == 1             # one retry inside the r1 tick
    assert res["fused_failures"] == 2      # first attempt + its retry
    assert res["breaker_opens"] == 1
    assert res["breaker_probes"] == 1
    assert res["breaker_closes"] == 1
    assert res["breaker"]["state"] == "closed"
    assert res["loop_errors"] == 0


def test_poisoned_row_fails_owner_only(space, evaluator):
    """A NaN row injected post-fetch fails exactly its owner with a
    typed numerical_error; the co-batched sibling stays bit-exact."""
    a_idx, b_idx = [0, 1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 0, 1, 2, 3]

    async def _main():
        svc = PricingService(space, CFG)
        svc.faults = FaultInjector("seed=3;poison:p=1.0,n=1")
        await svc.start()
        ra, rb = await asyncio.gather(
            svc.submit(PriceRequest(indices=a_idx)),
            svc.submit(PriceRequest(indices=b_idx)))
        await svc.stop()
        return svc, ra, rb

    svc, ra, rb = asyncio.run(_main())
    failed = [r for r in (ra, rb) if not r.ok]
    clean = [r for r in (ra, rb) if r.ok]
    assert len(failed) == 1 and len(clean) == 1
    assert failed[0].error.code == NUMERICAL_ERROR
    assert "non-finite" in failed[0].error.message
    clean_idx = a_idx if clean[0] is ra else b_idx
    direct = evaluator.evaluate_indices(np.asarray(clean_idx))
    assert np.array_equal(clean[0].result.sku_unit_total,
                          direct.sku_unit_total)
    assert np.array_equal(clean[0].result.portfolio_cost,
                          direct.portfolio_cost)
    res = svc.snapshot()["resilience"]
    assert res["numerical_errors"] == 1
    assert res["faults_injected"] == 1
    assert svc.sched.pending_rows == 0


# ---------------------------------------------------------------------------
# Backpressure recovery under concurrent submitters
# ---------------------------------------------------------------------------


def test_backpressure_recovery_under_concurrency(space):
    """queue_full under a concurrent burst, then full drain, then every
    rejected submitter is re-admitted and served."""
    cfg = dataclasses.replace(CFG, max_pending=64)
    size = space.size()
    idx = (np.arange(32) % size).tolist()

    async def _main():
        svc = PricingService(space, cfg)
        await svc.start()
        burst = [asyncio.ensure_future(svc.submit(PriceRequest(indices=idx)))
                 for _ in range(6)]
        first = await asyncio.gather(*burst)
        retries = [await svc.submit(PriceRequest(indices=idx))
                   for _ in range(sum(not r.ok for r in first))]
        await svc.stop()
        return svc, first, retries

    svc, first, retries = asyncio.run(_main())
    rejected = [r for r in first if not r.ok]
    assert len(rejected) == 4              # 2 x 32 rows fit the 64 budget
    assert all(r.error.code == QUEUE_FULL for r in rejected)
    assert all("row budget" in r.error.message for r in rejected)
    assert all(r.ok for r in first if r.ok)
    assert len(retries) == 4 and all(r.ok for r in retries)
    assert svc.sched.pending_rows == 0
    assert svc.snapshot()["n_rejected"] == 4


# ---------------------------------------------------------------------------
# Watchdog on a live service: stall -> trip -> flight dump -> survive
# ---------------------------------------------------------------------------


def test_watchdog_trips_and_dumps_on_stalled_tick(space, tmp_path,
                                                  monkeypatch):
    monkeypatch.setenv("REPRO_FLIGHT_DIR", str(tmp_path))
    cfg = dataclasses.replace(CFG, watchdog_timeout_s=0.15)

    async def _main():
        svc = PricingService(space, cfg)
        svc.faults = FaultInjector("seed=5;stall:p=1.0,ms=500,n=1")
        await svc.start()
        r1 = await svc.submit(PriceRequest(indices=[0, 1]))
        r2 = await svc.submit(PriceRequest(indices=[2, 3]))
        await svc.stop()
        return svc, r1, r2

    svc, r1, r2 = asyncio.run(_main())
    assert r1.ok and r2.ok                 # a stall delays, never corrupts
    res = svc.snapshot()["resilience"]
    assert res["watchdog_trips"] == 1      # latched: one trip per stall
    assert res["watchdog_dumps"] == 1
    dumps = list(tmp_path.glob("flight_*.json"))
    assert len(dumps) == 1                 # exactly one recording
    assert svc.watchdog.snapshot()["trips"] == 1
    assert res["loop_errors"] == 0


# ---------------------------------------------------------------------------
# A seeded multi-fault chaos schedule: typed-or-correct, zero leakage
# ---------------------------------------------------------------------------


def test_chaos_schedule_typed_and_bit_exact_by_provenance(space, evaluator,
                                                          oracle):
    """Under a seeded schedule of dispatch errors, poisoned rows, floods
    and a forced recompile, every response is ok or carries a typed
    envelope; ok rows are bit-exact against the oracle their provenance
    mask names (fused vs legacy-f32); nothing escapes the tick loop."""
    spec_str = ("seed=13;dispatch_error:p=0.4;poison:p=0.35,n=2;"
                "flood:p=0.25,n=2;recompile:p=0.5,n=1")
    cfg = dataclasses.replace(CFG, breaker_cooldown_s=0.05,
                              result_cache_entries=0)
    rng = np.random.default_rng(7)
    batches = [rng.integers(0, space.size(), 8).tolist() for _ in range(12)]

    async def _main():
        svc = PricingService(space, cfg)
        svc.faults = FaultInjector(spec_str)
        await svc.start()
        resps = await asyncio.gather(
            *(svc.submit(PriceRequest(indices=b)) for b in batches))
        await svc.stop()
        return svc, resps

    svc, resps = asyncio.run(_main())
    res = svc.snapshot()["resilience"]
    assert res["loop_errors"] == 0
    assert res["faults_injected"] >= 1
    allowed = {QUEUE_FULL, NUMERICAL_ERROR}
    n_ok = 0
    for idx_list, r in zip(batches, resps):
        if not r.ok:
            assert r.error.code in allowed, r.error
            continue
        n_ok += 1
        idx = np.asarray(idx_list, np.int64)
        mask = (r.degraded_rows if r.degraded
                else np.zeros(idx.size, bool))
        fused = evaluator.evaluate_indices(idx)
        legacy = (oracle.evaluate_indices_legacy(idx)
                  if mask.any() else None)
        for j in range(idx.size):
            src = legacy if mask[j] else fused
            assert np.array_equal(r.result.sku_unit_total[j],
                                  src.sku_unit_total[j]), (j, mask[j])
            assert r.result.portfolio_cost[j] == src.portfolio_cost[j]
    assert n_ok >= 1                       # the service kept serving
    assert svc.sched.pending_rows == 0


# ---------------------------------------------------------------------------
# Faults disabled (the default): no overhead, no counter movement
# ---------------------------------------------------------------------------


def test_disabled_faults_leave_no_trace(space):
    reqs = [PriceRequest(indices=[0, 1, 2]),
            MCRiskRequest(indices=[3, 4], mc=McSpec(draws=64, seed=2)),
            PriceRequest(indices=[5], deadline_ms=60_000.0)]
    resps, svc = serve(space, reqs, CFG)
    assert all(r.ok for r in resps), [r.error for r in resps]
    assert not any(r.degraded for r in resps)
    assert not svc.faults                  # env-off default
    res = svc.snapshot()["resilience"]
    for key in ("retries", "fused_failures", "fallback_ticks",
                "fallback_rows", "numerical_errors", "deadline_rejected",
                "cancelled", "watchdog_trips", "watchdog_dumps",
                "loop_errors", "loop_restarts", "faults_injected",
                "breaker_opens"):
        assert res[key] == 0, key
    assert res["breaker"]["state"] == "closed"
    assert res["deadlines_active"] == 0    # met deadlines drain the gauge
    assert svc.snapshot()["recompiles_after_warmup"] == 0


# ---------------------------------------------------------------------------
# Bench guard: infrastructure failures are typed exits, not tracebacks
# ---------------------------------------------------------------------------


_SCRIPT = pathlib.Path(__file__).resolve().parents[1] / "scripts" / \
    "check_bench_regression.py"
_GOOD_ENGINE = '{"systems_per_sec": 100.0, "worst_rel": 0.0}\n'


def _run_guard(root):
    return subprocess.run(
        [sys.executable, str(_SCRIPT), "engine", "--root", str(root)],
        capture_output=True, text=True, timeout=60)


def test_bench_guard_missing_and_truncated_files(tmp_path):
    basedir = tmp_path / "benchmarks" / "baselines"
    basedir.mkdir(parents=True)
    (basedir / "BENCH_engine.json").write_text(_GOOD_ENGINE)

    p = _run_guard(tmp_path)               # current run missing
    assert p.returncode == 2, p.stdout + p.stderr
    assert "MISSING" in p.stdout
    assert "Traceback" not in p.stdout + p.stderr

    (tmp_path / "BENCH_engine.json").write_text('{"systems_per_sec": 5')
    p = _run_guard(tmp_path)               # truncated json
    assert p.returncode == 2, p.stdout + p.stderr
    assert "UNREADABLE" in p.stdout
    assert "Traceback" not in p.stdout + p.stderr

    (tmp_path / "BENCH_engine.json").write_text(_GOOD_ENGINE)
    p = _run_guard(tmp_path)               # healthy run passes
    assert p.returncode == 0, p.stdout + p.stderr

    (tmp_path / "BENCH_engine.json").write_text(
        '{"systems_per_sec": 1.0, "worst_rel": 1.0}')
    p = _run_guard(tmp_path)               # regression is exit 1, not 2
    assert p.returncode == 1, p.stdout + p.stderr
    assert "FAIL" in p.stdout
