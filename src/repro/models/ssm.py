"""Mamba2 (SSD) mixer — chunked parallel scan for train/prefill, O(1)
recurrent step for decode (the sub-quadratic path behind ``long_500k``).

Chunked SSD (Dao & Gu 2024): split the sequence into chunks of length L;
within a chunk the state-space kernel is a lower-triangular (L, L) decay
matrix (quadratic, MXU-friendly); across chunks a cheap ``lax.scan``
carries the (H, N, P) state.  B/C are group-shared (G=1), so the C·Bᵀ
inner product is computed once and reused by all heads.

The same math is implemented as a Pallas kernel in kernels/mamba_scan.py;
``ssd_chunked`` here is both the XLA execution path and the oracle.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, rmsnorm


def mamba_spec(d_model: int, *, expand: int = 2, headdim: int = 64,
               state: int = 64, conv_width: int = 4) -> Dict[str, ParamSpec]:
    d_inner = expand * d_model
    h = d_inner // headdim
    conv_dim = d_inner + 2 * state                      # x, B, C get conv'd
    return {
        "in_proj": ParamSpec((d_model, 2 * d_inner + 2 * state + h),
                             ("embed", "mlp")),
        "conv_w": ParamSpec((conv_width, conv_dim), (None, "mlp")),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "A_log": ParamSpec((h,), (None,), init="zeros"),
        "D": ParamSpec((h,), (None,), init="ones"),
        "dt_bias": ParamSpec((h,), (None,), init="zeros"),
        "norm": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "out_proj": ParamSpec((d_inner, d_model), ("mlp", "embed")),
    }


def _mamba_dims(params):
    d_model, proj = params["in_proj"].shape
    h = params["A_log"].shape[0]
    conv_dim = params["conv_w"].shape[1]
    state = 0
    # proj = 2*d_inner + 2*state + h ; conv_dim = d_inner + 2*state
    d_inner = proj - conv_dim - h
    state = (conv_dim - d_inner) // 2
    headdim = d_inner // h
    return d_inner, h, headdim, state


def causal_conv(x, w, b, init_state=None):
    """Depthwise causal conv. x:(B,S,C), w:(W,C). Returns (y, tail_state)."""
    width = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(width))
    tail = xp[:, xp.shape[1] - (width - 1):, :]
    return y + b[None, None, :], tail


def _segsum(da):
    """Lower-triangular pairwise sums: out[..., t, s] = sum_{s<r<=t} da_r."""
    l = da.shape[-1]
    cs = jnp.cumsum(da, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(xh, dt, a_log, bm, cm, *, chunk: int = 128,
                init_state=None):
    """Chunked SSD. xh:(B,S,H,P) dt:(B,S,H) bm/cm:(B,S,N) (group-shared).

    Returns (y:(B,S,H,P), final_state:(B,H,N,P)).
    """
    b, s, h, p = xh.shape
    n = bm.shape[-1]
    l = min(chunk, s)
    assert s % l == 0, f"seq {s} not divisible by chunk {l}"
    nc = s // l
    a = -jnp.exp(a_log.astype(jnp.float32))            # (H,) negative
    dt32 = dt.astype(jnp.float32)
    da = dt32 * a[None, None, :]                       # (B,S,H)

    xc = xh.astype(jnp.float32).reshape(b, nc, l, h, p)
    dtc = dt32.reshape(b, nc, l, h)
    dac = da.reshape(b, nc, l, h)
    bc = bm.astype(jnp.float32).reshape(b, nc, l, n)
    cc = cm.astype(jnp.float32).reshape(b, nc, l, n)

    # --- intra-chunk (quadratic in l, head-shared C·B^T) ---
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)         # (B,nc,L,L)
    decay = jnp.exp(_segsum(jnp.moveaxis(dac, -1, 2))) # (B,nc,H,L,L)
    scores = cb[:, :, None] * decay                    # (B,nc,H,L,L)
    y_intra = jnp.einsum("bchij,bcjh,bcjhp->bcihp", scores, dtc, xc)

    # --- chunk summaries -> inter-chunk scan ---
    cum = jnp.cumsum(dac, axis=2)                      # (B,nc,L,H)
    rem = cum[:, :, -1:, :] - cum                      # decay to chunk end
    sc = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                    bc, dtc * jnp.exp(rem), xc)        # (B,nc,H,N,P)
    total = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)

    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), jnp.float32)

    def body(state, inp):
        sc_c, tot_c = inp                              # (B,H,N,P),(B,H)
        prev = state
        state = state * tot_c[..., None, None] + sc_c
        return state, prev

    final, prevs = jax.lax.scan(
        body, init_state.astype(jnp.float32),
        (jnp.moveaxis(sc, 1, 0), jnp.moveaxis(total, 1, 0)))
    prevs = jnp.moveaxis(prevs, 0, 1)                  # (B,nc,H,N,P)

    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         cc, prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(xh.dtype), final


def ssd_step(state, xh, dt, a_log, bm, cm):
    """Recurrent single-token step. state:(B,H,N,P) xh:(B,H,P) dt:(B,H)."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    da = dt.astype(jnp.float32) * a[None, :]           # (B,H)
    decay = jnp.exp(da)[..., None, None]
    upd = jnp.einsum("bn,bh,bhp->bhnp", bm.astype(jnp.float32),
                     dt.astype(jnp.float32), xh.astype(jnp.float32))
    new_state = state * decay + upd
    y = jnp.einsum("bn,bhnp->bhp", cm.astype(jnp.float32), new_state)
    return new_state, y.astype(xh.dtype)


# ---------------------------------------------------------------------------
# Full mixer layer
# ---------------------------------------------------------------------------


def _project(params, x):
    d_inner, h, headdim, state = _mamba_dims(params)
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * state], -1)
    return z, xbc, dt, (d_inner, h, headdim, state)


def mamba_layer(params, x, *, chunk: int = 128, impl: str = "xla"):
    """Train/prefill Mamba2 mixer over a full sequence.

    Sequences not divisible by the chunk are zero-padded at the END
    (causal: pad positions cannot affect real outputs) and trimmed.
    """
    b, s0, _ = x.shape
    pad = (-s0) % min(chunk, s0) if s0 else 0
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    b, s, _ = x.shape
    z, xbc, dt, (d_inner, h, headdim, state) = _project(params, x)
    xbc, _ = causal_conv(xbc, params["conv_w"], params["conv_b"])
    xbc = jax.nn.silu(xbc)
    xh, bm, cm = jnp.split(xbc, [d_inner, d_inner + state], -1)
    xh = xh.reshape(b, s, h, headdim)
    dt = jax.nn.softplus(dt + params["dt_bias"][None, None, :])
    if impl == "pallas":
        from ..kernels import ops as kops
        y, _ = kops.mamba_scan(xh, dt, params["A_log"], bm, cm, chunk=chunk)
    else:
        y, _ = ssd_chunked(xh, dt, params["A_log"], bm, cm, chunk=chunk)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, s, d_inner)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(z))
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return out[:, :s0] if pad else out


def mamba_init_cache(params, batch: int, dtype=jnp.float32):
    d_inner, h, headdim, state = _mamba_dims(params)
    width = params["conv_w"].shape[0]
    conv_dim = params["conv_w"].shape[1]
    return {
        "conv": jnp.zeros((batch, width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, h, state, headdim), jnp.float32),
    }


def mamba_decode_layer(params, x, cache):
    """Single-token step. x:(B,1,D); cache {'conv','ssm'}."""
    b = x.shape[0]
    z, xbc, dt, (d_inner, h, headdim, state) = _project(params, x)
    xbc, conv_state = causal_conv(xbc, params["conv_w"], params["conv_b"],
                                  init_state=cache["conv"])
    xbc = jax.nn.silu(xbc)
    xh, bm, cm = jnp.split(xbc[:, 0], [d_inner, d_inner + state], -1)
    xh = xh.reshape(b, h, headdim)
    dt = jax.nn.softplus(dt[:, 0] + params["dt_bias"][None, :])
    new_ssm, y = ssd_step(cache["ssm"], xh, dt, params["A_log"], bm, cm)
    y = y + params["D"][None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, 1, d_inner)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(z))
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return out, {"conv": conv_state, "ssm": new_ssm}
