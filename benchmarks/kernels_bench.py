"""Kernel micro-bench: XLA-path wall time on CPU (the Pallas path is
interpret-only here — its perf target is the TPU; correctness is
covered by tests).  Reported to track CPU-side regressions."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from .common import emit, timed

RNG = np.random.default_rng(0)


def rnd(*s):
    return jnp.asarray(RNG.standard_normal(s), jnp.float32)


def run():
    rows = []
    q, k, v = rnd(1, 512, 8, 64), rnd(1, 512, 2, 64), rnd(1, 512, 2, 64)
    f = jax.jit(lambda q, k, v: ops.flash_attention(q, k, v, impl="xla"))
    _, us = timed(lambda: f(q, k, v).block_until_ready())
    rows.append({"name": "attention_xla_512x8x64", "us_per_call": us})

    xh, dt = rnd(1, 256, 4, 32), jnp.abs(rnd(1, 256, 4)) * 0.1
    al, bm, cm = rnd(4), rnd(1, 256, 16), rnd(1, 256, 16)
    g = jax.jit(lambda *a: ops.mamba_scan(*a, impl="xla")[0])
    _, us = timed(lambda: g(xh, dt, al, bm, cm).block_until_ready())
    rows.append({"name": "ssd_xla_256x4x32", "us_per_call": us})

    x, w = rnd(8, 128, 64), rnd(8, 64, 128)
    h = jax.jit(lambda x, w: ops.moe_gmm(x, w, impl="xla"))
    _, us = timed(lambda: h(x, w).block_until_ready())
    rows.append({"name": "gmm_xla_8x128x64x128", "us_per_call": us})

    xr, sc = rnd(1024, 512), rnd(512)
    r = jax.jit(lambda x, s: ops.fused_rmsnorm(x, s, impl="xla"))
    _, us = timed(lambda: r(xr, sc).block_until_ready())
    rows.append({"name": "rmsnorm_xla_1024x512", "us_per_call": us})
    emit("kernel_microbench_cpu", rows)
    return rows


if __name__ == "__main__":
    run()
