"""Generate EXPERIMENTS.md from results/*.json + the calibration run.

Run: PYTHONPATH=src python scripts/make_experiments.py
"""
import io
import json
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
OPT = ROOT / "results" / "dryrun_optimized.json"
BASE = ROOT / "results" / "dryrun_baseline.json"

PERF_LOG = """
## §Perf — hillclimbing log (hypothesis → change → measure → verdict)

Three cells were selected per the brief: **worst roofline fraction**
(`xlstm_125m/train_4k`, 0.0066), **most collective-bound**
(`mistral_large_123b/train_4k`, t_coll 76.6 s ≈ t_bound), and **most
representative of the paper's cost/scale axis**
(`deepseek_v2_236b/train_4k` — the 236B MoE: the largest silicon
footprint, i.e. the system the Chiplet Actuary co-design layer prices).
All terms are per-device seconds on the 16x16 pod (brief's v5e-class
constants).  The baseline table is `results/dryrun_baseline.json`
(paper-faithful framework, first-compile configuration); the optimized
table is `results/dryrun_optimized.json`.

### Iteration 0 — infrastructure fixes discovered by the first baselines
* **Hypothesis:** per-device memory should be dominated by weights+opt
  state. **Measured:** 438 GB/dev (mistral train).  Three real bugs:
  (1) remat carries batch-sharded only -> added Megatron-SP sequence
  sharding of the residual (seq@model); (2) gradient-accumulation
  microbatches multiplied the batch instead of splitting it; (3) GQA's
  (H -> Hkv x G) head reshape defeated GSPMD propagation (96@16 can't
  split (8,12)) -> repeat KV to full heads on the XLA path.
  438 -> 35.8 GB/dev, t_bound 99.7 -> 87.7 s. **Confirmed.**
* decode cells: KV caches were unsharded on sequence -> `kv_seq@model`
  rule (flash-decode layout): deepseek-7b decode 153.9 -> 28.8 GB/dev.

### Iteration 1 — mixed-precision einsum operands (bf16 in, f32 out)
* **Hypothesis:** f32-cast operands double attention traffic; keeping
  bf16 operands with `preferred_element_type=f32` halves it (napkin:
  attention operand bytes / 2).
* **Measured:** t unchanged (87.68 -> 87.68 s). **Refuted as measured**:
  the CPU dry-run backend upcasts bf16 dots to f32 regardless, so the
  change is invisible in CPU-compiled HLO (it remains correct for TPU,
  where the MXU consumes bf16 natively).  Led directly to the
  cast-artifact analysis below.
* **Lesson:** the dry-run backend materializes f32 shadow copies of
  bf16 weights/caches that a TPU would never allocate.  The analyzer
  now (a) skips pure dtype-cast fusions, (b) chases fusion operand uses
  through casts.  mistral decode 0.907 -> 0.219 s (4.1x) once the
  in-place cache scatter (`.at[b, kv_len].set`, was `jnp.where` over
  the full cache) landed with it.

### Iteration 2 — mistral_large_123b/train_4k (collective-bound)
* Collective composition: all-reduce 9.2 TB/dev (in-loop full-size grad
  partials over the seq-sharded axis), all-gather 6.3 TB/dev (FSDP
  weight gathers x3 passes x4 microbatches).
* **Grid:** `remat=dots` (skip the recompute pass) / `accum=2` /
  both / `act_shard=batch2d`.
  - remat=dots: t 77.3 -> 77.3 s, mem 37 -> 68 GB. **Refuted** (XLA
    re-gathers weights for backward regardless; memory doubles).
  - batch2d: t -> 685.6 s. **Refuted hard** — 2D-batch grad partials
    all-reduce at full size over both axes.  Valuable negative result.
  - accum=2: t 77.3 -> 57.6 s. accum=1: 54.8 s but 68 GB/dev.
    **Confirmed**: grad-sync cost scales with microbatch count;
    accum=2 balances memory (52 GB -> 26 GB on the 2-pod mesh).
* With the flash-kernel VMEM credit (see Method note): t_bound 47.5 s,
  **frac 0.174 -> 0.321** (1.85x).  Config updated: accum=2.

### Iteration 3 — xlstm_125m/train_4k (worst fraction)
* **Hypothesis:** the quadratic mLSTM D-matrix (B,H,4096,4096 f32) and
  the 4096-step sLSTM scan dominate traffic (napkin: ~10 materialized
  (B,H,S,S) buffers/layer ~ TBs).
* **Changes:** (a) NEW chunked mLSTM (flash-linear-attention dataflow:
  intra-chunk quadratic + carried (K,V) matrix memory; exact to 3e-6 vs
  the parallel form; also unlocks long-context xLSTM training);
  (b) NEW fused sLSTM Pallas kernel (states live in VMEM across the
  whole sequence; only gate inputs/hidden stream) backing the
  recurrent-state credit in the analyzer.
* **Measured (final sweep):** train_4k t 1.331 -> 0.742 s (**1.79x**,
  frac 0.0066 -> 0.0119); prefill_32k 1.153 -> 0.089 s (**12.9x**,
  frac 0.0025 -> 0.033).  **Confirmed.**  (Fraction stays low because a 125M model at
  d=768/H=4 cannot fill a 256-chip pod — heads/FFN are too small to
  shard; the right fix at fleet level is a smaller slice, which the
  cost model quantifies in $/step.)

### Iteration 4 — deepseek_v2_236b/train_4k (paper-representative)
* **Hypothesis:** MoE dispatch dominates: the sort-based dispatch
  materializes ~10 (N·k, D)-sized buffers (mask multiplies, un-permute,
  (N,k,D) combine) = ~14 TB/dev.
* **Change:** dispatch rewrite — OOB-drop/fill scatter instead of
  validity mask multiplies; weighted scatter-add straight into (N, D)
  (skips the un-permute buffer and the k-sum).
* **Measured:** train 84.3 -> 76.2 s (now collective-bound; baseline
  142.2 s); prefill_32k 82.5 -> 20.7 s.  **Confirmed.**
* accum=2 probe: 76.2 -> 74.2 s (-3%) for +70% memory. **Rejected.**
* **Iteration 4b — dispatch memory.** The (N·k, D) gather/scatter
  transients lowered REPLICATED on feature (266 GB/dev at 32k prefill
  on the 2-pod mesh).  Probe 1: token-blockwise scan — memory fixed
  (19.5 GB) but each block all-gathered the token table (t_coll 21 ->
  124 s). **Refuted.**  Probe 2: keep the monolithic dispatch but pin
  the token table + transients FEATURE-sharded (rows replicated,
  D@model -> local row gathers): train 76.2 -> **70.9 s**
  (frac 0.037), prefill 17.3 s (frac 0.050), memory 148 -> 35 GB/dev
  single-pod. **Confirmed** — final: baseline 142.2 -> 70.9 s
  (**2.0x**) train, 82.5 -> 17.3 s (**4.8x**) prefill.

### Iteration 5 (bonus, beyond the required three) — zamba2_7b + long_500k
* **Hypothesis:** SSD decay-tile traffic scales with S·L (nc x L² per
  pass) -> halving ssm_chunk 128->64 halves the dominant memory term;
  accum=2 halves activation residency.
* **Measured:** train_4k 10.31 -> 6.92 s (**1.49x**, frac 0.0828 ->
  0.123); prefill_32k 2.43 -> 1.55 s (frac 0.184).  **Confirmed**
  (config updated; note: L=64 gives 25% MXU tile utilization on the
  intra-chunk matmul — acceptable while the cell sits 10x from its
  compute roof).
* **long_500k (batch=1):** the data axis idles when batch can't shard
  -> new rule `kv -> data` (per-tensor divisibility fallback keeps
  every batch>1 cell unchanged, verified by re-runs): the 500k KV
  cache shards 256-way (kv_seq@model x kv@data): zamba long_500k
  23.0 -> **1.63 GB/dev**, t 0.149 -> 0.0114 s (**13x**).

### Method note — the two VMEM credits (beyond-paper, documented)
The dry-run lowers the XLA fallback path (Pallas-TPU cannot lower on
CPU).  That path must materialize (a) flash attention/SSD score tiles
and (b) recurrent cell states to HBM; the shipped Pallas kernels hold
both in VMEM on the target.  The analyzer therefore reports BOTH
`t_memory_xla_path` and the kernel-path `t_memory` (hbm_bytes minus
score-tile and recurrent-state traffic).  §Roofline uses the kernel
path; every credit is backed by a tested kernel
(flash_attention/flash_decode/mamba_scan/slstm_cell, allclose vs
oracles in tests/test_kernels.py).

### Net effect (all 32 runnable single-pod cells, final framework)
Geomean t_bound speedup **3.99x** vs the paper-faithful baseline
snapshot; largest wins on prefill (10-13x: score tiles + MoE dispatch
+ cast artifacts) and decode (up to 24x: in-place cache scatter +
kv_seq sharding); best absolute fractions: mistral prefill 0.63
(compute-bound — at the roofline knee), dense train 0.26-0.32
(collective-bound at FSDP's inherent gather/reduce cost for
123B x 1M tokens on 256 chips).

### Stopping criterion
Last three accepted changes on the dominant terms gained 1.87x / 1.76x
/ 1.85x; the follow-up probes (accum sweeps on dsv2, remat=dots,
batch2d) all gained <5% or regressed — per the brief's rule
(three consecutive <5% changes) the loop was stopped at the grid above
for the three chosen cells; remaining cells report baselines (now
measured under the final framework, see table).
"""


def fmt_rows(rows, cols, header=True):
    out = []
    if header:
        out.append("| " + " | ".join(cols) + " |")
        out.append("|" + "---|" * len(cols))
    for r in rows:
        out.append("| " + " | ".join(
            f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
            for c in cols) + " |")
    return "\n".join(out)


def main():
    sys.path.insert(0, str(ROOT / "src"))
    sys.path.insert(0, str(ROOT))
    from benchmarks.roofline import rows_from

    opt = json.loads(OPT.read_text()) if OPT.exists() else {}
    base = json.loads(BASE.read_text()) if BASE.exists() else {}

    cal = subprocess.run(
        [sys.executable, str(ROOT / "scripts" / "calibrate.py")],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"})
    cal_txt = cal.stdout.strip()

    buf = io.StringIO()
    w = buf.write
    w("# EXPERIMENTS — Chiplet Actuary reproduction + multi-pod framework\n\n")
    w("All numbers regenerate with:\n```\n")
    w("PYTHONPATH=src python scripts/calibrate.py\n")
    w("PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both "
      "--out results/dryrun_optimized.json\n")
    w("PYTHONPATH=src python -m benchmarks.run\n")
    w("PYTHONPATH=src python scripts/make_experiments.py\n```\n\n")

    w("## §Paper validation — the model reproduces the paper's claims\n\n")
    w("Every quantitative claim in Secs. 4–5 of the paper, checked "
      "against the model (also asserted in tests/test_paper_claims.py):\n\n")
    w("```\n" + cal_txt + "\n```\n\n")
    w("Note (flagged, not hidden): the paper's “6 chiplets / 4 sockets "
      "→ 119 systems” contradicts its own formula Σᵢ₌₁ᵏ C(n+i−1,i): "
      "f(6,4)=209; 119 corresponds to f(7,3). We implement the "
      "formula.\n\n")

    # ---- dry run ----
    w("## §Dry-run — 10 archs × 4 shapes × {16×16, 2×16×16}\n\n")
    n_ok = sum(1 for k, v in opt.items()
               if v["status"] == "ok" and len(k.split("|")) == 3)
    n_skip = sum(1 for k, v in opt.items()
                 if v["status"] == "skip" and len(k.split("|")) == 3)
    n_fail = sum(1 for k, v in opt.items()
                 if v["status"] == "fail" and len(k.split("|")) == 3)
    w(f"`lower().compile()` succeeded for **{n_ok} cells** "
      f"({n_skip} documented skips: `long_500k` on the 8 pure "
      f"full-attention archs × 2 meshes; {n_fail} failures) — every "
      "supported (arch × shape) on BOTH meshes. Per-cell "
      "memory_analysis / cost_analysis / collective schedules: "
      "`results/dryrun_optimized.json`.\n\n")
    rows = rows_from(opt, "2x16x16")
    w("Two-pod (2×16×16 = 512 chips) memory proof (GB/device, "
      "`memory_analysis()`):\n\n")
    small = [{"arch": r["arch"], "shape": r["shape"],
              "GB_per_dev": r["mem_gb_per_dev"], "bound": r["bound"]}
             for r in rows]
    w(fmt_rows(small, ["arch", "shape", "GB_per_dev", "bound"]) + "\n\n")
    over = [r for r in small if r["GB_per_dev"] > 16]
    w(f"**Memory-fit note:** {len(small)-len(over)}/{len(small)} runnable "
      "two-pod cells fit the 16 GB/chip HBM budget outright. The "
      f"{len(over)} over-budget cells are the largest train/prefill "
      "workloads (236B MoE, 123B dense, 7B-hybrid at batch 256·4k); "
      "their floor is parameter+optimizer state and remat carries — the "
      "deployment answer is a 4-pod slice (state halves again) and/or "
      "smaller per-pod batch, exactly the capacity-vs-cost trade the "
      "codesign layer prices ($/step scales with fleet size; see "
      "benchmarks/codesign.py).\n\n")

    # ---- roofline ----
    w("## §Roofline — three terms per (arch × shape), single pod 16×16\n\n")
    w("compute = FLOPs/(chips·197TF); memory = HBM bytes/(chips·819GB/s) "
      "(Pallas-kernel path; the XLA-path number is kept in the JSON); "
      "collective = bytes/(chips·4·50GB/s). `frac` = MODEL_FLOPS /"
      "(chips·peak·t_bound) — the MFU-style score. `useful` = "
      "MODEL_FLOPS / HLO FLOPs (remat/redundancy waste).\n\n")
    rows = rows_from(opt, "16x16")
    w(fmt_rows(rows, ["arch", "shape", "bound", "t_compute_s",
                      "t_memory_s", "t_collective_s", "t_bound_s",
                      "useful_ratio", "roofline_frac"]) + "\n\n")
    w("Reading the bottlenecks: train cells are compute/memory-mixed "
      "with collective pressure from FSDP gathers + grad reduction; "
      "decode cells are inherently memory-bound (weights+KV per token); "
      "the per-cell `one sentence on what would move the dominant "
      "term` lives in the §Perf log and DESIGN.md §8.\n\n")

    # ---- before/after ----
    if base:
        w("## §Perf — baseline vs optimized (single-pod t_bound)\n\n")
        base_rows = {(r["arch"], r["shape"]): r
                     for r in rows_from(base, "16x16")}
        comp = []
        for r in rows_from(opt, "16x16"):
            b = base_rows.get((r["arch"], r["shape"]))
            if not b or r["bound"] == "SKIP" or b["t_bound_s"] <= 0:
                continue
            comp.append({
                "arch": r["arch"], "shape": r["shape"],
                "baseline_t_s": b["t_bound_s"],
                "optimized_t_s": r["t_bound_s"],
                "speedup_x": b["t_bound_s"] / max(r["t_bound_s"], 1e-12),
                "frac_before": b["roofline_frac"],
                "frac_after": r["roofline_frac"],
            })
        comp.sort(key=lambda r: -r["speedup_x"])
        w(fmt_rows(comp, ["arch", "shape", "baseline_t_s",
                          "optimized_t_s", "speedup_x", "frac_before",
                          "frac_after"]) + "\n")
    w(PERF_LOG)

    (ROOT / "EXPERIMENTS.md").write_text(buf.getvalue())
    print(f"wrote EXPERIMENTS.md ({len(buf.getvalue())} bytes)")


if __name__ == "__main__":
    main()
