"""Crash-safe serving: the durable admission journal (WAL roundtrip,
torn-tail tolerance, fsync batching, segment rotation + GC), bit-exact
search checkpoint/resume (dse-level and through the service, zero
tolerance vs the uninterrupted ``portfolio_search`` oracle), checkpoint
store hardening (corrupt-step fallback, kill-mid-write atomicity,
retention-K), the injected ``crash`` fault -> journal replay recovery
(no admitted request silently lost), and bounded-drain ``stop()``
semantics with typed ``shutting_down`` envelopes."""
import asyncio
import json

import jax
import numpy as np
import pytest

from repro.checkpoint.store import CheckpointManager, latest_step
from repro.dse import (DesignSpace, RiskConfig, SKU, SearchState,
                       Uncertainty, portfolio_search)
from repro.resilience import FAULT_KINDS, FaultInjector
from repro.service import (DurabilityConfig, McSpec, MCRiskRequest,
                           PriceRequest, PriceSystemsRequest,
                           PricingService, RankRequest, RequestJournal,
                           SHUTTING_DOWN, SearchRequest, ServiceConfig,
                           WhatIfRequest, request_from_wire,
                           request_to_wire)
from repro.service.durability import JournalEntry  # noqa: F401 (export)


def _space(**kw):
    d = dict(skus=(SKU("laptop", 200.0, 2e6), SKU("server", 400.0, 5e5)),
             processes=("7nm", "12nm"), integrations=("MCM",),
             chiplet_counts=(1, 2, 4), allow_reuse=True)
    d.update(kw)
    return DesignSpace(**d)


@pytest.fixture(scope="module")
def space():
    return _space()


@pytest.fixture(autouse=True)
def _no_env_faults(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)


def _cfg(tmp_path, **kw):
    dcfg = DurabilityConfig(directory=tmp_path / "dur", checkpoint_every=1,
                            **{k: kw.pop(k) for k in
                               ("fsync_every", "segment_max_records")
                               if k in kw})
    return ServiceConfig(chunk=16, split=4, durability=dcfg, **kw)


# ---------------------------------------------------------------------------
# Wire codec: every request kind roundtrips
# ---------------------------------------------------------------------------


WIRE_CASES = [
    PriceRequest(indices=[3, 1, 7],
                 mc=McSpec(draws=32, quantiles=(0.5,), seed=9,
                           sigmas=Uncertainty(defect_sigma=0.1,
                                              wafer_cost_sigma=0.2,
                                              bond_sigma=0.3,
                                              interposer_sigma=0.4))),
    RankRequest(indices=None, top_k=5, objective="cost"),
    MCRiskRequest(indices=[2, 4], mc=McSpec(draws=16), deadline_ms=500.0),
    WhatIfRequest(base=3, processes=("7nm",), integrations=("MCM",)),
    SearchRequest(seed=11, population=8, generations=4, elite=2,
                  risk=RiskConfig(n_draws=16, quantile=0.8)),
    PriceSystemsRequest(specs=({"kind": "soc", "name": "a", "area": 100.0,
                                "process": "7nm", "quantity": 1.0},)),
]


@pytest.mark.parametrize("req", WIRE_CASES,
                         ids=[r.kind for r in WIRE_CASES])
def test_wire_roundtrip(req):
    d = request_to_wire(req)
    assert json.loads(json.dumps(d)) == d          # JSON-safe
    back = request_from_wire(d)
    assert back.kind == req.kind
    assert request_to_wire(back) == d              # stable fixpoint


def test_wire_resolves_candidates_to_indices(space):
    cand = space.candidate_at(5)
    req = PriceRequest(candidates=(cand,))
    d = request_to_wire(req, space)
    assert d["indices"] == [5]
    assert request_from_wire(d).indices == [5]


# ---------------------------------------------------------------------------
# RequestJournal: WAL semantics
# ---------------------------------------------------------------------------


def _wire(i=0):
    return request_to_wire(PriceRequest(indices=[i]))


def test_journal_replay_roundtrip(tmp_path):
    j = RequestJournal(tmp_path)
    j.admit(1, _wire(1))
    j.admit(2, _wire(2))
    j.done(1, "ok")
    j.close()
    j2 = RequestJournal(tmp_path)
    entries = j2.replay()
    assert [e.uid for e in entries] == [2]
    assert entries[0].origin == 2
    assert entries[0].request.indices == [2]
    assert j2.max_uid == 2
    j2.close()


def test_journal_replay_preserves_origin_across_chains(tmp_path):
    j = RequestJournal(tmp_path)
    j.admit(1, _wire())
    j.admit(5, _wire(), origin=1)   # replay of 1 under uid 5
    j.done(1, "replayed")
    j.close()
    j2 = RequestJournal(tmp_path)
    entries = j2.replay()
    assert [(e.uid, e.origin) for e in entries] == [(5, 1)]
    j2.close()


def test_journal_torn_tail_ignored(tmp_path):
    j = RequestJournal(tmp_path)
    j.admit(1, _wire(1))
    j.admit(2, _wire(2))
    j.close()
    # crash mid-write: the last record is half a line
    seg = sorted(tmp_path.glob("journal_*.log"))[-1]
    text = seg.read_text()
    seg.write_text(text[:-20])
    j2 = RequestJournal(tmp_path)
    assert j2.torn_records == 1
    assert [e.uid for e in j2.replay()] == [1]     # uid 2's record was torn
    j2.close()


def test_journal_fsync_batching(tmp_path):
    j = RequestJournal(tmp_path, fsync_every=4)
    for i in range(1, 9):
        j.admit(i, _wire(i))
    assert j.appends == 8
    assert j.fsyncs == 2                           # batches of 4
    j.sync()
    assert j.fsyncs == 2                           # nothing pending
    j.close()


def test_journal_rotation_and_gc(tmp_path):
    # tiny segments: every 2 records rotate; terminal-only segments drop
    j = RequestJournal(tmp_path, segment_max_records=2)
    for i in range(1, 7):
        j.admit(i, _wire(i))
        j.done(i, "ok")
    assert j.rotations >= 4
    assert j.open_count == 0
    # steady state: GC dropped fully-terminal closed segments
    assert len(list(tmp_path.glob("journal_*.log"))) <= 2
    j.close()
    j2 = RequestJournal(tmp_path)
    assert j2.replay() == []
    assert j2.max_uid <= 6
    j2.close()


def test_journal_open_admit_survives_rotation_gc(tmp_path):
    """The open admit is carried forward on every rotation, so GC of
    its original segment never loses it — and its done record (written
    long after the admit's segment rotated away) terminates it for
    good."""
    j = RequestJournal(tmp_path, segment_max_records=2)
    j.admit(1, _wire(1))                           # stays open throughout
    for i in range(2, 8):
        j.admit(i, _wire(i))
        j.done(i, "ok")
    j.close()
    j2 = RequestJournal(tmp_path)
    assert [(e.uid, e.origin) for e in j2.replay()] == [(1, 1)]
    j2.done(1, "ok")
    j2.close()
    j3 = RequestJournal(tmp_path)
    assert j3.replay() == []
    j3.close()


def test_journal_stats_hook(tmp_path):
    seen = {}
    j = RequestJournal(tmp_path, fsync_every=1,
                       stats_hook=lambda k, n: seen.__setitem__(
                           k, seen.get(k, 0) + n))
    j.admit(1, _wire())
    j.done(1, "ok")
    j.close()
    assert seen["journal_appends"] >= 2
    assert seen["journal_fsyncs"] >= 2


# ---------------------------------------------------------------------------
# Checkpoint store hardening (satellites: corrupt fallback, kill-mid-write,
# retention-K)
# ---------------------------------------------------------------------------


def _tree(x):
    return {"a": np.full((4,), x, np.float32)}


def test_restore_latest_falls_back_on_corrupt_step(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    m.save(1, _tree(1.0))
    m.save(2, _tree(2.0))
    # bit-rot step 2's arrays: digest check must reject it
    npz = tmp_path / "step_00000002" / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:-7] + b"garbage")
    step, tree = m.restore_latest(_tree(0.0))
    assert step == 1
    assert m.corrupt_fallbacks == 1
    np.testing.assert_array_equal(tree["a"], _tree(1.0)["a"])


def test_restore_latest_raises_when_all_corrupt(tmp_path):
    m = CheckpointManager(tmp_path, keep=3)
    m.save(1, _tree(1.0))
    (tmp_path / "step_00000001" / "arrays.npz").write_bytes(b"junk")
    with pytest.raises(ValueError, match="no readable checkpoint"):
        m.restore_latest(_tree(0.0))
    assert m.corrupt_fallbacks == 1


def test_restore_latest_empty_dir(tmp_path):
    m = CheckpointManager(tmp_path / "nothing", keep=3)
    assert m.restore_latest(_tree(0.0)) == (None, None)


def test_kill_mid_write_atomicity(tmp_path):
    """Crash between arrays.npz write and the atomic rename: the .tmp
    dir is invisible to latest_step()/steps() and resume uses the prior
    published step."""
    m = CheckpointManager(tmp_path, keep=3)
    m.save(1, _tree(1.0))
    # simulate the torn step-2 write: tmp dir with arrays but no rename
    tmp = tmp_path / "step_00000002.tmp-deadbeef"
    tmp.mkdir()
    np.savez(tmp / "arrays.npz", a0=_tree(2.0)["a"])
    (tmp / "manifest.json").write_text("{}")
    assert latest_step(tmp_path) == 1
    assert m.steps() == [1]
    step, tree = m.restore_latest(_tree(0.0))
    assert step == 1
    np.testing.assert_array_equal(tree["a"], _tree(1.0)["a"])
    # the next save sweeps the orphan
    m.save(3, _tree(3.0))
    assert not any(".tmp-" in p.name for p in tmp_path.iterdir())


def test_retention_k_eviction(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    for s in range(1, 6):
        m.save(s, _tree(float(s)))
    assert m.steps() == [4, 5]
    step, tree = m.restore_latest(_tree(0.0))
    assert step == 5


# ---------------------------------------------------------------------------
# SearchState + portfolio_search checkpoint/resume: bit-exact (zero
# tolerance) vs the uninterrupted oracle
# ---------------------------------------------------------------------------


def _exact_result_equal(a, b):
    assert a.history == b.history                  # float-exact dicts
    assert a.n_evaluated == b.n_evaluated
    assert a.objective_key == b.objective_key
    assert [r.label for r in a.ranked] == [r.label for r in b.ranked]
    assert [r.objective(a.objective_key) for r in a.ranked] == \
           [r.objective(b.objective_key) for r in b.ranked]
    assert a.pareto == b.pareto


@pytest.mark.parametrize("risk", [None, RiskConfig(n_draws=16,
                                                   quantile=0.9)],
                         ids=["nominal", "risk"])
def test_portfolio_search_resume_bitexact(space, tmp_path, risk):
    key = jax.random.PRNGKey(7)
    kw = dict(population=8, generations=6, elite=3, risk=risk)
    oracle = portfolio_search(space, key, **kw)
    # interrupted run: stops after 3 generations, checkpointing each
    portfolio_search(space, key, **{**kw, "generations": 3},
                     checkpoint_dir=tmp_path, checkpoint_every=1)
    assert CheckpointManager(tmp_path).steps() != []
    # resume to the full budget: must be bit-exact vs the oracle
    resumed = portfolio_search(space, key, **kw, checkpoint_dir=tmp_path,
                               checkpoint_every=1, resume=True)
    _exact_result_equal(resumed, oracle)


def test_portfolio_search_resume_from_every_generation(space, tmp_path):
    """Zero tolerance at EVERY interruption point, not just one."""
    key = jax.random.PRNGKey(3)
    kw = dict(population=8, generations=5, elite=3)
    oracle = portfolio_search(space, key, **kw)
    for stop_at in (2, 3, 4):
        d = tmp_path / f"stop{stop_at}"
        portfolio_search(space, key, **{**kw, "generations": stop_at},
                         checkpoint_dir=d, checkpoint_every=1)
        assert CheckpointManager(d).steps() != []
        resumed = portfolio_search(space, key, **kw, checkpoint_dir=d,
                                   resume=True)
        _exact_result_equal(resumed, oracle)


def test_search_state_roundtrips_through_manager(space, tmp_path):
    st = SearchState.init(jax.random.PRNGKey(0), 8, space.size(), None)
    st.seen.update([1, 2, 3])
    st.history.append({"generation": 0, "evaluated": 3,
                       "best_objective": 1.5, "best_label": "x",
                       "gen_best": 1.5})
    st.best_obj, st.best_idx, st.gen = 1.5, 2, 1
    m = CheckpointManager(tmp_path, keep=2)
    st.save(m)
    back = SearchState.restore_latest(m, 8)
    assert back.gen == 1 and back.seen == {1, 2, 3}
    assert back.history == st.history
    assert back.best_obj == 1.5 and back.best_idx == 2
    np.testing.assert_array_equal(np.asarray(back.pop), np.asarray(st.pop))
    np.testing.assert_array_equal(np.asarray(back.k_loop),
                                  np.asarray(st.k_loop))


def test_checkpoint_every_skips_final_generation(space, tmp_path):
    portfolio_search(space, jax.random.PRNGKey(1), population=8,
                     generations=4, elite=3, checkpoint_dir=tmp_path,
                     checkpoint_every=2)
    assert CheckpointManager(tmp_path).steps() == [2]   # not gen 4


# ---------------------------------------------------------------------------
# Service: crash fault -> journal replay -> bit-exact recovery
# ---------------------------------------------------------------------------


def test_crash_is_a_fault_kind():
    assert "crash" in FAULT_KINDS
    inj = FaultInjector("seed=1;crash:p=1.0,n=1")
    assert inj.fire("crash") is not None
    assert inj.fire("crash") is None               # n=1 cap


def test_service_crash_replay_search_bitexact(space, tmp_path):
    """The acceptance oracle: a search killed mid-run by the injected
    crash fault, resumed from journal + checkpoint, returns results
    bit-exact vs the uninterrupted portfolio_search call — and the
    journaled request is answered, not lost."""
    async def main():
        svc = PricingService(space, _cfg(tmp_path))
        await svc.start()
        # seed=1 p=0.3: first crash fire is check 6 (deterministic), so
        # several generations (and checkpoints) land first.
        svc.faults = FaultInjector("seed=1;crash:p=0.3,n=1")
        resp = await svc.submit(SearchRequest(seed=3, population=8,
                                              generations=10, elite=3))
        assert not resp.ok and resp.error.code == SHUTTING_DOWN
        assert svc.snapshot()["durability"]["crashes"] == 1
        await svc.stop()
        # restart: journal rescanned from disk, open work replayed
        svc.faults = FaultInjector("")
        await svc.start()
        replayed = await svc.drain_replayed()
        await svc.stop()
        assert len(replayed) == 1
        rr = replayed[0]
        assert rr.ok and rr.replayed and rr.replayed_from is not None
        snap = svc.snapshot()["durability"]
        assert snap["journal_replayed"] == 1
        assert snap["checkpoints_restored"] == 1
        assert snap["checkpoints_removed"] >= 1    # cleaned after finish
        return rr

    rr = asyncio.run(main())
    oracle = portfolio_search(space, jax.random.PRNGKey(3), population=8,
                              generations=10, elite=3)
    _exact_result_equal(rr.result, oracle)


def test_service_crash_no_admitted_request_lost(space, tmp_path):
    """Every journaled request is answered or typed-rejected across the
    crash: nothing silently disappears."""
    async def main():
        svc = PricingService(space, _cfg(tmp_path))
        await svc.start()
        ok_resp = await svc.submit(PriceRequest(indices=[1, 5, 9]))
        assert ok_resp.ok
        # crash before the pending requests can be served
        svc.faults = FaultInjector("seed=1;crash:p=1.0,n=1")
        pending = [
            svc.submit(PriceRequest(indices=[2, 6])),
            svc.submit(RankRequest(indices=[0, 1, 2, 3], top_k=2)),
        ]
        crashed = await asyncio.gather(*pending)
        for r in crashed:
            assert not r.ok and r.error.code == SHUTTING_DOWN
        # while crashed, new submissions get typed shutting_down
        r = await svc.submit(PriceRequest(indices=[0]))
        assert not r.ok and r.error.code == SHUTTING_DOWN
        await svc.stop()
        svc.faults = FaultInjector("")
        await svc.start()
        replayed = await svc.drain_replayed()
        await svc.stop()
        # both journaled-but-unserved requests came back, answered ok
        assert sorted(r.kind for r in replayed) == ["price", "rank"]
        for r in replayed:
            assert r.ok and r.replayed
        # and the journal is fully terminal: a third start replays nothing
        j = RequestJournal(svc.dcfg.journal_dir)
        assert j.replay() == []
        j.close()
        return replayed

    replayed = asyncio.run(main())
    price = next(r for r in replayed if r.kind == "price")
    assert price.result.idx.tolist() == [2, 6]


def test_replay_parity_price_request(space, tmp_path):
    """A replayed price request prices bit-exactly what the original
    would have (same indices through the same fused kernels)."""
    async def main():
        svc = PricingService(space, _cfg(tmp_path))
        await svc.start()
        direct = await svc.submit(PriceRequest(indices=[4, 8]))
        svc.faults = FaultInjector("seed=1;crash:p=1.0,n=1")
        r = await svc.submit(PriceRequest(indices=[3, 7, 11]))
        assert not r.ok
        await svc.stop()
        svc.faults = FaultInjector("")
        await svc.start()
        (rr,) = await svc.drain_replayed()
        oracle = await svc.submit(PriceRequest(indices=[3, 7, 11]))
        await svc.stop()
        assert rr.ok and rr.replayed
        np.testing.assert_array_equal(rr.result.portfolio_cost,
                                      oracle.result.portfolio_cost)
        assert direct.ok
    asyncio.run(main())


def test_uid_continuity_across_restart(space, tmp_path):
    """New admissions after a restart never collide with journaled
    uids (max_uid carries the watermark)."""
    async def main():
        svc = PricingService(space, _cfg(tmp_path))
        await svc.start()
        svc.faults = FaultInjector("seed=1;crash:p=1.0,n=1")
        r = await svc.submit(PriceRequest(indices=[1]))
        crashed_uid = r.request_id
        await svc.stop()
        # a FRESH service over the same directory (new process shape)
        svc2 = PricingService(space, _cfg(tmp_path))
        await svc2.start()
        replayed = await svc2.drain_replayed()
        fresh = await svc2.submit(PriceRequest(indices=[2]))
        await svc2.stop()
        assert replayed[0].ok
        assert fresh.request_id > crashed_uid
        assert replayed[0].request_id > crashed_uid
    asyncio.run(main())


def test_durability_counters_mirrored_to_registry(space, tmp_path):
    from repro.obs.registry import REGISTRY
    async def main():
        svc = PricingService(space, _cfg(tmp_path))
        await svc.start()
        before = REGISTRY.counter("service_journal_appends").get()
        await svc.submit(PriceRequest(indices=[1]))
        await svc.stop()
        snap = svc.snapshot()["durability"]
        assert snap["journal_appends"] >= 2        # admit + done
        assert REGISTRY.counter("service_journal_appends").get() > before
        assert snap["enabled"] and snap["journal"] is None  # closed
    asyncio.run(main())


def test_no_durability_config_means_no_journal(space, tmp_path):
    async def main():
        svc = PricingService(space, ServiceConfig(chunk=16, split=4))
        await svc.start()
        r = await svc.submit(PriceRequest(indices=[1]))
        await svc.stop()
        assert r.ok and not r.replayed
        snap = svc.snapshot()["durability"]
        assert not snap["enabled"] and snap["journal_appends"] == 0
        assert not (tmp_path / "dur").exists()
    asyncio.run(main())


# ---------------------------------------------------------------------------
# Bounded drain
# ---------------------------------------------------------------------------


def test_drain_timeout_rejects_unfinished_with_typed_envelope(space,
                                                              tmp_path):
    """stop(drain_timeout_s=0): in-flight work is checkpointed and gets
    a typed shutting_down envelope instead of blocking stop forever."""
    async def main():
        svc = PricingService(space, _cfg(tmp_path))
        await svc.start()
        # a long search that cannot finish instantly
        fut = asyncio.ensure_future(svc.submit(SearchRequest(
            seed=5, population=8, generations=2000, elite=3)))
        # let a couple of generations run
        for _ in range(20):
            await asyncio.sleep(0.01)
            if svc.snapshot()["gen_ticks"] >= 2:
                break
        await svc.stop(drain_timeout_s=0.05)
        resp = await fut
        assert not resp.ok and resp.error.code == SHUTTING_DOWN
        snap = svc.snapshot()["durability"]
        assert snap["drain_calls"] == 1
        assert snap["drain_timeouts"] == 1
        assert snap["drain_rejected"] == 1
        assert snap["drain_checkpointed"] == 1
        # the drained search left a checkpoint for the operator
        origin = resp.request_id
        assert svc.dcfg.checkpoint_dir(origin).exists()
        # drain rejection is terminal in the journal: no replay
        j = RequestJournal(svc.dcfg.journal_dir)
        assert j.replay() == []
        j.close()
    asyncio.run(main())


def test_stop_default_drains_unbounded(space):
    """Default stop() preserves the original semantics: every admitted
    request finishes ok."""
    async def main():
        svc = PricingService(space, ServiceConfig(chunk=16, split=4))
        await svc.start()
        fut = asyncio.ensure_future(svc.submit(SearchRequest(
            seed=5, population=8, generations=4, elite=3)))
        await asyncio.sleep(0)
        await svc.stop()
        resp = await fut
        assert resp.ok
    asyncio.run(main())


def test_submit_after_stop_rejected_shutting_down(space):
    async def main():
        svc = PricingService(space, ServiceConfig(chunk=16, split=4))
        await svc.start()
        await svc.stop()
        r = await svc.submit(PriceRequest(indices=[1]))
        assert not r.ok and r.error.code == SHUTTING_DOWN
    asyncio.run(main())


def test_drain_timeout_config_default(space, tmp_path):
    """ServiceConfig.drain_timeout_s is the stop() fallback."""
    async def main():
        svc = PricingService(space, _cfg(tmp_path, drain_timeout_s=0.05))
        await svc.start()
        fut = asyncio.ensure_future(svc.submit(SearchRequest(
            seed=5, population=8, generations=2000, elite=3)))
        for _ in range(20):
            await asyncio.sleep(0.01)
            if svc.snapshot()["gen_ticks"] >= 1:
                break
        await svc.stop()                           # no arg: cfg default
        resp = await fut
        assert not resp.ok and resp.error.code == SHUTTING_DOWN
        assert svc.snapshot()["durability"]["drain_timeouts"] == 1
    asyncio.run(main())
