"""Portfolio optimizer: evolutionary search over the candidate space.

Answers "what is the cheapest multi-chiplet architecture for this SKU
portfolio at these volumes?" — optionally under parameter uncertainty,
where the objective becomes a high quantile of the Monte Carlo portfolio
cost and the result carries a cost-vs-risk Pareto front.

The loop is a (mu + lambda) evolutionary search with elitism: sample a
population, price it through the :class:`~repro.dse.evaluate.ChunkedEvaluator`
(every generation reuses the same compiled chunk trace), keep the elite,
refill by crossover + mutation, repeat.  All randomness flows from one
explicit ``jax.random`` PRNG key, so the same key always returns the
same winner (pinned by ``tests/test_dse.py``); already-priced candidates
are cached and never re-evaluated.

For brute-forceable spaces, :func:`exhaustive_search` enumerates — the
cross-check that the evolutionary loop recovers the true optimum.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core.explorer import pareto_front
from .evaluate import CandidateResult, ChunkedEvaluator
from .space import Candidate, DesignSpace
from .uncertainty import Uncertainty


@dataclasses.dataclass(frozen=True)
class RiskConfig:
    """Turns the search uncertainty-aware: optimize a cost quantile."""

    n_draws: int = 128
    sigmas: Uncertainty = dataclasses.field(default_factory=Uncertainty)
    quantile: float = 0.9

    @property
    def objective_key(self) -> str:
        return f"q{int(round(self.quantile * 100))}"


@dataclasses.dataclass
class SearchResult:
    best: CandidateResult
    ranked: List[CandidateResult]      # every priced candidate, best first
    pareto: List[Dict]                 # cost-vs-risk front (risk runs only)
    history: List[Dict]                # per-generation progress
    n_evaluated: int                   # distinct candidates priced
    objective_key: str = "cost"

    def top(self, k: int = 10) -> List[CandidateResult]:
        return self.ranked[:k]


def _rank(results: Sequence[CandidateResult], key: str
          ) -> List[CandidateResult]:
    # label is the deterministic tie-breaker: equal-cost candidates
    # always rank in the same order regardless of arrival order.
    return sorted(results, key=lambda r: (r.objective(key), r.label))


def _front(results: Sequence[CandidateResult], key: str) -> List[Dict]:
    if key == "cost":
        return []
    pts = [{"label": r.label, "mean": r.risk["mean"], key: r.risk[key],
            "candidate": r.candidate} for r in results if r.risk]
    return pareto_front(pts, "mean", key)


def _rng_from_key(key) -> np.random.Generator:
    """Derive host-side randomness deterministically from a jax PRNG key."""
    seed = int(jax.device_get(
        jax.random.randint(key, (), 0, np.iinfo(np.int32).max)))
    return np.random.default_rng(seed)


def _check_evaluator(space: DesignSpace, flow: str,
                     ev: ChunkedEvaluator) -> ChunkedEvaluator:
    """A passed-in evaluator must agree with the search's space/flow —
    it binds both, and a mismatch would silently price the wrong
    portfolio."""
    if ev.space != space:
        raise ValueError("evaluator was built for a different DesignSpace")
    if ev.flow != flow:
        raise ValueError(
            f"evaluator flow {ev.flow!r} != requested flow {flow!r}")
    return ev


def _mc_kwargs(risk: RiskConfig, mc_key) -> Dict:
    return dict(mc_key=mc_key, mc_draws=risk.n_draws, mc_sigmas=risk.sigmas,
                mc_quantiles=(0.5, risk.quantile))


def _default_mc_key(key):
    """The one shared derivation of the Monte Carlo key from a search key:
    exhaustive and evolutionary runs given the same ``key`` price every
    candidate under identical scenarios, so their quantile objectives are
    directly comparable (common random numbers)."""
    return jax.random.fold_in(key, 1)


def exhaustive_search(space: DesignSpace,
                      evaluator: Optional[ChunkedEvaluator] = None,
                      flow: str = "chip-last",
                      risk: Optional[RiskConfig] = None,
                      mc_key=None, key=None) -> SearchResult:
    """Price every candidate in the space (small spaces only).

    In risk mode the Monte Carlo scenarios come from ``mc_key`` (or are
    derived from ``key`` exactly as :func:`portfolio_search` does, so
    passing the same ``key`` to both makes their quantile objectives
    comparable).
    """
    ev = _check_evaluator(space, flow, evaluator) if evaluator \
        else ChunkedEvaluator(space, flow=flow)
    kw = {}
    obj = "cost"
    if risk is not None:
        if mc_key is None:
            mc_key = _default_mc_key(key if key is not None
                                     else jax.random.PRNGKey(0))
        kw = _mc_kwargs(risk, mc_key)
        obj = risk.objective_key
    results = ev.evaluate(list(space.enumerate_candidates()), **kw)
    ranked = _rank(results, obj)
    return SearchResult(best=ranked[0], ranked=ranked,
                        pareto=_front(results, obj), history=[],
                        n_evaluated=len(results), objective_key=obj)


def portfolio_search(space: DesignSpace, key, *,
                     population: int = 32, generations: int = 12,
                     elite: int = 6, jump_prob: float = 0.15,
                     risk: Optional[RiskConfig] = None,
                     evaluator: Optional[ChunkedEvaluator] = None,
                     flow: str = "chip-last") -> SearchResult:
    """Evolutionary portfolio search, deterministic in ``key``.

    ``risk=RiskConfig(...)`` switches the objective from nominal
    portfolio cost to the configured Monte Carlo quantile (common random
    numbers across all candidates, derived from ``key``).
    """
    if elite < 1 or elite > population:
        raise ValueError("need 1 <= elite <= population")
    rng = _rng_from_key(key)
    ev = _check_evaluator(space, flow, evaluator) if evaluator \
        else ChunkedEvaluator(space, candidates_per_chunk=min(population, 64),
                              flow=flow)
    obj = "cost"
    ev_kw = {}
    if risk is not None:
        obj = risk.objective_key
        ev_kw = _mc_kwargs(risk, _default_mc_key(key))

    seen: Dict[Candidate, CandidateResult] = {}
    history: List[Dict] = []

    def price(cands: Sequence[Candidate]):
        fresh = []
        for c in cands:
            if c not in seen and c not in fresh:
                fresh.append(c)
        for r in ev.evaluate(fresh, **ev_kw):
            seen[r.candidate] = r

    pop = space.sample(rng, population)
    for gen in range(generations):
        price(pop)
        ranked_pop = _rank([seen[c] for c in set(pop)], obj)
        elites = ranked_pop[:elite]
        best_all = _rank(list(seen.values()), obj)[0]
        history.append({"generation": gen, "evaluated": len(seen),
                        "best_objective": best_all.objective(obj),
                        "best_label": best_all.label,
                        "gen_best": ranked_pop[0].objective(obj)})
        if gen == generations - 1:
            break
        next_pop = [r.candidate for r in elites]
        guard = 0
        while len(next_pop) < population:
            pa = elites[int(rng.integers(len(elites)))].candidate
            pb = elites[int(rng.integers(len(elites)))].candidate
            child = space.crossover(rng, pa, pb)
            if rng.random() < 0.8:
                child = space.mutate(rng, child, jump_prob=jump_prob)
            guard += 1
            if child in next_pop and guard < 10 * population:
                continue
            next_pop.append(child)
        pop = next_pop

    ranked = _rank(list(seen.values()), obj)
    return SearchResult(best=ranked[0], ranked=ranked,
                        pareto=_front(ranked, obj), history=history,
                        n_evaluated=len(seen), objective_key=obj)
