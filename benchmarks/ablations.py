"""Paper ablations beyond the headline figures.

* Eq. (5): chip-first vs chip-last packaging flows — the paper states
  chip-last is the priority selection; quantify the gap per node/area.
* Sec. 4.1 ("as the yield of 7nm improves, the advantage is smaller"):
  defect-density sensitivity of the multi-chip advantage.
* Negative-binomial cluster parameter c: model-risk band for the
  headline Fig. 4 numbers.
"""
from repro.core import re_cost, soc_system, split_system
from repro.core.technology import PROCESS_NODES
from .common import emit

import dataclasses


def run():
    rows = []
    for node in ("7nm", "5nm"):
        for area in (400.0, 800.0):
            s = split_system("s", area, node, 3, "2.5D")
            last = re_cost(s, "chip-last").total
            first = re_cost(s, "chip-first").total
            rows.append({"node": node, "area_mm2": area,
                         "chip_last": last, "chip_first": first,
                         "chip_first_penalty": first / last - 1})
    emit("ablation_eq5_chip_first_vs_last", rows)
    assert all(r["chip_first_penalty"] > 0 for r in rows)

    rows = []
    base = PROCESS_NODES["7nm"]
    for d0 in (0.05, 0.07, 0.09, 0.11, 0.13):
        nd = dataclasses.replace(base, defect_density=d0)
        import repro.core.technology as T
        old = T.PROCESS_NODES["7nm"]
        T.PROCESS_NODES["7nm"] = nd
        try:
            soc = re_cost(soc_system("s", 800.0, "7nm")).total
            mcm = re_cost(split_system("m", 800.0, "7nm", 3, "MCM")).total
        finally:
            T.PROCESS_NODES["7nm"] = old
        rows.append({"defect_density": d0, "soc": soc, "mcm3": mcm,
                     "mcm_saving": 1 - mcm / soc})
    emit("ablation_defect_density_sensitivity", rows)
    # paper Sec 4.1: maturing yield shrinks the multi-chip advantage
    assert rows[0]["mcm_saving"] < rows[-1]["mcm_saving"]

    rows = []
    for c in (1.0, 3.0, 6.0, 1e6):    # 1e6 ~ Poisson limit
        nd = dataclasses.replace(PROCESS_NODES["5nm"], cluster_param=c)
        import repro.core.technology as T
        old = T.PROCESS_NODES["5nm"]
        T.PROCESS_NODES["5nm"] = nd
        try:
            soc = re_cost(soc_system("s", 800.0, "5nm"))
        finally:
            T.PROCESS_NODES["5nm"] = old
        rows.append({"cluster_c": c,
                     "defect_share": soc.chip_defects / soc.total})
    emit("ablation_cluster_param_sensitivity", rows)
    return rows


if __name__ == "__main__":
    run()
