"""Declarative multi-chiplet design-space definition (repro.dse).

A :class:`DesignSpace` describes a *product portfolio* — the SKUs a
vendor ships, each with a module inventory (total functional area) and a
production volume — together with the architectural freedoms the search
may exercise: allowed process nodes, integration technologies, chiplet
counts, and cross-SKU chiplet-reuse (the paper's SCMS scheme generalized
to arbitrary per-SKU socket counts via
:func:`repro.core.reuse.portfolio_reuse_systems`).

A :class:`Candidate` is one fully concrete point of that space: either a
per-SKU tuple of :class:`ArchChoice` (independent architectures) or a
:class:`ReuseChoice` (one shared chiplet design collocated across the
whole portfolio).  ``candidate_systems`` lowers a candidate to the
:class:`~repro.core.system.System` group that
:class:`~repro.core.batch.SystemBatch` packs and the engine prices.

The space is countable: ``size()`` / ``candidate_at(i)`` give a total
order, so exhaustive enumeration, uniform sampling and index-based
decoding all agree — the property the seeded-determinism tests pin.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.reuse import portfolio_reuse_systems
from ..core.system import System, spec
from ..core.technology import node, tech

_REL_TOL = 1e-6


@dataclasses.dataclass(frozen=True)
class SKU:
    """One product in the portfolio: a module inventory and its volume."""

    name: str
    module_area_mm2: float
    quantity: float


@dataclasses.dataclass(frozen=True)
class ArchChoice:
    """Architecture of a single SKU: ``n_chiplets`` even slices of the
    module area on ``process``, packaged with ``integration``.

    ``n_chiplets == 1`` always means the monolithic SoC baseline
    (integration "SoC", no D2D overhead), as in the paper's Fig. 4.
    """

    n_chiplets: int
    process: str
    integration: str

    def label(self) -> str:
        if self.n_chiplets == 1:
            return f"soc/{self.process}"
        return f"{self.n_chiplets}x/{self.process}/{self.integration}"


@dataclasses.dataclass(frozen=True)
class ReuseChoice:
    """One shared chiplet design across the whole portfolio (SCMS-style):
    every SKU is ``round(area / slice_area_mm2)`` copies of the slice."""

    slice_area_mm2: float
    process: str
    integration: str
    package_reuse: bool = False

    def label(self) -> str:
        pkg = "+pkg" if self.package_reuse else ""
        return (f"reuse[{self.slice_area_mm2:g}mm2/{self.process}"
                f"/{self.integration}{pkg}]")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One concrete portfolio architecture (hashable — search dedup key)."""

    choices: Tuple[ArchChoice, ...] = ()
    reuse: Optional[ReuseChoice] = None

    def __post_init__(self):
        if (self.reuse is None) == (not self.choices):
            raise ValueError("candidate needs choices xor a reuse scheme")

    @property
    def is_reuse(self) -> bool:
        return self.reuse is not None

    def label(self) -> str:
        if self.reuse is not None:
            return self.reuse.label()
        return " | ".join(c.label() for c in self.choices)


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """The searchable portfolio design space.

    ``chiplet_counts`` containing 1 enables the monolithic-SoC option per
    SKU; counts > 1 combine with every (process, integration) pair.
    ``allow_reuse`` adds SCMS-style candidates whose slice areas are
    derived from the SKU areas (a slice is valid iff every SKU area is an
    in-range integer multiple of it).  ``reuse_within_sku`` gives the
    slices of one non-reuse split a single design name (chiplet NRE paid
    once per SKU); the paper's Fig. 4 no-reuse assumption is
    ``reuse_within_sku=False``.
    """

    skus: Tuple[SKU, ...]
    processes: Tuple[str, ...] = ("7nm",)
    integrations: Tuple[str, ...] = ("MCM",)
    chiplet_counts: Tuple[int, ...] = (1, 2, 3, 4)
    allow_reuse: bool = True
    reuse_package_options: Tuple[bool, ...] = (False,)
    reuse_within_sku: bool = True

    def __post_init__(self):
        if not self.skus:
            raise ValueError("design space needs at least one SKU")
        names = [s.name for s in self.skus]
        if len(set(names)) != len(names):
            raise ValueError("SKU names must be unique")
        if not self.processes:
            raise ValueError("design space needs at least one process node")
        if not self.integrations and max(self.chiplet_counts) > 1:
            raise ValueError(
                "chiplet counts > 1 need at least one integration tech")
        for p in self.processes:
            node(p)
        for t in self.integrations:
            if t == "SoC":
                raise ValueError(
                    "integrations are multi-chip technologies; the SoC "
                    "baseline is the n_chiplets=1 option")
            tech(t)
        if not self.chiplet_counts or min(self.chiplet_counts) < 1:
            raise ValueError("chiplet_counts must be positive")

    # -- choice inventories (cached: the space is frozen, and the search
    # loop asks for them on every sample/mutate/crossover) -------------------
    @functools.cached_property
    def _arch_choices(self) -> Tuple[ArchChoice, ...]:
        out = []
        if 1 in self.chiplet_counts:
            out += [ArchChoice(1, p, "SoC") for p in self.processes]
        out += [ArchChoice(n, p, t)
                for n in sorted(set(self.chiplet_counts)) if n > 1
                for p in self.processes for t in self.integrations]
        return tuple(out)

    @functools.cached_property
    def _reuse_choices(self) -> Tuple[ReuseChoice, ...]:
        if not self.allow_reuse:
            return ()
        return tuple(ReuseChoice(a, p, t, pkg)
                     for a in self.reuse_slice_areas()
                     for p in self.processes for t in self.integrations
                     for pkg in self.reuse_package_options)

    def arch_choices(self) -> List[ArchChoice]:
        """Per-SKU architecture options (same menu for every SKU)."""
        return list(self._arch_choices)

    def reuse_slice_areas(self) -> List[float]:
        """Slice areas under which every SKU is an in-range integer
        multiple — the valid cross-SKU reuse granularities."""
        counts = sorted(set(self.chiplet_counts))
        cands = sorted({s.module_area_mm2 / n
                        for s in self.skus for n in counts}, reverse=True)
        out: List[float] = []
        for a in cands:
            ok = True
            for s in self.skus:
                k = s.module_area_mm2 / a
                if abs(k - round(k)) > _REL_TOL * max(k, 1.0) \
                        or int(round(k)) not in counts:
                    ok = False
                    break
            if ok and not any(abs(a - b) <= _REL_TOL * a for b in out):
                out.append(a)
        return out

    def reuse_choices(self) -> List[ReuseChoice]:
        return list(self._reuse_choices)

    def reuse_counts(self, r: ReuseChoice) -> Tuple[int, ...]:
        """Per-SKU socket counts under ``r`` — rejects a slice that does
        not implement the SKU inventories (wrong area or out-of-range
        count), so foreign/hand-built reuse candidates cannot be silently
        lowered to the wrong silicon."""
        counts = []
        for s in self.skus:
            k = s.module_area_mm2 / r.slice_area_mm2
            if abs(k - round(k)) > _REL_TOL * max(k, 1.0) \
                    or int(round(k)) not in self.chiplet_counts:
                raise ValueError(
                    f"slice {r.slice_area_mm2:g} mm^2 does not tile SKU "
                    f"{s.name!r} ({s.module_area_mm2:g} mm^2) within the "
                    f"allowed chiplet counts {self.chiplet_counts}")
            counts.append(int(round(k)))
        return tuple(counts)

    # -- countable enumeration ----------------------------------------------
    def size(self) -> int:
        return (len(self._arch_choices) ** len(self.skus)
                + len(self._reuse_choices))

    def candidate_at(self, i: int) -> Candidate:
        """Decode index ``i`` (0 <= i < size()) into a candidate."""
        arch = self._arch_choices
        n_arch = len(arch) ** len(self.skus)
        if i < 0 or i >= self.size():
            raise IndexError(f"candidate index {i} out of range")
        if i < n_arch:
            # match enumerate_candidates(): SKU 0 is the most significant
            # digit of the mixed-radix index
            digits = []
            for _ in self.skus:
                i, d = divmod(i, len(arch))
                digits.append(arch[d])
            return Candidate(choices=tuple(reversed(digits)))
        return Candidate(reuse=self._reuse_choices[i - n_arch])

    def enumerate_candidates(self) -> Iterator[Candidate]:
        for combo in itertools.product(self._arch_choices,
                                       repeat=len(self.skus)):
            yield Candidate(choices=combo)
        for r in self._reuse_choices:
            yield Candidate(reuse=r)

    def sample(self, rng: np.random.Generator, n: int) -> List[Candidate]:
        """Uniform-with-replacement sample of ``n`` candidates."""
        return [self.candidate_at(int(i))
                for i in rng.integers(0, self.size(), size=n)]

    # -- search neighborhood -------------------------------------------------
    def mutate(self, rng: np.random.Generator, cand: Candidate,
               jump_prob: float = 0.15) -> Candidate:
        """A random neighbor: tweak one SKU's choice (or hop between the
        reuse and independent families); occasionally jump anywhere."""
        if rng.random() < jump_prob:
            return self.candidate_at(int(rng.integers(0, self.size())))
        reuse = self._reuse_choices
        if cand.is_reuse:
            if len(reuse) > 1 and rng.random() < 0.7:
                others = [r for r in reuse if r != cand.reuse]
                return Candidate(reuse=others[int(rng.integers(len(others)))])
            return self.candidate_at(
                int(rng.integers(0, len(self._arch_choices)
                                 ** len(self.skus))))
        arch = self._arch_choices
        if reuse and rng.random() < 0.15:
            return Candidate(reuse=reuse[int(rng.integers(len(reuse)))])
        i = int(rng.integers(len(self.skus)))
        others = [a for a in arch if a != cand.choices[i]]
        if not others:
            return cand
        new = list(cand.choices)
        new[i] = others[int(rng.integers(len(others)))]
        return Candidate(choices=tuple(new))

    def crossover(self, rng: np.random.Generator, a: Candidate,
                  b: Candidate) -> Candidate:
        """Per-SKU uniform crossover; reuse candidates fall back to
        mutation (they have no per-SKU genes)."""
        if a.is_reuse or b.is_reuse:
            return self.mutate(rng, a)
        picks = rng.integers(0, 2, size=len(self.skus))
        return Candidate(choices=tuple(
            (a if p == 0 else b).choices[i] for i, p in enumerate(picks)))

    # -- batching bounds -----------------------------------------------------
    def max_chips(self) -> int:
        """Widest system any candidate can produce (padding bound)."""
        m = max(self.chiplet_counts)
        for r in self._reuse_choices:
            m = max(m, max(self.reuse_counts(r)))
        return m


def candidate_systems(space: DesignSpace, cand: Candidate) -> List[System]:
    """Lower one candidate to its per-SKU :class:`System` group.

    The group is meant to be priced with NRE shared *within* the
    candidate (one ``share_nre`` group): reuse candidates then amortize
    the single chiplet design over the whole portfolio volume.
    """
    if cand.choices and len(cand.choices) != len(space.skus):
        raise ValueError(
            f"candidate has {len(cand.choices)} per-SKU choices but the "
            f"space has {len(space.skus)} SKUs")
    if cand.reuse is not None:
        r = cand.reuse
        return portfolio_reuse_systems(
            r.slice_area_mm2, r.process, r.integration,
            counts=list(space.reuse_counts(r)),
            quantities=[s.quantity for s in space.skus],
            names=[s.name for s in space.skus],
            package_reuse=r.package_reuse)
    out = []
    for sku, c in zip(space.skus, cand.choices):
        if c.n_chiplets == 1:
            out.append(spec({"kind": "soc", "name": sku.name,
                             "area": sku.module_area_mm2,
                             "process": c.process,
                             "quantity": sku.quantity}))
        else:
            out.append(spec({"kind": "split", "name": sku.name,
                             "area": sku.module_area_mm2,
                             "process": c.process, "n": c.n_chiplets,
                             "integration": c.integration,
                             "quantity": sku.quantity,
                             "reuse_chiplet": space.reuse_within_sku}))
    return out
