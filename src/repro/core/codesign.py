"""Accelerator co-design bridge (beyond-paper extension).

The paper's decision method, applied to the accelerators this framework
trains on: price TPU-class accelerator packages (monolithic vs chiplet)
with the faithful Chiplet Actuary model, then combine with the multi-pod
dry-run's roofline terms to get cost-per-step / perf-per-dollar for every
assigned architecture.

An accelerator die is modeled as compute area + SRAM/uncore area + HBM-PHY
area (PHY/analog does not scale well -> candidate for a mature-node center
die, the paper's OCME insight).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .batch import SystemBatch
from .engine import CostEngine
from .system import Module, System, make_chip
from .technology import node, tech

_ENGINE = CostEngine()

# TPU v5e-class peak per chip (brief's hardware constants).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW_PER_LINK = 50e9         # B/s


@dataclasses.dataclass(frozen=True)
class AcceleratorSpec:
    """Silicon contents of one accelerator package (areas in mm^2)."""

    name: str
    compute_area: float = 300.0     # MXU/VPU arrays + SRAM
    uncore_area: float = 60.0       # NoC, scheduler, scalar cores
    phy_area: float = 80.0          # HBM + ICI PHYs ('unscalable' analog)
    process: str = "5nm"
    phy_process: Optional[str] = None  # heterogeneous variant
    peak_flops: float = PEAK_FLOPS_BF16


def accelerator_systems(spec: AcceleratorSpec, quantity: float = 1e6
                        ) -> Dict[str, System]:
    """Candidate packagings of one accelerator: monolithic SoC, 2-chiplet
    MCM (compute split), 2.5D compute+IO split (OCME-style), heterogeneous
    2.5D with the PHY die on a mature node."""
    p = spec.process
    pp = spec.phy_process or p
    total = spec.compute_area + spec.uncore_area + spec.phy_area

    def mod(nm, area, proc):
        return Module(name=f"{spec.name}_{nm}_{proc}", area_mm2=area, process=proc)

    out: Dict[str, System] = {}
    # Monolithic SoC (PHY forced onto the advanced node).
    soc_die = make_chip(f"{spec.name}_soc", [mod("all", total, p)], p,
                        integration="SoC")
    out["SoC"] = System(f"{spec.name}_SoC", (soc_die,), "SoC", quantity)

    # Homogeneous 2-chiplet MCM: compute sliced in half, uncore+phy on each.
    half = total / 2.0
    c = make_chip(f"{spec.name}_half", [mod("half", half, p)], p,
                  integration="MCM")
    out["MCM-2x"] = System(f"{spec.name}_MCM2", (c, c), "MCM", quantity)

    # 2.5D compute-die + IO-die split (same node).
    cd = make_chip(f"{spec.name}_compute", [mod("compute", spec.compute_area, p)],
                   p, integration="2.5D")
    io = make_chip(f"{spec.name}_io",
                   [mod("io", spec.uncore_area + spec.phy_area, p)], p,
                   integration="2.5D")
    out["2.5D-CIO"] = System(f"{spec.name}_25D", (cd, io), "2.5D", quantity)

    # Heterogeneous: PHY/uncore die on the mature node (OCME insight).
    io_h = make_chip(f"{spec.name}_io_{pp}",
                     [mod("io", spec.uncore_area + spec.phy_area, pp)], pp,
                     integration="2.5D")
    out["2.5D-hetero"] = System(f"{spec.name}_25Dh", (cd, io_h), "2.5D", quantity)
    return out


def price_accelerators(spec: AcceleratorSpec, quantity: float = 1e6
                       ) -> Dict[str, Dict[str, float]]:
    """Amortized unit cost of every packaging candidate of one accelerator.

    All candidates are priced in one :class:`CostEngine` trace;
    ``share_nre=False`` keeps each candidate its own product group (the
    candidates are alternatives, not co-produced systems).
    """
    candidates = accelerator_systems(spec, quantity)
    batch = SystemBatch.from_systems(list(candidates.values()),
                                     share_nre=False)
    tc = _ENGINE.total(batch)
    out: Dict[str, Dict[str, float]] = {}
    for i, label in enumerate(candidates):
        total = float(tc.total[i])
        out[label] = {
            "unit_cost": total,
            "re": float(tc.re.total[i]),
            "nre_per_unit": float(tc.nre.total[i]),
            "die_cost": float(tc.re.die_cost[i]),
            "packaging_cost": float(tc.re.packaging_cost[i]),
            "usd_per_pflops": total / (spec.peak_flops / 1e15),
        }
    return out


def cost_per_step(roofline_cell: Dict, chip_unit_cost: float,
                  n_chips: int,
                  lifetime_seconds: float = 3 * 365 * 86400.0
                  ) -> Dict[str, float]:
    """Price one training/serving step of a dry-run cell.

    ``roofline_cell`` must carry ``t_compute/t_memory/t_collective``
    seconds (from benchmarks.roofline); step time is their max
    (perfect-overlap lower bound).  Silicon cost is amortized over the
    fleet's useful life in *seconds*, so a slower step on the same
    fleet costs proportionally more — the quantity the partitioning /
    packaging decision actually trades against (paper Sec. 4.2's
    amortization logic applied to accelerator time instead of units).
    """
    t_step = max(roofline_cell["t_compute"], roofline_cell["t_memory"],
                 roofline_cell["t_collective"])
    fleet = chip_unit_cost * n_chips
    usd_per_step = fleet * t_step / lifetime_seconds
    return {
        "t_step_bound_s": t_step,
        "fleet_cost_usd": fleet,
        "usd_per_step": usd_per_step,
        "usd_per_exaflop": usd_per_step
        / max(roofline_cell.get("hlo_flops", 1.0), 1.0) * 1e18,
    }
