"""Production mesh builders.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod=True -> 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests / elastic restore experiments."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def describe(mesh) -> str:
    return (f"mesh {dict(zip(mesh.axis_names, mesh.devices.shape))} "
            f"({mesh.devices.size} devices)")
