"""Host-side numerical guardrails.

Two validators, numpy + stdlib only (importable from ``core`` and
``service`` without cycles):

* :func:`nonfinite_paths` — walk an arbitrary request-shaped object
  (dataclasses, dicts, sequences, numpy arrays, scalars) and return
  human-readable paths of every NaN/Inf numeric leaf.  The service
  protocol layer uses it to reject a request with ``invalid_request``
  *before* the bad value can reach a fused kernel and contaminate
  coalesced siblings.
* :func:`validate_packed_arrays` — range checks over the staged
  ``SystemBatch.from_systems`` host arrays (all values finite,
  areas/costs/quantities non-negative, yields inside (0, 1],
  ``package_area_factor`` strictly positive since the engine divides by
  it).  Padded slots (zero areas, unit yields) are legal by
  construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Mapping, Sequence

import numpy as np

# Stop after this many problems: error envelopes should name the first
# offenders, not serialize a million-row array of NaNs.
_MAX_PROBLEMS = 8


def _scan_array(arr: np.ndarray, path: str, problems: List[str]):
    if arr.dtype.kind not in "fc":
        return
    finite = np.isfinite(arr)
    if finite.all():
        return
    flat_bad = np.flatnonzero(~finite.reshape(-1))
    for pos in flat_bad[:2]:
        idx = np.unravel_index(int(pos), arr.shape) if arr.ndim else ()
        loc = "".join(f"[{int(i)}]" for i in idx)
        problems.append(f"{path}{loc} = {arr.reshape(-1)[int(pos)]}")
        if len(problems) >= _MAX_PROBLEMS:
            return


def nonfinite_paths(obj: Any, path: str = "value",
                    _depth: int = 0) -> List[str]:
    """Paths of non-finite numeric leaves in ``obj`` (empty = clean)."""
    problems: List[str] = []
    _walk_nonfinite(obj, path, problems, _depth)
    return problems


def _walk_nonfinite(obj: Any, path: str, problems: List[str], depth: int):
    if len(problems) >= _MAX_PROBLEMS or depth > 8 or obj is None:
        return
    # bool is an int subclass; int/bool/str can't be non-finite.
    if isinstance(obj, (bool, int, str, bytes, np.integer, np.bool_)):
        return
    if isinstance(obj, (float, np.floating, complex, np.complexfloating)):
        if not np.isfinite(obj):
            problems.append(f"{path} = {obj}")
        return
    if isinstance(obj, np.ndarray):
        _scan_array(obj, path, problems)
        return
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        for f in dataclasses.fields(obj):
            _walk_nonfinite(getattr(obj, f.name), f"{path}.{f.name}",
                            problems, depth + 1)
        return
    if isinstance(obj, Mapping):
        for k, v in obj.items():
            _walk_nonfinite(v, f"{path}[{k!r}]", problems, depth + 1)
        return
    if isinstance(obj, Sequence):
        # Fast path: an all-numeric sequence vectorizes to one isfinite.
        try:
            arr = np.asarray(obj, dtype=np.float64)
        except (TypeError, ValueError):
            arr = None
        if arr is not None and arr.dtype.kind == "f":
            _scan_array(arr, path, problems)
            return
        for i, v in enumerate(obj):
            _walk_nonfinite(v, f"{path}[{i}]", problems, depth + 1)
        return
    # Opaque object (jax arrays land here only if someone smuggles one
    # into a request; Uncertainty et al. are dataclasses and recurse).


# ---------------------------------------------------------------------------
# SystemBatch staging-array validation
# ---------------------------------------------------------------------------

# Per-chip (n_systems, max_chips) staged arrays: bound kind per key.
_CHIP_NONNEG = ("area", "defect", "wafer_cost", "cluster", "sort_cost",
                "bump_cost")
_CHIP_YIELD = ("wafer_yield",)
# Per-system (n_systems,) staged arrays.
_SYS_NONNEG = ("package_area", "substrate_cost", "substrate_layer",
               "interposer_cost", "interposer_defect",
               "interposer_area_factor", "interposer_cluster",
               "bond_cost_per_chip", "quantity")
_SYS_YIELD = ("y2_chip_bond", "y3_substrate_bond", "assembly_yield")
_SYS_POSITIVE = ("package_area_factor",)   # engine divides by it


def _offenders(mask: np.ndarray, arr: np.ndarray, key: str,
               names: Sequence[str], problems: List[str]):
    """Append ``system 'name': key[j] = value`` lines for True mask
    slots (mask/arr are the staged (n,) or (n, c) arrays)."""
    bad = np.flatnonzero(mask.reshape(-1))
    for pos in bad[:2]:
        if arr.ndim == 2:
            i, j = np.unravel_index(int(pos), arr.shape)
            loc = f"{key}[{int(j)}]"
        else:
            i, loc = int(pos), key
        name = names[int(i)] if int(i) < len(names) else f"#{int(i)}"
        problems.append(f"system {name!r}: {loc} = {arr.reshape(-1)[int(pos)]}")
        if len(problems) >= _MAX_PROBLEMS:
            return


def validate_packed_arrays(chip: Mapping[str, np.ndarray],
                           system: Mapping[str, np.ndarray],
                           names: Sequence[str]) -> List[str]:
    """Range-check the ``from_systems`` staging arrays; returns problem
    strings (empty = valid).  ``chip`` maps the per-chip keys to
    (n_systems, max_chips) arrays with a ``mask`` entry marking filled
    slots; ``system`` maps per-system keys to (n_systems,) arrays."""
    problems: List[str] = []
    slot = np.asarray(chip["mask"], bool)

    for key, arr in chip.items():
        a = np.asarray(arr)
        _offenders(~np.isfinite(a) & slot, a, key, names, problems)
    for key, arr in system.items():
        a = np.asarray(arr)
        _offenders(~np.isfinite(a), a, key, names, problems)
    if problems:
        return problems[:_MAX_PROBLEMS]

    for key in _CHIP_NONNEG:
        a = np.asarray(chip[key])
        _offenders((a < 0.0) & slot, a, key, names, problems)
    for key in _CHIP_YIELD:
        a = np.asarray(chip[key])
        _offenders(((a <= 0.0) | (a > 1.0)) & slot, a, key, names, problems)
    for key in _SYS_NONNEG:
        a = np.asarray(system[key])
        _offenders(a < 0.0, a, key, names, problems)
    for key in _SYS_YIELD:
        a = np.asarray(system[key])
        _offenders((a <= 0.0) | (a > 1.0), a, key, names, problems)
    for key in _SYS_POSITIVE:
        a = np.asarray(system[key])
        _offenders(a <= 0.0, a, key, names, problems)
    return problems[:_MAX_PROBLEMS]
