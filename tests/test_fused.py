"""The fused DSE pipeline: vectorized candidate encoder parity (full
enumeration, zero rel err vs the host System packing), closed-form NRE vs
the engine's segment-sum path, array-native batch construction, and the
single-trace contract of the jitted search generation step."""
import jax
import numpy as np
import pytest

from repro.core import CostEngine, SystemBatch
from repro.core.engine import TRACE_COUNTS, portfolio_totals
from repro.dse import (ChunkedEvaluator, DesignSpace, RiskConfig, SKU,
                       encode_batch, mc_totals, portfolio_search)
from repro.dse.space import encoded_nre
from repro.dse.uncertainty import mc_re_totals_impl

ENGINE = CostEngine()


def _space(**kw):
    d = dict(skus=(SKU("laptop", 200.0, 2e6), SKU("server", 400.0, 5e5)),
             processes=("7nm", "12nm"), integrations=("MCM",),
             chiplet_counts=(1, 2, 4), allow_reuse=True,
             reuse_package_options=(False, True))
    d.update(kw)
    return DesignSpace(**d)


@pytest.fixture(scope="module")
def space():
    return _space()


# ---------------------------------------------------------------------------
# Encoder: full-enumeration parity with the host packing path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kw", [{}, {"reuse_within_sku": False},
                                {"allow_reuse": False},
                                {"integrations": ("MCM", "2.5D")}])
def test_encode_batch_full_enumeration_bit_parity(kw):
    """Every candidate in the space, encoded from indices, prices exactly
    (zero relative error) like the candidate_systems + from_systems +
    pad_batch chunk it replaces."""
    sp = _space(**kw)
    idx = np.arange(sp.size())
    encoded = encode_batch(sp, idx)
    legacy = ChunkedEvaluator(sp, candidates_per_chunk=sp.size(),
                              fused=False).pack_chunk(
        list(sp.enumerate_candidates()))
    assert encoded.chip_area.shape == legacy.chip_area.shape
    for flow in ("chip-last", "chip-first"):
        te = jax.device_get(ENGINE.total(encoded, flow=flow))
        tl = jax.device_get(ENGINE.total(legacy, flow=flow))
        for part in ("re", "nre"):
            np.testing.assert_array_equal(
                np.asarray(getattr(te, part).total),
                np.asarray(getattr(tl, part).total))
        np.testing.assert_array_equal(np.asarray(te.total),
                                      np.asarray(tl.total))


def test_index_of_is_the_inverse_of_candidate_at(space):
    assert [space.index_of(space.candidate_at(i))
            for i in range(space.size())] == list(range(space.size()))
    three = _space(skus=(SKU("a", 100.0, 1.0), SKU("b", 200.0, 1.0),
                         SKU("c", 400.0, 1.0)))
    with pytest.raises(ValueError):
        space.index_of(three.candidate_at(0))   # foreign candidate


def test_encoded_nre_matches_engine_segment_sums(space):
    rng = np.random.default_rng(3)
    idx = rng.integers(0, space.size(), 64)
    enc = space.encoder()
    batch = encode_batch(space, idx)
    ana = jax.device_get(encoded_nre(enc.tables, enc.meta, idx))
    gen = jax.device_get(ENGINE.nre(batch))
    for part in ("modules", "chips", "packages", "d2d", "total"):
        a = np.asarray(getattr(ana, part))
        g = np.asarray(getattr(gen, part))
        scale = np.maximum(np.abs(g), 1e-9)
        assert float(np.max(np.abs(a - g) / scale)) < 1e-6, part


# ---------------------------------------------------------------------------
# SystemBatch.from_arrays
# ---------------------------------------------------------------------------


def test_from_arrays_roundtrip_and_validation(space):
    b = encode_batch(space, np.arange(4))
    leaves = {f: getattr(b, f) for f in SystemBatch._LEAVES}
    rb = SystemBatch.from_arrays(**leaves)
    np.testing.assert_array_equal(np.asarray(ENGINE.total(rb).total),
                                  np.asarray(ENGINE.total(b).total))
    with pytest.raises(ValueError):
        SystemBatch.from_arrays(**{k: v for k, v in leaves.items()
                                   if k != "quantity"})
    with pytest.raises(ValueError):
        SystemBatch.from_arrays(**leaves, extra_leaf=leaves["quantity"])
    bad = dict(leaves)
    bad["quantity"] = leaves["quantity"][:-1]
    with pytest.raises(ValueError):
        SystemBatch.from_arrays(**bad)


# ---------------------------------------------------------------------------
# Fused evaluator: index path == object path == legacy path
# ---------------------------------------------------------------------------


def test_evaluate_indices_matches_object_api_and_legacy(space):
    rng = np.random.default_rng(0)
    idx = np.asarray(sorted({int(i) for i in
                             rng.integers(0, space.size(), 24)}))
    fused = ChunkedEvaluator(space, candidates_per_chunk=8)
    arrays = fused.evaluate_indices(idx)
    assert len(arrays) == idx.size
    obj = fused.evaluate([space.candidate_at(int(i)) for i in idx])
    np.testing.assert_array_equal(
        arrays.portfolio_cost, np.asarray([r.portfolio_cost for r in obj],
                                          arrays.portfolio_cost.dtype))
    legacy = ChunkedEvaluator(space, candidates_per_chunk=8,
                              fused=False).evaluate(
        [space.candidate_at(int(i)) for i in idx])
    worst = max(abs(a.portfolio_cost - b.portfolio_cost) / b.portfolio_cost
                for a, b in zip(obj, legacy))
    assert worst < 1e-6
    with pytest.raises(RuntimeError):
        ChunkedEvaluator(space, fused=False).evaluate_indices(idx)
    with pytest.raises(IndexError):
        fused.evaluate_indices(np.asarray([space.size()]))


def test_fused_risk_stats_match_legacy_quantiles(space):
    rng = np.random.default_rng(1)
    cands = [space.candidate_at(int(i))
             for i in rng.integers(0, space.size(), 6)]
    key = jax.random.PRNGKey(11)
    kw = dict(mc_key=key, mc_draws=64, mc_quantiles=(0.5, 0.9))
    fused = ChunkedEvaluator(space, candidates_per_chunk=8).evaluate(
        cands, **kw)
    legacy = ChunkedEvaluator(space, candidates_per_chunk=8,
                              fused=False).evaluate(cands, **kw)
    for f, l in zip(fused, legacy):
        for stat in ("mean", "q50", "q90"):
            assert f.risk[stat] == pytest.approx(l.risk[stat], rel=1e-5)


def test_mc_re_draws_plus_nre_equals_full_mc(space):
    """NRE is scenario-invariant: RE-only draws plus the one NRE row must
    reproduce the full Monte-Carlo totals bit for bit."""
    batch = encode_batch(space, np.arange(6))
    key = jax.random.PRNGKey(2)
    sig = np.asarray([0.2, 0.1, 0.25, 0.2], np.float32)
    full = np.asarray(mc_totals(batch, key, n_draws=32))
    re_only = np.asarray(jax.jit(
        lambda b, k: mc_re_totals_impl(b, k, sig, "chip-last", 32))(
        batch, key))
    nre = np.asarray(ENGINE.nre(batch).total)
    np.testing.assert_array_equal(full, re_only + nre[None, :])


# ---------------------------------------------------------------------------
# Search: one generation-step trace across generations and runs
# ---------------------------------------------------------------------------


def test_multi_generation_search_compiles_one_generation_step(space):
    kw = dict(population=10, generations=5, elite=3)
    ev = ChunkedEvaluator(space, candidates_per_chunk=8)
    before = dict(TRACE_COUNTS)
    r1 = portfolio_search(space, jax.random.PRNGKey(42), evaluator=ev, **kw)
    after = dict(TRACE_COUNTS)
    assert after.get("gen_step", 0) - before.get("gen_step", 0) == 1, \
        "5 generations must share exactly one generation-step trace"
    # a second same-shaped search (different key) adds zero traces at all
    r2 = portfolio_search(space, jax.random.PRNGKey(43), evaluator=ev, **kw)
    assert dict(TRACE_COUNTS) == after
    assert len(r1.history) == len(r2.history) == 5


def test_portfolio_totals_reduction(space):
    vals = np.asarray([[1.0, 2.0], [3.0, 4.0]], np.float32)
    out = np.asarray(portfolio_totals(vals.reshape(-1), [10.0, 100.0]))
    np.testing.assert_allclose(out, [210.0, 430.0])


def test_risk_search_objective_consistent_with_gen_step(space):
    """The generation step's on-device quantile objective and the final
    materialized risk stats come from the same fused computation — the
    winner's objective must equal the minimum over the ranked list."""
    sr = portfolio_search(space, jax.random.PRNGKey(9), population=8,
                          generations=3, elite=3,
                          risk=RiskConfig(n_draws=32, quantile=0.8))
    assert sr.objective_key == "q80"
    assert sr.best.objective("q80") == min(r.objective("q80")
                                           for r in sr.ranked)
    assert sr.history[-1]["best_objective"] >= sr.best.objective("q80") - 1e-6
