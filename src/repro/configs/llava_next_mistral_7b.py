"""LLaVA-NeXT (Mistral-7B backbone) — anyres tiling, stub vision tower.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
Backbone = Mistral-7B: 32L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 32000.  ``input_specs`` provides precomputed anyres patch embeddings
(the vision tower + projector are the stub frontend per the brief).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b", family="vlm",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, rope_theta=1e6,
    n_img_patches=2880,     # 5 anyres tiles x 576 patches (24x24 @ CLIP-L)
    subquadratic=False,
)
