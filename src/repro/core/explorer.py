"""Vectorized design-space exploration over the Chiplet Actuary model.

Sweeps are expressed as declarative spec dicts, packed into one
:class:`~repro.core.batch.SystemBatch`, and priced by the jitted
:class:`~repro.core.engine.CostEngine` in a single trace — the engine
behind the Fig. 2/4 benchmarks and the partitioning decision method
(Sec. 6 takeaway 1: "splitting into two or three chiplets is usually
sufficient").  Unlike the old ``re_cost_split``-based sweeps, these cover
*heterogeneous* partitions: unequal slices, mixed process nodes, mixed
integration technologies, all in one batch.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Sequence

import jax
import jax.numpy as jnp

from .batch import SystemBatch
from .engine import CostEngine
from .technology import PROCESS_NODES, node, tech
from .yield_model import raw_die_cost, yield_negative_binomial

_ENGINE = CostEngine()


def cost_area_curve(process: str, areas_mm2: jnp.ndarray, early: bool = False):
    """Fig. 2: yield and normalized cost/area vs die area for one node.

    Cost is normalized to the cost-per-area of the raw wafer, as in the
    paper's Fig. 2.
    """
    n = node(process)
    d0 = n.defect_density_early if early else n.defect_density
    y = yield_negative_binomial(areas_mm2, d0, n.cluster_param)
    raw = jax.vmap(lambda a: raw_die_cost(a, n.wafer_cost))(areas_mm2)
    # raw wafer cost per mm^2 (ideal full utilization of a 300mm wafer)
    per_mm2 = n.wafer_cost / (jnp.pi * 150.0 ** 2)
    norm_cost = (raw / y) / (areas_mm2 * per_mm2)
    return {"area": areas_mm2, "yield": y, "norm_cost_per_area": norm_cost}


def sweep_specs(specs: Sequence[Mapping], flow: str = "chip-last",
                share_nre: bool = False):
    """Price arbitrary spec dicts in one engine trace.

    Returns ``(batch, total_cost)`` where ``total_cost`` is the engine's
    :class:`~repro.core.engine.TotalCost` with (N,)-array fields.
    """
    batch = SystemBatch.from_specs(specs, share_nre=share_nre)
    return batch, _ENGINE.total(batch, flow=flow)


def sweep_partitions(process: str, integration: str,
                     areas_mm2: Sequence[float],
                     n_chiplets: Sequence[int], early: bool = False,
                     flow: str = "chip-last"):
    """RE-cost surface over (module area x number of chiplets) — Fig. 4 data.

    ``n = 1`` means the unsplit module (no D2D overhead) placed in the
    given integration technology's package.
    """
    specs = []
    for a in areas_mm2:
        for n in n_chiplets:
            specs.append({
                "kind": "split", "area": float(a), "process": process,
                "n": int(n), "integration": integration, "early": early,
                "d2d_overhead": 0.0 if int(n) == 1 else None,
            })
    batch = SystemBatch.from_specs(specs)
    totals = _ENGINE.re(batch, flow=flow).total.reshape(
        len(areas_mm2), len(n_chiplets))
    return {"areas": jnp.asarray(areas_mm2, jnp.float32),
            "n_chiplets": jnp.asarray(n_chiplets, jnp.float32),
            "total": totals}


def sweep_hetero_partitions(area_mm2: float, partitions: Sequence[Sequence],
                            integration: str, early: bool = False,
                            flow: str = "chip-last") -> List[Dict]:
    """Price heterogeneous partitions of one module area.

    Each partition is a sequence of ``(fraction, process)`` slices — e.g.
    ``[(0.5, "5nm"), (0.25, "7nm"), (0.25, "7nm")]`` puts half the module
    on 5nm and the rest on two 7nm chiplets.  Fractions are normalized.
    Returns one row per partition with the RE breakdown.
    """
    specs = []
    for i, part in enumerate(partitions):
        fracs = [float(f) for f, _ in part]
        procs = [p for _, p in part]
        specs.append({"kind": "split", "name": f"part{i}",
                      "area": float(area_mm2), "fractions": fracs,
                      "processes": procs, "integration": integration,
                      "early": early,
                      # a single-slice partition is the unsplit module
                      "d2d_overhead": 0.0 if len(part) == 1 else None})
    batch = SystemBatch.from_specs(specs)
    br = jax.device_get(_ENGINE.re(batch, flow=flow))
    rows = []
    for i, part in enumerate(partitions):
        rows.append({"partition": list(part), "total": float(br.total[i]),
                     "die_cost": float(br.die_cost[i]),
                     "packaging_cost": float(br.packaging_cost[i])})
    return rows


def best_partition(process: str, integration: str, area_mm2: float,
                   max_chiplets: int = 8, early: bool = False) -> Dict:
    """Integer argmin over chiplet count for one (node, tech, area)."""
    ns = list(range(1, max_chiplets + 1))
    res = sweep_partitions(process, integration, [area_mm2], ns, early=early)
    totals = jax.device_get(res["total"])[0]
    i = int(totals.argmin())
    return {"best_n": ns[i], "best_cost": float(totals[i]),
            "soc_cost": float(totals[0]),
            "saving": 1.0 - float(totals[i]) / float(totals[0])}


def pareto_front(points: Sequence[Dict], x_key: str, y_key: str) -> List[Dict]:
    """Lower-left Pareto front (minimize both keys), deterministically.

    Points are sorted by ``(x, y)`` (stable, so equal keys keep input
    order) and a point is kept iff its y is *strictly* below every
    previously kept point's y.  Consequences of the strict ``<``: the
    first point of an equal-``(x, y)`` duplicate group wins, and a
    y-tie at larger x is treated as dominated and dropped — ties never
    produce a nondeterministic front.
    """
    pts = sorted(points, key=lambda p: (p[x_key], p[y_key]))
    front, best_y = [], float("inf")
    for p in pts:
        if p[y_key] < best_y:
            front.append(p)
            best_y = p[y_key]
    return front
