"""xLSTM-125M — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

12 blocks, d_model 768, 4 heads, vocab 50304, d_ff 0 (mixer-only blocks).
One sLSTM per 4 blocks (rest mLSTM), xLSTM[3:1]-style.  Sub-quadratic:
runs long_500k with O(1)/token matrix-memory decode.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, slstm_every=4,
    subquadratic=True,
)
