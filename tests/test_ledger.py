"""repro.obs ledger/SLO/tracing v2: the serving-cost ledger's
sum-to-tick-wall invariant under coalescing + chunk splitting, bill
determinism under seeded arrival interleaving, trace_id survival across
journal replay and checkpoint resume, SLO burn math and latching, and
histogram exemplar/quantile edge cases."""
import asyncio

import numpy as np
import pytest

from repro.dse import DesignSpace, SKU
from repro.obs.ledger import Ledger
from repro.obs.registry import Histogram, Registry
from repro.obs.slo import SLObjective, SLOTracker
from repro.resilience import FaultInjector
from repro.service import (DurabilityConfig, PriceRequest, PricingService,
                           RankRequest, RequestJournal, SHUTTING_DOWN,
                           SearchRequest, ServiceConfig, request_to_wire,
                           serve)


def _space(**kw):
    d = dict(skus=(SKU("laptop", 200.0, 2e6), SKU("server", 400.0, 5e5)),
             processes=("7nm", "12nm"), integrations=("MCM",),
             chiplet_counts=(1, 2, 4), allow_reuse=True)
    d.update(kw)
    return DesignSpace(**d)


@pytest.fixture(scope="module")
def space():
    return _space()


@pytest.fixture(autouse=True)
def _no_env_faults(monkeypatch):
    monkeypatch.delenv("REPRO_FAULTS", raising=False)


CFG = ServiceConfig(chunk=16, split=4, warm_mc=((64, (0.5, 0.9)),))


# ---------------------------------------------------------------------------
# Ledger unit: exact pro-ration, remainder absorption, terminal paths
# ---------------------------------------------------------------------------


def test_charge_tick_shares_sum_exactly_to_wall():
    led = Ledger(registry=Registry())
    bills = [led.open(f"t{i}", i, "price") for i in range(3)]
    # awkward row counts that do NOT divide the wall evenly
    led.charge_tick("chunk", 0.0123, [(bills[0], 7), (bills[1], 3),
                                      (bills[2], 6)], slots=16, used=16)
    total = sum(b.device_ms for b in bills)
    assert total == pytest.approx(12.3, abs=0.0)      # exact, not approx
    assert led.tick_residual_rel_max == 0.0
    assert led.unattributed_ms == 0.0
    # shares ordered by rows contributed
    assert bills[0].device_ms > bills[2].device_ms > bills[1].device_ms
    for b in bills:
        assert b.ticks == 1 and b.rows_priced in (7, 3, 6)


def test_charge_tick_padded_share_and_dispatch_proration():
    led = Ledger(registry=Registry())
    a, b = led.open("a", 1, "price"), led.open("b", 2, "rank")
    led.charge_tick("chunk", 0.010, [(a, 6), (b, 2)], slots=16, used=8,
                    dispatch_s=0.004, retries=1)
    # half the slots are padding: every rider's padded share is half of
    # its wall share
    assert a.padded_ms == pytest.approx(a.device_ms * 0.5)
    assert b.padded_ms == pytest.approx(b.device_ms * 0.5)
    assert a.dispatch_ms == pytest.approx(3.0)        # 6/8 of 4 ms
    assert b.dispatch_ms == pytest.approx(1.0)
    assert a.retries == 1 and b.retries == 1


def test_charge_tick_with_no_riders_books_unattributed():
    led = Ledger(registry=Registry())
    led.charge_tick("chunk", 0.005, [], slots=16, used=0)
    assert led.unattributed_ms == pytest.approx(5.0)
    assert led.device_ms_total == 0.0
    snap = led.snapshot()
    assert snap["unattributed_ms"] == pytest.approx(5.0)
    assert snap["by_lane"]["chunk"]["ticks"] == 1


def test_close_is_idempotent_and_first_terminal_wins():
    led = Ledger(registry=Registry())
    bill = led.open("x", 1, "price")
    led.close(bill, status="deadline_exceeded", latency_s=0.2)
    led.close(bill, status="ok", latency_s=9.9)       # double terminal
    assert bill.status == "deadline_exceeded"
    assert bill.latency_ms == pytest.approx(200.0)
    snap = led.snapshot()
    assert snap["closed"] == 1
    assert snap["by_kind"]["price"]["requests"] == 1
    assert snap["by_kind"]["price"]["errors"] == 1


def test_late_charge_after_close_still_lands_in_aggregates():
    # the deferred-finish ordering in the server normally charges before
    # closing, but a failure path can close first — the kind aggregates
    # accumulate at charge time, so the share is never lost
    led = Ledger(registry=Registry())
    bill = led.open("x", 1, "price")
    led.close(bill, status="internal_error")
    led.charge_tick("chunk", 0.002, [(bill, 4)], slots=4, used=4)
    assert led.snapshot()["by_kind"]["price"]["device_ms"] == \
        pytest.approx(2.0)
    assert bill.device_ms == pytest.approx(2.0)


def test_bill_for_finds_open_and_closed():
    led = Ledger(registry=Registry())
    a = led.open("a", 1, "price")
    b = led.open("b", 2, "rank")
    led.close(b, status="ok")
    assert led.bill_for(1) is a
    assert led.bill_for(2) is b
    assert led.bill_for(99) is None


# ---------------------------------------------------------------------------
# Service level: bills decompose the measured tick wall under
# coalescing + chunk splitting, and every envelope is billed
# ---------------------------------------------------------------------------


def _mixed_requests():
    rng = np.random.default_rng(7)
    reqs = [PriceRequest(indices=rng.integers(0, 40, n).tolist())
            for n in (23, 9, 31, 4, 17)]       # spans chunks, forces splits
    reqs.append(RankRequest(indices=list(range(40)), top_k=3))
    return reqs


def test_bills_sum_to_tick_wall_under_coalescing(space):
    resps, svc = serve(space, _mixed_requests(), CFG)
    assert all(r.ok for r in resps), [r.error for r in resps]
    led = svc.snapshot()["ledger"]
    assert led["open"] == 0
    assert led["unattributed_ms"] == 0.0
    assert led["tick_residual_rel_max"] < 1e-9
    # the closed bills are a complete decomposition of the billed wall
    total_billed = sum(r.bill["device_ms"] for r in resps)
    assert total_billed == pytest.approx(led["device_ms_total"], rel=1e-9)
    # and the billed wall is exactly the per-lane tick wall
    lane_wall = sum(v["wall_ms"] for v in led["by_lane"].values())
    assert total_billed == pytest.approx(lane_wall, rel=1e-9)
    for r in resps:
        assert r.trace_id
        assert r.bill["status"] == "ok"
        assert r.bill["ticks"] >= 1
        assert r.bill["trace_id"] == r.trace_id


def test_bill_structure_deterministic_under_seeded_interleaving(space):
    """Same seeded arrival order twice -> identical bill structure
    (ticks ridden, rows billed, statuses); wall-clock fields may differ."""
    def run_once():
        resps, svc = serve(space, _mixed_requests(), CFG)
        assert all(r.ok for r in resps)
        return [(r.kind, r.bill["ticks"], r.bill["rows_priced"],
                 r.bill["status"], r.bill["cache_hit"]) for r in resps]

    assert run_once() == run_once()


def test_rejections_carry_trace_id_and_closed_bill(space):
    async def main():
        svc = PricingService(space, ServiceConfig(chunk=16, split=4,
                                                  max_pending=8))
        await svc.start()
        ok = svc.submit(PriceRequest(indices=[0, 1]))
        too_big = svc.submit(PriceRequest(indices=list(range(32))))
        invalid = svc.submit(PriceRequest(indices=[10_000_000]))
        out = await asyncio.gather(ok, too_big, invalid)
        await svc.stop()
        return out, svc

    (ok, too_big, invalid), svc = asyncio.run(main())
    assert ok.ok and ok.trace_id and ok.bill["status"] == "ok"
    for r in (too_big, invalid):
        assert not r.ok
        assert r.trace_id, "rejections must still carry a trace_id"
        assert r.bill is not None and r.bill["status"] == r.error.code
    led = svc.snapshot()["ledger"]
    assert led["open"] == 0                      # rejected bills closed too
    assert led["by_kind"]["price"]["errors"] == 2


def test_cache_hit_bills_zero_device_ms(space):
    # sequential submits: the second answers from the host result cache
    async def main():
        svc = PricingService(space, CFG)
        await svc.start()
        r1 = await svc.submit(PriceRequest(indices=[2, 4, 6]))
        r2 = await svc.submit(PriceRequest(indices=[2, 4, 6]))
        await svc.stop()
        return [r1, r2], svc

    resps, svc = asyncio.run(main())
    assert all(r.ok for r in resps)
    hit = next(r for r in resps if r.cached)
    assert hit.bill["cache_hit"] is True
    assert hit.bill["device_ms"] == 0.0
    assert hit.bill["ticks"] == 0
    led = svc.snapshot()["ledger"]
    assert led["by_kind"]["price"]["cache_hits"] == 1


# ---------------------------------------------------------------------------
# trace_id durability: journal replay and checkpoint resume
# ---------------------------------------------------------------------------


def test_trace_id_survives_crash_replay_and_checkpoint_resume(space,
                                                              tmp_path):
    dcfg = DurabilityConfig(directory=tmp_path / "dur", checkpoint_every=1)
    cfg = ServiceConfig(chunk=16, split=4, durability=dcfg)

    async def main():
        svc = PricingService(space, cfg)
        await svc.start()
        svc.faults = FaultInjector("seed=1;crash:p=0.3,n=1")
        crashed = await svc.submit(SearchRequest(seed=3, population=8,
                                                 generations=10, elite=3))
        assert not crashed.ok and crashed.error.code == SHUTTING_DOWN
        assert crashed.trace_id
        await svc.stop()
        # restart over the same durability dir: the journal replays the
        # search and the checkpoint restores its state mid-run
        svc.faults = FaultInjector("")
        await svc.start()
        replayed = await svc.drain_replayed()
        await svc.stop()
        return crashed, replayed, svc

    crashed, replayed, svc = asyncio.run(main())
    assert len(replayed) == 1
    rr = replayed[0]
    assert rr.ok and rr.replayed
    # ONE logical request, ONE trace across the process restart —
    # the replayed answer correlates with the pre-crash admission
    assert rr.trace_id == crashed.trace_id
    assert rr.bill["trace_id"] == crashed.trace_id
    assert rr.bill["replayed"] is True
    dur = svc.snapshot()["durability"]
    assert dur["checkpoints_restored"] == 1      # resume actually happened


def test_checkpoint_extra_roundtrips_trace_id(space, tmp_path):
    from repro.checkpoint.store import CheckpointManager
    from repro.dse.search import SearchState
    import jax
    st = SearchState.init(jax.random.PRNGKey(0), population=8,
                          size=space.size(), risk=None)
    st.trace_id = "deadbeefcafef00d"
    mgr = CheckpointManager(tmp_path / "ckpt", keep=2)
    st.save(mgr)
    back = SearchState.restore_latest(mgr, 8)
    assert back is not None
    assert back.trace_id == "deadbeefcafef00d"


def test_journal_admit_roundtrips_trace_id(space, tmp_path):
    from repro.service import RequestJournal, request_to_wire
    j = RequestJournal(tmp_path / "j")
    wire = request_to_wire(PriceRequest(indices=[1, 2]), space)
    j.admit(1, wire, trace_id="feedface01020304")
    j.admit(2, wire)                              # pre-tracing record shape
    j.close()
    j2 = RequestJournal(tmp_path / "j")
    entries = j2.replay()
    j2.close()
    assert [e.trace_id for e in entries] == ["feedface01020304", ""]


# ---------------------------------------------------------------------------
# SLO tracker: burn math, latching, windowing
# ---------------------------------------------------------------------------


def test_slo_burn_math_and_violation_counts():
    reg = Registry()
    slo = SLOTracker((SLObjective(kind="price", latency_ms=100.0,
                                  latency_target=0.9, availability=0.9,
                                  window_s=60.0, alert_burn_rate=10.0),),
                     registry=reg)
    for i in range(8):
        slo.observe("price", 0.010, True, now=float(i))
    slo.observe("price", 0.500, True, now=8.0)    # latency violation
    slo.observe("price", 0.010, False, now=9.0)   # availability error
    snap = slo.snapshot()["price"]
    # burn = bad_frac / (1 - target) = 0.1 / 0.1 = 1.0 for each dimension
    assert snap["latency_burn"] == pytest.approx(1.0)
    assert snap["availability_burn"] == pytest.approx(1.0)
    assert snap["latency_violations"] == 1
    assert snap["errors"] == 1
    assert snap["burn_events"] == 0               # alert threshold is 10x
    assert reg.gauge("slo_price_latency_burn").get() == pytest.approx(1.0)
    # other kinds don't feed this objective
    slo.observe("rank", 9.9, False, now=10.0)
    assert slo.snapshot()["price"]["errors"] == 1


def test_slo_burn_event_latches_once_per_excursion():
    fired = []
    slo = SLOTracker((SLObjective(kind="*", availability=0.5,
                                  window_s=1e9, alert_burn_rate=1.0),),
                     registry=Registry(),
                     on_burn=lambda k, dim, burn, tid: fired.append(
                         (k, dim, round(burn, 3), tid)))
    slo.observe("price", 0.0, True, now=0.0)
    slo.observe("price", 0.0, False, trace_id="aaaa", now=1.0)  # burn 1.0
    slo.observe("price", 0.0, False, trace_id="bbbb", now=2.0)  # still over
    assert len(fired) == 1                         # latched: one per excursion
    assert fired[0][0] == "*" and fired[0][1] == "availability"
    assert fired[0][3] == "aaaa"
    # recover: enough ok traffic drops burn below the threshold...
    for i in range(8):
        slo.observe("price", 0.0, True, now=3.0 + i)
    assert not slo.snapshot()["all"]["burning"]
    # ...so the next excursion fires a NEW event
    for i in range(20):
        slo.observe("price", 0.0, False, now=20.0 + i)
    assert len(fired) == 2
    assert slo.snapshot()["all"]["burn_events"] == 2


def test_slo_window_prunes_old_events():
    slo = SLOTracker((SLObjective(kind="*", availability=0.9,
                                  window_s=10.0),), registry=Registry())
    slo.observe("price", 0.0, False, now=0.0)
    assert slo.snapshot()["all"]["availability_burn"] > 0
    slo.observe("price", 0.0, True, now=100.0)    # old failure aged out
    snap = slo.snapshot()["all"]
    assert snap["window_n"] == 1
    assert snap["availability_burn"] == 0.0
    assert snap["errors"] == 1                     # lifetime counter stays


def test_service_slo_burn_records_flight_event(space):
    # an impossible latency target makes every answer a violation with
    # burn >> 1: the service's on_burn hook must land a flight record
    cfg = ServiceConfig(chunk=16, split=4,
                        slos=(SLObjective(kind="*", latency_ms=0.0,
                                          latency_target=0.99,
                                          alert_burn_rate=1.0),))
    resps, svc = serve(space, [PriceRequest(indices=[0, 1, 2])], cfg)
    assert resps[0].ok
    slo = svc.snapshot()["slo"]
    assert slo["enabled"]
    assert slo["objectives"]["all"]["burn_events"] >= 1
    burns = svc.flight.records("slo_burn")
    assert burns and burns[0]["dimension"] == "latency"
    assert burns[0]["trace_id"] == resps[0].trace_id


def test_slo_disabled_by_default(space):
    resps, svc = serve(space, [PriceRequest(indices=[0])], CFG)
    assert resps[0].ok
    assert svc.slo is None
    assert svc.snapshot()["slo"] == {"enabled": False}


# ---------------------------------------------------------------------------
# Histogram exemplars / quantiles: edge cases
# ---------------------------------------------------------------------------


def test_histogram_empty_and_single_sample():
    h = Histogram("h")
    s = h.sample()
    assert s["count"] == 0 and "exemplars" not in s
    assert h.quantile(0.5) == 0.0
    h.observe(3.5)
    s = h.sample()
    assert s["p50"] == s["p99"] == 3.5
    assert "exemplars" not in s                    # none attached


def test_histogram_exemplars_bounded_latest_wins():
    h = Histogram("h", max_exemplars=4)
    for i in range(10):
        h.observe(float(i), exemplar=f"trace{i}")
    ex = h.exemplars()
    assert len(ex) == 4
    assert [e["ref"] for e in ex] == ["trace6", "trace7", "trace8", "trace9"]
    assert h.sample()["exemplars"] == ex
    # empty-string exemplars are dropped, not stored
    h.observe(99.0, exemplar="")
    assert len(h.exemplars()) == 4


def test_histogram_exemplars_in_exposition():
    reg = Registry()
    reg.histogram("lat", help="x").observe(1.25, exemplar="abcd1234")
    text = reg.exposition()
    assert '# EXEMPLAR lat{trace_id="abcd1234"} 1.25' in text
    # classic Prometheus parsers see only comments + standard lines
    for line in text.splitlines():
        assert line.startswith("#") or " " in line


def test_histogram_snapshot_shape_unchanged_without_exemplars():
    # regression guard for snapshot consumers: exemplar-free histograms
    # must keep the exact pre-exemplar key set
    h = Histogram("h")
    h.observe(1.0)
    assert set(h.sample()) == {"count", "sum", "min", "max", "mean",
                               "p50", "p95", "p99"}
