"""Grouped (per-expert) matmul as a Pallas kernel.

(E, C, D) @ (E, D, F) -> (E, C, F): grid (E, nC, nF, nD) with the
contraction axis innermost and an fp32 (BC, BF) accumulator in VMEM —
the standard blocked matmul, batched over the expert axis so one kernel
launch serves the whole expert buffer after MoE dispatch.

Block sizes default to the MXU-native 128; C (capacity) is padded by
the wrapper when needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref):
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += x_ref[0].astype(jnp.float32) @ \
        w_ref[0].astype(jnp.float32)

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm(x, w, *, bc: int = 128, bf: int = 128, bd: int = 128,
        interpret: bool = False):
    """(E,C,D) @ (E,D,F) -> (E,C,F)."""
    e, c, d = x.shape
    f = w.shape[-1]
    bc, bf, bd = min(bc, c), min(bf, f), min(bd, d)
    pc = (-c) % bc
    if pc:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, 0)))
    assert d % bd == 0 and f % bf == 0, (d, bd, f, bf)
    cp = c + pc

    out = pl.pallas_call(
        _kernel,
        grid=(e, cp // bc, f // bf, d // bd),
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda ee, ic, jf, kd: (ee, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda ee, ic, jf, kd: (ee, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf),
                               lambda ee, ic, jf, kd: (ee, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((e, cp, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :c] if pc else out
