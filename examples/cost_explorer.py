"""Architecture exploration: sweep the (area x n_chiplets x tech x node)
design space with the engine-backed explorer, print the Pareto frontier,
price heterogeneous (mixed-node) partitions, and run the (beyond-paper)
differentiable partitioner.

  PYTHONPATH=src python examples/cost_explorer.py
"""
from repro.core import pareto_front, sweep_hetero_partitions, sweep_partitions
from repro.core.gradient import optimize_chiplet_count, optimize_uneven_split


def main():
    points = []
    for node in ("14nm", "7nm", "5nm"):
        for integ in ("MCM", "InFO", "2.5D"):
            res = sweep_partitions(node, integ,
                                   areas_mm2=[200, 400, 600, 800],
                                   n_chiplets=[1, 2, 3, 4, 5, 6])
            totals = res["total"]
            for i, a in enumerate(res["areas"]):
                for j, n in enumerate(res["n_chiplets"]):
                    points.append({
                        "node": node, "integ": integ, "area": float(a),
                        "n": int(n), "cost": float(totals[i, j]),
                    })
    # Pareto: cheapest way to buy silicon area
    front = pareto_front(
        [{"x": -p["area"], "y": p["cost"], **p} for p in points], "x", "y")
    print("cost-area Pareto frontier (max area, min cost):")
    for p in front:
        print(f"  {p['area']:5.0f}mm2  ${p['cost']:8.0f}  "
              f"{p['node']} {p['integ']} n={p['n']}")

    print("\nheterogeneous partitions of an 800mm2 module (MCM):")
    rows = sweep_hetero_partitions(800.0, [
        [(1.0, "5nm")],
        [(0.5, "5nm"), (0.5, "5nm")],
        [(0.5, "5nm"), (0.5, "7nm")],
        [(0.5, "5nm"), (0.25, "7nm"), (0.25, "12nm")],
    ], integration="MCM")
    for r in rows:
        parts = " + ".join(f"{f:.2f}@{p}" for f, p in r["partition"])
        print(f"  ${r['total']:8.0f}  {parts}")

    print("\ndifferentiable partitioner (relaxed chiplet count):")
    for node in ("7nm", "5nm"):
        r = optimize_chiplet_count(node, "MCM", 800.0)
        print(f"  {node} 800mm2 MCM: n*={r.n_relaxed:.2f} -> "
              f"round {r.n_rounded}, cost ${r.cost_rounded:.0f} "
              f"(SoC ${r.cost_soc:.0f})")

    print("\nuneven module-to-chiplet assignment (full engine objective):")
    u = optimize_uneven_split("5nm", "MCM", [300.0, 200.0, 100.0, 100.0,
                                             100.0], 3)
    print(f"  assignment {u['assignment']}  chip areas "
          f"{[round(a) for a in u['chip_areas']]}  "
          f"hard cost ${u['hard_cost']:.0f}")


if __name__ == "__main__":
    main()
