"""Zamba2-7B — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; unverified]

81 Mamba2 layers, d_model 3584, ssm_state 64; a shared transformer block
(32 heads GQA kv=32, d_ff 14336) applied every 6 layers, alternating
between 2 shared weight sets (Zamba2's weight-shared attention).
Sub-quadratic: runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, ssm_state=64, ssm_headdim=64, ssm_expand=2,
    attn_every=6, n_shared_attn=2,
    subquadratic=True,
    # perf (EXPERIMENTS §Perf iter 5): SSD decay-tile traffic scales with
    # S*L -> chunk 64 halves it; accum=2 halves activation residency.
    ssm_chunk=64, accum=2,
)
