"""jit'd public wrappers over the Pallas kernels.

Every op takes ``impl``: "pallas" (the TPU kernel; ``interpret=True``
under tests on CPU) or "xla" (the pure-jnp oracle — also the dry-run
lowering path, since Pallas-TPU cannot lower on the CPU backend).

``flash_attention`` carries a custom_vjp whose backward is the oracle's
VJP: training through the Pallas forward is exact; a dedicated Pallas
backward kernel is a further optimization, not a correctness need.

Model-zoo layouts (B,S,H,D) are converted to kernel layouts (B,H,S,D)
here so call sites stay clean.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_fwd
from .flash_decode import flash_decode as _flash_decode
from .mamba_scan import mamba_scan as _mamba_scan
from .moe_gmm import gmm as _gmm
from .rmsnorm import rmsnorm as _rmsnorm
from .slstm_cell import slstm_seq as _slstm_seq


def _on_cpu() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# flash attention (B,S,H,D) public layout
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_attn_core(q, k, v, causal, scale, interpret):
    return flash_attention_fwd(q, k, v, causal=causal, scale=scale,
                               interpret=interpret)


def _flash_attn_fwd_rule(q, k, v, causal, scale, interpret):
    out = _flash_attn_core(q, k, v, causal, scale, interpret)
    return out, (q, k, v)


def _flash_attn_bwd_rule(causal, scale, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                             scale=scale), q, k, v)
    return vjp(g)


_flash_attn_core.defvjp(_flash_attn_fwd_rule, _flash_attn_bwd_rule)


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    impl: str = "pallas", interpret: Optional[bool] = None):
    """q:(B,S,H,D) k/v:(B,T,Hkv,D) -> (B,S,H,Dv)."""
    interp = _on_cpu() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if impl == "xla":
        out = ref.attention_ref(qt, kt, vt, causal=causal, scale=scale)
    else:
        out = _flash_attn_core(qt, kt, vt, causal, scale, interp)
    return jnp.swapaxes(out, 1, 2)


def flash_decode(q, k, v, kv_len, *, scale=None, impl: str = "pallas",
                 interpret: Optional[bool] = None):
    """q:(B,1,H,D) k/v:(B,T,Hkv,D) kv_len:(B,) -> (B,1,H,Dv)."""
    interp = _on_cpu() if interpret is None else interpret
    qk = q[:, 0].swapaxes(1, 1)                        # (B,H,D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if impl == "xla":
        out = ref.decode_ref(qk, kt, vt, kv_len, scale=scale)
    else:
        out = _flash_decode(qk, kt, vt, kv_len, scale=scale,
                            interpret=interp)
    return out[:, None]


def mamba_scan(xh, dt, a_log, bm, cm, *, chunk: int = 128,
               impl: str = "pallas", interpret: Optional[bool] = None):
    """Chunked SSD; signature mirrors models.ssm.ssd_chunked."""
    interp = _on_cpu() if interpret is None else interpret
    if impl == "xla":
        return ref.ssd_ref(xh, dt, a_log, bm, cm)
    return _mamba_scan(xh, dt, a_log, bm, cm, chunk=chunk,
                       interpret=interp)


def moe_gmm(x, w, *, impl: str = "pallas",
            interpret: Optional[bool] = None):
    interp = _on_cpu() if interpret is None else interpret
    if impl == "xla":
        return ref.gmm_ref(x, w)
    return _gmm(x, w, interpret=interp)


def fused_rmsnorm(x, scale, *, eps: float = 1e-5, impl: str = "pallas",
                  interpret: Optional[bool] = None):
    interp = _on_cpu() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if impl == "xla":
        out = ref.rmsnorm_ref(x2, scale, eps)
    else:
        out = _rmsnorm(x2, scale, eps=eps, interpret=interp)
    return out.reshape(shape)


def slstm_seq(xg, r, bias, *, impl: str = "pallas",
              interpret: Optional[bool] = None):
    """Fused sLSTM over a sequence: xg:(B,S,4,H,Dh) -> h:(B,S,H,Dh)."""
    interp = _on_cpu() if interpret is None else interpret
    if impl == "xla":
        return ref.slstm_seq_ref(xg, r, bias)
    return _slstm_seq(xg, r, bias, interpret=interp)
