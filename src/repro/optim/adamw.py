"""AdamW with fp32 master weights, built directly on pytrees.

Optimizer state is a spec tree too, so the dry-run can shard it like the
params (ZeRO-3-equivalent: params are already FSDP+TP sharded, and m/v/
master inherit the same sharding => fully sharded optimizer state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.common import ParamSpec, is_spec, spec_map


class OptState(NamedTuple):
    step: Any          # () int32
    master: Any        # fp32 copy of params (same tree)
    m: Any             # first moment (fp32)
    v: Any             # second moment (fp32)


def adamw_init(params) -> OptState:
    # copy=True: the master must never alias the param buffer (donation)
    f32 = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.array(x, jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return OptState(step=jnp.zeros((), jnp.int32), master=f32(params),
                    m=zeros(params), v=zeros(params))


def adamw_init_spec(spec_tree) -> OptState:
    """Spec-tree version for the dry-run (no allocation)."""
    f32spec = spec_map(
        lambda s: ParamSpec(s.shape, s.axes, jnp.float32, init="zeros"),
        spec_tree)
    return OptState(
        step=ParamSpec((), (), jnp.int32, init="zeros"),
        master=spec_map(lambda s: ParamSpec(s.shape, s.axes, jnp.float32,
                                            init=s.init, scale=s.scale),
                        spec_tree),
        m=f32spec, v=f32spec)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(grads, state: OptState, lr, *, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_norm: float = 1.0,
                 param_dtype=jnp.bfloat16) -> Tuple[Any, OptState]:
    """One AdamW step. Returns (new_params_in_param_dtype, new_state).

    Global-norm clipping is fused into the moment update (a scalar scale,
    not a clipped copy of the whole gradient tree — at 123B params that
    copy alone is ~2 GB/device).
    """
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        p = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return m, v, p

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree_util.tree_map(
        lambda p: p.astype(param_dtype), new_master)
    return new_params, OptState(step=step, master=new_master, m=new_m,
                                v=new_v)
