"""Multi-device programs executed in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (tests must not
pollute the main process's single-device jax).

Each function prints MAXDIFF <value> on success; the wrapper asserts.
"""
import os
import sys


def _setup(n=8):
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"


def pipeline():
    _setup(4)
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import make_mesh
    from repro.parallel.pipeline import mlp_stage, pipeline_forward

    mesh = make_mesh((4,), ("stage",))
    rng = np.random.default_rng(0)
    S, M, mb, d = 4, 6, 8, 16
    params = {"w1": jnp.asarray(rng.standard_normal((S, d, d)) * 0.3,
                                jnp.float32),
              "w2": jnp.asarray(rng.standard_normal((S, d, d)) * 0.3,
                                jnp.float32)}
    xs = jnp.asarray(rng.standard_normal((M, mb, d)), jnp.float32)

    run = pipeline_forward(mlp_stage, mesh, "stage")
    with mesh:
        got = jax.jit(run)(params, xs)

    # sequential reference: stage 0..3 applied in order
    want = xs
    for s in range(S):
        p = {"w1": params["w1"][s], "w2": params["w2"][s]}
        want = jax.vmap(lambda x: mlp_stage(p, x))(want)
    print("MAXDIFF", float(jnp.abs(got - want).max()))


def flash_decode_sm():
    _setup(8)
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import make_mesh
    from repro.parallel.collectives import flash_decode_shardmap
    from repro.kernels import ref

    mesh = make_mesh((8,), ("model",))
    rng = np.random.default_rng(1)
    b, h, t, d = 2, 4, 64, 16
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
    fn = flash_decode_shardmap(mesh, "model")
    with mesh:
        got = jax.jit(fn)(q, k, v)
    want = ref.decode_ref(q, jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2))
    print("MAXDIFF", float(jnp.abs(got - want).max()))


def compressed_psum():
    _setup(8)
    import functools
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.launch.mesh import make_mesh
    from repro.parallel.collectives import compressed_psum as cp

    mesh = make_mesh((2, 4), ("pod", "data"))
    rng = np.random.default_rng(2)
    # per-(pod,data)-shard gradients: 8 local copies stacked on axis 0
    g = jnp.asarray(rng.standard_normal((2, 4, 64)), jnp.float32)
    errs = jnp.zeros((2, 4, 64), jnp.float32)
    reducer = cp(mesh, pod_axis="pod", inner_axes=("data",),
                 k_fraction=1.0)   # k=100%: compression lossless-ish

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("pod", "data"), P("pod", "data")),
                       out_specs=(P("pod", "data"), P("pod", "data")),
                       check_rep=False)
    def run(g_local, e_local):
        gg, ee = reducer({"g": g_local[0, 0]}, {"g": e_local[0, 0]})
        return gg["g"][None, None], ee["g"][None, None]

    with mesh:
        out, err = jax.jit(run)(g, errs)
    want = g.sum(axis=(0, 1))
    got = np.asarray(out)[0, 0]
    # int8 quantization: tolerance scales with max |sum|
    tol = float(np.abs(want).max()) / 127 * 2 + 1e-5
    raw = float(np.abs(got - np.asarray(want)).max())
    print("MAXDIFF", 0.0 if raw < tol else raw)
    print("RAWDIFF", raw, "TOL", tol)


def sharded_train_matches_single():
    _setup(8)
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.parallel import sharding as shd, steps as st
    from repro.data import DataConfig, synthetic_batch

    cfg = get_config("deepseek_7b").reduced().replace(dtype="float32")
    dc = DataConfig(seq_len=16, global_batch=4, vocab=cfg.vocab)
    batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dc, 0).items()}

    # single-device loss
    state = st.init_train_state(cfg, jax.random.PRNGKey(0))
    step = st.make_train_step(cfg, total_steps=5)
    _, m1 = jax.jit(step)(state, batch)

    # sharded loss on a 4x2 mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    rules = shd.default_rules()
    state2 = st.init_train_state(cfg, jax.random.PRNGKey(0))
    with mesh, shd.use_mesh(mesh, rules):
        sh = st.abstract_state(cfg, mesh, rules)
        state2 = jax.tree_util.tree_map(
            lambda x, a: jax.device_put(x, a.sharding), state2, sh)
        bsh = st.abstract_batch(cfg, dc_to_shape(dc), mesh, rules)
        batch2 = {k: jax.device_put(v, bsh[k].sharding)
                  for k, v in batch.items()}
        _, m2 = jax.jit(step)(state2, batch2)
    print("MAXDIFF", abs(float(m1["loss"]) - float(m2["loss"])))


def dc_to_shape(dc):
    from repro.configs.base import InputShape
    return InputShape("t", dc.seq_len, dc.global_batch, "train")


def hlo_analyzer_exact():
    _setup(8)
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.launch.mesh import make_mesh
    from repro.analysis.hlo import analyze_hlo_text

    mesh = make_mesh((2, 4), ("data", "model"))

    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h.sum()

    L, D, B = 5, 64, 32
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32,
                             sharding=NamedSharding(mesh, P(None, None,
                                                            "model")))
    x = jax.ShapeDtypeStruct((B, D), jnp.float32,
                             sharding=NamedSharding(mesh, P("data", None)))
    with mesh:
        comp = jax.jit(f).lower(w, x).compile()
    rep = analyze_hlo_text(comp.as_text())
    # per-device dot flops: L iterations x 2 * (B/2) * D * (D/4)
    want = L * 2 * (B // 2) * D * (D // 4)
    print("MAXDIFF", abs(rep.flops - want) / want)
    assert rep.trip_counts == [L], rep.trip_counts




def elastic_restore():
    """Checkpoint written on a (4,2) mesh restores onto (2,4) — values
    identical after re-commit with the new shardings."""
    _setup(8)
    import tempfile
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.checkpoint import restore, save
    from repro.configs import get_config
    from repro.launch.mesh import make_mesh
    from repro.parallel import sharding as shd, steps as st

    cfg = get_config("glm4_9b").reduced().replace(dtype="float32")
    rules = shd.default_rules()
    mesh_a = make_mesh((4, 2), ("data", "model"))
    state = st.init_train_state(cfg, jax.random.PRNGKey(0))
    with mesh_a:
        sh_a = st.abstract_state(cfg, mesh_a, rules)
        state_a = jax.tree_util.tree_map(
            lambda x, a: jax.device_put(x, a.sharding), state, sh_a)
    d = tempfile.mkdtemp()
    save(d, 1, state_a)

    mesh_b = make_mesh((2, 4), ("data", "model"))
    with mesh_b:
        sh_b = st.abstract_state(cfg, mesh_b, rules)
        restored = restore(d, 1, state_a,
                           shardings=jax.tree_util.tree_map(
                               lambda a: a.sharding, sh_b))
    diffs = [float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(state.params),
        jax.tree_util.tree_leaves(restored.params))]
    # sharding of a restored leaf reflects the NEW mesh
    leaf = jax.tree_util.tree_leaves(restored.params)[0]
    assert "model" in str(leaf.sharding.mesh.axis_names)
    print("MAXDIFF", max(diffs))


if __name__ == "__main__":
    globals()[sys.argv[1]]()
