from .hlo import HLOCostReport, analyze_hlo_text
from .roofline import RooflineTerms, roofline_from_report, HW
