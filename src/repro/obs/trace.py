"""Low-overhead structured span tracer (the `repro.obs` timing surface).

One process-wide :class:`Tracer` collects nestable, labeled spans —
``pack``, ``jit_compile``, ``kernel_dispatch``, ``device_get``,
``chunk``, ``generation``, ``tick`` — into a bounded ring buffer and
exports them two ways:

* :meth:`Tracer.export_chrome` — Chrome/Perfetto ``trace_event`` JSON
  (load the file at https://ui.perfetto.dev or ``chrome://tracing``);
* :meth:`Tracer.phase_table` — aggregate per-phase wall tables (count /
  total / mean / max seconds per span name), the form the benchmarks
  fold into ``BENCH_*.json``.

Tracing is **off by default and zero-cost when off**: ``span()`` is one
predicate check returning a shared no-op context manager, and nothing
else in the module runs.  Enable with ``REPRO_TRACE=1`` in the
environment (read at import) or :func:`enable` at runtime.  Nothing
here ever touches the device or forces a host sync — spans time
whatever the caller already does, they never add ``block_until_ready``.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time
from collections import deque
from typing import Dict, List, Optional

_ENV_VAR = "REPRO_TRACE"
_TRUE = ("1", "true", "yes", "on")


def _env_enabled() -> bool:
    return os.environ.get(_ENV_VAR, "").strip().lower() in _TRUE


class _NullSpan:
    """Shared do-nothing context manager returned while tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records a complete ("ph": "X") event on exit."""

    __slots__ = ("tracer", "name", "labels", "t0", "parent")

    def __init__(self, tracer: "Tracer", name: str, labels: Dict):
        self.tracer = tracer
        self.name = name
        self.labels = labels
        self.t0 = 0.0
        self.parent = None

    def __enter__(self):
        stack = self.tracer._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        stack = self.tracer._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        self.tracer._record(self.name, self.t0, t1 - self.t0, self.parent,
                            self.labels)
        return False


class Tracer:
    """Bounded in-process span collector (see module docstring)."""

    def __init__(self, capacity: int = 500_000, enabled: bool = None):
        self.capacity = int(capacity)
        self._enabled = _env_enabled() if enabled is None else bool(enabled)
        self._events: deque = deque(maxlen=self.capacity)
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._local = threading.local()

    # -- state ---------------------------------------------------------------
    def enabled(self) -> bool:
        return self._enabled

    def enable(self, on: bool = True):
        self._enabled = bool(on)

    def clear(self):
        with self._lock:
            self._events.clear()

    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    # -- recording -----------------------------------------------------------
    def span(self, name: str, **labels):
        """Context manager timing a phase; no-op while tracing is off."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, labels)

    def _record(self, name: str, t0: float, dur_s: float,
                parent: Optional[str], labels: Dict):
        with self._lock:
            self._events.append((name, t0 - self._t0, dur_s, parent,
                                 labels or None))

    def add_complete(self, name: str, dur_s: float, t_end: float = None,
                     **labels):
        """Record an already-measured phase (probes that timed a call
        themselves); attributed to the innermost open span as parent."""
        if not self._enabled:
            return
        t1 = time.perf_counter() if t_end is None else t_end
        stack = self._stack()
        self._record(name, t1 - dur_s, dur_s,
                     stack[-1] if stack else None, labels)

    def instant(self, name: str, **labels):
        """Zero-duration marker event."""
        if not self._enabled:
            return
        stack = self._stack()
        self._record(name, time.perf_counter(), 0.0,
                     stack[-1] if stack else None, labels)

    # -- introspection -------------------------------------------------------
    def events(self) -> List[Dict]:
        """Snapshot of collected events as dicts (oldest first)."""
        with self._lock:
            raw = list(self._events)
        return [{"name": n, "t_s": ts, "dur_s": dur, "parent": parent,
                 "labels": labels or {}}
                for n, ts, dur, parent, labels in raw]

    def phase_table(self) -> Dict[str, Dict[str, float]]:
        """Aggregate wall per span name: count / total / mean / max (s)."""
        table: Dict[str, Dict[str, float]] = {}
        for ev in self.events():
            row = table.setdefault(ev["name"], {"count": 0, "total_s": 0.0,
                                                "max_s": 0.0})
            row["count"] += 1
            row["total_s"] += ev["dur_s"]
            row["max_s"] = max(row["max_s"], ev["dur_s"])
        for row in table.values():
            row["mean_s"] = row["total_s"] / row["count"]
        return table

    def coverage(self, parent: str = "tick") -> float:
        """Fraction of ``parent`` span wall accounted for by its direct
        child spans — the "do the spans explain the tick?" check."""
        parent_s = child_s = 0.0
        for ev in self.events():
            if ev["name"] == parent:
                parent_s += ev["dur_s"]
            elif ev["parent"] == parent:
                child_s += ev["dur_s"]
        return child_s / parent_s if parent_s > 0 else 0.0

    def trace_tree(self, trace_id: str) -> List[Dict]:
        """Every recorded event belonging to one request's trace.

        A span/instant belongs to trace ``X`` when its labels carry
        ``trace_id == X`` (request-scoped events: ``request_admit``,
        ``request_done``, ``admission_compile``, …) or when ``X`` is in
        its ``trace_ids`` label (shared events: a coalesced ``tick``
        span lists every request that rode it).  Events come back
        oldest-first, so admission → ticks → terminal reads in causal
        order and ``export_chrome`` of the same ring shows the tree.
        """
        out = []
        for ev in self.events():
            labels = ev["labels"]
            if labels.get("trace_id") == trace_id or \
                    trace_id in (labels.get("trace_ids") or ()):
                out.append(ev)
        return out

    def count(self, name: str, parent: Optional[str] = "__any__") -> int:
        """Number of recorded ``name`` events, optionally restricted to
        those nested under ``parent``."""
        return sum(1 for ev in self.events()
                   if ev["name"] == name
                   and (parent == "__any__" or ev["parent"] == parent))

    # -- export --------------------------------------------------------------
    def chrome_events(self) -> List[Dict]:
        """Events in Chrome ``trace_event`` form (complete "X" phases,
        microsecond timestamps)."""
        tid = threading.get_ident() % 2 ** 31
        out = []
        for ev in self.events():
            args = dict(ev["labels"])
            if ev["parent"]:
                args["parent"] = ev["parent"]
            out.append({"name": ev["name"], "ph": "X", "cat": "repro",
                        "ts": ev["t_s"] * 1e6, "dur": ev["dur_s"] * 1e6,
                        "pid": os.getpid(), "tid": tid, "args": args})
        return out

    def export_chrome(self, path) -> pathlib.Path:
        """Write the ring as a Chrome/Perfetto ``trace_event`` JSON file."""
        path = pathlib.Path(path)
        payload = {"traceEvents": self.chrome_events(),
                   "displayTimeUnit": "ms"}
        path.write_text(json.dumps(payload, default=float) + "\n")
        return path


# The process-wide tracer every instrumented module shares.
TRACER = Tracer()


def enabled() -> bool:
    """Is tracing currently on (``REPRO_TRACE=1`` or ``enable()``)?"""
    return TRACER.enabled()


def span(name: str, **labels):
    """``with span("tick"): ...`` on the shared tracer."""
    return TRACER.span(name, **labels)
