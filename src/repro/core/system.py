"""Module / Chip / Package abstraction (paper Sec. 3.1, Eq. (3)).

    m_i in {m_1, ..., m_D2D} = M
    c_i  = Chip({m_i, m_D2D})
    SoC_j = Package(Chip({m_k1, m_k2, ...}))
    MCM_j = Package({c_k1, c_k2, ...})

A :class:`Module` is an indivisible group of functional units; the D2D
interface is a special module automatically attached to every chiplet (its
area is a technology-dependent fraction of the chiplet, Sec. 3.2).  A
:class:`Chip` is a set of modules fabricated on one process node.  A
:class:`System` is a package holding one chip (SoC) or several chiplets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .technology import IntegrationTech, ProcessNode, node, tech

D2D_MODULE_PREFIX = "__d2d__"


@dataclasses.dataclass(frozen=True)
class Module:
    """An indivisible functional block, tied to a process node."""

    name: str
    area_mm2: float
    process: str  # key into PROCESS_NODES

    @property
    def node(self) -> ProcessNode:
        return node(self.process)

    @property
    def is_d2d(self) -> bool:
        return self.name.startswith(D2D_MODULE_PREFIX)


def d2d_module(process: str, area_mm2: float) -> Module:
    """The D2D interface module for one process node (Sec. 3.1: D2D
    interfaces under different nodes are diverse modules)."""
    return Module(name=f"{D2D_MODULE_PREFIX}{process}", area_mm2=area_mm2,
                  process=process)


@dataclasses.dataclass(frozen=True)
class Chip:
    """A die: a tuple of modules on a single node.

    ``name`` identifies the *design* — two systems containing chips of the
    same name reuse one NRE effort (chiplet reuse).
    """

    name: str
    modules: Tuple[Module, ...]
    process: str
    early_defects: bool = False  # use early-ramp defect density (AMD study)

    def __post_init__(self):
        for m in self.modules:
            if m.process != self.process:
                raise ValueError(
                    f"module {m.name} on {m.process} cannot sit on a "
                    f"{self.process} chip {self.name}")

    @property
    def node(self) -> ProcessNode:
        return node(self.process)

    @property
    def area_mm2(self) -> float:
        return float(sum(m.area_mm2 for m in self.modules))

    @property
    def module_area_mm2(self) -> float:
        """Area of functional (non-D2D) modules."""
        return float(sum(m.area_mm2 for m in self.modules if not m.is_d2d))

    @property
    def defect_density(self) -> float:
        n = self.node
        return n.defect_density_early if self.early_defects else n.defect_density


def make_chip(name: str, modules: Sequence[Module], process: str,
              integration: str = "SoC", early_defects: bool = False,
              d2d_overhead: Optional[float] = None) -> Chip:
    """Build a chip, automatically attaching the D2D module for multi-chip
    integration technologies (Sec. 3.2: D2D takes a fixed share of the chip
    area, 10% in the paper's EPYC-calibrated experiments)."""
    t = tech(integration)
    overhead = t.d2d_area_overhead if d2d_overhead is None else d2d_overhead
    mods = tuple(modules)
    if overhead > 0.0:
        func_area = sum(m.area_mm2 for m in mods)
        # D2D occupies `overhead` fraction of the final chip area:
        # d2d = overhead/(1-overhead) * functional area.
        d2d_area = func_area * overhead / (1.0 - overhead)
        mods = mods + (d2d_module(process, d2d_area),)
    return Chip(name=name, modules=mods, process=process,
                early_defects=early_defects)


@dataclasses.dataclass(frozen=True)
class System:
    """One product: a package with chips inside, made in some quantity."""

    name: str
    chips: Tuple[Chip, ...]
    integration: str            # key into INTEGRATION_TECHS
    quantity: float = 1.0       # production quantity (for NRE amortization)
    package_name: Optional[str] = None  # shared name => package reuse
    package_area_mm2: Optional[float] = None  # forced area (package reuse)

    @property
    def tech(self) -> IntegrationTech:
        return tech(self.integration)

    @property
    def silicon_area_mm2(self) -> float:
        return float(sum(c.area_mm2 for c in self.chips))

    @property
    def package_area(self) -> float:
        if self.package_area_mm2 is not None:
            return self.package_area_mm2
        return self.silicon_area_mm2 * self.tech.package_area_factor

    @property
    def package_id(self) -> str:
        """Identity of the package *design* for NRE sharing."""
        return self.package_name or f"pkg:{self.name}"

    @property
    def n_chips(self) -> int:
        return len(self.chips)


# ---------------------------------------------------------------------------
# Declarative spec builder — the canonical constructor behind the public API.
# ---------------------------------------------------------------------------


def spec(d: Dict) -> System:
    """Build a :class:`System` from a declarative dict.

    Three shapes are accepted (``kind`` is inferred when omitted):

    * ``{"kind": "soc", "name": ..., "area": mm2, "process": node,
       "quantity": q, "early": bool}`` — monolithic SoC.
    * ``{"kind": "split", "name": ..., "area": mm2, "process": node,
       "n": k, "integration": tech, "fractions": [...], "processes": [...],
       "quantity": q, "early": bool, "d2d_overhead": f,
       "reuse_chiplet": bool}`` — `area` partitioned into chiplets.
       ``fractions`` (normalized internally) makes the slices unequal and
       ``processes`` gives each slice its own node — heterogeneous splits.
    * ``{"kind": "chips", "name": ..., "chips": [{"name":..., "area": mm2,
       "process": node, "early": bool, "d2d_overhead": f}, ...],
       "integration": tech, "quantity": q, "package_name": ...,
       "package_area": mm2}`` — fully general heterogeneous system.

    This is what :func:`soc_system` / :func:`split_system` now wrap, and
    what ``SystemBatch.from_specs`` consumes.
    """
    d = dict(d)
    kind = d.pop("kind", None)
    if kind is None:
        if "chips" in d:
            kind = "chips"
        elif "n" in d or "fractions" in d or "processes" in d:
            kind = "split"
        else:
            kind = "soc"

    name = d.pop("name", "sys")
    quantity = float(d.pop("quantity", 1.0))
    early = bool(d.pop("early", d.pop("early_defects", False)))

    if kind == "soc":
        area = _required_area(kind, d)
        process = d.pop("process")
        node(process)   # fail at spec time, not at batch-pack time
        _reject_extra(kind, d)
        m = Module(name=f"{name}_modules", area_mm2=area, process=process)
        chip = make_chip(f"{name}_die", [m], process, integration="SoC",
                         early_defects=early)
        return System(name=name, chips=(chip,), integration="SoC",
                      quantity=quantity)

    if kind == "split":
        area = _required_area(kind, d)
        process = d.pop("process", None)
        integration = d.pop("integration")
        fractions = d.pop("fractions", None)
        processes = d.pop("processes", None)
        n = int(d.pop("n", d.pop("n_chiplets",
                                 len(fractions) if fractions is not None
                                 else len(processes) if processes else 0)))
        d2d_overhead = d.pop("d2d_overhead", None)
        reuse_chiplet = bool(d.pop("reuse_chiplet", False))
        _reject_extra(kind, d)
        if n <= 0:
            raise ValueError("split spec needs n >= 1 (or fractions/processes)")
        if fractions is None:
            fractions = [1.0 / n] * n
        if len(fractions) != n:
            raise ValueError(f"{len(fractions)} fractions for n={n} chiplets")
        total_f = float(sum(fractions))
        fractions = [f / total_f for f in fractions]
        if processes is None:
            processes = [process] * n
        if len(processes) != n or any(p is None for p in processes):
            raise ValueError("need a process for every chiplet")
        for p in processes:
            node(p)     # fail at spec time, not at batch-pack time
        if reuse_chiplet and (len(set(processes)) > 1
                              or max(fractions) - min(fractions) > 1e-12):
            raise ValueError("reuse_chiplet requires identical slices")
        chips = []
        for i, (f, p) in enumerate(zip(fractions, processes)):
            cname = f"{name}_slice" if reuse_chiplet else f"{name}_slice{i}"
            m = Module(name=f"{cname}_modules", area_mm2=area * f, process=p)
            chips.append(make_chip(cname, [m], p, integration=integration,
                                   early_defects=early,
                                   d2d_overhead=d2d_overhead))
        return System(name=name, chips=tuple(chips), integration=integration,
                      quantity=quantity)

    if kind == "chips":
        chip_specs = d.pop("chips")
        integration = d.pop("integration")
        package_name = d.pop("package_name", None)
        package_area = d.pop("package_area", d.pop("package_area_mm2", None))
        _reject_extra(kind, d)
        chips = []
        for i, c in enumerate(chip_specs):
            if isinstance(c, Chip):
                chips.append(c)
                continue
            c = dict(c)
            cname = c.pop("name", f"{name}_chip{i}")
            carea = _required_area("chip", c)
            cproc = c.pop("process")
            node(cproc)     # fail at spec time, not at batch-pack time
            cearly = bool(c.pop("early", c.pop("early_defects", early)))
            covh = c.pop("d2d_overhead", None)
            _reject_extra("chip", c)
            m = Module(name=f"{cname}_modules", area_mm2=carea, process=cproc)
            chips.append(make_chip(cname, [m], cproc, integration=integration,
                                   early_defects=cearly, d2d_overhead=covh))
        return System(name=name, chips=tuple(chips), integration=integration,
                      quantity=quantity, package_name=package_name,
                      package_area_mm2=package_area)

    raise ValueError(f"unknown spec kind {kind!r}")


def _reject_extra(kind: str, leftover: Dict):
    if leftover:
        raise ValueError(f"unknown keys in {kind!r} spec: {sorted(leftover)}")


def _required_area(kind: str, d: Dict) -> float:
    area = d.pop("area", d.pop("area_mm2", d.pop("module_area_mm2", None)))
    if area is None:
        raise ValueError(f"{kind!r} spec needs an 'area' (mm^2)")
    return float(area)


def soc_system(name: str, module_area_mm2: float, process: str,
               quantity: float = 1.0, early_defects: bool = False) -> System:
    """Monolithic SoC holding `module_area` worth of modules on one die.

    Thin wrapper over :func:`spec`.
    """
    return spec({"kind": "soc", "name": name, "area": module_area_mm2,
                 "process": process, "quantity": quantity,
                 "early": early_defects})


def split_system(name: str, module_area_mm2: float, process: str,
                 n_chiplets: int, integration: str, quantity: float = 1.0,
                 early_defects: bool = False,
                 d2d_overhead: Optional[float] = None,
                 reuse_chiplet: bool = False) -> System:
    """Partition `module_area` evenly into n chiplets (Fig. 4 experiments).

    ``reuse_chiplet=True`` gives every chiplet the same design name so NRE
    is paid once (homogeneous split); otherwise each slice is its own design
    (the paper's Fig. 4/6 'no reuse' assumption).  Thin wrapper over
    :func:`spec`; pass ``fractions``/``processes`` there for heterogeneous
    splits.
    """
    return spec({"kind": "split", "name": name, "area": module_area_mm2,
                 "process": process, "n": n_chiplets,
                 "integration": integration, "quantity": quantity,
                 "early": early_defects, "d2d_overhead": d2d_overhead,
                 "reuse_chiplet": reuse_chiplet})
