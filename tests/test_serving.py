"""Serving engine: continuous batching correctness on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.models.common import init_params
from repro.serving import Request, ServeConfig, ServingEngine

KEY = jax.random.PRNGKey(0)


def _engine(arch="deepseek_7b", slots=3, cache_len=64):
    cfg = get_config(arch).reduced().replace(dtype="float32")
    params = init_params(api.param_spec(cfg), KEY)
    return cfg, params, ServingEngine(
        cfg, params, ServeConfig(n_slots=slots, cache_len=cache_len))


def _reference_greedy(cfg, params, prompt, n_new):
    """Prefill + sequential decode without the engine."""
    from repro.models import transformer as tf
    toks = jnp.asarray(prompt[None, :], jnp.int32)
    logits, cache = tf.lm_prefill(cfg, params, toks, 64)
    out = [int(jnp.argmax(logits[0]))]
    kv = jnp.asarray([len(prompt)], jnp.int32)
    for _ in range(n_new - 1):
        logits, cache = tf.lm_decode(
            cfg, params, jnp.asarray([[out[-1]]], jnp.int32), cache, kv)
        kv = kv + 1
        out.append(int(jnp.argmax(logits[0])))
    return out


def test_single_request_matches_reference():
    cfg, params, eng = _engine()
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 7).astype(np.int32)
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    done = eng.run_until_drained()
    assert len(done) == 1
    want = _reference_greedy(cfg, params, prompt, 6)
    assert done[0].output == want


def test_continuous_batching_isolation():
    """Concurrent requests produce the same outputs as sequential runs."""
    cfg, params, eng = _engine(slots=3)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(3, 9)))
               .astype(np.int32) for _ in range(5)]
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=5))
    done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
    assert len(done) == 5
    for r in done:
        want = _reference_greedy(cfg, params, prompts[r.uid], 5)
        assert r.output == want, f"uid {r.uid}"


def test_slots_are_reused():
    cfg, params, eng = _engine(slots=2)
    rng = np.random.default_rng(2)
    for i in range(6):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(0, cfg.vocab, 4)
                           .astype(np.int32),
                           max_new_tokens=3))
    done = eng.run_until_drained()
    assert len(done) == 6
    assert all(len(r.output) == 3 for r in done)


def test_recurrent_family_serving():
    """xLSTM (pure state, no KV cache) through the same engine."""
    cfg, params, eng = _engine("xlstm_125m", slots=2, cache_len=32)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 5).astype(np.int32)
               for _ in range(3)]
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = sorted(eng.run_until_drained(), key=lambda r: r.uid)
    assert len(done) == 3
    for r in done:
        want = _reference_greedy(cfg, params, prompts[r.uid], 4)
        assert r.output == want, f"uid {r.uid}"
