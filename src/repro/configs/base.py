"""Architecture configuration schema + registry + input shapes.

One ``ArchConfig`` per assigned architecture lives in
``src/repro/configs/<id>.py``; ``get_config(name)`` loads it.  Every
config also provides ``reduced()`` — the small same-family variant used
by CPU smoke tests (the FULL config is exercised only via the dry-run).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 => d_model // n_heads
    attn: str = "gqa"            # gqa | mla
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # --- MLA ---
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_ff_expert: int = 0
    first_dense: int = 0
    capacity_factor: float = 1.25
    # --- hybrid (Mamba2 + shared attention) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0          # shared attn applied after every k ssm layers
    n_shared_attn: int = 2       # alternating shared blocks
    # --- xLSTM ---
    slstm_every: int = 0         # one sLSTM per k blocks (rest mLSTM)
    # --- encoder-decoder ---
    n_dec_layers: int = 0
    dec_len: int = 448
    # --- VLM ---
    n_img_patches: int = 0       # patch embeddings prepended to the text
    # --- execution ---
    subquadratic: bool = False   # can run long_500k
    accum: int = 1               # gradient-accumulation microbatches (train)
    remat: str = "full"          # full | dots | none
    act_shard: str = "seq"       # seq (Megatron-SP) | batch2d (2D batch)
    attn_chunk: int = 1024
    ssm_chunk: int = 128
    attn_impl: str = "chunked"   # chunked | full | pallas
    scan_layers: bool = True

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to 256 so the table TP-shards on any mesh
        (Megatron/MaxText-style padding; pad logits are masked)."""
        return ((self.vocab + 255) // 256) * 256

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 4), d_model=128,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) or 1,
            d_ff=256 if self.d_ff else 0, vocab=512, head_dim=32,
            dtype="float32", attn_chunk=64, ssm_chunk=16,
        )
        if self.attn == "mla":
            kw.update(q_lora=64 if self.q_lora else 0, kv_lora=32,
                      qk_nope=16, qk_rope=16, v_head=32, head_dim=32)
        if self.family == "moe":
            kw.update(n_experts=8, top_k=2, n_shared=min(self.n_shared, 1),
                      d_ff_expert=64, first_dense=min(self.first_dense, 1))
        if self.family == "hybrid":
            kw.update(n_layers=7, ssm_state=16, ssm_headdim=16,
                      attn_every=3, n_shared_attn=2, n_kv_heads=4)
        if self.family == "ssm":
            kw.update(n_layers=4, slstm_every=4)
        if self.family == "encdec":
            kw.update(n_layers=2, n_dec_layers=2, dec_len=16)
        if self.family == "vlm":
            kw.update(n_img_patches=8)
        return self.replace(name=self.name + "-smoke", **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned to every LM-family architecture)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "llava_next_mistral_7b", "minicpm3_4b", "glm4_9b", "mistral_large_123b",
    "deepseek_7b", "deepseek_moe_16b", "deepseek_v2_236b", "whisper_medium",
    "zamba2_7b", "xlstm_125m",
]


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name.replace('-', '_')}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cell_supported(cfg: ArchConfig, shape: InputShape) -> Tuple[bool, str]:
    """Is (arch x shape) a runnable dry-run cell? (brief's skip rules)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode is quadratic-cost; skipped per brief"
    return True, ""
