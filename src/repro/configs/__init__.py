from .base import (ARCH_IDS, SHAPES, ArchConfig, InputShape, all_configs,
                   cell_supported, get_config)
