"""Retry-with-backoff and a circuit breaker for the fused-dispatch path.

Both are host-side and synchronous: the service tick loop is single-
threaded by design (one lane, one dispatch, one ``device_get`` per
tick), so the breaker needs no locking — it is a small state machine
advanced by the tick that owns it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """``retries`` extra attempts after the first, sleeping
    ``backoff_s * attempt`` before retry ``attempt`` (linear backoff —
    the retry budget here is 1-2 attempts, not a remote-API ladder)."""

    retries: int = 1
    backoff_s: float = 0.005


def call_with_retry(fn: Callable, policy: RetryPolicy = RetryPolicy(),
                    on_retry: Optional[Callable[[int, BaseException], None]] = None,
                    sleep: Callable[[float], None] = time.sleep):
    """Call ``fn()``; on exception retry up to ``policy.retries`` times.

    ``on_retry(attempt, error)`` observes each failed attempt (1-based).
    The last error re-raises once the budget is spent.
    """
    attempts = 1 + max(0, policy.retries)
    for attempt in range(1, attempts + 1):
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 - retry means "any failure"
            if on_retry is not None:
                on_retry(attempt, e)
            if attempt == attempts:
                raise
            sleep(policy.backoff_s * attempt)


class CircuitBreaker:
    """closed -> open -> half_open fused-path gate.

    * **closed**: traffic flows; ``threshold`` *consecutive* failures
      open the breaker.
    * **open**: ``allow()`` returns False until ``cooldown_s`` has
      elapsed, then transitions to **half_open** and admits exactly one
      probe.
    * **half_open**: the probe's ``record_success`` closes the breaker,
      its ``record_failure`` re-opens (and restarts the cool-down).

    ``clock`` is injectable for tests; ``on_event`` observes
    ``"open"`` / ``"close"`` / ``"probe"`` transitions.  ``open_s_total``
    accumulates wall spent open/half_open — the recovery-latency metric
    chaos benches report.
    """

    def __init__(self, threshold: int = 1, cooldown_s: float = 2.0,
                 clock: Callable[[], float] = time.monotonic,
                 on_event: Optional[Callable[[str], None]] = None):
        self.threshold = max(1, int(threshold))
        self.cooldown_s = float(cooldown_s)
        self.clock = clock
        self.on_event = on_event
        self.state = "closed"
        self.failures = 0           # consecutive, resets on success
        self.opened_at: Optional[float] = None
        self._cooldown_from: Optional[float] = None
        self.opens = 0
        self.closes = 0
        self.probes = 0
        self.open_s_total = 0.0
        self.last_open_s: Optional[float] = None

    def _emit(self, event: str):
        if self.on_event is not None:
            self.on_event(event)

    def allow(self) -> bool:
        """May the protected path be attempted right now?"""
        if self.state == "closed":
            return True
        if self.state == "open":
            if self.clock() - self._cooldown_from < self.cooldown_s:
                return False
            self.state = "half_open"
            self.probes += 1
            self._emit("probe")
            return True
        # half_open: one probe is already in flight this tick; the tick
        # loop is serial so a second allow() before its verdict means
        # the probe tick itself re-entered — let it through.
        return True

    def record_success(self):
        self.failures = 0
        if self.state != "closed":
            self.state = "closed"
            self.closes += 1
            dt = self.clock() - self.opened_at
            self.open_s_total += dt
            self.last_open_s = dt
            self.opened_at = None
            self._emit("close")

    def record_failure(self):
        self.failures += 1
        if self.state == "closed" and self.failures < self.threshold:
            return
        # half_open probe failed, or threshold reached: (re)open and
        # restart the cool-down window from now.  opened_at keeps the
        # *original* open time so open-duration accounting spans failed
        # probes.
        if self.opened_at is None:
            self.opened_at = self.clock()
        if self.state != "open":
            self.state = "open"
            self.opens += 1
            self._emit("open")
        self._cooldown_from = self.clock()

    def snapshot(self) -> dict:
        out = {
            "state": self.state,
            "consecutive_failures": self.failures,
            "opens": self.opens,
            "closes": self.closes,
            "probes": self.probes,
            "open_s_total": round(self.open_s_total, 6),
            "last_open_s": (round(self.last_open_s, 6)
                            if self.last_open_s is not None else None),
        }
        if self.opened_at is not None:
            out["open_for_s"] = round(self.clock() - self.opened_at, 6)
        return out
