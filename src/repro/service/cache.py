"""Two-level caching for the pricing service.

1. **Trace cache** — the compiled-kernel layer.  Every chunk/MC/search
   signature the service is configured to serve is compiled once at
   startup (or, for a signature first seen at admission time, compiled
   *at admission*, off the tick loop), so the hot path never pays a
   recompile: :class:`TraceCache` tracks which signatures are warm and
   counts any in-tick retrace as a violation the metrics/tests surface.
2. **Result cache** — an LRU over finished answers keyed on
   ``(space fingerprint, flow, mc signature, candidate-index digest)``.
   A repeated sweep (the common interactive pattern: re-rank the same
   shortlist after looking at a report) is served from the host with
   zero device work.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from collections import OrderedDict
from typing import Any, Dict, Hashable, Optional, Tuple

import numpy as np

from ..core.engine import TRACE_COUNTS
from ..dse.space import DesignSpace
from ..obs.trace import TRACER

# TRACE_COUNTS keys that indicate device-kernel (re)compilation relevant
# to the service's lanes.
_TRACE_KEYS = ("fused_chunk", "fused_chunk_mc", "gen_step", "re", "nre",
               "total", "mc", "mc_re")


def space_fingerprint(space: DesignSpace) -> str:
    """Stable digest of a space definition — the cache namespace.

    Two structurally identical spaces (same SKUs/menus/flags) fingerprint
    identically regardless of object identity."""
    payload = {
        "skus": [[s.name, s.module_area_mm2, s.quantity]
                 for s in space.skus],
        "processes": list(space.processes),
        "integrations": list(space.integrations),
        "chiplet_counts": list(space.chiplet_counts),
        "allow_reuse": space.allow_reuse,
        "reuse_package_options": list(space.reuse_package_options),
        "reuse_within_sku": space.reuse_within_sku,
    }
    blob = json.dumps(payload, sort_keys=True).encode()
    return hashlib.sha1(blob).hexdigest()


def index_digest(idx: np.ndarray) -> str:
    """Digest of a candidate index vector (order-sensitive: the response
    rows are positional)."""
    a = np.ascontiguousarray(np.asarray(idx, np.int64))
    return hashlib.sha1(a.tobytes()).hexdigest()


class LRUCache:
    """Tiny ordered-dict LRU with hit/miss counters."""

    def __init__(self, max_entries: int = 256):
        self.max_entries = int(max_entries)
        self._d: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._d)

    def get(self, key: Hashable):
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key: Hashable, value: Any):
        if self.max_entries <= 0:
            return
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def stats(self) -> Dict[str, float]:
        return {"entries": len(self._d), "hits": self.hits,
                "misses": self.misses, "hit_rate": self.hit_rate}


class ResultCache:
    """LRU of finished :class:`EvalArrays` keyed on
    ``(space fingerprint, flow, mc signature, index digest)``.

    Only index-addressed sweeps are cached (price / mc_risk / rank share
    entries: a rank over cached arrays re-ranks on the host).  Entries
    above ``max_rows`` are not cached — a 1M-candidate sweep should not
    evict the interactive working set."""

    def __init__(self, max_entries: int = 256, max_rows: int = 65536):
        self.lru = LRUCache(max_entries)
        self.max_rows = int(max_rows)

    @staticmethod
    def key(fingerprint: str, flow: str, mc_sig: Optional[Tuple],
            idx: np.ndarray) -> Tuple:
        return (fingerprint, flow, mc_sig, index_digest(idx))

    def get(self, key: Tuple):
        return self.lru.get(key)

    def put(self, key: Tuple, arrays) -> bool:
        if len(arrays) > self.max_rows:
            return False
        self.lru.put(key, arrays)
        return True

    def stats(self) -> Dict[str, float]:
        return self.lru.stats()


@dataclasses.dataclass(frozen=True)
class LaneSignature:
    """The static jit-cache key of one service lane: what must be warm
    before requests of this shape hit the tick loop."""

    kind: str                                 # chunk | mc | gen | raw
    flow: str
    static: Tuple = ()                        # e.g. (draws, quantiles)


class TraceCache:
    """Tracks warmed kernel signatures + counts post-warmup retraces.

    The actual compiled executables live in jax's jit cache (module-level
    jits in ``repro.dse.evaluate`` / ``search`` / ``repro.core.engine``,
    shared with the direct APIs — that sharing is what makes service
    responses bit-exact against them).  This class records *which*
    signatures have been compiled and meters TRACE_COUNTS so the metrics
    can prove the hot path stayed recompile-free."""

    def __init__(self):
        self.warmed: Dict[LaneSignature, bool] = {}
        self._tick_recompiles = 0

    def is_warm(self, sig: LaneSignature) -> bool:
        return self.warmed.get(sig, False)

    def ensure(self, sig: LaneSignature, compile_fn,
               trace_id: str = "") -> bool:
        """Compile ``sig`` now (admission time) if cold.  Returns True if
        a compile actually happened.  ``trace_id`` labels the compile
        span with the request that forced the cold compile, so "why was
        this admission slow" is answerable from its trace tree."""
        if self.is_warm(sig):
            return False
        with TRACER.span("admission_compile", kind=sig.kind,
                         flow=sig.flow, trace_id=trace_id):
            compile_fn()
        self.warmed[sig] = True
        return True

    # -- tick-time recompile metering ---------------------------------------
    @staticmethod
    def counts() -> Dict[str, int]:
        return {k: TRACE_COUNTS.get(k, 0) for k in _TRACE_KEYS}

    def meter_tick(self, before: Dict[str, int]) -> int:
        """Record (and return) the number of traces taken during a tick —
        anything nonzero means a cold request leaked onto the hot path."""
        after = self.counts()
        delta = sum(after[k] - before.get(k, 0) for k in _TRACE_KEYS)
        self._tick_recompiles += delta
        return delta

    @property
    def tick_recompiles(self) -> int:
        return self._tick_recompiles

    def stats(self) -> Dict[str, Any]:
        return {"warmed_signatures": len(self.warmed),
                "tick_recompiles": self._tick_recompiles}
