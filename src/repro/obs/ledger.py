"""Serving-cost ledger: per-request resource bills for the pricing service.

The service coalesces many requests into one fused dispatch per tick, so
"what did *this* request cost to serve" is not directly measurable — the
device prices a padded batch and every rider shares the wall.  The
ledger closes that gap with an explicit accounting model:

* :meth:`Ledger.open` mints a :class:`Bill` at admission (one per
  request uid, keyed by its ``trace_id``);
* :meth:`Ledger.charge_tick` pro-rates one tick's measured wall across
  the requests that rode it, **by rows contributed**: a request that
  contributed 96 of a tick's 128 priced rows pays 75% of the tick's
  device ms, of its probe-attributed dispatch ms, and of its padded
  waste (``wall * (1 - used/slots)``);
* :meth:`Ledger.close` finalizes the bill at the terminal path (ok,
  typed error, cached, cancelled) with latency, cache/degraded/replay
  provenance and mirrors it into the metrics registry — including a
  ``ledger_request_device_ms`` histogram carrying the request's
  ``trace_id`` as an exemplar.

Two invariants are tracked continuously and exposed as registry gauges
so benchmarks and CI can assert them:

* **sum-to-wall**: the shares charged for a tick sum to that tick's
  measured wall; ``ledger_tick_residual_rel`` records the worst
  relative residual seen (float rounding only, so ~1e-9 in practice);
* **no unattributed time**: a tick whose plan named no payers books its
  wall into ``ledger_unattributed_ms`` — the service never produces one
  on the bench, and the regression guard pins the counter at zero.

Aggregates (per request kind and per lane) accumulate at charge/close
time, not at snapshot time, so late charges after a failure-path close
still land in the cost-per-query rollup.  The ledger is independent of
tracing: bills are charged from the tick wall the server already
measures, so the untraced hot path stays untraced.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .registry import REGISTRY, Registry


@dataclasses.dataclass
class Bill:
    """One request's resource bill, accumulated across the ticks it rode."""

    trace_id: str
    uid: int
    kind: str
    replayed: bool = False
    status: str = "open"          # open | ok | cancelled | <error code>
    ticks: int = 0                # coalesced ticks this request rode
    rows_priced: int = 0          # rows it contributed across those ticks
    device_ms: float = 0.0        # pro-rated share of measured tick wall
    dispatch_ms: float = 0.0      # share of probe-attributed jit wall
    padded_ms: float = 0.0        # share of padded-slot waste
    retries: int = 0              # tick retries this request rode through
    degraded_rows: int = 0        # rows answered via the legacy fallback
    cache_hit: bool = False
    latency_ms: float = 0.0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _KindAgg:
    requests: int = 0
    ok: int = 0
    errors: int = 0
    cache_hits: int = 0
    replayed: int = 0
    rows_priced: int = 0
    device_ms: float = 0.0
    dispatch_ms: float = 0.0
    padded_ms: float = 0.0
    retries: int = 0
    degraded_rows: int = 0


@dataclasses.dataclass
class _LaneAgg:
    ticks: int = 0
    wall_ms: float = 0.0
    rows_priced: int = 0
    padded_ms: float = 0.0
    dispatch_ms: float = 0.0


class Ledger:
    """Bill store + tick-share accountant (see module docstring)."""

    def __init__(self, registry: Optional[Registry] = None,
                 keep_closed: int = 512):
        self._registry = registry if registry is not None else REGISTRY
        self._lock = threading.Lock()
        self._open: Dict[int, Bill] = {}
        self._closed: List[Bill] = []
        self._keep_closed = int(keep_closed)
        self._by_kind: Dict[str, _KindAgg] = {}
        self._by_lane: Dict[str, _LaneAgg] = {}
        self.ticks_charged = 0
        self.device_ms_total = 0.0
        self.unattributed_ms = 0.0
        self.tick_residual_rel_max = 0.0
        self.closed_total = 0

    # -- lifecycle -----------------------------------------------------------
    def open(self, trace_id: str, uid: int, kind: str,
             replayed: bool = False) -> Bill:
        bill = Bill(trace_id=trace_id, uid=int(uid), kind=str(kind),
                    replayed=bool(replayed))
        with self._lock:
            self._open[bill.uid] = bill
        return bill

    def charge_tick(self, lane: str, wall_s: float,
                    parts: Sequence[Tuple[Bill, int]], slots: int, used: int,
                    dispatch_s: float = 0.0, retries: int = 0):
        """Split one tick's measured wall across its riders by rows.

        ``parts`` is ``[(bill, rows_contributed)]`` — one entry per
        distinct request (callers aggregate multiple slot assignments of
        the same owner first).  The last rider absorbs the rounding
        remainder so the shares sum to ``wall_s`` exactly.
        """
        wall_ms = float(wall_s) * 1e3
        dispatch_ms = float(dispatch_s) * 1e3
        slots = max(int(slots), 1)
        padded_frac = max(0.0, 1.0 - float(used) / slots)
        total_rows = sum(max(int(n), 0) for _, n in parts)
        with self._lock:
            self.ticks_charged += 1
            lane_agg = self._by_lane.setdefault(lane, _LaneAgg())
            lane_agg.ticks += 1
            lane_agg.wall_ms += wall_ms
            lane_agg.rows_priced += total_rows
            lane_agg.padded_ms += wall_ms * padded_frac
            lane_agg.dispatch_ms += dispatch_ms
            if total_rows <= 0 or not parts:
                self.unattributed_ms += wall_ms
                self._mirror_invariants()
                return
            charged = 0.0
            for i, (bill, rows) in enumerate(parts):
                rows = max(int(rows), 0)
                if i == len(parts) - 1:
                    share = wall_ms - charged   # remainder-absorbing
                else:
                    share = wall_ms * rows / total_rows
                charged += share
                bill.ticks += 1
                bill.rows_priced += rows
                bill.device_ms += share
                bill.dispatch_ms += dispatch_ms * rows / total_rows
                bill.padded_ms += share * padded_frac
                bill.retries += int(retries)
                kind_agg = self._by_kind.setdefault(bill.kind, _KindAgg())
                kind_agg.rows_priced += rows
                kind_agg.device_ms += share
                kind_agg.dispatch_ms += dispatch_ms * rows / total_rows
                kind_agg.padded_ms += share * padded_frac
                kind_agg.retries += int(retries)
            self.device_ms_total += wall_ms
            residual = abs(charged - wall_ms)
            rel = residual / wall_ms if wall_ms > 0 else 0.0
            self.tick_residual_rel_max = max(self.tick_residual_rel_max, rel)
            self._mirror_invariants()

    def close(self, bill: Bill, status: str = "ok", cache_hit: bool = False,
              degraded_rows: int = 0, latency_s: float = 0.0):
        """Finalize a bill at its terminal path; idempotent per uid."""
        with self._lock:
            was_open = self._open.pop(bill.uid, None) is not None
            if not was_open and bill.status != "open":
                return                     # already closed (double terminal)
            bill.status = str(status)
            bill.cache_hit = bool(cache_hit)
            bill.degraded_rows = int(degraded_rows)
            bill.latency_ms = float(latency_s) * 1e3
            self.closed_total += 1
            self._closed.append(bill)
            if len(self._closed) > self._keep_closed:
                del self._closed[: len(self._closed) - self._keep_closed]
            agg = self._by_kind.setdefault(bill.kind, _KindAgg())
            agg.requests += 1
            agg.ok += 1 if bill.status == "ok" else 0
            agg.errors += 0 if bill.status in ("ok", "cancelled") else 1
            agg.cache_hits += 1 if bill.cache_hit else 0
            agg.replayed += 1 if bill.replayed else 0
            agg.degraded_rows += bill.degraded_rows
        reg = self._registry
        reg.counter("ledger_bills_closed",
                    help="requests with a finalized cost bill").inc()
        if bill.cache_hit:
            reg.counter("ledger_bills_cached").inc()
        reg.counter("ledger_rows_priced").inc(max(bill.rows_priced, 0))
        reg.histogram("ledger_request_device_ms",
                      help="per-request pro-rated device ms").observe(
            bill.device_ms, exemplar=bill.trace_id)

    def _mirror_invariants(self):
        # called under self._lock; gauge writes are cheap and lock-free
        reg = self._registry
        reg.counter("ledger_ticks_charged",
                    help="ticks whose wall was billed to riders").inc()
        reg.gauge("ledger_tick_residual_rel",
                  help="worst |billed-wall|/wall across ticks").set(
            self.tick_residual_rel_max)
        reg.gauge("ledger_unattributed_ms",
                  help="tick wall with no request to bill").set(
            self.unattributed_ms)
        reg.gauge("ledger_device_ms_total").set(self.device_ms_total)

    # -- introspection -------------------------------------------------------
    def bill_for(self, uid: int) -> Optional[Bill]:
        with self._lock:
            b = self._open.get(uid)
            if b is not None:
                return b
            for bill in reversed(self._closed):
                if bill.uid == uid:
                    return bill
        return None

    def snapshot(self) -> Dict:
        """JSON-ready rollup: invariants + per-kind / per-lane aggregates."""
        with self._lock:
            by_kind = {}
            for kind, agg in sorted(self._by_kind.items()):
                row = dataclasses.asdict(agg)
                row["device_ms_per_query"] = (
                    agg.device_ms / agg.requests if agg.requests else 0.0)
                by_kind[kind] = row
            by_lane = {lane: dataclasses.asdict(agg)
                       for lane, agg in sorted(self._by_lane.items())}
            return {
                "open": len(self._open),
                "closed": self.closed_total,
                "ticks_charged": self.ticks_charged,
                "device_ms_total": self.device_ms_total,
                "tick_residual_rel_max": self.tick_residual_rel_max,
                "unattributed_ms": self.unattributed_ms,
                "by_kind": by_kind,
                "by_lane": by_lane,
            }
