"""Declarative multi-chiplet design-space definition (repro.dse).

A :class:`DesignSpace` describes a *product portfolio* — the SKUs a
vendor ships, each with a module inventory (total functional area) and a
production volume — together with the architectural freedoms the search
may exercise: allowed process nodes, integration technologies, chiplet
counts, and cross-SKU chiplet-reuse (the paper's SCMS scheme generalized
to arbitrary per-SKU socket counts via
:func:`repro.core.reuse.portfolio_reuse_systems`).

A :class:`Candidate` is one fully concrete point of that space: either a
per-SKU tuple of :class:`ArchChoice` (independent architectures) or a
:class:`ReuseChoice` (one shared chiplet design collocated across the
whole portfolio).  ``candidate_systems`` lowers a candidate to the
:class:`~repro.core.system.System` group that
:class:`~repro.core.batch.SystemBatch` packs and the engine prices.

The space is countable: ``size()`` / ``candidate_at(i)`` give a total
order, so exhaustive enumeration, uniform sampling and index-based
decoding all agree — the property the seeded-determinism tests pin.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Dict, Iterator, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.batch import SystemBatch
from ..core.engine import NREBreakdown
from ..core.reuse import portfolio_reuse_systems
from ..core.system import System, spec
from ..core.technology import node, tech

_REL_TOL = 1e-6


@dataclasses.dataclass(frozen=True)
class SKU:
    """One product in the portfolio: a module inventory and its volume."""

    name: str
    module_area_mm2: float
    quantity: float


@dataclasses.dataclass(frozen=True)
class ArchChoice:
    """Architecture of a single SKU: ``n_chiplets`` even slices of the
    module area on ``process``, packaged with ``integration``.

    ``n_chiplets == 1`` always means the monolithic SoC baseline
    (integration "SoC", no D2D overhead), as in the paper's Fig. 4.
    """

    n_chiplets: int
    process: str
    integration: str

    def label(self) -> str:
        if self.n_chiplets == 1:
            return f"soc/{self.process}"
        return f"{self.n_chiplets}x/{self.process}/{self.integration}"


@dataclasses.dataclass(frozen=True)
class ReuseChoice:
    """One shared chiplet design across the whole portfolio (SCMS-style):
    every SKU is ``round(area / slice_area_mm2)`` copies of the slice."""

    slice_area_mm2: float
    process: str
    integration: str
    package_reuse: bool = False

    def label(self) -> str:
        pkg = "+pkg" if self.package_reuse else ""
        return (f"reuse[{self.slice_area_mm2:g}mm2/{self.process}"
                f"/{self.integration}{pkg}]")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One concrete portfolio architecture (hashable — search dedup key)."""

    choices: Tuple[ArchChoice, ...] = ()
    reuse: Optional[ReuseChoice] = None

    def __post_init__(self):
        if (self.reuse is None) == (not self.choices):
            raise ValueError("candidate needs choices xor a reuse scheme")

    @property
    def is_reuse(self) -> bool:
        return self.reuse is not None

    def label(self) -> str:
        if self.reuse is not None:
            return self.reuse.label()
        return " | ".join(c.label() for c in self.choices)


@dataclasses.dataclass(frozen=True)
class DesignSpace:
    """The searchable portfolio design space.

    ``chiplet_counts`` containing 1 enables the monolithic-SoC option per
    SKU; counts > 1 combine with every (process, integration) pair.
    ``allow_reuse`` adds SCMS-style candidates whose slice areas are
    derived from the SKU areas (a slice is valid iff every SKU area is an
    in-range integer multiple of it).  ``reuse_within_sku`` gives the
    slices of one non-reuse split a single design name (chiplet NRE paid
    once per SKU); the paper's Fig. 4 no-reuse assumption is
    ``reuse_within_sku=False``.
    """

    skus: Tuple[SKU, ...]
    processes: Tuple[str, ...] = ("7nm",)
    integrations: Tuple[str, ...] = ("MCM",)
    chiplet_counts: Tuple[int, ...] = (1, 2, 3, 4)
    allow_reuse: bool = True
    reuse_package_options: Tuple[bool, ...] = (False,)
    reuse_within_sku: bool = True

    def __post_init__(self):
        if not self.skus:
            raise ValueError("design space needs at least one SKU")
        names = [s.name for s in self.skus]
        if len(set(names)) != len(names):
            raise ValueError("SKU names must be unique")
        if not self.processes:
            raise ValueError("design space needs at least one process node")
        if not self.integrations and max(self.chiplet_counts) > 1:
            raise ValueError(
                "chiplet counts > 1 need at least one integration tech")
        for p in self.processes:
            node(p)
        for t in self.integrations:
            if t == "SoC":
                raise ValueError(
                    "integrations are multi-chip technologies; the SoC "
                    "baseline is the n_chiplets=1 option")
            tech(t)
        if not self.chiplet_counts or min(self.chiplet_counts) < 1:
            raise ValueError("chiplet_counts must be positive")

    # -- choice inventories (cached: the space is frozen, and the search
    # loop asks for them on every sample/mutate/crossover) -------------------
    @functools.cached_property
    def _arch_choices(self) -> Tuple[ArchChoice, ...]:
        out = []
        if 1 in self.chiplet_counts:
            out += [ArchChoice(1, p, "SoC") for p in self.processes]
        out += [ArchChoice(n, p, t)
                for n in sorted(set(self.chiplet_counts)) if n > 1
                for p in self.processes for t in self.integrations]
        return tuple(out)

    @functools.cached_property
    def _reuse_choices(self) -> Tuple[ReuseChoice, ...]:
        if not self.allow_reuse:
            return ()
        return tuple(ReuseChoice(a, p, t, pkg)
                     for a in self.reuse_slice_areas()
                     for p in self.processes for t in self.integrations
                     for pkg in self.reuse_package_options)

    def arch_choices(self) -> List[ArchChoice]:
        """Per-SKU architecture options (same menu for every SKU)."""
        return list(self._arch_choices)

    def reuse_slice_areas(self) -> List[float]:
        """Slice areas under which every SKU is an in-range integer
        multiple — the valid cross-SKU reuse granularities."""
        counts = sorted(set(self.chiplet_counts))
        cands = sorted({s.module_area_mm2 / n
                        for s in self.skus for n in counts}, reverse=True)
        out: List[float] = []
        for a in cands:
            ok = True
            for s in self.skus:
                k = s.module_area_mm2 / a
                if abs(k - round(k)) > _REL_TOL * max(k, 1.0) \
                        or int(round(k)) not in counts:
                    ok = False
                    break
            if ok and not any(abs(a - b) <= _REL_TOL * a for b in out):
                out.append(a)
        return out

    def reuse_choices(self) -> List[ReuseChoice]:
        return list(self._reuse_choices)

    def reuse_counts(self, r: ReuseChoice) -> Tuple[int, ...]:
        """Per-SKU socket counts under ``r`` — rejects a slice that does
        not implement the SKU inventories (wrong area or out-of-range
        count), so foreign/hand-built reuse candidates cannot be silently
        lowered to the wrong silicon."""
        counts = []
        for s in self.skus:
            k = s.module_area_mm2 / r.slice_area_mm2
            if abs(k - round(k)) > _REL_TOL * max(k, 1.0) \
                    or int(round(k)) not in self.chiplet_counts:
                raise ValueError(
                    f"slice {r.slice_area_mm2:g} mm^2 does not tile SKU "
                    f"{s.name!r} ({s.module_area_mm2:g} mm^2) within the "
                    f"allowed chiplet counts {self.chiplet_counts}")
            counts.append(int(round(k)))
        return tuple(counts)

    # -- countable enumeration ----------------------------------------------
    def size(self) -> int:
        return (len(self._arch_choices) ** len(self.skus)
                + len(self._reuse_choices))

    def candidate_at(self, i: int) -> Candidate:
        """Decode index ``i`` (0 <= i < size()) into a candidate."""
        arch = self._arch_choices
        n_arch = len(arch) ** len(self.skus)
        if i < 0 or i >= self.size():
            raise IndexError(f"candidate index {i} out of range")
        if i < n_arch:
            # match enumerate_candidates(): SKU 0 is the most significant
            # digit of the mixed-radix index
            digits = []
            for _ in self.skus:
                i, d = divmod(i, len(arch))
                digits.append(arch[d])
            return Candidate(choices=tuple(reversed(digits)))
        return Candidate(reuse=self._reuse_choices[i - n_arch])

    def enumerate_candidates(self) -> Iterator[Candidate]:
        for combo in itertools.product(self._arch_choices,
                                       repeat=len(self.skus)):
            yield Candidate(choices=combo)
        for r in self._reuse_choices:
            yield Candidate(reuse=r)

    def sample(self, rng: np.random.Generator, n: int) -> List[Candidate]:
        """Uniform-with-replacement sample of ``n`` candidates."""
        return [self.candidate_at(int(i))
                for i in rng.integers(0, self.size(), size=n)]

    # -- search neighborhood -------------------------------------------------
    def mutate(self, rng: np.random.Generator, cand: Candidate,
               jump_prob: float = 0.15) -> Candidate:
        """A random neighbor: tweak one SKU's choice (or hop between the
        reuse and independent families); occasionally jump anywhere."""
        if rng.random() < jump_prob:
            return self.candidate_at(int(rng.integers(0, self.size())))
        reuse = self._reuse_choices
        if cand.is_reuse:
            if len(reuse) > 1 and rng.random() < 0.7:
                others = [r for r in reuse if r != cand.reuse]
                return Candidate(reuse=others[int(rng.integers(len(others)))])
            return self.candidate_at(
                int(rng.integers(0, len(self._arch_choices)
                                 ** len(self.skus))))
        arch = self._arch_choices
        if reuse and rng.random() < 0.15:
            return Candidate(reuse=reuse[int(rng.integers(len(reuse)))])
        i = int(rng.integers(len(self.skus)))
        others = [a for a in arch if a != cand.choices[i]]
        if not others:
            return cand
        new = list(cand.choices)
        new[i] = others[int(rng.integers(len(others)))]
        return Candidate(choices=tuple(new))

    def crossover(self, rng: np.random.Generator, a: Candidate,
                  b: Candidate) -> Candidate:
        """Per-SKU uniform crossover; reuse candidates fall back to
        mutation (they have no per-SKU genes)."""
        if a.is_reuse or b.is_reuse:
            return self.mutate(rng, a)
        picks = rng.integers(0, 2, size=len(self.skus))
        return Candidate(choices=tuple(
            (a if p == 0 else b).choices[i] for i, p in enumerate(picks)))

    # -- batching bounds -----------------------------------------------------
    def max_chips(self) -> int:
        """Widest system any candidate can produce (padding bound)."""
        m = max(self.chiplet_counts)
        for r in self._reuse_choices:
            m = max(m, max(self.reuse_counts(r)))
        return m

    # -- index algebra (inverse of candidate_at) ----------------------------
    @functools.cached_property
    def _arch_index(self) -> Dict[ArchChoice, int]:
        return {a: i for i, a in enumerate(self._arch_choices)}

    @functools.cached_property
    def _reuse_index(self) -> Dict[ReuseChoice, int]:
        return {r: i for i, r in enumerate(self._reuse_choices)}

    def index_of(self, cand: Candidate) -> int:
        """The unique index with ``candidate_at(index_of(c)) == c`` — the
        bridge from candidate objects to the array-native fused pipeline."""
        try:
            if cand.reuse is not None:
                return (len(self._arch_choices) ** len(self.skus)
                        + self._reuse_index[cand.reuse])
            if len(cand.choices) != len(self.skus):
                raise KeyError(cand)
            i = 0
            base = len(self._arch_choices)
            for c in cand.choices:       # SKU 0 is the most significant digit
                i = i * base + self._arch_index[c]
            return i
        except KeyError:
            raise ValueError(
                f"candidate {cand.label()} is not a member of this "
                "design space") from None

    def encoder(self) -> "CandidateEncoder":
        """The cached vectorized candidate encoder for this space."""
        return self._encoder

    @functools.cached_property
    def _encoder(self) -> "CandidateEncoder":
        return CandidateEncoder(self)


def candidate_systems(space: DesignSpace, cand: Candidate) -> List[System]:
    """Lower one candidate to its per-SKU :class:`System` group.

    The group is meant to be priced with NRE shared *within* the
    candidate (one ``share_nre`` group): reuse candidates then amortize
    the single chiplet design over the whole portfolio volume.
    """
    if cand.choices and len(cand.choices) != len(space.skus):
        raise ValueError(
            f"candidate has {len(cand.choices)} per-SKU choices but the "
            f"space has {len(space.skus)} SKUs")
    if cand.reuse is not None:
        r = cand.reuse
        return portfolio_reuse_systems(
            r.slice_area_mm2, r.process, r.integration,
            counts=list(space.reuse_counts(r)),
            quantities=[s.quantity for s in space.skus],
            names=[s.name for s in space.skus],
            package_reuse=r.package_reuse)
    out = []
    for sku, c in zip(space.skus, cand.choices):
        if c.n_chiplets == 1:
            out.append(spec({"kind": "soc", "name": sku.name,
                             "area": sku.module_area_mm2,
                             "process": c.process,
                             "quantity": sku.quantity}))
        else:
            out.append(spec({"kind": "split", "name": sku.name,
                             "area": sku.module_area_mm2,
                             "process": c.process, "n": c.n_chiplets,
                             "integration": c.integration,
                             "quantity": sku.quantity,
                             "reuse_chiplet": space.reuse_within_sku}))
    return out


# ---------------------------------------------------------------------------
# Vectorized candidate encoder — the on-device half of candidate_systems.
# ---------------------------------------------------------------------------

# Per-(SKU, extended choice) float tables the encoder gathers from.  Every
# value is read off the *actual* System objects candidate_systems builds
# (same float64 -> float32 cast as SystemBatch.from_systems), so the
# encoded batch is bit-identical to the host-packed one.
_CHOICE_TABLE_FIELDS = (
    # chip slots
    "n_chips", "chip_area", "mod_area", "chip_defect", "wafer_cost",
    "cluster", "wafer_yield", "sort_cost", "bump_cost",
    # chip/module NRE coefficients
    "nre_chip_k", "nre_chip_fixed", "nre_mod_k",
    # D2D interface
    "has_d2d", "d2d_pidx",
    # per-system / package
    "package_area", "package_area_factor", "substrate_cost",
    "substrate_layer", "interposer_cost", "interposer_defect",
    "interposer_area_factor", "interposer_cluster", "y2_chip_bond",
    "y3_substrate_bond", "assembly_yield", "bond_cost_per_chip",
    "pkg_k", "pkg_fixed",
)


@dataclasses.dataclass(frozen=True)
class EncoderMeta:
    """Static (hashable) geometry of a space's encoder — the part of the
    encoding that participates in jit cache keys."""

    n_skus: int
    max_chips: int
    n_arch_choices: int      # A: per-SKU architecture menu size
    n_reuse_choices: int     # R: cross-SKU reuse candidates
    n_processes: int         # P: D2D entity namespace width per candidate
    n_arch: int              # A ** n_skus (first reuse index)
    size: int                # total candidate count
    reuse_within_sku: bool


class CandidateEncoder:
    """Pure-array lowering of candidate *indices* to a :class:`SystemBatch`.

    Construction walks every (SKU, architecture choice) and every reuse
    choice ONCE through :func:`candidate_systems` (the parity oracle) and
    records the resulting per-system / per-chip floats in dense
    ``(S, A + R)`` tables.  :meth:`encode` is then pure ``jnp``: decoding
    a ``(K,)`` index vector into a padded, NRE-grouped ``(K * S)``-system
    batch is all gathers and broadcasts, traceable inside an outer jit —
    zero per-candidate Python, which is what moves the DSE inner loop
    on-device (see :mod:`repro.dse.evaluate` / ``search``).

    The NRE entity layout is canonical rather than discovery-ordered:
    candidate ``j`` owns chip/module entity rows ``1 + j*S*C .. ``,
    package rows ``1 + j*S ..`` and D2D rows ``1 + j*P ..`` (row 0 of
    every table is a shared zero-NRE sink for padded slots).  Shapes
    match :func:`repro.dse.evaluate.chunk_shape` exactly, so encoded and
    host-packed chunks share one compiled engine trace.
    """

    def __init__(self, space: DesignSpace):
        if space.size() > np.iinfo(np.int32).max:
            raise ValueError(
                f"space has {space.size()} candidates; the int32 index "
                "encoding supports at most 2**31 - 1")
        self.space = space
        s, c = len(space.skus), space.max_chips()
        a, r = len(space._arch_choices), len(space._reuse_choices)
        p = len(space.processes)
        self.meta = EncoderMeta(
            n_skus=s, max_chips=c, n_arch_choices=a, n_reuse_choices=r,
            n_processes=p, n_arch=a ** s, size=space.size(),
            reuse_within_sku=space.reuse_within_sku)

        tab = {f: np.zeros((s, a + r), np.float32)
               for f in _CHOICE_TABLE_FIELDS}
        pkg_shared = np.zeros((a + r,), np.float32)
        for e in range(a + r):
            if e < a:
                cand = Candidate(choices=(space._arch_choices[e],) * s)
            else:
                ch = space._reuse_choices[e - a]
                pkg_shared[e] = 1.0 if ch.package_reuse else 0.0
                cand = Candidate(reuse=ch)
            for i, sys in enumerate(candidate_systems(space, cand)):
                self._fill(tab, i, e, sys)
        self.tables: Dict[str, jnp.ndarray] = {
            k: jnp.asarray(v) for k, v in tab.items()}
        self.tables["pkg_shared"] = jnp.asarray(pkg_shared)
        # static per-process D2D NRE menu (row values are candidate-free)
        self.tables["d2d_nre"] = jnp.asarray(
            [node(p_).nre_d2d for p_ in space.processes], jnp.float32)
        self.tables["quantity"] = jnp.asarray(
            [sk.quantity for sk in space.skus], jnp.float32)
        # mixed-radix digit extractors, SKU 0 most significant
        self.tables["digit_pow"] = jnp.asarray(
            [a ** (s - 1 - i) for i in range(s)], jnp.int32)

    def _fill(self, tab, i: int, e: int, sys: System):
        chip = sys.chips[0]
        for other in sys.chips[1:]:     # even slices / reuse copies only
            if (other.area_mm2 != chip.area_mm2
                    or other.process != chip.process):
                raise ValueError(
                    f"encoder requires homogeneous chips per system; "
                    f"{sys.name} mixes designs")
        nd, t = chip.node, sys.tech
        d2d = [m for m in chip.modules if m.is_d2d]
        v = {
            "n_chips": sys.n_chips, "chip_area": chip.area_mm2,
            "mod_area": chip.module_area_mm2,
            "chip_defect": chip.defect_density,
            "wafer_cost": nd.wafer_cost, "cluster": nd.cluster_param,
            "wafer_yield": nd.wafer_yield, "sort_cost": nd.wafer_sort_cost,
            "bump_cost": nd.bump_cost_per_mm2,
            "nre_chip_k": nd.nre_chip_per_mm2,
            "nre_chip_fixed": nd.nre_fixed_per_chip,
            "nre_mod_k": nd.nre_module_per_mm2,
            "has_d2d": 1.0 if d2d else 0.0,
            "d2d_pidx": (self.space.processes.index(chip.process)
                         if d2d else 0),
            "package_area": sys.package_area,
            "package_area_factor": t.package_area_factor,
            "substrate_cost": t.substrate_cost_per_mm2,
            "substrate_layer": t.substrate_layer_factor,
            "interposer_cost": t.interposer_cost_per_mm2,
            "interposer_defect": t.interposer_defect_density,
            "interposer_area_factor": t.interposer_area_factor,
            "interposer_cluster": node(t.interposer_node).cluster_param,
            "y2_chip_bond": t.y2_chip_bond,
            "y3_substrate_bond": t.y3_substrate_bond,
            "assembly_yield": t.assembly_yield,
            "bond_cost_per_chip": t.bond_cost_per_chip,
            "pkg_k": t.nre_package_per_mm2,
            "pkg_fixed": t.nre_fixed_per_package,
        }
        for k, val in v.items():
            tab[k][i, e] = val

    def encode(self, idx) -> SystemBatch:
        """Lower a ``(K,)`` int vector of candidate indices to a padded
        ``SystemBatch`` (one NRE group per candidate) — pure jnp."""
        return encode_arrays(self.tables, self.meta, idx)


def _decode(tables: Dict[str, jnp.ndarray], meta: EncoderMeta, idx):
    """Shared index decode: (K,) indices -> (is_reuse (K,), ext (K, S))
    where ``ext`` is each SKU's extended-choice column (arch digit, or
    ``A + r`` for reuse candidates)."""
    a = meta.n_arch_choices
    idx = jnp.asarray(idx, jnp.int32)
    is_reuse = idx >= meta.n_arch                                    # (K,)
    arch_i = jnp.where(is_reuse, 0, idx)
    digits = (arch_i[:, None] // tables["digit_pow"][None, :]) % a   # (K,S)
    r = jnp.where(is_reuse, idx - meta.n_arch, 0)
    ext = jnp.where(is_reuse[:, None], a + r[:, None], digits)       # (K,S)
    return is_reuse, ext


def encode_arrays(tables: Dict[str, jnp.ndarray], meta: EncoderMeta,
                  idx) -> SystemBatch:
    """Pure-array candidate decode (traceable; see :class:`CandidateEncoder`).

    ``tables`` may be traced or concrete; ``meta`` is static.  Out-of-range
    indices are undefined behavior (clipped gathers), mirroring
    ``candidate_at``'s host-side range check which callers enforce.
    """
    s, c, p = meta.n_skus, meta.max_chips, meta.n_processes
    is_reuse, ext = _decode(tables, meta, idx)
    k = ext.shape[0]
    n = k * s

    srange = jnp.arange(s, dtype=jnp.int32)

    def g(name):
        """(K, S) per-system gather, flattened to (N,)."""
        return tables[name][srange[None, :], ext].reshape(n)

    n_chips = g("n_chips")
    mask = (jnp.arange(c, dtype=jnp.float32)[None, :]
            < n_chips[:, None]).astype(jnp.float32)                  # (N,C)

    def chip(name, pad=0.0):
        val = g(name)[:, None] * mask
        return val if pad == 0.0 else val + pad * (1.0 - mask)

    # -- canonical NRE entity layout (see class docstring) -----------------
    sys_i = jnp.arange(n, dtype=jnp.int32)
    cand_of_sys = sys_i // s
    is_reuse_sys = jnp.repeat(is_reuse, s)
    slot = jnp.arange(c, dtype=jnp.int32)[None, :]
    own_row = 1 + (sys_i * c)[:, None] + slot                        # (N,C)
    sku_row = 1 + (sys_i * c)[:, None] + 0 * slot
    cand_row = 1 + (cand_of_sys * (s * c))[:, None] + 0 * slot
    arch_row = sku_row if meta.reuse_within_sku else own_row
    chip_ids = jnp.where(mask > 0.0,
                         jnp.where(is_reuse_sys[:, None], cand_row,
                                   arch_row), 0).astype(jnp.int32)

    def ent(values_2d):
        """Prefix a zero sink row and flatten (N, C) slot values."""
        return jnp.concatenate(
            [jnp.zeros((1,), jnp.float32), values_2d.reshape(-1)])

    pkg_shared = (tables["pkg_shared"][ext[:, 0]] > 0.0)             # (K,)
    pkg_shared_sys = jnp.repeat(pkg_shared, s)
    pkg_ids = jnp.where(pkg_shared_sys, 1 + cand_of_sys * s,
                        1 + sys_i).astype(jnp.int32)

    inst_sys = jnp.repeat(sys_i, c)                                  # (N*C,)
    has_d2d = (g("has_d2d")[:, None] * mask) > 0.0
    d2d_ids = jnp.where(
        has_d2d,
        1 + (cand_of_sys * p)[:, None] + g("d2d_pidx").astype(jnp.int32)[
            :, None] + 0 * slot,
        0).astype(jnp.int32)

    quantity = jnp.tile(tables["quantity"], k)
    zero1 = jnp.zeros((1,), jnp.float32)
    return SystemBatch.from_arrays(
        chip_area=chip("chip_area"),
        chip_defect=chip("chip_defect"),
        chip_wafer_cost=chip("wafer_cost"),
        chip_cluster=chip("cluster", pad=1.0),
        chip_wafer_yield=chip("wafer_yield", pad=1.0),
        chip_sort_cost=chip("sort_cost"),
        chip_bump_cost=chip("bump_cost"),
        chip_mask=mask,
        package_area=g("package_area"),
        package_area_factor=g("package_area_factor"),
        substrate_cost=g("substrate_cost"),
        substrate_layer=g("substrate_layer"),
        interposer_cost=g("interposer_cost"),
        interposer_defect=g("interposer_defect"),
        interposer_area_factor=g("interposer_area_factor"),
        interposer_cluster=g("interposer_cluster"),
        y2_chip_bond=g("y2_chip_bond"),
        y3_substrate_bond=g("y3_substrate_bond"),
        assembly_yield=g("assembly_yield"),
        bond_cost_per_chip=g("bond_cost_per_chip"),
        quantity=quantity,
        chip_entity_id=chip_ids,
        chip_entity_area=ent(chip("chip_area")),
        chip_entity_k=ent(chip("nre_chip_k")),
        chip_entity_fixed=ent(chip("nre_chip_fixed")),
        pkg_entity_id=pkg_ids,
        pkg_entity_area=jnp.concatenate([zero1, g("package_area")]),
        pkg_entity_k=jnp.concatenate([zero1, g("pkg_k")]),
        pkg_entity_fixed=jnp.concatenate([zero1, g("pkg_fixed")]),
        mod_sys=inst_sys,
        mod_entity=chip_ids.reshape(-1),
        mod_entity_area=ent(chip("mod_area")),
        mod_entity_k=ent(chip("nre_mod_k")),
        d2d_sys=inst_sys,
        d2d_entity=d2d_ids.reshape(-1),
        d2d_entity_nre=jnp.concatenate([zero1,
                                        jnp.tile(tables["d2d_nre"], k)]),
    )


def encode_batch(space: DesignSpace, idx) -> SystemBatch:
    """Vectorized ``candidate_at`` + ``candidate_systems`` + packing: turn
    a ``(K,)`` vector of candidate indices into the padded, NRE-grouped
    :class:`SystemBatch` the engine prices — entirely in array ops, so it
    composes with an outer ``jax.jit`` (the fused DSE pipeline)."""
    return space.encoder().encode(idx)


def encoded_nre(tables: Dict[str, jnp.ndarray], meta: EncoderMeta,
                idx) -> NREBreakdown:
    """Closed-form per-unit NRE for encoder-canonical candidate batches.

    The generic engine amortizes design entities with ``segment_sum``
    scatters — correct for arbitrary batches, but scatter-adds serialize
    on CPU and dominate the sweep wall-clock.  The encoder's canonical
    layout makes every Eq. (6)-(8) denominator *closed-form*:

    * within-SKU sharing: the SKU's ``n`` chips (and module instances)
      share one design over ``q * n`` uses -> per-unit ``NRE_e / q``
      (``reuse_within_sku=False``: ``n`` distinct designs, ``n*NRE_e/q``);
    * cross-SKU reuse: one design over ``sum_s q_s * n_s`` uses;
    * packages: own design over ``q`` (shared: over ``sum_s q_s``);
    * D2D: one interface per (candidate, process) over the
      ``q_s * n_s`` of the SKUs that use it (a one-hot reduce over the
      P-wide process menu, not a scatter).

    Returns the engine's :class:`~repro.core.engine.NREBreakdown` with
    ``(K * S,)`` fields, matching ``CostEngine.nre`` on the same encoded
    batch to float32 rounding (pinned <= 1e-6 relative by
    ``tests/test_fused.py``) — the fused pipeline's NRE stage.
    """
    s, p = meta.n_skus, meta.n_processes
    eps = jnp.float32(1e-30)
    is_reuse, ext = _decode(tables, meta, idx)
    k = ext.shape[0]
    srange = jnp.arange(s, dtype=jnp.int32)

    def g(name):                                     # (K, S) gathers
        return tables[name][srange[None, :], ext]

    q = jnp.broadcast_to(tables["quantity"][None, :], (k, s))
    n = g("n_chips")
    reuse_col = is_reuse[:, None]

    # chip + module designs (Eq. 7/8)
    chip_nre = g("nre_chip_k") * g("chip_area") + g("nre_chip_fixed")
    mod_nre = g("nre_mod_k") * g("mod_area")
    denom_c = jnp.maximum((q * n).sum(-1, keepdims=True), eps)
    mult = 1.0 if meta.reuse_within_sku else n
    chips = jnp.where(reuse_col, n * chip_nre / denom_c,
                      mult * chip_nre / jnp.maximum(q, eps))
    modules = jnp.where(reuse_col, n * mod_nre / denom_c,
                        mult * mod_nre / jnp.maximum(q, eps))

    # package designs: own per system unless the reuse scheme shares one
    pkg_nre = g("pkg_k") * g("package_area") + g("pkg_fixed")
    shared = tables["pkg_shared"][ext] > 0.0
    denom_p = jnp.maximum(q.sum(-1, keepdims=True), eps)
    packages = jnp.where(shared, pkg_nre / denom_p,
                         pkg_nre / jnp.maximum(q, eps))

    # D2D interfaces: one per (candidate, process) across the candidate
    has = g("has_d2d")
    pidx = g("d2d_pidx").astype(jnp.int32)
    w = has * q * n                                          # (K, S) uses
    onehot = (pidx[:, :, None]
              == jnp.arange(p, dtype=jnp.int32)[None, None, :])
    denom_d = (w[:, :, None] * onehot).sum(1)                # (K, P)
    den_sys = jnp.take_along_axis(denom_d, pidx, axis=1)     # (K, S)
    d2d = has * n * tables["d2d_nre"][pidx] / jnp.maximum(den_sys, eps)

    flat = k * s
    return NREBreakdown(modules=modules.reshape(flat),
                        chips=chips.reshape(flat),
                        packages=packages.reshape(flat),
                        d2d=d2d.reshape(flat))
