"""Vectorized design-space exploration over the Chiplet Actuary model.

``vmap``-based sweeps over (module area x chiplet count x technology x
node) grids — the engine behind the Fig. 2/4 benchmarks and the
partitioning decision method (Sec. 6 takeaway 1: "splitting into two or
three chiplets is usually sufficient").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp

from .re_cost import re_cost_split
from .technology import PROCESS_NODES, node, tech
from .yield_model import raw_die_cost, yield_negative_binomial


def cost_area_curve(process: str, areas_mm2: jnp.ndarray, early: bool = False):
    """Fig. 2: yield and normalized cost/area vs die area for one node.

    Cost is normalized to the cost-per-area of the raw wafer, as in the
    paper's Fig. 2.
    """
    n = node(process)
    d0 = n.defect_density_early if early else n.defect_density
    y = yield_negative_binomial(areas_mm2, d0, n.cluster_param)
    raw = jax.vmap(lambda a: raw_die_cost(a, n.wafer_cost))(areas_mm2)
    # raw wafer cost per mm^2 (ideal full utilization of a 300mm wafer)
    per_mm2 = n.wafer_cost / (jnp.pi * 150.0 ** 2)
    norm_cost = (raw / y) / (areas_mm2 * per_mm2)
    return {"area": areas_mm2, "yield": y, "norm_cost_per_area": norm_cost}


import functools


@functools.partial(jax.jit, static_argnames=("tech_arrays",))
def _split_totals(areas, ns, wafer_cost, d0, cluster, tech_arrays):
    """(A, N) grid of split totals; tech params passed as scalars."""
    def one(area):
        def per_n(n):
            return re_cost_split(area, n, wafer_cost=wafer_cost,
                                 defect_density=d0, cluster=cluster,
                                 tech_params=tech_arrays)["total"]
        return jax.vmap(per_n)(ns)
    return jax.vmap(one)(areas)


def sweep_partitions(process: str, integration: str,
                     areas_mm2: Sequence[float],
                     n_chiplets: Sequence[int], early: bool = False):
    """RE-cost surface over (module area x number of chiplets) — Fig. 4 data."""
    n = node(process)
    t = tech(integration)
    d0 = n.defect_density_early if early else n.defect_density
    areas = jnp.asarray(areas_mm2, jnp.float32)
    ns = jnp.asarray(n_chiplets, jnp.float32)
    totals = _split_totals(areas, ns, n.wafer_cost, d0, n.cluster_param, t)
    return {"areas": areas, "n_chiplets": ns, "total": totals}


def best_partition(process: str, integration: str, area_mm2: float,
                   max_chiplets: int = 8, early: bool = False) -> Dict:
    """Integer argmin over chiplet count for one (node, tech, area)."""
    ns = list(range(1, max_chiplets + 1))
    res = sweep_partitions(process, integration, [area_mm2], ns, early=early)
    totals = jax.device_get(res["total"])[0]
    i = int(totals.argmin())
    return {"best_n": ns[i], "best_cost": float(totals[i]),
            "soc_cost": float(totals[0]),
            "saving": 1.0 - float(totals[i]) / float(totals[0])}


def pareto_front(points: Sequence[Dict], x_key: str, y_key: str) -> List[Dict]:
    """Lower-left Pareto front (minimize both keys)."""
    pts = sorted(points, key=lambda p: (p[x_key], p[y_key]))
    front, best_y = [], float("inf")
    for p in pts:
        if p[y_key] < best_y:
            front.append(p)
            best_y = p[y_key]
    return front
