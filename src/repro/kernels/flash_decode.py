"""Flash decode (one query token vs a long KV cache) as a Pallas kernel.

Grid (B, H, nT): the cache-block axis innermost, online-softmax state in
VMEM — the single-token specialization of flash attention where the
whole point is streaming a 32k..500k cache through VMEM once.  A
`kv_len` scalar (prefetched to SMEM conceptually; here an int32 operand)
masks the unwritten tail of the cache, so one compiled kernel serves any
fill level — what the continuous-batching engine needs.

q block (1,1,1,D) is repeated across cache blocks; KV blocks are
(1,1,BK,D) with the GQA head-divide in the index map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bk: int):
    ib = pl.program_id(0)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[ib]
    k_start = ik * bk

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (1, D)
        k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)            # (BK, Dv)
        s = (q @ k.T) * scale                          # (1, BK)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + p @ v
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q, k, v, kv_len, *, scale=None, bk: int = 256,
                 interpret: bool = False):
    """q:(B,H,D) k/v:(B,Hkv,T,D) kv_len:(B,) -> (B,H,Dv)."""
    b, h, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = h // hkv
    scale = d ** -0.5 if scale is None else scale
    bk = min(bk, t)
    assert t % bk == 0, (t, bk)

    grid = (b, h, t // bk)
    kernel = functools.partial(_kernel, scale=scale, bk=bk)
    q4 = q[:, :, None, :]                              # (B,H,1,D)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),     # kv_len (B,) scalar
            pl.BlockSpec((1, 1, 1, d), lambda bb, hh, ik: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, hh, ik, g=group: (bb, hh // g, ik, 0)),
            pl.BlockSpec((1, 1, bk, dv),
                         lambda bb, hh, ik, g=group: (bb, hh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, dv),
                               lambda bb, hh, ik: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, dv), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q4, k, v)[:, :, 0, :]
