"""Paper Fig. 5: AMD chiplet-architecture RE validation (early defect
densities 0.13/7nm, 0.12/12nm as the paper uses)."""
from repro.core import Module, System, make_chip, re_cost, soc_system
from .common import emit


def run():
    rows = []
    ccd = make_chip("amd_ccd", [Module("amd_ccd_mod", 74.0, "7nm")], "7nm",
                    integration="MCM", early_defects=True)
    for cores, n_ccd, iod_area in ((8, 1, 125.0), (16, 2, 125.0),
                                   (32, 4, 416.0)):
        iod = make_chip(f"amd_iod_{iod_area}",
                        [Module(f"amd_iod_mod_{iod_area}", iod_area,
                                "12nm")], "12nm", integration="MCM",
                        early_defects=True)
        mcm = re_cost(System(f"amd{cores}_mcm",
                             tuple([ccd] * n_ccd + [iod]), "MCM"))
        soc = re_cost(soc_system(f"amd{cores}_soc",
                                 74.0 * n_ccd + iod_area, "7nm",
                                 early_defects=True))
        rows.append({
            "cores": cores,
            "soc_die_cost": soc.die_cost, "mcm_die_cost": mcm.die_cost,
            "die_saving": 1 - mcm.die_cost / soc.die_cost,
            "mcm_total": mcm.total, "soc_total": soc.total,
            "total_saving": 1 - mcm.total / soc.total,
            "mcm_packaging_share": mcm.packaging_cost / mcm.total,
        })
    emit("fig5_amd_validation", rows)
    return rows


if __name__ == "__main__":
    run()
