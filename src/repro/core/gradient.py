"""Differentiable chiplet partitioning (beyond-paper extension).

The paper sweeps integer chiplet counts; here we exploit the JAX
implementation to *differentiate* the RE model and gradient-descend on

  * a continuous relaxation of the chiplet count ``n`` (rounded at the end),
    via :func:`repro.core.engine.re_split_relaxed` — the same primitives the
    batched :class:`~repro.core.engine.CostEngine` uses, so the relaxed
    objective and the faithful model share one source of truth (real wafer
    yield, sort/bump costs, Eq. 4/5 flow terms);
  * uneven split fractions (softmax-parameterized) optimized against the
    *full* engine RE objective by swapping traced chip areas into a
    :class:`~repro.core.batch.SystemBatch` template — heterogeneous
    partitions, not just even splits.

This is an extension, clearly separated from the faithful model: the
faithful integer sweep (explorer.best_partition) is always reported next
to the relaxed optimum in the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence

import jax
import jax.numpy as jnp

from .batch import SystemBatch
from .engine import CostEngine, _re_impl, re_split_relaxed
from .system import spec
from .technology import node, tech

_ENGINE = CostEngine()


@dataclasses.dataclass
class PartitionResult:
    n_relaxed: float
    n_rounded: int
    cost_relaxed: float
    cost_rounded: float
    cost_soc: float
    iterations: int


def _total(n, area, nd, d0, t):
    return re_split_relaxed(
        area, n, wafer_cost=nd.wafer_cost, defect_density=d0,
        cluster=nd.cluster_param, tech_params=t,
        wafer_yield=nd.wafer_yield, sort_cost=nd.wafer_sort_cost,
        bump_cost=nd.bump_cost_per_mm2,
        interposer_cluster=node(t.interposer_node).cluster_param)["total"]


def optimize_chiplet_count(process: str, integration: str, area_mm2: float,
                           early: bool = False, lr: float = 0.05,
                           steps: int = 300, n0: float = 2.0) -> PartitionResult:
    """Gradient descent on log(n) to minimize the continuous RE total."""
    nd = node(process)
    t = tech(integration)
    d0 = nd.defect_density_early if early else nd.defect_density

    soc_cost = _total(1.0, area_mm2, nd, d0, t)

    def loss(log_n):
        n = jnp.exp(log_n) + 1.0  # n >= 1
        # normalized: O(1) gradients for any node/area (raw $ costs give
        # log-space SGD steps of ~e^80 and the descent diverges)
        return _total(n, area_mm2, nd, d0, t) / soc_cost

    grad = jax.jit(jax.grad(loss))
    val = jax.jit(lambda ln: loss(ln) * soc_cost)
    log_n = jnp.log(jnp.asarray(n0 - 1.0 + 1e-3))
    for i in range(steps):
        g = grad(log_n)
        log_n = log_n - lr * g
    n_rel = float(jnp.exp(log_n) + 1.0)
    n_round = max(1, int(round(n_rel)))
    cost_rel = float(val(log_n))
    cost_round = float(_total(float(n_round), area_mm2, nd, d0, t))
    cost_soc = float(_total(1.0, area_mm2, nd, d0, t))
    return PartitionResult(n_relaxed=n_rel, n_rounded=n_round,
                           cost_relaxed=cost_rel, cost_rounded=cost_round,
                           cost_soc=cost_soc, iterations=steps)


def optimize_uneven_split(process: str, integration: str,
                          module_areas_mm2: Sequence[float],
                          n_chiplets: int, early: bool = False,
                          lr: float = 0.1, steps: int = 500) -> Dict:
    """Assign m modules to n chiplets via a relaxed (softmax) assignment.

    The soft assignment induces (traced) chip areas that are swapped into
    a :class:`SystemBatch` template and priced by the *full* engine RE
    model — interposer, bonding, defect and wasted-KGD terms included,
    unlike the old approximate objective.  Returns the hard assignment
    recovered by argmax plus its faithfully re-evaluated cost.
    """
    nd = node(process)
    t = tech(integration)
    areas = jnp.asarray(module_areas_mm2, jnp.float32)
    m = areas.shape[0]
    ovh = t.d2d_area_overhead
    total_area = float(areas.sum())

    # Template: even n-way split of the right total; its chip_area /
    # package_area leaves are replaced by traced values during descent.
    template = SystemBatch.from_systems([spec({
        "kind": "split", "name": "uneven", "area": total_area,
        "process": process, "n": n_chiplets, "integration": integration,
        "early": early})])

    def re_total(chip_areas):
        silicon = chip_areas.sum()
        batch = template.replace(
            chip_area=chip_areas[None, :],
            package_area=(silicon * t.package_area_factor)[None])
        return _re_impl(batch, "chip-last").total[0]

    def loss(logits):
        p = jax.nn.softmax(logits, axis=1)          # (m, n) soft assignment
        chip_areas = (p.T @ areas) / (1.0 - ovh)    # + D2D share per chiplet
        return re_total(chip_areas)

    grad = jax.jit(jax.grad(loss))
    val = jax.jit(loss)
    key = jax.random.PRNGKey(0)
    logits = 0.01 * jax.random.normal(key, (m, n_chiplets))
    for _ in range(steps):
        logits = logits - lr * grad(logits)
    hard = jax.device_get(jnp.argmax(logits, axis=1))
    chip_areas = [float(areas[hard == i].sum()) for i in range(n_chiplets)]
    occupied = [a for a in chip_areas if a > 0.0]
    hard_batch = SystemBatch.from_systems([spec({
        "kind": "chips", "name": "uneven_hard",
        "chips": [{"area": a, "process": process, "early": early}
                  for a in occupied],
        "integration": integration})])
    hard_cost = float(_ENGINE.re(hard_batch).total[0])
    return {"assignment": hard.tolist(), "chip_areas": chip_areas,
            "soft_cost": float(val(logits)), "hard_cost": hard_cost}
