"""Fused RMSNorm as a Pallas kernel.

Grid (nRows,): each step normalizes a (BR, D) row block entirely in
VMEM — one HBM read + one write per element (the XLA fallback emits
separate square/mean/rsqrt/mul kernels unless fusion wins).  D stays
unblocked: for every assigned arch D <= 12288 -> 48 KB/row fp32, far
under the ~16 MB VMEM budget even at BR = 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                 # (BR, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)[None, :]
                  ).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-5, br: int = 256,
            interpret: bool = False):
    """(N,D),(D,) -> (N,D)."""
    nrows, d = x.shape
    br = min(br, nrows)
    pr = (-nrows) % br
    if pr:
        x = jnp.pad(x, ((0, pr), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((nrows + pr) // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows + pr, d), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:nrows] if pr else out
