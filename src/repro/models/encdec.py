"""Encoder-decoder backbone (Whisper-medium class).

The audio conv frontend is a STUB per the brief: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, D).  The backbone is the real
thing: a bidirectional encoder stack and a causal decoder stack with
cross-attention, scan-over-layers like the decoder-only path.

decode shapes: the assigned ``seq_len`` is the number of ENCODER frames;
the decoder is bounded at cfg.dec_len (Whisper's 448).  ``decode_*``
shapes lower one decoder token against (self KV cache + frozen cross KV).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain
from .attention import (attend_chunked, attend_decode, attend_full,
                        gqa_decode_layer, gqa_spec, gqa_output,
                        gqa_project_qkv, _scatter_kv)
from .common import (ParamSpec, cross_entropy, embed, embed_spec,
                     mask_padded_vocab, rmsnorm, rmsnorm_spec, swiglu,
                     swiglu_spec, unembed)
from .transformer import stack_specs, _attn_cache_spec, _remat


def _enc_block_spec(cfg) -> Dict:
    return {"ln1": rmsnorm_spec(cfg.d_model), "ln2": rmsnorm_spec(cfg.d_model),
            "attn": gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh),
            "ffn": swiglu_spec(cfg.d_model, cfg.d_ff)}


def _dec_block_spec(cfg) -> Dict:
    sp = _enc_block_spec(cfg)
    sp["ln_x"] = rmsnorm_spec(cfg.d_model)
    sp["xattn"] = gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh)
    return sp


def encdec_spec(cfg) -> Dict:
    return {
        "embed": embed_spec(cfg.padded_vocab, cfg.d_model),
        "dec_pos": ParamSpec((cfg.dec_len, cfg.d_model), (None, "embed"),
                             scale=0.02),
        "enc_blocks": stack_specs(_enc_block_spec(cfg), cfg.n_layers),
        "dec_blocks": stack_specs(_dec_block_spec(cfg), cfg.n_dec_layers),
        "enc_norm": rmsnorm_spec(cfg.d_model),
        "final_norm": rmsnorm_spec(cfg.d_model),
    }


def _self_attn(cfg, p, x, positions, causal: bool):
    q, k, v = gqa_project_qkv(p, x, positions, cfg.rope_theta)
    if causal:
        o = attend_chunked(q, k, v, chunk=cfg.attn_chunk)
    else:
        o = attend_chunked(q, k, v, causal=False, chunk=cfg.attn_chunk)
    return gqa_output(p, o)


def _cross_attn(cfg, p, x, enc_k, enc_v):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    o = attend_chunked(q, enc_k, enc_v, causal=False, chunk=cfg.attn_chunk)
    return gqa_output(p, o)


def cross_kv(cfg, p, enc_out):
    k = jnp.einsum("bsd,dnk->bsnk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", enc_out, p["wv"])
    return k, v


def encode(cfg, params, frames):
    """frames: (B, S_enc, D) precomputed embeddings (stub frontend)."""
    x = constrain(frames.astype(cfg.jdtype), "batch", "seq", "act_embed")
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(h, p):
        h = h + _self_attn(cfg, p["attn"],
                           rmsnorm(p["ln1"], h, cfg.norm_eps),
                           positions, causal=False)
        h = h + swiglu(p["ffn"], rmsnorm(p["ln2"], h, cfg.norm_eps))
        return constrain(h, "batch", "seq", "act_embed"), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["enc_blocks"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(cfg, params, enc_out, dec_tokens):
    """Teacher-forced decoder: (B, S_dec) -> logits (B, S_dec, V)."""
    b, sd = dec_tokens.shape
    x = embed(params["embed"], dec_tokens).astype(cfg.jdtype)
    x = x + params["dec_pos"][None, :sd].astype(cfg.jdtype)
    positions = jnp.broadcast_to(jnp.arange(sd), (b, sd))

    def body(h, p):
        h = h + _self_attn(cfg, p["attn"],
                           rmsnorm(p["ln1"], h, cfg.norm_eps),
                           positions, causal=True)
        ek, ev = cross_kv(cfg, p["xattn"], enc_out)
        h = h + _cross_attn(cfg, p["xattn"],
                            rmsnorm(p["ln_x"], h, cfg.norm_eps), ek, ev)
        h = h + swiglu(p["ffn"], rmsnorm(p["ln2"], h, cfg.norm_eps))
        return constrain(h, "batch", "seq", "act_embed"), None

    x, _ = jax.lax.scan(_remat(body, cfg), x, params["dec_blocks"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return mask_padded_vocab(unembed(params["embed"], x), cfg.vocab)


def encdec_loss(cfg, params, batch):
    """batch: {'frames': (B,S,D), 'dec_tokens': (B,Sd), 'labels': (B,Sd)}."""
    enc = encode(cfg, params, batch["frames"])
    logits = decode_train(cfg, params, enc, batch["dec_tokens"])
    return cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving: prefill = encode + cross-KV + BOS; decode = 1 token/step
# ---------------------------------------------------------------------------


def encdec_cache_spec(cfg, batch: int, enc_len: int) -> Dict:
    dt = cfg.jdtype
    return {
        "self": stack_specs(_attn_cache_spec(cfg, batch, cfg.dec_len, dt),
                            cfg.n_dec_layers),
        "cross_k": ParamSpec((cfg.n_dec_layers, batch, enc_len,
                              cfg.n_kv_heads, cfg.dh),
                             ("layers", "batch", "kv_seq", "kv", None), dt,
                             init="zeros"),
        "cross_v": ParamSpec((cfg.n_dec_layers, batch, enc_len,
                              cfg.n_kv_heads, cfg.dh),
                             ("layers", "batch", "kv_seq", "kv", None), dt,
                             init="zeros"),
    }


def encdec_prefill(cfg, params, frames):
    """Encode audio; build cross-KV; return empty self-cache."""
    enc = encode(cfg, params, frames)
    b = enc.shape[0]
    dt = cfg.jdtype

    def kv_body(_, p):
        k, v = cross_kv(cfg, p["xattn"], enc)
        return None, (k.astype(dt), v.astype(dt))

    _, (cks, cvs) = jax.lax.scan(kv_body, None, params["dec_blocks"])
    self_cache = {
        "k": jnp.zeros((cfg.n_dec_layers, b, cfg.dec_len, cfg.n_kv_heads,
                        cfg.dh), dt),
        "v": jnp.zeros((cfg.n_dec_layers, b, cfg.dec_len, cfg.n_kv_heads,
                        cfg.dh), dt),
    }
    return {"self": self_cache, "cross_k": cks, "cross_v": cvs}


def encdec_decode(cfg, params, token, cache, kv_len):
    """One decoder token. token:(B,1); kv_len:(B,) decoder cache fill."""
    b = token.shape[0]
    x = embed(params["embed"], token).astype(cfg.jdtype)
    pos_emb = jnp.take(params["dec_pos"], jnp.clip(kv_len, 0,
                                                   cfg.dec_len - 1), axis=0)
    x = x + pos_emb[:, None, :].astype(cfg.jdtype)

    def body(h, inp):
        p, ck, cv, xk, xv = inp
        hn = rmsnorm(p["ln1"], h, cfg.norm_eps)
        a, nck, ncv = gqa_decode_layer(p["attn"], hn, ck, cv, kv_len, kv_len,
                                       cfg.rope_theta)
        h = h + a
        hn = rmsnorm(p["ln_x"], h, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", hn, p["xattn"]["wq"])
        xo = attend_decode(q, xk, xv)
        h = h + gqa_output(p["xattn"], xo)
        h = h + swiglu(p["ffn"], rmsnorm(p["ln2"], h, cfg.norm_eps))
        return h, (nck, ncv)

    x, (nck, ncv) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"]["k"],
                  cache["self"]["v"], cache["cross_k"], cache["cross_v"]))
    new_cache = {"self": {"k": nck, "v": ncv}, "cross_k": cache["cross_k"],
                 "cross_v": cache["cross_v"]}
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = mask_padded_vocab(unembed(params["embed"], x[:, 0]), cfg.vocab)
    return logits, new_cache
