import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any jax import (device count
# locks on first init), which is why this module has no __future__ import
# and the docstring sits below.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell we build the EXACT production step function (train /
prefill / serve), attach NamedShardings to ShapeDtypeStruct stand-ins
(zero allocation), ``.lower().compile()`` it on the 16x16 single-pod and
2x16x16 two-pod meshes, and record:

  * memory_analysis()    — per-device bytes (proves it fits),
  * cost_analysis()      — XLA's own (loop-body-once) numbers,
  * analysis.hlo         — trip-count-aware FLOPs / HBM / collective bytes,
  * analysis.roofline    — the three roofline terms + MODEL_FLOPS ratio.

Results accumulate in a JSON cache (resumable; cells are skipped when
already present unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch glm4_9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.analysis.hlo import analyze_hlo_text
from repro.analysis.roofline import model_flops, roofline_from_report
from repro.configs.base import (ARCH_IDS, SHAPES, cell_supported, get_config)
from repro.launch.mesh import describe, make_production_mesh
from repro.parallel import sharding as shd
from repro.parallel import steps as st

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "results" / "dryrun.json"


def build_cell(cfg, shape, mesh, rules, overrides=None):
    """Returns (fn, example_args) for jit lowering of one cell."""
    if overrides:
        cfg = cfg.replace(**overrides)
    if shape.kind == "train":
        fn = st.make_train_step(cfg, accum=cfg.accum)
        state = st.abstract_state(cfg, mesh, rules)
        batch = st.abstract_batch(cfg, shape, mesh, rules, accum=cfg.accum)
        return fn, (state, batch), {"donate_argnums": (0,)}
    if shape.kind == "prefill":
        fn = st.make_prefill_step(cfg, cache_len=shape.seq_len)
        params = st.abstract_state(cfg, mesh, rules).params
        batch = st.abstract_batch(cfg, shape, mesh, rules)
        return fn, (params, batch), {}
    if shape.kind == "decode":
        fn = st.make_serve_step(cfg)
        params = st.abstract_state(cfg, mesh, rules).params
        batch = st.abstract_batch(cfg, shape, mesh, rules)
        cache = st.abstract_cache(cfg, shape, mesh, rules)
        return fn, (params, batch, cache), {"donate_argnums": (2,)}
    raise ValueError(shape.kind)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"status": "skip", "reason": why}

    eff_cfg = cfg.replace(**overrides) if overrides else cfg
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.default_rules(multi_pod=multi_pod,
                              act_shard=eff_cfg.act_shard)
    t0 = time.time()
    with mesh, shd.use_mesh(mesh, rules):
        fn, args, jit_kw = build_cell(cfg, shape, mesh, rules, overrides)
        lowered = jax.jit(fn, **jit_kw).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    eff = cfg.replace(**overrides) if overrides else cfg
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):      # older jax: one dict per computation
        ca = ca[0] if ca else {}
    text = compiled.as_text()
    rep = analyze_hlo_text(text, score_chunks=(eff.attn_chunk,
                                               eff.ssm_chunk))
    mf = model_flops(cfg, shape)
    terms = roofline_from_report(rep, chips=mesh.devices.size,
                                 model_flops=mf)

    result = {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": int(mesh.devices.size),
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes)
                / 1e9, 3),
        },
        "xla_cost_analysis": {
            "flops_per_device_loop_once": ca.get("flops", 0.0),
            "bytes_accessed_loop_once": ca.get("bytes accessed", 0.0),
        },
        "hlo_analysis": rep.as_dict(),
        "roofline": terms.as_dict(),
    }
    return result


def cell_key(arch, shape, mesh_label, tag=""):
    k = f"{arch}|{shape}|{mesh_label}"
    return f"{k}|{tag}" if tag else k


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS)
    p.add_argument("--shape", choices=list(SHAPES))
    p.add_argument("--mesh", choices=["single", "multi", "both"],
                   default="single")
    p.add_argument("--all", action="store_true")
    p.add_argument("--force", action="store_true")
    p.add_argument("--out", type=Path, default=DEFAULT_OUT)
    p.add_argument("--tag", default="", help="variant tag for perf sweeps")
    p.add_argument("--override", action="append", default=[],
                   help="cfg override key=value (e.g. remat=dots)")
    args = p.parse_args(argv)

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    args.out.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if args.out.exists():
        results = json.loads(args.out.read_text())

    failures = 0
    for a, s, mp in cells:
        label = "2x16x16" if mp else "16x16"
        key = cell_key(a, s, label, args.tag)
        if key in results and results[key].get("status") in ("ok", "skip") \
                and not args.force:
            print(f"[cached] {key}: {results[key]['status']}")
            continue
        print(f"[run] {key} ...", flush=True)
        try:
            res = run_cell(a, s, mp, overrides or None)
            if overrides:
                res["overrides"] = overrides
        except Exception as e:
            traceback.print_exc()
            res = {"status": "fail", "error": f"{type(e).__name__}: {e}"}
            failures += 1
        results[key] = res
        args.out.write_text(json.dumps(results, indent=1, sort_keys=True))
        if res["status"] == "ok":
            r = res["roofline"]
            print(f"  ok: compile {res['t_compile_s']}s  "
                  f"mem/dev {res['memory']['peak_estimate_gb']} GB  "
                  f"bound={r['bound']}  t={r['t_bound']:.4f}s  "
                  f"frac={r['roofline_fraction']:.3f}")
        else:
            print(f"  {res['status']}: {res.get('reason') or res.get('error')}")
    print(f"done: {len(cells)} cells, {failures} failures -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
