"""Guard the benchmark perf trajectory against the committed baselines.

  python scripts/check_bench_regression.py [--min-ratio 0.15] [name ...]

Compares the ``BENCH_<name>.json`` files the benchmarks write at the
repo root (see ``benchmarks/common.write_bench_json``) against the
committed ``benchmarks/baselines/BENCH_<name>.json``:

* throughput keys must stay within ``--min-ratio`` of the baseline
  (generous by default: CI boxes are noisy and shared, so the guard
  catches order-of-magnitude regressions, not jitter);
* absolute floors/ceilings (speedup ratios, parity errors) are enforced
  exactly — these are correctness-adjacent and machine-independent.

Exit code 1 on any violation; prints a per-key PASS/FAIL table.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
BASELINES = ROOT / "benchmarks" / "baselines"

# (key, kind, threshold): kind "ratio" compares against min_ratio *
# baseline[key]; "min"/"max" are machine-independent absolute bounds.
RULES = {
    "dse": [
        ("candidates_per_sec", "ratio", None),
        ("fused_vs_legacy", "min", 10.0),
        ("parity_vs_legacy_rel", "max", 1e-6),
        ("parity_worst_rel", "max", 1e-5),
    ],
    "engine": [
        ("systems_per_sec", "ratio", None),
        ("worst_rel", "max", 1e-5),
    ],
    "service": [
        # ratio compares like-for-like: CI runs --fast and the committed
        # baseline is a --fast run.  The full bench additionally asserts
        # aggregate throughput >= 0.5x the single-client fused rate
        # in-process (mode-dependent, so not a baseline rule here).
        ("agg_candidates_per_sec", "ratio", None),
        ("recompiles_after_warmup", "max", 0.0),
    ],
}


def check(name: str, min_ratio: float) -> bool:
    if name not in RULES:
        print(f"[{name}] UNKNOWN benchmark — known: {sorted(RULES)}")
        return False
    cur_path = ROOT / f"BENCH_{name}.json"
    base_path = BASELINES / f"BENCH_{name}.json"
    if not cur_path.exists():
        print(f"[{name}] MISSING {cur_path} — run the benchmark first")
        return False
    if not base_path.exists():
        print(f"[{name}] MISSING baseline {base_path} — commit one "
              f"(copy a trusted BENCH_{name}.json there)")
        return False
    cur = json.loads(cur_path.read_text())
    base = json.loads(base_path.read_text())
    ok = True
    failures = []
    for key, kind, bound in RULES[name]:
        if key not in cur:
            print(f"[{name}] FAIL {key} MISSING from {cur_path.name} "
                  f"(rule {kind}) — did the benchmark finish?")
            failures.append((key, "missing from current run"))
            ok = False
            continue
        have = float(cur[key])
        if kind == "ratio":
            if key not in base:
                print(f"[{name}] FAIL {key} MISSING from baseline "
                      f"{base_path.name} — re-commit the baseline")
                failures.append((key, "missing from baseline"))
                ok = False
                continue
            want = min_ratio * float(base[key])
            good = have >= want
            detail = (f">= {want:,.1f} ({min_ratio:g}x baseline "
                      f"{float(base[key]):,.1f})")
            miss = (f"short by {want - have:,.6g} "
                    f"({have / want:.2%} of the floor)" if not good else "")
        elif kind == "min":
            want = float(bound)
            good = have >= want
            detail = f">= {want:g}"
            miss = f"short by {want - have:,.6g}" if not good else ""
        else:
            want = float(bound)
            good = have <= want
            detail = f"<= {want:g}"
            miss = f"over by {have - want:,.6g}" if not good else ""
        print(f"[{name}] {'PASS' if good else 'FAIL'} {key} = {have:,.6g} "
              f"(need {detail})" + (f" — {miss}" if miss else ""))
        if not good:
            failures.append((key, miss))
        ok &= good
    for key, why in failures:
        print(f"[{name}] RULE FAILED: {key} — {why}")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("names", nargs="*", default=list(RULES))
    ap.add_argument("--min-ratio", type=float, default=0.15,
                    help="throughput floor as a fraction of baseline")
    args = ap.parse_args()
    ok = all(check(n, args.min_ratio) for n in (args.names or list(RULES)))
    if not ok:
        print("benchmark regression detected")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
