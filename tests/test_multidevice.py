"""Multi-device behaviour (pipeline, shard_map collectives, sharded-vs-
single training, HLO analyzer) — each in a subprocess with 4-8 fake
devices so the main pytest process stays single-device."""
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def _run(prog: str, tol: float = 1e-4) -> float:
    env = {"PYTHONPATH": f"{ROOT}/src:{ROOT}/tests",
           "PATH": "/usr/bin:/bin:/usr/local/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in env and k != "XLA_FLAGS"})
    out = subprocess.run(
        [sys.executable, str(ROOT / "tests" / "md_programs.py"), prog],
        capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, f"{prog} failed:\n{out.stdout}\n{out.stderr}"
    for line in out.stdout.splitlines():
        if line.startswith("MAXDIFF"):
            return float(line.split()[1])
    raise AssertionError(f"no MAXDIFF in output:\n{out.stdout}")


def test_pipeline_parallel_matches_sequential():
    assert _run("pipeline") < 1e-5


def test_flash_decode_shardmap_matches_reference():
    assert _run("flash_decode_sm") < 1e-4


def test_compressed_psum_hierarchical_reduction():
    # program prints 0.0 when diff under tolerance
    assert _run("compressed_psum") == 0.0


def test_sharded_training_loss_matches_single_device():
    assert _run("sharded_train_matches_single") < 5e-4


def test_hlo_analyzer_counts_scanned_dot_flops_exactly():
    assert _run("hlo_analyzer_exact") < 1e-9


def test_elastic_restore_across_mesh_shapes():
    assert _run("elastic_restore") == 0.0


def test_dryrun_cli_end_to_end(tmp_path):
    """The dry-run CLI on the smallest real cell, fresh subprocess."""
    import json
    import os
    import subprocess
    import sys
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = f"{ROOT}/src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "xlstm_125m", "--shape", "decode_32k", "--mesh", "single",
         "--out", str(tmp_path / "dr.json")],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + out.stderr
    res = json.loads((tmp_path / "dr.json").read_text())
    cell = res["xlstm_125m|decode_32k|16x16"]
    assert cell["status"] == "ok"
    assert cell["chips"] == 256
    assert cell["roofline"]["t_bound"] > 0
