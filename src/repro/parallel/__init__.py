from . import sharding
