"""repro.service.metrics in isolation: quantile edge cases, cached- and
failed-request accounting, the per-lane occupancy fix (gen ticks now
count toward slot occupancy instead of being a blind spot), snapshot key
stability, and the registry mirroring of service counters."""
import time

import pytest

from repro.obs.registry import REGISTRY
from repro.service.metrics import LaneStats, ServiceMetrics, _quantiles


# ---------------------------------------------------------------------------
# Quantile helper edge cases
# ---------------------------------------------------------------------------


def test_quantiles_empty_list_is_zeros():
    assert _quantiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0,
                              "mean": 0.0}


def test_quantiles_single_sample_is_that_sample():
    q = _quantiles([0.25])
    assert q["p50"] == q["p95"] == q["p99"] == q["mean"] == 0.25


def test_quantiles_are_ordered():
    q = _quantiles([float(i) for i in range(100)])
    assert q["p50"] <= q["p95"] <= q["p99"]
    assert q["mean"] == pytest.approx(49.5)


# ---------------------------------------------------------------------------
# Request lifecycle
# ---------------------------------------------------------------------------


def test_cached_request_ttfr_equals_latency():
    m = ServiceMetrics()
    rec = m.start_request("price", 8, time.perf_counter())
    m.finish_request(rec, ok=True, cached=True)    # t_first never set
    assert rec.cached
    assert rec.t_first == rec.t_done
    assert rec.ttfr_s == rec.latency_s
    assert rec.latency_s >= 0.0
    snap = m.snapshot()
    assert snap["n_ok"] == 1
    assert snap["latency_s"]["p50"] == pytest.approx(rec.latency_s)


def test_error_and_rejection_counting():
    m = ServiceMetrics()
    ok = m.start_request("price", 4, time.perf_counter())
    m.finish_request(ok, ok=True)
    bad = m.start_request("search", 0, time.perf_counter())
    m.finish_request(bad, ok=False)
    m.reject()
    m.reject()
    snap = m.snapshot()
    assert snap["n_requests"] == 2
    assert snap["n_ok"] == 1
    assert snap["n_errors"] == 1
    assert snap["n_rejected"] == 2
    assert snap["requests_by_kind"] == {"price": 1, "search": 1}
    # failed requests don't poison the ok-latency quantiles
    assert snap["latency_s"]["p50"] == pytest.approx(ok.latency_s)


# ---------------------------------------------------------------------------
# Tick accounting: per-lane occupancy including the gen lane
# ---------------------------------------------------------------------------


def test_gen_ticks_count_toward_occupancy():
    m = ServiceMetrics()
    m.record_tick("chunk", slots=16, used=8, rows_priced=8, wall_s=0.010)
    m.record_tick("gen", slots=32, used=32, rows_priced=32, wall_s=0.020)
    snap = m.snapshot()
    # gen work is IN the aggregate now: (8+32)/(16+32)
    assert snap["slot_occupancy"] == pytest.approx(40 / 48)
    assert snap["padded_waste_frac"] == pytest.approx(1 - 40 / 48)
    assert snap["rows_priced"] == 40
    assert snap["ticks"] == 2 and snap["gen_ticks"] == 1
    assert snap["device_gets"] == 2
    assert snap["busy_s"] == pytest.approx(0.030)


def test_per_lane_breakdown():
    m = ServiceMetrics()
    m.record_tick("chunk", 16, 8, 8, 0.010)
    m.record_tick("chunk", 16, 16, 16, 0.012)
    m.record_tick("gen", 32, 32, 32, 0.020)
    m.record_tick("mc", 16, 4, 4, 0.005)
    snap = m.snapshot()
    per = snap["per_lane"]
    assert set(per) == {"chunk", "gen", "mc"}
    assert per["chunk"]["ticks"] == 2
    assert per["chunk"]["occupancy"] == pytest.approx(24 / 32)
    assert per["chunk"]["padded_waste_frac"] == pytest.approx(1 - 24 / 32)
    assert per["gen"]["occupancy"] == 1.0
    assert per["gen"]["rows_priced"] == 32
    assert per["mc"]["occupancy"] == pytest.approx(4 / 16)
    assert snap["ticks_by_lane"] == {"chunk": 2, "gen": 1, "mc": 1}
    # rows_priced is consistent: lanes sum to the aggregate
    assert sum(l["rows_priced"] for l in per.values()) \
        == snap["rows_priced"]


def test_lane_stats_empty_division_guards():
    ls = LaneStats()
    assert ls.occupancy == 0.0
    d = ls.as_dict()
    assert d["occupancy"] == 0.0 and d["padded_waste_frac"] == 0.0
    m = ServiceMetrics()
    snap = m.snapshot()
    assert snap["slot_occupancy"] == 0.0
    assert snap["rows_per_sec_busy"] == 0.0


# ---------------------------------------------------------------------------
# Snapshot surface stability (bench/CI consumers key on these)
# ---------------------------------------------------------------------------

EXPECTED_KEYS = {
    "n_requests", "n_done", "n_ok", "n_errors", "n_rejected",
    "requests_by_kind", "latency_s", "ttfr_s", "ticks", "device_gets",
    "gen_ticks", "ticks_by_lane", "per_lane", "slot_occupancy",
    "padded_waste_frac", "rows_priced", "busy_s", "rows_per_sec_busy",
    "wall_s",
}


def test_snapshot_key_stability():
    m = ServiceMetrics()
    assert set(m.snapshot()) == EXPECTED_KEYS
    snap = m.snapshot(trace_stats={"tick_recompiles": 0},
                      cache_stats={"hits": 1})
    assert set(snap) == EXPECTED_KEYS | {"trace", "result_cache",
                                         "recompiles_after_warmup"}
    assert snap["recompiles_after_warmup"] == 0


def test_write_json_roundtrip(tmp_path):
    import json
    m = ServiceMetrics()
    m.record_tick("chunk", 8, 8, 8, 0.001)
    path = m.write_json(tmp_path / "snap.json")
    doc = json.loads(path.read_text())
    assert doc["ticks"] == 1
    assert doc["per_lane"]["chunk"]["occupancy"] == 1.0


# ---------------------------------------------------------------------------
# Registry mirroring
# ---------------------------------------------------------------------------


def test_service_counters_mirrored_into_registry():
    before_req = (REGISTRY.get("service_requests").get()
                  if REGISTRY.get("service_requests") else 0)
    before_tick = (REGISTRY.get("service_ticks").get()
                   if REGISTRY.get("service_ticks") else 0)
    m = ServiceMetrics()
    rec = m.start_request("price", 4, time.perf_counter())
    m.finish_request(rec, ok=True)
    m.record_tick("chunk", 8, 8, 8, 0.001)
    assert REGISTRY.get("service_requests").get() == before_req + 1
    assert REGISTRY.get("service_ticks").get() == before_tick + 1
    assert REGISTRY.get("service_latency_s").count >= 1
