"""Portfolio search: cheapest multi-chiplet architecture for a product line.

  PYTHONPATH=src python examples/portfolio_search.py

A vendor ships three SKUs (laptop / desktop / server parts) at very
different volumes.  Should each get its own silicon?  One shared chiplet
design collocated 1x/2x/4x (the paper's SCMS scheme)?  Which node, which
packaging?  `repro.dse` prices the whole candidate space through the
batched CostEngine and searches it — here with Monte Carlo risk so the
answer is "cheapest at the 90th percentile", not just at nominal
parameters.
"""
import jax

from repro.dse import (DesignSpace, SKU, RiskConfig, detail_rows,
                       exhaustive_search, format_table, portfolio_search,
                       result_rows, sensitivities)


def main():
    space = DesignSpace(
        skus=(SKU("laptop", 150.0, 2e6),       # 150 mm^2 of modules, 2M units
              SKU("desktop", 300.0, 1e6),
              SKU("server", 600.0, 3e5)),
        processes=("5nm", "7nm"),
        integrations=("MCM", "2.5D"),
        chiplet_counts=(1, 2, 3, 4),
        allow_reuse=True,                      # SCMS-style shared chiplet
        reuse_package_options=(False, True))   # optionally share the package
    print(f"design space: {space.size()} candidate portfolio architectures")

    # 1. Nominal search: evolutionary loop, deterministic in the key.
    key = jax.random.PRNGKey(0)
    res = portfolio_search(space, key, population=48, generations=10,
                           elite=8)
    print(f"\nevaluated {res.n_evaluated} candidates; cheapest portfolio:")
    print(format_table(result_rows(res.top(5))))

    # 2. The space is small enough to brute-force — confirm the optimum.
    ex = exhaustive_search(space)
    print(f"\nexhaustive best ({ex.n_evaluated} candidates): "
          f"{ex.best.label}  ${ex.best.portfolio_cost:,.0f}")
    gap = res.best.portfolio_cost / ex.best.portfolio_cost - 1.0
    print("search found the exact optimum"
          if ex.best.label == res.best.label else
          f"search came within {gap:.2%} of the optimum "
          f"(heuristic — evaluated {res.n_evaluated}/{space.size()})")

    # 3. Uncertainty-aware: optimize the 90th-percentile portfolio cost
    #    under defect-density / wafer-price / bond-yield uncertainty.
    risky = portfolio_search(space, key, population=48, generations=6,
                             elite=8, risk=RiskConfig(n_draws=256,
                                                      quantile=0.9))
    r = risky.best
    print(f"\nrisk-aware winner (q90 objective): {r.label}")
    print(f"  mean ${r.risk['mean']:,.0f}   q50 ${r.risk['q50']:,.0f}   "
          f"q90 ${r.risk['q90']:,.0f}")
    print("cost-vs-risk Pareto front:")
    for p in risky.pareto:
        print(f"  {p['label']:40s} mean ${p['mean']:,.0f}  "
              f"q90 ${p['q90']:,.0f}")

    # 4. Itemized per-SKU economics of the winner (engine as_rows columns)
    #    and its local parameter sensitivities.
    print("\nwinner per-SKU breakdown:")
    print(format_table(detail_rows(space, res.best.candidate)))
    from repro.core import SystemBatch
    from repro.dse import candidate_systems
    batch = SystemBatch.from_systems(
        candidate_systems(space, res.best.candidate), share_nre=True)
    sens = sensitivities(batch)
    print("\nelasticities d(cost)/d(ln p) per SKU unit (USD per 100% move):")
    for p in ("chip_defect", "chip_wafer_cost", "y2_chip_bond"):
        vals = "  ".join(f"{float(v):8.2f}" for v in sens[p])
        print(f"  {p:18s} {vals}")


if __name__ == "__main__":
    main()
