"""Trip-count-aware analyzer for optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — an 88-layer
scanned transformer under-reports FLOPs by 88x.  This analyzer parses
``compiled.as_text()`` and computes, with loop multipliers:

  * matmul FLOPs          (dot ops, incl. inside fusions)
  * HBM traffic estimate  (per top-level op: output + operand bytes —
                           the post-fusion buffer-materialization model)
  * collective bytes      (all-reduce / all-gather / reduce-scatter /
                           all-to-all / collective-permute), per type

All shapes in a partitioned SPMD module are per-device shards, so every
number reported here is PER DEVICE — exactly what the roofline wants.

Loop trip counts come from the integer constants in each ``while``
condition computation (jax scans lower to ``compare(iv, L), dir=LT``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)\)(.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*{\s*$")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of all array shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return "", []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    out_type: str
    opcode: str
    args: str
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    types: Dict[str, str] = dataclasses.field(default_factory=dict)

    def operand_names(self, op: Op) -> List[str]:
        # %refs in args that are ops of this computation = data operands
        return [n for n in re.findall(r"%([\w.\-]+)", op.args)
                if n in self.types]

    def operand_bytes(self, op: Op) -> int:
        return sum(_shape_bytes(self.types[n])
                   for n in self.operand_names(op))


COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class HLOCostReport:
    """Per-device totals with while-loop multipliers applied."""

    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_counts: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    n_while: int = 0
    trip_counts: List[int] = dataclasses.field(default_factory=list)
    # Traffic of attention-score-shaped intermediates (f32, last dim ==
    # the flash chunk) materialized inside loops.  The XLA fallback path
    # must write them to HBM; the Pallas flash kernel holds them in VMEM
    # — `hbm_bytes - score_buffer_bytes` is the kernel-path estimate.
    score_buffer_bytes: float = 0.0
    # Non-streaming traffic inside long recurrences (trip >= 512): a
    # fused Pallas cell kernel keeps the state in VMEM across steps;
    # only the per-step input/output slices stream to HBM.
    recurrent_buffer_bytes: float = 0.0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    @property
    def hbm_bytes_kernel_path(self) -> float:
        return max(0.0, self.hbm_bytes - self.score_buffer_bytes
                   - self.recurrent_buffer_bytes)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "n_while": self.n_while, "trip_counts": list(self.trip_counts),
            "score_buffer_bytes": self.score_buffer_bytes,
            "recurrent_buffer_bytes": self.recurrent_buffer_bytes,
            "hbm_bytes_kernel_path": self.hbm_bytes_kernel_path,
        }


def parse_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m:
                cur = Computation(name=m.group(1), ops=[])
                if line.lstrip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}" or line.strip().startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, out_type, opcode, args, attrs = m.groups()
            cur.ops.append(Op(name, out_type.strip(), opcode, args, attrs))
            cur.types[name] = out_type.strip()
    if cur is not None:
        comps[cur.name] = cur
    if entry is None:
        # fall back: the computation named like the module or the last one
        entry = list(comps)[-1] if comps else ""
    return comps, entry


_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                        r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")


def _called_comps(op: Op) -> Dict[str, List[str]]:
    text = op.args + " " + op.attrs      # attrs may be swallowed into args
    out: Dict[str, List[str]] = {}
    for key in ("calls", "body", "condition", "to_apply"):
        m = re.search(key + r"=%?([\w.\-]+)", text)
        if m:
            out[key] = [m.group(1)]
    m = re.search(r"branch_computations={([^}]*)}", text)
    if m:
        out["branches"] = [b.strip().lstrip("%")
                           for b in m.group(1).split(",")]
    return out


_TRIP_RE = re.compile(r'known_trip_count[^0-9]*"n"\s*:\s*"(\d+)"')


def _trip_count(op: Op, comps: Dict[str, Computation],
                cond_name: str) -> int:
    # Preferred: XLA's own backend_config known_trip_count annotation.
    m = _TRIP_RE.search(op.args + " " + op.attrs)
    if m:
        return int(m.group(1))
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for o in cond.ops:
        if o.opcode == "constant":
            try:
                consts.append(int(o.args.strip()))
            except ValueError:
                pass
        consts += [int(c) for c in _CONST_RE.findall(o.args + o.attrs)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 * prod(output dims) * prod(contracting dims of lhs)."""
    _, out_dims = _first_shape_dims(op.out_type)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", op.args + op.attrs)
    operands = comp.operand_names(op)
    lhs_dims: List[int] = []
    if operands:
        _, lhs_dims = _first_shape_dims(comp.types[operands[0]])
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx:
                i = int(idx)
                if i < len(lhs_dims):
                    contract *= lhs_dims[i]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * contract


def _collective_bytes(op: Op, comp: Computation) -> float:
    opb = comp.operand_bytes(op)
    outb = _shape_bytes(op.out_type)
    kind = op.opcode.replace("-start", "").replace("-done", "")
    if kind == "all-reduce":
        return 2.0 * opb                 # ring: reduce-scatter + all-gather
    if kind == "all-gather":
        return float(outb)
    if kind == "reduce-scatter":
        return float(opb)
    if kind == "all-to-all":
        return float(opb)
    if kind == "collective-permute":
        return float(opb)
    return float(opb)


_SLICE_OPS = ("dynamic-slice", "slice", "gather")
_CAST_OPS = ("convert", "bitcast", "copy")


def _is_pure_cast_fusion(comps: Dict[str, Computation], op: Op) -> bool:
    """Fusion computing only dtype casts / layout copies.

    The CPU backend materializes f32 shadow copies of bf16 weights and
    caches (its dot emitter wants f32) and hoists them out of loops; a
    TPU consumes bf16 natively in the MXU, so these fusions would not
    exist there.  The roofline targets the TPU, so they are charged 0.
    """
    called = _called_comps(op)
    sub = comps.get(called.get("calls", [""])[0]) if "calls" in called \
        else None
    if sub is None:
        return False
    real = [o for o in sub.ops
            if o.opcode not in _CAST_OPS
            and o.opcode not in ("parameter", "tuple", "get-tuple-element")]
    return len(real) == 0


def _terminal_uses(sub: Computation, name: str, depth: int = 4) -> List[Op]:
    """Ops consuming `name`, chasing through pure casts up to `depth`."""
    out: List[Op] = []
    frontier = [name]
    for _ in range(depth):
        nxt: List[str] = []
        for n in frontier:
            pat = re.compile(r"%" + re.escape(n) + r"\b")
            for o in sub.ops:
                if pat.search(o.args):
                    if o.opcode in _CAST_OPS:
                        nxt.append(o.name)
                    else:
                        out.append(o)
        if not nxt:
            break
        frontier = nxt
    return out


def _fusion_operand_bytes(comps: Dict[str, Computation], op: Op,
                          comp: Computation) -> float:
    """Operand traffic of a fusion, slice-aware.

    A fused computation that only ever dynamic-slices one of its
    parameters (the scan-over-layers pattern: stacked params sliced per
    iteration) reads a SLICE, not the whole buffer.  For each fusion
    parameter, if every use inside the fused computation is a slice-like
    op, charge the slice outputs instead of the full operand.
    """
    called = _called_comps(op)
    sub = comps.get(called.get("calls", [""])[0]) if "calls" in called else None
    operands = comp.operand_names(op)
    if sub is None:
        return float(sum(_shape_bytes(comp.types[n]) for n in operands))
    # parameter number -> parameter op name in the fused computation
    param_names: Dict[int, str] = {}
    for o in sub.ops:
        if o.opcode == "parameter":
            m = re.match(r"\s*(\d+)", o.args)
            if m:
                param_names[int(m.group(1))] = o.name
    total = 0.0
    for i, n in enumerate(operands):
        full = _shape_bytes(comp.types[n])
        pname = param_names.get(i)
        if pname is None:
            total += full
            continue
        uses = _terminal_uses(sub, pname)
        slicey = uses and all(
            o.opcode in _SLICE_OPS or o.opcode == "dynamic-update-slice"
            for o in uses)
        if slicey:
            b = 0.0
            for o in uses:
                if o.opcode in _SLICE_OPS:
                    b += _shape_bytes(o.out_type)
                elif o.opcode == "dynamic-update-slice":
                    ons = sub.operand_names(o)
                    b += (_shape_bytes(sub.types[ons[1]]) if len(ons) > 1
                          else 0.0)
            total += b
        else:
            total += full
    return total


def _fusion_output_bytes(comps: Dict[str, Computation], op: Op) -> float:
    """Output traffic of a fusion; in-place dynamic-update-slice roots
    (scan stacking / cache writes) are charged the written slice only."""
    called = _called_comps(op)
    sub = comps.get(called.get("calls", [""])[0]) if "calls" in called else None
    if sub is None:
        return float(_shape_bytes(op.out_type))
    dus = [o for o in sub.ops if o.opcode == "dynamic-update-slice"]
    if not dus:
        return float(_shape_bytes(op.out_type))
    written = 0.0
    dus_out = 0.0
    for o in dus:
        ons = sub.operand_names(o)
        if len(ons) > 1:
            written += 2.0 * _shape_bytes(sub.types[ons[1]])  # read+write slice
        dus_out += _shape_bytes(o.out_type)
    # non-DUS outputs of the fusion still stream out in full
    out_total = _shape_bytes(op.out_type)
    return written + max(0.0, out_total - dus_out)


def _score_shaped(type_str: str, chunks) -> bool:
    dt, dims = _first_shape_dims(type_str)
    return bool(chunks) and dt in ("f32", "bf16") and len(dims) >= 3 \
        and dims[-1] in chunks


def _score_credit(op: Op, comp: Computation, chunks) -> float:
    """Bytes of flash-chunk-shaped f32 intermediates touched by `op`."""
    if not chunks:
        return 0.0
    b = 0.0
    if _score_shaped(op.out_type, chunks):
        b += _shape_bytes(op.out_type)
    for n in comp.operand_names(op):
        if _score_shaped(comp.types[n], chunks):
            b += _shape_bytes(comp.types[n])
    return b


RECURRENT_TRIP = 512


def analyze_computation(comps: Dict[str, Computation], name: str,
                        report: HLOCostReport, mult: float,
                        score_chunks=(), in_recurrence: bool = False) -> None:
    comp = comps.get(name)
    if comp is None:
        return

    def charge(amount: float, op: Op, streaming: bool = False):
        report.hbm_bytes += mult * amount
        credit = _score_credit(op, comp, score_chunks)
        report.score_buffer_bytes += mult * min(amount, credit)
        if in_recurrence and not streaming and credit == 0.0:
            report.recurrent_buffer_bytes += mult * amount

    for op in comp.ops:
        code = op.opcode
        called = _called_comps(op)
        if code == "while":
            trips = _trip_count(op, comps, called.get("condition", [""])[0])
            report.n_while += 1
            report.trip_counts.append(trips)
            if "body" in called:
                analyze_computation(
                    comps, called["body"][0], report, mult * trips,
                    score_chunks,
                    in_recurrence or trips >= RECURRENT_TRIP)
            continue
        if code == "conditional":
            for b in called.get("branches", []):
                analyze_computation(comps, b, report, mult, score_chunks,
                                    in_recurrence)
            continue
        if code in ("call", "async-start"):
            for key in ("to_apply", "calls"):
                if key in called:
                    analyze_computation(comps, called[key][0], report, mult,
                                        score_chunks, in_recurrence)
            report.hbm_bytes += mult * (_shape_bytes(op.out_type))
            continue
        if code == "fusion":
            if _is_pure_cast_fusion(comps, op):
                continue            # CPU-backend dtype-shadow artifact
            # FLOPs: dots inside the fused computation; traffic: the
            # fusion's own inputs/outputs (slice-aware on inputs).
            if "calls" in called:
                sub = HLOCostReport()
                analyze_computation(comps, called["calls"][0], sub, 1.0)
                report.flops += mult * sub.flops
            called_sub = comps.get(called.get("calls", [""])[0]) \
                if "calls" in called else None
            has_dus = bool(called_sub) and any(
                o.opcode == "dynamic-update-slice" for o in called_sub.ops)
            charge(_fusion_output_bytes(comps, op)
                   + _fusion_operand_bytes(comps, op, comp), op,
                   streaming=has_dus)
            continue
        base = code.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES:
            if code.endswith("-done"):
                continue                       # counted at -start
            b = _collective_bytes(op, comp)
            report.collective_bytes[base] = (
                report.collective_bytes.get(base, 0.0) + mult * b)
            report.collective_counts[base] = (
                report.collective_counts.get(base, 0) + max(1, int(mult)))
            continue
        if code == "dot":
            report.flops += mult * _dot_flops(op, comp)
            charge(_shape_bytes(op.out_type) + comp.operand_bytes(op), op)
            continue
        if code in ("convolution",):
            # rough: 2 * out_elems * (kernel elems) — kernel = 2nd operand
            _, out_dims = _first_shape_dims(op.out_type)
            operands = comp.operand_names(op)
            kernel = 1
            if len(operands) >= 2:
                _, kdims = _first_shape_dims(comp.types[operands[1]])
                for d in kdims:
                    kernel *= d
            out = 1
            for d in out_dims:
                out *= d
            report.flops += mult * 2.0 * out * kernel
            report.hbm_bytes += mult * (_shape_bytes(op.out_type)
                                        + comp.operand_bytes(op))
            continue
        if code in ("parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "after-all", "partition-id", "replica-id"):
            continue
        # slice-likes move only the slice, not the sliced buffer
        if code in _SLICE_OPS:
            charge(2.0 * _shape_bytes(op.out_type), op, streaming=True)
            continue
        if code == "dynamic-update-slice":
            ops_ = comp.operand_names(op)
            upd = (_shape_bytes(comp.types[ops_[1]]) if len(ops_) > 1
                   else _shape_bytes(op.out_type))
            charge(2.0 * upd, op, streaming=True)
            continue
        if code == "scatter":
            ops_ = comp.operand_names(op)
            upd = (_shape_bytes(comp.types[ops_[2]]) if len(ops_) > 2
                   else _shape_bytes(op.out_type))
            charge(2.0 * upd, op, streaming=True)
            continue
        if code in ("convert", "copy", "bitcast"):
            continue                # dtype/layout shadow (see docstring)
        # generic op: count materialized output (+ operands for big movers)
        if code in ("transpose", "reshape", "broadcast",
                    "concatenate", "pad", "reverse", "sort",
                    "reduce", "select", "iota", "add", "multiply"):
            charge(_shape_bytes(op.out_type) + comp.operand_bytes(op), op)
        else:
            charge(_shape_bytes(op.out_type), op)


def analyze_hlo_text(text: str, score_chunks=()) -> HLOCostReport:
    """score_chunks: flash-tile sizes (attn_chunk, ssm_chunk) — f32
    intermediates whose last dim matches are counted separately (they
    stay in VMEM on the Pallas-kernel path)."""
    comps, entry = parse_computations(text)
    report = HLOCostReport()
    analyze_computation(comps, entry, report, 1.0, tuple(score_chunks))
    return report
