"""Render a human-readable observability report for the pricing stack.

  python scripts/obs_report.py [--snapshot svc_snapshot.json]
                               [--bench BENCH_service.json]
                               [--metrics BENCH_service_metrics.json]
                               [--flight BENCH_service_trace.json]
                               [--trace TRACE_ID]
                               [--out report.txt] [--prom metrics.prom]

One CLI over every observability artifact the stack writes, offline and
stdlib-only (CI runs it on uploaded artifacts; no repro import needed):

* ``--snapshot`` — a ``PricingService.snapshot()`` JSON: request /
  latency / per-lane occupancy plus the serving-cost **ledger** rollup
  (cost-per-query by request kind, per-lane wall decomposition, the
  sum-to-tick-wall residual) and the **SLO** error-budget table;
* ``--bench`` — a ``BENCH_service.json`` from ``benchmarks.service_bench``
  (same ledger keys, flattened, plus the traced phase table when the run
  had ``REPRO_TRACE=1``);
* ``--metrics`` — a metrics-registry snapshot
  (``REGISTRY.write_json(...)``): every ``ledger_*`` / ``slo_*`` /
  ``service_*`` instrument, histogram quantiles and trace-id exemplars;
* ``--flight`` — a flight-recorder / Perfetto ``trace_event`` dump:
  span-name census and, with ``--trace``, the reconstructed span tree of
  one request;
* ``--prom`` — additionally re-render the ``--metrics`` snapshot as
  Prometheus text exposition (the offline twin of
  ``REGISTRY.exposition()``) and write it to a file.

Sections for inputs not given are skipped; with no inputs at all the
report says so and exits 0 (an empty CI artifact is not an error).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Optional


def _load(path: Optional[str], what: str) -> Optional[Dict]:
    if path is None:
        return None
    p = pathlib.Path(path)
    try:
        doc = json.loads(p.read_text())
    except (OSError, UnicodeDecodeError, json.JSONDecodeError) as e:
        print(f"obs_report: unreadable {what} file {p}: {e}",
              file=sys.stderr)
        raise SystemExit(2)
    if not isinstance(doc, dict):
        print(f"obs_report: {what} file {p} is not a JSON object",
              file=sys.stderr)
        raise SystemExit(2)
    return doc


def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:,.4g}"
    if isinstance(v, int):
        return f"{v:,}"
    return str(v)


def _table(rows: List[Dict], order: Optional[List[str]] = None) -> List[str]:
    """Fixed-width text table from a list of flat dicts."""
    if not rows:
        return ["  (no rows)"]
    cols = order or list(rows[0].keys())
    cells = [[_fmt(r.get(c, "")) for c in cols] for r in rows]
    widths = [max(len(c), *(len(row[i]) for row in cells))
              for i, c in enumerate(cols)]
    out = ["  " + "  ".join(c.ljust(w) for c, w in zip(cols, widths))]
    for row in cells:
        out.append("  " + "  ".join(c.rjust(w) for c, w in zip(row, widths)))
    return out


def _section(title: str) -> List[str]:
    return ["", title, "-" * len(title)]


# ---------------------------------------------------------------------------
# Phase / ledger / SLO renderers (shared by snapshot and bench inputs)
# ---------------------------------------------------------------------------

def _render_phases(phases: Dict) -> List[str]:
    out = _section("phase wall breakdown")
    rows = [{"phase": name, **stats}
            for name, stats in sorted(phases.items())]
    return out + _table(rows)


def _render_ledger(led: Dict) -> List[str]:
    out = _section("serving-cost ledger")
    out.append(f"  bills closed     : {_fmt(led.get('closed', 0))} "
               f"({_fmt(led.get('open', 0))} still open)")
    out.append(f"  ticks charged    : {_fmt(led.get('ticks_charged', 0))}")
    out.append(f"  device ms billed : "
               f"{_fmt(led.get('device_ms_total', 0.0))}")
    out.append(f"  worst tick residual (|billed-wall|/wall) : "
               f"{led.get('tick_residual_rel_max', 0.0):.3e}")
    out.append(f"  unattributed ms  : "
               f"{_fmt(led.get('unattributed_ms', 0.0))}")
    by_kind = led.get("by_kind") or {}
    if by_kind:
        out.append("")
        out.append("  cost per query by request kind:")
        rows = [{"kind": k, **v} for k, v in sorted(by_kind.items())]
        out += _table(rows, order=[
            "kind", "requests", "ok", "errors", "cache_hits", "replayed",
            "rows_priced", "device_ms", "device_ms_per_query",
            "dispatch_ms", "padded_ms", "retries", "degraded_rows"])
    by_lane = led.get("by_lane") or {}
    if by_lane:
        out.append("")
        out.append("  per-lane tick wall decomposition:")
        rows = [{"lane": k, **v} for k, v in sorted(by_lane.items())]
        out += _table(rows, order=["lane", "ticks", "wall_ms",
                                   "rows_priced", "padded_ms",
                                   "dispatch_ms"])
    return out


def _render_slo(slo: Dict) -> List[str]:
    out = _section("SLO / error budget")
    if not slo.get("enabled", False):
        out.append("  (tracker disabled for this run)")
        return out
    rows = []
    for name, st in sorted((slo.get("objectives") or {}).items()):
        obj = st.get("objective", {})
        rows.append({
            "objective": name,
            "kind": obj.get("kind", "*"),
            "latency_ms": obj.get("latency_ms"),
            "availability": obj.get("availability"),
            "window_n": st.get("window_n", 0),
            "latency_burn": st.get("latency_burn", 0.0),
            "availability_burn": st.get("availability_burn", 0.0),
            "violations": st.get("latency_violations", 0),
            "errors": st.get("errors", 0),
            "burn_events": st.get("burn_events", 0),
            "burning": st.get("burning", False),
        })
    return out + _table(rows)


# ---------------------------------------------------------------------------
# Input-specific sections
# ---------------------------------------------------------------------------

def render_snapshot(snap: Dict) -> List[str]:
    out = _section("service snapshot")
    for key in ("n_requests", "n_done", "n_ok", "n_errors", "n_rejected",
                "ticks", "rows_priced", "slot_occupancy",
                "recompiles_after_warmup"):
        if key in snap:
            out.append(f"  {key:<24}: {_fmt(snap[key])}")
    lat = snap.get("latency_s")
    if lat:
        out.append(f"  latency p50/p95/p99 (ms) : "
                   f"{lat['p50'] * 1e3:.2f} / {lat['p95'] * 1e3:.2f} / "
                   f"{lat['p99'] * 1e3:.2f}")
    if snap.get("obs", {}).get("phases"):
        out += _render_phases(snap["obs"]["phases"])
    if "ledger" in snap:
        out += _render_ledger(snap["ledger"])
    if "slo" in snap:
        out += _render_slo(snap["slo"])
    return out


def render_bench(bench: Dict) -> List[str]:
    out = _section("benchmark summary")
    for key in ("clients", "n_requests", "rows_priced",
                "agg_candidates_per_sec", "vs_single_client",
                "latency_p95_s", "slot_occupancy",
                "recompiles_after_warmup", "result_cache_hits",
                "ledger_ticks_charged", "ledger_device_ms_total",
                "ledger_tick_residual_rel_max", "ledger_unattributed_ms",
                "ledger_bills_closed"):
        if key in bench:
            out.append(f"  {key:<30}: {_fmt(bench[key])}")
    env = bench.get("env") or {}
    if env:
        out.append(f"  git_sha: {env.get('git_sha', 'unknown')}  "
                   f"backend: {env.get('backend', '?')}  "
                   f"traced: {env.get('trace_enabled')}")
    if bench.get("phases"):
        out += _render_phases(bench["phases"])
    if bench.get("ledger_by_kind"):
        out += _render_ledger({"closed": bench.get("ledger_bills_closed"),
                               "ticks_charged":
                                   bench.get("ledger_ticks_charged"),
                               "device_ms_total":
                                   bench.get("ledger_device_ms_total"),
                               "tick_residual_rel_max":
                                   bench.get("ledger_tick_residual_rel_max",
                                             0.0),
                               "unattributed_ms":
                                   bench.get("ledger_unattributed_ms"),
                               "by_kind": bench["ledger_by_kind"]})
    if "slo" in bench:
        out += _render_slo(bench["slo"])
    return out


def render_metrics(metrics: Dict) -> List[str]:
    out = _section("metrics registry")
    groups = {"ledger": [], "slo": [], "service": [], "other": []}
    for name, row in sorted(metrics.items()):
        g = ("ledger" if name.startswith("ledger_") else
             "slo" if name.startswith("slo_") else
             "service" if name.startswith("service_") else "other")
        groups[g].append((name, row))
    for g in ("ledger", "slo", "service", "other"):
        if not groups[g]:
            continue
        out.append(f"  [{g}]")
        for name, row in groups[g]:
            if row.get("kind") == "histogram":
                out.append(
                    f"    {name:<32} count={_fmt(row.get('count', 0))} "
                    f"sum={_fmt(row.get('sum', 0.0))} "
                    f"p50={_fmt(row.get('p50', 0.0))} "
                    f"p95={_fmt(row.get('p95', 0.0))} "
                    f"p99={_fmt(row.get('p99', 0.0))}")
                for ex in row.get("exemplars", []):
                    out.append(f"      exemplar trace_id={ex['ref']} "
                               f"value={_fmt(ex['value'])}")
            else:
                out.append(f"    {name:<32} {_fmt(row.get('value', 0.0))}")
    return out


def _flight_events(doc: Dict) -> List[Dict]:
    evs = doc.get("traceEvents", [])
    return [e for e in evs if isinstance(e, dict)]


def render_flight(doc: Dict, trace_id: Optional[str]) -> List[str]:
    out = _section("flight / trace dump")
    evs = _flight_events(doc)
    census: Dict[str, Dict] = {}
    for e in evs:
        row = census.setdefault(e.get("name", "?"),
                                {"events": 0, "wall_ms": 0.0})
        row["events"] += 1
        row["wall_ms"] += float(e.get("dur", 0.0)) / 1e3  # us -> ms
    rows = [{"name": n, **v} for n, v in
            sorted(census.items(), key=lambda kv: -kv[1]["wall_ms"])]
    out += _table(rows, order=["name", "events", "wall_ms"])
    if trace_id:
        out += _section(f"span tree for trace {trace_id}")
        mine = []
        for e in evs:
            args = e.get("args") or {}
            ids = args.get("trace_ids") or ()
            if args.get("trace_id") == trace_id or trace_id in ids:
                mine.append(e)
        if not mine:
            out.append("  (no events carry this trace_id)")
        for e in sorted(mine, key=lambda e: float(e.get("ts", 0.0))):
            dur = float(e.get("dur", 0.0)) / 1e3
            out.append(f"  {float(e.get('ts', 0.0)) / 1e3:>12.3f} ms  "
                       f"{e.get('name', '?'):<20} "
                       f"{f'{dur:.3f} ms' if dur else 'instant'}")
    return out


# ---------------------------------------------------------------------------
# Prometheus text from a registry snapshot (offline REGISTRY.exposition())
# ---------------------------------------------------------------------------

def prom_text(metrics: Dict) -> str:
    """Re-render a registry JSON snapshot in the exact text format
    ``repro.obs.registry.Registry.exposition`` emits (HELP lines are
    dropped — snapshots do not carry help strings)."""
    lines = []
    for name, row in metrics.items():
        kind = row.get("kind", "gauge")
        lines.append(f"# TYPE {name} {kind}")
        if kind == "histogram":
            lines.append(f"{name}_count {row.get('count', 0):g}")
            lines.append(f"{name}_sum {row.get('sum', 0.0):g}")
            for q in ("p50", "p95", "p99"):
                lines.append(
                    f'{name}{{quantile="{q[1:]}"}} {row.get(q, 0.0):g}')
            for ex in row.get("exemplars", []):
                lines.append(
                    f'# EXEMPLAR {name}{{trace_id="{ex["ref"]}"}} '
                    f'{ex["value"]:g}')
        else:
            lines.append(f"{name} {row.get('value', 0.0):g}")
    return "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--snapshot", help="PricingService.snapshot() JSON")
    ap.add_argument("--bench", help="BENCH_service.json from service_bench")
    ap.add_argument("--metrics", help="registry snapshot JSON "
                                      "(BENCH_service_metrics.json)")
    ap.add_argument("--flight", help="flight-recorder / Perfetto "
                                     "trace_event JSON dump")
    ap.add_argument("--trace", help="render the span tree of this "
                                    "trace_id (needs --flight)")
    ap.add_argument("--out", help="write the report here instead of stdout")
    ap.add_argument("--prom", help="also write the --metrics snapshot as "
                                   "Prometheus text exposition")
    args = ap.parse_args(argv)

    snap = _load(args.snapshot, "snapshot")
    bench = _load(args.bench, "bench")
    metrics = _load(args.metrics, "metrics")
    flight = _load(args.flight, "flight")

    lines = ["observability report"]
    if snap is not None:
        lines += render_snapshot(snap)
    if bench is not None:
        lines += render_bench(bench)
    if metrics is not None:
        lines += render_metrics(metrics)
    if flight is not None:
        lines += render_flight(flight, args.trace)
    if snap is bench is metrics is flight is None:
        lines.append("(no inputs given — nothing to report)")
    report = "\n".join(lines) + "\n"

    if args.out:
        pathlib.Path(args.out).write_text(report)
        print(f"wrote {args.out}")
    else:
        sys.stdout.write(report)
    if args.prom:
        if metrics is None:
            print("obs_report: --prom needs --metrics", file=sys.stderr)
            return 2
        pathlib.Path(args.prom).write_text(prom_text(metrics))
        print(f"wrote {args.prom}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
