"""SLO / error-budget tracking for the pricing service.

Objectives are declarative: an :class:`SLObjective` names a request
kind (or ``"*"`` for all kinds), a latency target ("99% of requests
answer within 250 ms") and/or an availability target ("99.9% of
requests succeed"), over a sliding window.  :class:`SLOTracker` consumes
the same terminal stream the serving-cost ledger closes bills from and
maintains, per objective:

* the window's bad-event fractions (latency violations, errors);
* the **burn rate** — bad fraction divided by the error budget
  ``1 - target``.  Burn 1.0 means "spending budget exactly as fast as
  the objective allows"; sustained burn above ``alert_burn_rate``
  means the budget will be exhausted early.

When a burn rate crosses its alert threshold the tracker latches a
burn event (one per excursion, not one per request) and invokes the
``on_burn`` callback — the service wires that to the flight recorder so
a budget burn auto-dumps the last N seconds of context, with the
offending ``trace_id`` attached.  Burn rates and violation counts are
mirrored into the metrics registry as ``slo_*`` gauges/counters.

Pure host-side bookkeeping: O(1) per observation amortized, no device
work, safe to leave enabled in production.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .registry import REGISTRY, Registry


@dataclasses.dataclass(frozen=True)
class SLObjective:
    """One declarative objective for a request kind (``"*"`` = any)."""

    kind: str = "*"
    latency_ms: Optional[float] = None    # per-request latency target
    latency_target: float = 0.99          # fraction that must meet it
    availability: Optional[float] = None  # fraction that must succeed
    window_s: float = 60.0
    alert_burn_rate: float = 1.0

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _Window:
    """Sliding window of terminal events for one objective."""

    objective: SLObjective
    events: deque = dataclasses.field(default_factory=deque)
    # events hold (t, latency_bad, error_bad, trace_id)
    latency_violations: int = 0     # lifetime counts (monotonic)
    errors: int = 0
    burn_events: int = 0
    burning: Dict[str, bool] = dataclasses.field(
        default_factory=lambda: {"latency": False, "availability": False})


def _metric_kind(kind: str) -> str:
    return "all" if kind == "*" else kind


class SLOTracker:
    """Feed terminal request outcomes; read burn rates (see module doc)."""

    def __init__(self, objectives: Sequence[SLObjective],
                 registry: Optional[Registry] = None,
                 on_burn: Optional[Callable] = None):
        self._registry = registry if registry is not None else REGISTRY
        self._on_burn = on_burn
        self._windows: List[_Window] = [
            _Window(objective=o) for o in objectives]
        self.observed = 0

    def __bool__(self) -> bool:
        return bool(self._windows)

    def objectives(self) -> List[SLObjective]:
        return [w.objective for w in self._windows]

    # -- ingestion -----------------------------------------------------------
    def observe(self, kind: str, latency_s: float, ok: bool,
                trace_id: str = "", now: Optional[float] = None):
        """Record one terminal outcome against every matching objective."""
        self.observed += 1
        t = time.monotonic() if now is None else float(now)
        latency_ms = float(latency_s) * 1e3
        for w in self._windows:
            o = w.objective
            if o.kind != "*" and o.kind != kind:
                continue
            latency_bad = (o.latency_ms is not None
                           and latency_ms > o.latency_ms)
            error_bad = not ok
            w.events.append((t, latency_bad, error_bad, trace_id))
            if latency_bad:
                w.latency_violations += 1
            if error_bad:
                w.errors += 1
            self._prune(w, t)
            self._evaluate(w, trace_id)

    @staticmethod
    def _prune(w: _Window, now: float):
        horizon = now - w.objective.window_s
        while w.events and w.events[0][0] < horizon:
            w.events.popleft()

    # -- burn math -----------------------------------------------------------
    @staticmethod
    def _burn(bad: int, n: int, target: Optional[float]) -> float:
        """bad-fraction / error-budget; 0 when the objective is absent."""
        if target is None or not n:
            return 0.0
        budget = max(1.0 - float(target), 1e-9)
        return (bad / n) / budget

    def _rates(self, w: _Window) -> Tuple[float, float]:
        n = len(w.events)
        lat_bad = sum(1 for _, lb, _, _ in w.events if lb)
        err_bad = sum(1 for _, _, eb, _ in w.events if eb)
        o = w.objective
        lat_target = o.latency_target if o.latency_ms is not None else None
        return (self._burn(lat_bad, n, lat_target),
                self._burn(err_bad, n, o.availability))

    def _evaluate(self, w: _Window, trace_id: str):
        lat_burn, avail_burn = self._rates(w)
        o, mk = w.objective, _metric_kind(w.objective.kind)
        reg = self._registry
        reg.gauge(f"slo_{mk}_latency_burn",
                  help="latency error-budget burn rate").set(lat_burn)
        reg.gauge(f"slo_{mk}_availability_burn",
                  help="availability error-budget burn rate").set(avail_burn)
        reg.counter(f"slo_{mk}_latency_violations").value = \
            float(w.latency_violations)
        reg.counter(f"slo_{mk}_errors").value = float(w.errors)
        for dim, burn in (("latency", lat_burn),
                          ("availability", avail_burn)):
            over = burn >= o.alert_burn_rate and burn > 0.0
            if over and not w.burning[dim]:
                w.burning[dim] = True
                w.burn_events += 1
                reg.counter("slo_burn_events",
                            help="error-budget burn excursions").inc()
                if self._on_burn is not None:
                    self._on_burn(o.kind, dim, burn, trace_id)
            elif not over and w.burning[dim]:
                w.burning[dim] = False   # excursion over; re-arm the latch

    # -- introspection -------------------------------------------------------
    def snapshot(self) -> Dict:
        out = {}
        for w in self._windows:
            lat_burn, avail_burn = self._rates(w)
            out[_metric_kind(w.objective.kind)] = {
                "objective": w.objective.as_dict(),
                "window_n": len(w.events),
                "latency_burn": lat_burn,
                "availability_burn": avail_burn,
                "latency_violations": w.latency_violations,
                "errors": w.errors,
                "burn_events": w.burn_events,
                "burning": any(w.burning.values()),
            }
        return out
