"""Pricing-service throughput benchmark: N concurrent clients with a
mixed request diet against one continuous-batching PricingService.

  PYTHONPATH=src python -m benchmarks.service_bench [--fast] [--clients N]

Each client interleaves large price sweeps with point queries; dedicated
clients add Monte-Carlo risk sweeps, ranking, what-if grids and an
evolutionary search, so every service lane (chunk / mc / gen / raw) sees
traffic while the scheduler coalesces across clients.

Asserts (acceptance criteria of the service):
  * ZERO jit recompiles after the warmup tick — every lane the workload
    touches was compiled at startup or admission, never on the tick loop;
  * aggregate coalesced throughput >= 0.5x the single-client fused
    ``ChunkedEvaluator`` rate under >= 8 concurrent clients (the
    continuous-batching overhead bound; skipped under --fast where the
    sample is too small to be stable, which instead enforces a loose p95
    latency ceiling for CI smoke);
  * EVERY answered request carries a trace_id and a closed ledger bill,
    per-tick bills sum to the measured tick wall within 5% with zero
    unattributed device ms, and (traced runs) every request's span tree
    is complete: admission marker + terminal marker, plus a billed tick
    for every request that reached the device.

``--slo`` additionally enables the declarative SLO tracker
(latency + availability objectives over a sliding window) and folds its
error-budget snapshot into BENCH_service.json.

Reports aggregate candidates/s, request latency p50/p95/p99, padded-slot
waste, and cache/recompile counters, and writes BENCH_service.json for
CI trend tracking (guarded against benchmarks/baselines/ by
scripts/check_bench_regression.py).
"""
import argparse
import asyncio
import json
import time

import numpy as np

from repro import obs
from repro.dse import ChunkedEvaluator
from repro.service import (McSpec, MCRiskRequest, PriceRequest,
                           PriceSystemsRequest, PricingService, RankRequest,
                           SearchRequest, SearchWarmup, ServiceConfig,
                           WhatIfRequest)

from .common import REPO_ROOT, emit, write_bench_json
from .dse_bench import SPACE


def _client_requests(i: int, rng: np.random.Generator, size: int,
                     sweeps: int, sweep_rows: int, fast: bool):
    """The mixed diet of client ``i`` (deterministic in the seed)."""
    reqs = []
    for _ in range(sweeps):
        reqs.append(PriceRequest(
            indices=rng.integers(0, size, sweep_rows).tolist()))
        reqs.append(PriceRequest(indices=rng.integers(0, size, 4).tolist()))
    if i == 0:
        reqs.append(SearchRequest(seed=1, population=32,
                                  generations=3 if fast else 8, elite=8))
    elif i == 1:
        reqs.append(MCRiskRequest(
            indices=rng.integers(0, size, 64).tolist(),
            mc=McSpec(draws=64, quantiles=(0.5, 0.9), seed=0)))
    elif i == 2:
        reqs.append(WhatIfRequest(base=int(rng.integers(0, size))))
    elif i == 3:
        reqs.append(RankRequest(indices=rng.integers(0, size, 128).tolist(),
                                top_k=5))
    elif i == 4:
        reqs.append(PriceSystemsRequest(specs=(
            {"kind": "soc", "name": "soc_a", "area": 250.0,
             "process": "7nm", "quantity": 1e6},
            {"kind": "split", "name": "mcm_b", "area": 500.0,
             "process": "7nm", "n_chiplets": 2, "integration": "MCM",
             "quantity": 5e5},)))
    return reqs


def run(fast: bool = False, clients: int = 8, slo: bool = False) -> dict:
    size = SPACE.size()
    chunk = 64 if fast else 128
    sweep_rows = 256 if fast else 2048
    sweeps = 2 if fast else 4
    slos = ()
    if slo:
        from repro.obs.slo import SLObjective
        # generous bounds for shared CI boxes: the point of the smoke is
        # that the tracker runs and snapshots, not that CI hardware is
        # fast; the real latency assertions below stay authoritative.
        slos = (SLObjective(kind="*", latency_ms=30_000.0,
                            latency_target=0.95, availability=0.95,
                            window_s=300.0),)
    cfg = ServiceConfig(
        chunk=chunk, split=max(8, chunk // 4),
        warm_mc=((64, (0.5, 0.9)),),
        warm_search=(SearchWarmup(population=32, elite=8),),
        max_pending=10_000_000, slos=slos)

    # -- single-client fused baseline (the 0.5x yardstick) -----------------
    ev = ChunkedEvaluator(SPACE, candidates_per_chunk=chunk)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, size, 4 * sweep_rows)
    ev.evaluate_indices(idx[:chunk])                       # compile
    t0 = time.perf_counter()
    ev.evaluate_indices(idx)
    single = idx.size / (time.perf_counter() - t0)

    # -- the concurrent mixed workload -------------------------------------
    async def _main():
        svc = PricingService(SPACE, cfg)
        await svc.start()                                  # warmup

        async def client(i: int):
            crng = np.random.default_rng(100 + i)
            out = []
            for req in _client_requests(i, crng, size, sweeps, sweep_rows,
                                        fast):
                out.append(await svc.submit(req))
            return out

        t0 = time.perf_counter()
        per_client = await asyncio.gather(*(client(i)
                                            for i in range(clients)))
        wall = time.perf_counter() - t0
        await svc.stop()
        return per_client, wall, svc

    per_client, wall, svc = asyncio.run(_main())
    flat = [r for rs in per_client for r in rs]
    bad = [r for r in flat if not r.ok]
    assert not bad, f"{len(bad)} requests failed: {bad[0].error}"

    snap = svc.snapshot()
    agg = snap["rows_priced"] / wall
    summary = {
        "clients": clients,
        "n_requests": snap["n_ok"],
        "rows_priced": snap["rows_priced"],
        "wall_s": wall,
        "agg_candidates_per_sec": agg,
        "single_client_candidates_per_sec": single,
        "vs_single_client": agg / single,
        "latency_p50_s": snap["latency_s"]["p50"],
        "latency_p95_s": snap["latency_s"]["p95"],
        "latency_p99_s": snap["latency_s"]["p99"],
        "ttfr_p50_s": snap["ttfr_s"]["p50"],
        "ticks": snap["ticks"],
        "device_gets": snap["device_gets"],
        "slot_occupancy": snap["slot_occupancy"],
        "padded_waste_frac": snap["padded_waste_frac"],
        "recompiles_after_warmup": snap["recompiles_after_warmup"],
        "result_cache_hits": snap["result_cache"]["hits"],
        "ledger_ticks_charged": snap["ledger"]["ticks_charged"],
        "ledger_device_ms_total": snap["ledger"]["device_ms_total"],
        "ledger_tick_residual_rel_max":
            snap["ledger"]["tick_residual_rel_max"],
        "ledger_unattributed_ms": snap["ledger"]["unattributed_ms"],
        "ledger_bills_closed": snap["ledger"]["closed"],
        "ledger_by_kind": snap["ledger"]["by_kind"],
        "fast": fast,
    }
    if slo:
        summary["slo"] = snap["slo"]
    if obs.enabled():
        # per-phase breakdown (compile / dispatch / device_get / pack /
        # scatter) rides along only on traced runs, so untraced
        # BENCH_service.json keys never change.
        summary["phases"] = snap["obs"]["phases"]
        summary["jit"] = snap["obs"]["jit"]
        summary["device_get"] = snap["obs"]["device_get"]
        summary["tick_coverage"] = snap["obs"]["tick_coverage"]
        summary["recompiles_in_ticks"] = snap["obs"]["recompiles_in_ticks"]
    emit("service: mixed workload", [{
        "clients": clients, "requests": summary["n_requests"],
        "rows": summary["rows_priced"],
        "agg_cands_per_sec": agg, "single_client": single,
        "vs_single": summary["vs_single_client"],
        "p50_ms": summary["latency_p50_s"] * 1e3,
        "p95_ms": summary["latency_p95_s"] * 1e3,
        "p99_ms": summary["latency_p99_s"] * 1e3,
        "occupancy": summary["slot_occupancy"],
        "recompiles": summary["recompiles_after_warmup"]}])
    write_bench_json("service", summary)

    # -- acceptance --------------------------------------------------------
    assert snap["device_gets"] == snap["ticks"], \
        "tick loop must sync exactly once per tick"
    assert summary["recompiles_after_warmup"] == 0, \
        f"hot path recompiled {summary['recompiles_after_warmup']}x"
    # serving-cost ledger: every answered request is billed, and the
    # bills are a true decomposition of the measured tick wall.
    unbilled = [r for r in flat if not r.trace_id or r.bill is None
                or r.bill["status"] == "open"]
    assert not unbilled, \
        f"{len(unbilled)} responses lack a trace_id/closed ledger bill"
    led = snap["ledger"]
    assert led["open"] == 0, f"{led['open']} bills left open after drain"
    assert led["tick_residual_rel_max"] <= 0.05, \
        (f"per-tick bills diverge from measured tick wall by "
         f"{led['tick_residual_rel_max']:.1%} (need <= 5%)")
    assert led["unattributed_ms"] == 0.0, \
        f"{led['unattributed_ms']:.3f} device ms billed to nobody"
    if obs.enabled():
        # traced run: export the Perfetto trace + registry snapshot and
        # hold the tracer to its own acceptance bar — spans must account
        # for >= 90% of measured tick wall, and the tracer's independent
        # compile attribution must agree that warmed ticks never retrace.
        from repro.obs.registry import REGISTRY
        trace_path = svc.dump_flight_recorder(
            REPO_ROOT / "BENCH_service_trace.json")
        doc = json.loads(trace_path.read_text())
        assert doc.get("traceEvents"), "trace export produced no events"
        REGISTRY.write_json(REPO_ROOT / "BENCH_service_metrics.json")
        print(f"# wrote {trace_path}")
        print(f"# wrote {REPO_ROOT / 'BENCH_service_metrics.json'}")
        cov = summary["tick_coverage"]
        assert cov >= 0.9, \
            f"trace spans cover {cov:.1%} of tick wall (need >= 90%)"
        assert summary["recompiles_in_ticks"] == 0, \
            (f"tracer attributed {summary['recompiles_in_ticks']} "
             f"jit compiles to warmed ticks")
        # span-tree completeness: every response's trace_id must resolve
        # to an admission marker, a terminal marker and — for answers
        # that reached the device — at least one tick span that billed it.
        from repro.obs.trace import TRACER
        for r in flat:
            tree = TRACER.trace_tree(r.trace_id)
            names = {ev["name"] for ev in tree}
            assert "request_admit" in names, \
                f"trace {r.trace_id}: no admission marker"
            assert names & {"request_done", "request_error"}, \
                f"trace {r.trace_id}: no terminal marker"
            if r.ok and not r.cached:
                assert "tick" in names, \
                    f"trace {r.trace_id}: answered on-device without a tick"
        print(f"# service: traced run — {cov:.1%} tick coverage, "
              f"0 tracer-attributed tick recompiles, "
              f"{len(flat)} complete span trees")
    if fast:
        # CI smoke: tiny sample, shared boxes — just a sanity ceiling
        assert summary["latency_p95_s"] < 30.0, \
            f"p95 {summary['latency_p95_s']:.2f}s absurd for the smoke load"
    else:
        assert summary["vs_single_client"] >= 0.5, \
            (f"coalesced throughput {agg:,.0f} cands/s is "
             f"{summary['vs_single_client']:.2f}x the single-client rate "
             f"{single:,.0f} (need >= 0.5x)")
    print(f"# service: {agg:,.0f} cands/s across {clients} clients "
          f"({summary['vs_single_client']:.2f}x single-client), "
          f"p95 {summary['latency_p95_s']*1e3:.1f} ms, "
          f"0 hot-path recompiles")
    print(f"# ledger: {led['closed']} bills over {led['ticks_charged']} "
          f"ticks, worst tick residual {led['tick_residual_rel_max']:.2e}, "
          f"unattributed {led['unattributed_ms']:.3f} ms")
    return summary


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: small sweeps, loose bounds")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--slo", action="store_true",
                    help="enable the SLO/error-budget tracker and fold "
                         "its snapshot into BENCH_service.json")
    args = ap.parse_args()
    run(fast=args.fast, clients=args.clients, slo=args.slo)


if __name__ == "__main__":
    main()
