"""Fused, fixed-shape batched candidate pricing (repro.dse).

The hot path is **index-native and on-device**: a chunk of candidate
*indices* is decoded by :func:`~repro.dse.space.encode_arrays` into a
padded, NRE-grouped :class:`~repro.core.batch.SystemBatch` *inside* the
jit graph, priced by the un-jitted
:class:`~repro.core.engine.CostEngine` implementation, reduced to
per-candidate portfolio costs (and, optionally, Monte-Carlo risk
quantiles) in the same graph, and shipped to the host with exactly one
``jax.device_get`` per chunk.  Pricing 10k+ candidates is one retained
jit trace per (chunk-shape, flow, mc-config) and zero per-candidate
Python — the >=30x candidate-throughput path ``benchmarks/dse_bench.py``
pins.

The original host-packing path (``candidate_systems`` +
``SystemBatch.from_systems`` + :func:`~repro.core.batch.pad_batch`) is
retained behind ``fused=False`` as the parity oracle; both paths produce
chunks with identical array signatures and therefore share one compiled
engine trace.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.batch import SystemBatch, pad_batch
from ..core.engine import (CostEngine, TRACE_COUNTS, _re_impl, finite_rows,
                           portfolio_totals)
from ..obs import jaxhooks
from ..obs.trace import TRACER as _TRACER
from .space import (Candidate, DesignSpace, EncoderMeta, candidate_systems,
                    encode_arrays, encoded_nre)
from .uncertainty import (Uncertainty, mc_re_totals_impl, mc_totals,
                          portfolio_draws, portfolio_risk_stats)


@dataclasses.dataclass(frozen=True)
class ChunkShape:
    """Worst-case array signature of one evaluation chunk."""

    candidates: int
    n_systems: int
    max_chips: int
    chip_entities: int
    pkg_entities: int
    mod_entities: int
    mod_instances: int
    d2d_entities: int
    d2d_instances: int

    def pad_kwargs(self) -> Dict[str, int]:
        d = dataclasses.asdict(self)
        d.pop("candidates")
        return d


def chunk_shape(space: DesignSpace, candidates_per_chunk: int) -> ChunkShape:
    """Upper-bound shapes for any ``candidates_per_chunk`` candidates.

    Per candidate: S systems (one per SKU), each at most ``max_chips``
    chips; each chip carries one functional module and at most one D2D
    module instance; chip/module design entities are bounded by the chip
    instances, package entities by S, D2D entities by the process menu.
    Entity tables get one slack row so padded instances always have a
    zero-NRE row to point at.  The vectorized encoder emits exactly this
    signature, so fused and host-packed chunks share one engine trace.
    """
    k = int(candidates_per_chunk)
    s = len(space.skus)
    c = space.max_chips()
    per_cand_chips = s * c
    return ChunkShape(
        candidates=k,
        n_systems=k * s,
        max_chips=c,
        chip_entities=k * per_cand_chips + 1,
        pkg_entities=k * s + 1,
        mod_entities=k * per_cand_chips + 1,
        mod_instances=k * per_cand_chips,
        d2d_entities=k * len(space.processes) + 1,
        d2d_instances=k * per_cand_chips,
    )


# ---------------------------------------------------------------------------
# The fused chunk kernels: decode -> price -> portfolio-reduce (-> risk)
# ---------------------------------------------------------------------------


def _fused_totals(tables, idx, *, meta: EncoderMeta, flow: str):
    """Decode + price one chunk: RE via the engine implementation, NRE via
    the layout's closed forms (no scatters) — (re, nre, total), each (N,).

    The ONE composition of the fused objective: both the evaluator chunk
    kernels and the search generation step price through this function
    (and :func:`_fused_risk_draws` for the Monte-Carlo tail), so their
    objectives are identical by construction.
    """
    batch = encode_arrays(tables, meta, idx)
    re_tot = _re_impl(batch, flow).total
    nre_tot = encoded_nre(tables, meta, idx).total
    return batch, re_tot, nre_tot, re_tot + nre_tot


def _fused_risk_draws(batch, nre_tot, qty, mc_key, sig, flow: str,
                      n_draws: int, n_skus: int):
    """(draws, K) Monte-Carlo portfolio costs for a priced fused chunk:
    RE-only scenario draws plus the once-per-batch NRE row (no perturbed
    parameter enters the NRE model)."""
    draws = mc_re_totals_impl(batch, mc_key, sig, flow, n_draws) \
        + nre_tot[None, :]                                   # (draws, K*S)
    return portfolio_draws(draws, qty, n_skus)


def _chunk_impl(tables, idx, qty, *, meta: EncoderMeta, flow: str):
    TRACE_COUNTS["fused_chunk"] += 1
    _, re_tot, nre_tot, total = _fused_totals(tables, idx, meta=meta,
                                              flow=flow)
    k, s = idx.shape[0], meta.n_skus
    unit = total.reshape(k, s)
    pf = portfolio_totals(unit, qty)
    # trailing element: (K,) in-graph numerical guardrail — True where
    # every per-row output is finite (see engine.finite_rows)
    return (unit, re_tot.reshape(k, s), nre_tot.reshape(k, s), pf,
            finite_rows(unit, pf))


def _chunk_mc_impl(tables, idx, qty, key, sig, *, meta: EncoderMeta,
                   flow: str, n_draws: int, quantiles: Tuple[float, ...]):
    TRACE_COUNTS["fused_chunk_mc"] += 1
    batch, re_tot, nre_tot, total = _fused_totals(tables, idx, meta=meta,
                                                  flow=flow)
    k, s = idx.shape[0], meta.n_skus
    unit = total.reshape(k, s)
    pf_draws = _fused_risk_draws(batch, nre_tot, qty, key, sig, flow,
                                 n_draws, s)                 # (draws, K)
    risk = portfolio_risk_stats(pf_draws, quantiles)
    pf = portfolio_totals(unit, qty)
    return (unit, re_tot.reshape(k, s), nre_tot.reshape(k, s), pf, risk,
            finite_rows(unit, pf, *risk.values()))


# Module-level jits with tables passed as (pytree) arguments, so every
# evaluator over a same-shaped space shares one compiled trace.  The obs
# probes attribute per-signature compile vs dispatch wall when tracing
# is enabled and forward transparently when it is not.
_CHUNK_JIT = jaxhooks.instrument(
    jax.jit(_chunk_impl, static_argnames=("meta", "flow")),
    "dse.chunk", trace_key="fused_chunk", counts=TRACE_COUNTS)
_CHUNK_MC_JIT = jaxhooks.instrument(
    jax.jit(_chunk_mc_impl,
            static_argnames=("meta", "flow", "n_draws", "quantiles")),
    "dse.chunk_mc", trace_key="fused_chunk_mc", counts=TRACE_COUNTS)


@dataclasses.dataclass
class EvalArrays:
    """Struct-of-arrays result of the fused pipeline: one row per
    candidate index, everything already on the host (single transfer)."""

    idx: np.ndarray               # (K,) candidate indices
    sku_unit_total: np.ndarray    # (K, S) USD per unit, RE + amortized NRE
    sku_unit_re: np.ndarray       # (K, S)
    sku_unit_nre: np.ndarray      # (K, S)
    portfolio_cost: np.ndarray    # (K,) sum_i quantity_i * unit_total_i
    risk: Optional[Dict[str, np.ndarray]] = None   # each (K,)
    finite: Optional[np.ndarray] = None   # (K,) bool; False = NaN/Inf row

    def __len__(self) -> int:
        return self.idx.shape[0]

    def objective(self, key: str = "cost") -> np.ndarray:
        if key == "cost":
            return self.portfolio_cost
        if self.risk is None or key not in self.risk:
            raise KeyError(f"no risk stat {key!r}; evaluate with mc_key set")
        return self.risk[key]


@dataclasses.dataclass
class CandidateResult:
    """Priced candidate: per-SKU unit economics + the portfolio total."""

    candidate: Candidate
    label: str
    sku_names: Sequence[str]
    sku_unit_total: np.ndarray   # (S,) USD per unit, RE + amortized NRE
    sku_unit_re: np.ndarray      # (S,)
    sku_unit_nre: np.ndarray     # (S,)
    portfolio_cost: float        # sum_i quantity_i * unit_total_i, USD
    risk: Optional[Dict[str, float]] = None  # filled by uncertainty pass

    def objective(self, key: str = "cost") -> float:
        """Scalar ranking objective: 'cost' or a risk stat (e.g. 'q90')."""
        if key == "cost":
            return self.portfolio_cost
        if self.risk is None or key not in self.risk:
            raise KeyError(f"no risk stat {key!r} on {self.label}; "
                           "evaluate with mc_key set")
        return self.risk[key]


class ChunkedEvaluator:
    """Prices candidate streams in constant-shape chunks.

    >>> ev = ChunkedEvaluator(space, candidates_per_chunk=64)
    >>> arrays = ev.evaluate_indices(np.arange(10_000))   # fused hot path
    >>> results = ev.evaluate(space.sample(rng, 100))     # object API
    >>> ev.candidates_per_sec

    ``fused=True`` (default) runs the on-device pipeline; ``fused=False``
    keeps the host-packing reference path (same chunk signature, same
    compiled engine trace — the parity oracle).
    """

    def __init__(self, space: DesignSpace, candidates_per_chunk: int = 64,
                 engine: Optional[CostEngine] = None,
                 flow: str = "chip-last", fused: bool = True):
        self.space = space
        self.engine = engine or CostEngine()
        self.flow = flow
        self.fused = bool(fused)
        self.shape = chunk_shape(space, candidates_per_chunk)
        self.encoder = space.encoder() if self.fused else None
        self._qty32 = jnp.asarray([sk.quantity for sk in space.skus],
                                  jnp.float32)
        self.reset_stats()

    # -- throughput bookkeeping ---------------------------------------------
    def reset_stats(self):
        self.n_candidates = 0
        self.n_systems = 0
        self.n_chunks = 0
        self.elapsed_s = 0.0

    @property
    def candidates_per_sec(self) -> float:
        return self.n_candidates / max(self.elapsed_s, 1e-12)

    @property
    def systems_per_sec(self) -> float:
        return self.n_systems / max(self.elapsed_s, 1e-12)

    def stats(self) -> Dict[str, float]:
        return {"n_candidates": self.n_candidates,
                "n_systems": self.n_systems, "n_chunks": self.n_chunks,
                "elapsed_s": self.elapsed_s,
                "candidates_per_sec": self.candidates_per_sec,
                "systems_per_sec": self.systems_per_sec}

    # -- fused index-native path --------------------------------------------
    def evaluate_indices(self, idx, mc_key=None, mc_draws: int = 128,
                         mc_sigmas=None,
                         mc_quantiles: Sequence[float] = (0.5, 0.9),
                         ) -> EvalArrays:
        """Price candidate *indices* through the fused on-device pipeline.

        The stream is cut into constant-shape chunks (the final partial
        chunk is padded by repeating its first index; padded rows are
        dropped).  Every chunk is one jitted decode->price->reduce call,
        dispatched asynchronously; the whole stream then syncs with a
        single ``jax.device_get`` — no per-chunk (let alone
        per-candidate) device->host round-trips.  With ``mc_key`` set the
        same call also returns Monte-Carlo portfolio risk stats computed
        in-graph under common random numbers (the same key for every
        chunk).
        """
        if not self.fused:
            raise RuntimeError("evaluate_indices requires fused=True")
        idx = np.asarray(idx, np.int64)
        if idx.ndim != 1 or idx.size == 0:
            raise ValueError("need a 1-D, non-empty index vector")
        if idx.min() < 0 or idx.max() >= self.space.size():
            raise IndexError("candidate index out of range")
        k = self.shape.candidates
        sig = quantiles = None
        if mc_key is not None:
            sig = (mc_sigmas or Uncertainty()).as_array()
            quantiles = tuple(float(q) for q in mc_quantiles)
        t0 = time.perf_counter()
        pending, reals = [], []
        for lo in range(0, idx.size, k):
            with _TRACER.span("chunk", lo=lo):
                chunk = idx[lo:lo + k]
                n_real = chunk.size
                if n_real < k:
                    chunk = np.concatenate(
                        [chunk, np.full(k - n_real, chunk[0], chunk.dtype)])
                dev = jnp.asarray(chunk, jnp.int32)
                if mc_key is None:
                    out = _CHUNK_JIT(self.encoder.tables, dev, self._qty32,
                                     meta=self.encoder.meta, flow=self.flow)
                else:
                    out = _CHUNK_MC_JIT(self.encoder.tables, dev,
                                        self._qty32, mc_key, sig,
                                        meta=self.encoder.meta,
                                        flow=self.flow,
                                        n_draws=int(mc_draws),
                                        quantiles=quantiles)
                pending.append(out)
                reals.append(n_real)
        host = jax.device_get(pending)          # one sync for the stream
        self.elapsed_s += time.perf_counter() - t0
        outs = [jax.tree_util.tree_map(lambda a, nr=nr: a[:nr], o)
                for o, nr in zip(host, reals)]
        self.n_candidates += int(sum(reals))
        self.n_systems += int(sum(reals)) * len(self.space.skus)
        self.n_chunks += len(reals)

        def cat(i):
            return np.concatenate([o[i] for o in outs], axis=0)

        risk = None
        if mc_key is not None:
            risk = {kk: np.concatenate([o[4][kk] for o in outs], axis=0)
                    for kk in outs[0][4]}
        finite = np.concatenate([o[-1] for o in outs], axis=0)
        return EvalArrays(idx=idx, sku_unit_total=cat(0), sku_unit_re=cat(1),
                          sku_unit_nre=cat(2), portfolio_cost=cat(3),
                          risk=risk, finite=finite)

    def results_from_arrays(self, arrays: EvalArrays,
                            candidates: Optional[Sequence[Candidate]] = None,
                            ) -> List[CandidateResult]:
        """Materialize host :class:`CandidateResult` objects (labels and
        all) from fused pipeline output — the cold path, meant for
        winners/reports rather than the full stream."""
        if candidates is None:
            candidates = [self.space.candidate_at(int(i))
                          for i in arrays.idx]
        names = [sk.name for sk in self.space.skus]
        out = []
        for j, cand in enumerate(candidates):
            risk = None
            if arrays.risk is not None:
                risk = {kk: float(v[j]) for kk, v in arrays.risk.items()}
            out.append(CandidateResult(
                candidate=cand, label=cand.label(), sku_names=names,
                sku_unit_total=np.asarray(arrays.sku_unit_total[j],
                                          np.float64),
                sku_unit_re=np.asarray(arrays.sku_unit_re[j], np.float64),
                sku_unit_nre=np.asarray(arrays.sku_unit_nre[j], np.float64),
                portfolio_cost=float(arrays.portfolio_cost[j]), risk=risk))
        return out

    # -- object API ----------------------------------------------------------
    def evaluate(self, candidates: Sequence[Candidate],
                 mc_key=None, mc_draws: int = 128, mc_sigmas=None,
                 mc_quantiles: Sequence[float] = (0.5, 0.9),
                 ) -> List[CandidateResult]:
        """Price every candidate; optionally attach Monte Carlo risk stats.

        With ``mc_key`` set, each chunk is additionally priced under
        ``mc_draws`` correlated parameter scenarios (see
        :mod:`repro.dse.uncertainty`) — the *same* key (common random
        numbers) is reused for every chunk so candidates are compared
        under identical scenarios regardless of chunking.

        Candidates that are valid for ``candidate_systems`` but not
        members of this space's menus cannot be index-encoded; such a
        stream transparently falls back to the host-packing path.
        """
        candidates = list(candidates)
        if not candidates:
            return []
        if self.fused:
            try:
                idx = np.asarray([self.space.index_of(c)
                                  for c in candidates], np.int64)
            except ValueError:
                idx = None      # foreign-but-priceable candidates
            if idx is not None:
                arrays = self.evaluate_indices(
                    idx, mc_key=mc_key, mc_draws=mc_draws,
                    mc_sigmas=mc_sigmas, mc_quantiles=mc_quantiles)
                return self.results_from_arrays(arrays, candidates)
        return self._evaluate_legacy(candidates, mc_key, mc_draws,
                                     mc_sigmas, mc_quantiles)

    # -- legacy host-packing path (parity oracle) ---------------------------
    def pack_chunk(self, chunk: Sequence[Candidate]) -> SystemBatch:
        """Pack <= candidates_per_chunk candidates into one padded batch
        via the host ``System`` route (reference path)."""
        if len(chunk) > self.shape.candidates:
            raise ValueError(f"chunk of {len(chunk)} exceeds "
                             f"{self.shape.candidates} candidates")
        systems, groups = [], []
        for j, cand in enumerate(chunk):
            grp = candidate_systems(self.space, cand)
            systems += grp
            groups += [j] * len(grp)
        batch = SystemBatch.from_systems(systems, share_nre=groups,
                                         max_chips=self.shape.max_chips)
        return pad_batch(batch, **self.shape.pad_kwargs())

    def _legacy_chunk_host(self, chunk: Sequence[Candidate], mc_key,
                           mc_draws: int, mc_sigmas) -> Tuple:
        """Price one candidate chunk through the host-packing path.

        Returns float64 host arrays ``(total, re, nre, pf_draws)`` with
        the first three ``(len(chunk) * S,)`` per-system rows and
        ``pf_draws`` a ``(draws, len(chunk))`` portfolio-cost matrix (or
        None without ``mc_key``).  This is op-for-op the math of the
        legacy parity oracle — :meth:`_evaluate_legacy` builds its
        ``CandidateResult`` objects from exactly these values — and it
        is what the service's degraded mode prices through, so fallback
        responses are bit-exact float32 casts of oracle float64s.
        Per-row values are chunk-composition-independent (cost-neutral
        padding; MC draws are systematic scalar multipliers), so how a
        tick re-chunks the rows cannot change them.
        """
        s = len(self.space.skus)
        qty = np.asarray([sk.quantity for sk in self.space.skus], np.float64)
        batch = self.pack_chunk(chunk)
        dev = [self.engine.total(batch, flow=self.flow)]
        if mc_key is not None:
            draws = mc_totals(batch, mc_key, n_draws=mc_draws,
                              flow=self.flow, sigmas=mc_sigmas)
            # fold the real (unpadded) rows into per-candidate
            # portfolio costs: (draws, len(chunk))
            dev.append(portfolio_draws(draws[:, :len(chunk) * s], qty, s))
        # every device->host transfer of the chunk in one batched get
        host = jax.device_get(tuple(dev))
        tc = host[0]
        pf_draws = np.asarray(host[1], np.float64) \
            if mc_key is not None else None
        return (np.asarray(tc.total, np.float64),
                np.asarray(tc.re.total, np.float64),
                np.asarray(tc.nre.total, np.float64), pf_draws)

    @staticmethod
    def _legacy_risk(pf_col: np.ndarray,
                     quantiles: Sequence[float]) -> Dict[str, float]:
        """Host risk stats of one candidate's draw column — shared by the
        oracle and the degraded path so the two stay bit-identical."""
        risk = {"mean": float(pf_col.mean()), "std": float(pf_col.std())}
        for q in quantiles:
            risk[f"q{int(round(q * 100))}"] = float(np.quantile(pf_col, q))
        return risk

    def _evaluate_legacy(self, candidates, mc_key, mc_draws, mc_sigmas,
                         mc_quantiles) -> List[CandidateResult]:
        s = len(self.space.skus)
        qty = np.asarray([sk.quantity for sk in self.space.skus], np.float64)
        names = [sk.name for sk in self.space.skus]
        out: List[CandidateResult] = []
        k = self.shape.candidates
        for lo in range(0, len(candidates), k):
            chunk = candidates[lo:lo + k]
            t0 = time.perf_counter()
            total, re_tot, nre_tot, pf_draws = self._legacy_chunk_host(
                chunk, mc_key, mc_draws, mc_sigmas)
            self.elapsed_s += time.perf_counter() - t0
            for j, cand in enumerate(chunk):
                rows = slice(j * s, (j + 1) * s)
                unit = total[rows]
                risk = self._legacy_risk(pf_draws[:, j], mc_quantiles) \
                    if pf_draws is not None else None
                out.append(CandidateResult(
                    candidate=cand, label=cand.label(), sku_names=names,
                    sku_unit_total=unit, sku_unit_re=re_tot[rows],
                    sku_unit_nre=nre_tot[rows],
                    portfolio_cost=float((qty * unit).sum()), risk=risk))
            self.n_candidates += len(chunk)
            self.n_systems += len(chunk) * s
            self.n_chunks += 1
        return out

    def evaluate_indices_legacy(self, idx, mc_key=None, mc_draws: int = 128,
                                mc_sigmas=None,
                                mc_quantiles: Sequence[float] = (0.5, 0.9),
                                ) -> EvalArrays:
        """Index-native pricing through the **legacy host-packing path**.

        Same signature and :class:`EvalArrays` contract as
        :meth:`evaluate_indices`, but every chunk goes host ``System``
        packing -> engine -> host, no fused decode.  This is the
        degraded-mode evaluator the pricing service falls back to when
        fused dispatch fails: slow (per-candidate Python packing) but
        correct, with results equal to float32 casts of the legacy
        oracle's float64 values by construction (shared
        :meth:`_legacy_chunk_host` / :meth:`_legacy_risk`).  Works with
        ``fused=False`` evaluators too — no encoder needed.
        """
        idx = np.asarray(idx, np.int64)
        if idx.ndim != 1 or idx.size == 0:
            raise ValueError("need a 1-D, non-empty index vector")
        if idx.min() < 0 or idx.max() >= self.space.size():
            raise IndexError("candidate index out of range")
        s = len(self.space.skus)
        qty = np.asarray([sk.quantity for sk in self.space.skus], np.float64)
        quantiles = tuple(float(q) for q in mc_quantiles)
        n, k = idx.size, self.shape.candidates
        unit = np.empty((n, s), np.float32)
        re_a = np.empty((n, s), np.float32)
        nre_a = np.empty((n, s), np.float32)
        pf = np.empty((n,), np.float32)
        risk = None
        if mc_key is not None:
            risk = {kk: np.empty((n,), np.float32)
                    for kk in ("mean", "std")
                    + tuple(f"q{int(round(q * 100))}" for q in quantiles)}
        t0 = time.perf_counter()
        for lo in range(0, n, k):
            with _TRACER.span("legacy_chunk", lo=lo):
                chunk = [self.space.candidate_at(int(i))
                         for i in idx[lo:lo + k]]
                total, re_tot, nre_tot, pf_draws = self._legacy_chunk_host(
                    chunk, mc_key, mc_draws, mc_sigmas)
                for j in range(len(chunk)):
                    rows = slice(j * s, (j + 1) * s)
                    u = total[rows]
                    unit[lo + j] = u
                    re_a[lo + j] = re_tot[rows]
                    nre_a[lo + j] = nre_tot[rows]
                    pf[lo + j] = float((qty * u).sum())
                    if pf_draws is not None:
                        for kk, v in self._legacy_risk(
                                pf_draws[:, j], quantiles).items():
                            risk[kk][lo + j] = v
        self.elapsed_s += time.perf_counter() - t0
        self.n_candidates += n
        self.n_systems += n * s
        self.n_chunks += -(-n // k)
        finite = np.isfinite(unit).all(-1) & np.isfinite(pf)
        if risk is not None:
            for v in risk.values():
                finite &= np.isfinite(v)
        return EvalArrays(idx=idx, sku_unit_total=unit, sku_unit_re=re_a,
                          sku_unit_nre=nre_a, portfolio_cost=pf, risk=risk,
                          finite=finite)


def evaluate_direct(space: DesignSpace, cand: Candidate,
                    engine: Optional[CostEngine] = None,
                    flow: str = "chip-last") -> CandidateResult:
    """Unchunked, unpadded single-candidate pricing (reference path).

    Builds the candidate's group as its own ``share_nre=True`` batch and
    prices it directly — the cross-check the padded-chunk parity tests
    compare against.
    """
    engine = engine or CostEngine()
    grp = candidate_systems(space, cand)
    tc = jax.device_get(engine.total(
        SystemBatch.from_systems(grp, share_nre=True), flow=flow))
    qty = np.asarray([sk.quantity for sk in space.skus], np.float64)
    unit = np.asarray(tc.total, np.float64)
    return CandidateResult(
        candidate=cand, label=cand.label(),
        sku_names=[sk.name for sk in space.skus], sku_unit_total=unit,
        sku_unit_re=np.asarray(tc.re.total, np.float64),
        sku_unit_nre=np.asarray(tc.nre.total, np.float64),
        portfolio_cost=float((qty * unit).sum()))
