"""Differentiable chiplet partitioning (beyond-paper extension).

The paper sweeps integer chiplet counts; here we exploit the JAX
implementation to *differentiate* the RE model and gradient-descend on

  * a continuous relaxation of the chiplet count ``n`` (rounded at the end),
  * uneven split fractions (softmax-parameterized), useful when modules
    have different yield sensitivity (heterogeneous defect densities).

This is an extension, clearly separated from the faithful model: the
faithful integer sweep (explorer.best_partition) is always reported next
to the relaxed optimum in the benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .re_cost import re_cost_split
from .technology import node, tech


@dataclasses.dataclass
class PartitionResult:
    n_relaxed: float
    n_rounded: int
    cost_relaxed: float
    cost_rounded: float
    cost_soc: float
    iterations: int


def _total(n, area, wafer_cost, d0, cluster, t):
    return re_cost_split(area, n, wafer_cost=wafer_cost, defect_density=d0,
                         cluster=cluster, tech_params=t)["total"]


def optimize_chiplet_count(process: str, integration: str, area_mm2: float,
                           early: bool = False, lr: float = 0.05,
                           steps: int = 300, n0: float = 2.0) -> PartitionResult:
    """Gradient descent on log(n) to minimize the continuous RE total."""
    nd = node(process)
    t = tech(integration)
    d0 = nd.defect_density_early if early else nd.defect_density

    soc_cost = _total(1.0, area_mm2, nd.wafer_cost, d0, nd.cluster_param, t)

    def loss(log_n):
        n = jnp.exp(log_n) + 1.0  # n >= 1
        # normalized: O(1) gradients for any node/area (raw $ costs give
        # log-space SGD steps of ~e^80 and the descent diverges)
        return _total(n, area_mm2, nd.wafer_cost, d0, nd.cluster_param,
                      t) / soc_cost

    grad = jax.jit(jax.grad(loss))
    val = jax.jit(lambda ln: loss(ln) * soc_cost)
    log_n = jnp.log(jnp.asarray(n0 - 1.0 + 1e-3))
    for i in range(steps):
        g = grad(log_n)
        log_n = log_n - lr * g
    n_rel = float(jnp.exp(log_n) + 1.0)
    n_round = max(1, int(round(n_rel)))
    cost_rel = float(val(log_n))
    cost_round = float(_total(float(n_round), area_mm2, nd.wafer_cost, d0,
                              nd.cluster_param, t))
    cost_soc = float(_total(1.0, area_mm2, nd.wafer_cost, d0,
                            nd.cluster_param, t))
    return PartitionResult(n_relaxed=n_rel, n_rounded=n_round,
                           cost_relaxed=cost_rel, cost_rounded=cost_round,
                           cost_soc=cost_soc, iterations=steps)


def optimize_uneven_split(process: str, integration: str,
                          module_areas_mm2, n_chiplets: int,
                          early: bool = False, lr: float = 0.1,
                          steps: int = 500) -> Dict:
    """Assign m modules to n chiplets via a relaxed (softmax) assignment.

    Minimizes the sum of per-chiplet good-die costs + packaging; returns
    the hard assignment recovered by argmax.  Modules are treated as
    divisible during optimization (a common relaxation); the reported hard
    cost re-evaluates the rounded assignment faithfully.
    """
    from .yield_model import raw_die_cost, yield_negative_binomial

    nd = node(process)
    t = tech(integration)
    d0 = nd.defect_density_early if early else nd.defect_density
    areas = jnp.asarray(module_areas_mm2, jnp.float32)
    m = areas.shape[0]
    ovh = t.d2d_area_overhead

    def chip_cost(chip_area):
        a = chip_area / (1.0 - ovh)
        y = yield_negative_binomial(a, d0, nd.cluster_param) * 0.99
        return raw_die_cost(a, nd.wafer_cost) / y

    def loss(logits):
        p = jax.nn.softmax(logits, axis=1)          # (m, n) soft assignment
        chip_areas = p.T @ areas                    # (n,)
        sil = chip_areas.sum() / (1.0 - ovh)
        pkg = (sil * t.package_area_factor * t.substrate_cost_per_mm2
               * t.substrate_layer_factor)
        y2n = t.y2_chip_bond ** n_chiplets
        y3 = t.y3_substrate_bond * t.assembly_yield
        dies = jax.vmap(chip_cost)(chip_areas).sum()
        return dies / (y2n * y3) + pkg / y3

    grad = jax.jit(jax.grad(loss))
    val = jax.jit(loss)
    key = jax.random.PRNGKey(0)
    logits = 0.01 * jax.random.normal(key, (m, n_chiplets))
    for _ in range(steps):
        logits = logits - lr * grad(logits)
    hard = jax.device_get(jnp.argmax(logits, axis=1))
    chip_areas = [float(areas[hard == i].sum()) for i in range(n_chiplets)]
    return {"assignment": hard.tolist(), "chip_areas": chip_areas,
            "soft_cost": float(val(logits))}
