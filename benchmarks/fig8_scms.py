"""Paper Fig. 8: SCMS (single chiplet, multiple systems) reuse scheme."""
from repro.core import (amortized_costs, re_cost, scms_soc_equivalents,
                        scms_systems)
from .common import emit


def run():
    rows = []
    base = re_cost(scms_systems(integration="MCM")[-1]).total  # 4x MCM RE
    for integ in ("MCM", "2.5D"):
        for reuse in (False, True):
            systems = scms_systems(integration=integ, package_reuse=reuse)
            costs = amortized_costs(systems)
            for s in systems:
                c = costs[s.name]
                rows.append({
                    "integration": integ, "package_reuse": reuse,
                    "system": s.name,
                    "re_norm": c.re.total / base,
                    "packaging_share": c.re.packaging_cost / c.re.total,
                    "nre_chips_norm": c.nre_chips / base,
                    "nre_pkg_norm": c.nre_packages / base,
                    "total_norm": c.total / base,
                })
    socs = scms_soc_equivalents()
    costs = amortized_costs(socs)
    for s in socs:
        c = costs[s.name]
        rows.append({
            "integration": "SoC", "package_reuse": False, "system": s.name,
            "re_norm": c.re.total / base,
            "packaging_share": c.re.packaging_cost / c.re.total,
            "nre_chips_norm": c.nre_chips / base,
            "nre_pkg_norm": c.nre_packages / base,
            "total_norm": c.total / base,
        })
    emit("fig8_scms_reuse", rows)
    return rows


if __name__ == "__main__":
    run()
