"""Step functions (train / prefill / serve) shared by the trainer, the
serving engine and the multi-pod dry-run.

Everything is expressed over spec trees so the dry-run can lower the
exact production step with ShapeDtypeStruct inputs and NamedShardings,
and the CPU trainer can run the same function on real arrays.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, InputShape
from ..models import api
from ..models.common import ParamSpec, abstract_params, init_params, spec_map
from ..optim import (adamw_init, adamw_init_spec, adamw_update,
                     error_feedback_update, linear_warmup_cosine)
from .sharding import AxisRules, constrain, sharding_for, use_mesh


class TrainState(NamedTuple):
    params: Any
    opt: Any
    ef_err: Any = None      # error-feedback residuals (compression on)


def train_state_spec(cfg: ArchConfig,
                     compress: bool = False) -> TrainState:
    pspec = api.param_spec(cfg)
    # params live in the compute dtype; masters/moments in fp32
    pspec_dt = spec_map(
        lambda s: ParamSpec(s.shape, s.axes, cfg.jdtype, init=s.init,
                            scale=s.scale), pspec)
    ef = spec_map(lambda s: ParamSpec(s.shape, s.axes, jnp.float32,
                                      init="zeros"), pspec) if compress \
        else None
    return TrainState(params=pspec_dt, opt=adamw_init_spec(pspec),
                      ef_err=ef)


def init_train_state(cfg: ArchConfig, key,
                     compress: bool = False) -> TrainState:
    spec = api.param_spec(cfg)
    params32 = init_params(spec, key)
    params = jax.tree_util.tree_map(lambda x: x.astype(cfg.jdtype), params32)
    ef = jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params32) if compress \
        else None
    return TrainState(params=params, opt=adamw_init(params32), ef_err=ef)


def make_train_step(cfg: ArchConfig, *, base_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    accum: int = 1, compress_fraction: Optional[float] = None
                    ) -> Callable:
    """(TrainState, batch) -> (TrainState, metrics).

    ``accum`` > 1 expects batch leaves with a leading microbatch axis and
    scans over them (sequential accumulation = overlap-friendly under
    GSPMD: each microbatch's reduce-scatter overlaps the next one's
    compute).  ``compress_fraction`` enables error-feedback top-k+int8
    gradient compression (cross-pod wire model; see optim.compression).
    """
    loss_fn = api.loss_fn(cfg)
    axes_tree = jax.tree_util.tree_map(
        lambda s: s.axes, api.param_spec(cfg),
        is_leaf=lambda x: hasattr(x, "axes"))

    def shard_like_params(grads):
        """Pin gradient shardings to the parameter layout.

        Without this GSPMD is free to keep per-layer weight grads as
        replicated partial sums and all-reduce them at FULL size inside
        the backward loop (memory x16, collective x16); constraining to
        the param sharding turns that into reduce-scatter-style grads.
        """
        return jax.tree_util.tree_map(
            lambda g, ax: constrain(g, *ax), grads, axes_tree)

    def grads_of(params, batch):
        loss, g = jax.value_and_grad(lambda p: loss_fn(p, batch))(params)
        return loss, shard_like_params(g)

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        params = state.params
        if accum > 1:
            def acc_body(carry, mb):
                gsum, lsum = carry
                l, g = grads_of(params, mb)
                gsum = jax.tree_util.tree_map(jnp.add, gsum, g)
                return (gsum, lsum + l), None
            zeros = shard_like_params(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, loss), _ = jax.lax.scan(acc_body, (zeros, 0.0), batch)
            grads = jax.tree_util.tree_map(lambda g: g / accum, grads)
            loss = loss / accum
        else:
            loss, grads = grads_of(params, batch)

        new_ef = state.ef_err
        if compress_fraction is not None and state.ef_err is not None:
            # error-feedback top-k+int8 on the cross-pod wire (the wire
            # itself is modeled losslessly in-process; see
            # parallel.collectives.compressed_psum for the shard_map leg)
            pairs = jax.tree_util.tree_map(
                lambda g, e: error_feedback_update(
                    g.astype(jnp.float32), e, compress_fraction),
                grads, state.ef_err)
            grads = jax.tree_util.tree_map(
                lambda p: p[0], pairs,
                is_leaf=lambda x: isinstance(x, tuple))
            new_ef = jax.tree_util.tree_map(
                lambda p: p[1], pairs,
                is_leaf=lambda x: isinstance(x, tuple))

        lr = linear_warmup_cosine(state.opt.step, base_lr, warmup,
                                  total_steps)
        new_params, new_opt = adamw_update(grads, state.opt, lr,
                                           param_dtype=cfg.jdtype)
        metrics = {"loss": loss, "lr": lr, "step": new_opt.step}
        return TrainState(params=new_params, opt=new_opt,
                          ef_err=new_ef), metrics

    return step


def make_eval_step(cfg: ArchConfig) -> Callable:
    loss_fn = api.loss_fn(cfg)

    def step(params, batch):
        return loss_fn(params, batch)
    return step


def make_prefill_step(cfg: ArchConfig, cache_len: int) -> Callable:
    fn = api.prefill_fn(cfg, cache_len)

    def step(params, batch):
        return fn(params, batch)
    return step


def make_serve_step(cfg: ArchConfig) -> Callable:
    """One decode tick: greedy-sample next token and advance the cache."""
    fn = api.decode_fn(cfg)

    def step(params, batch, cache):
        logits, new_cache = fn(params, batch["token"], cache,
                               batch["kv_len"])
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return {"token": next_tok, "kv_len": batch["kv_len"] + 1}, new_cache

    return step


# ---------------------------------------------------------------------------
# Sharding helpers for step I/O
# ---------------------------------------------------------------------------


def batch_axes(cfg: ArchConfig, shape: InputShape):
    """Logical axes tree for one batch (matches api.input_spec)."""
    return {k: v.axes for k, v in api.input_spec(cfg, shape).items()}


def abstract_batch(cfg: ArchConfig, shape: InputShape, mesh=None,
                   rules: Optional[AxisRules] = None, accum: int = 1):
    spec = api.input_spec(cfg, shape)
    shard = None
    if mesh is not None and rules is not None:
        shard = lambda axes, shape: sharding_for(axes, mesh, rules, shape)
    if accum > 1:
        # split the global batch into `accum` microbatches (dim 0 = batch)
        spec = {k: ParamSpec((accum, v.shape[0] // accum) + v.shape[1:],
                             (None,) + v.axes, v.dtype)
                for k, v in spec.items()}
    return abstract_params(spec, shard)


def abstract_state(cfg: ArchConfig, mesh=None,
                   rules: Optional[AxisRules] = None):
    spec = train_state_spec(cfg)
    shard = None
    if mesh is not None and rules is not None:
        shard = lambda axes, shape: sharding_for(axes, mesh, rules, shape)
    return abstract_params(spec, shard)


def abstract_cache(cfg: ArchConfig, shape: InputShape, mesh=None,
                   rules: Optional[AxisRules] = None):
    spec = api.cache_spec(cfg, shape)
    shard = None
    if mesh is not None and rules is not None:
        shard = lambda axes, shape: sharding_for(axes, mesh, rules, shape)
    return abstract_params(spec, shard)


def materialize_batch(cfg: ArchConfig, shape: InputShape, seed: int = 0,
                      accum: int = 1):
    """Synthetic concrete batch matching input_spec (for CPU runs)."""
    import numpy as np
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in api.input_spec(cfg, shape).items():
        shp = ((accum, s.shape[0] // accum) + s.shape[1:]) if accum > 1 \
            else s.shape
        if s.dtype == jnp.int32:
            hi = cfg.vocab if "token" in k or "label" in k else 2
            out[k] = jnp.asarray(
                rng.integers(0, hi, size=shp, dtype=np.int64), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=shp), s.dtype)
    if "kv_len" in out:
        out["kv_len"] = jnp.full(out["kv_len"].shape, shape.seq_len - 1,
                                 jnp.int32)
    return out
