"""Chiplet-reuse scheme builders — paper Sec. 5 (Figs. 7-10).

Three schemes:

* SCMS  (Single Chiplet, Multiple Systems)   — Fig. 7(a) / Fig. 8
* OCME  (One Center, Multiple Extensions)    — Fig. 7(b) / Fig. 9
* FSMC  (A Few Sockets, Multiple Collocations) — Fig. 7(c) / Fig. 10

Each builder returns a list of :class:`System` groups ready for
:func:`repro.core.nre_cost.amortized_costs`.
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

from .system import Chip, Module, System, make_chip, soc_system
from .technology import tech


# ---------------------------------------------------------------------------
# SCMS — one chiplet design, systems of 1x/2x/4x chiplets (Sec. 5.1)
# ---------------------------------------------------------------------------


def scms_systems(module_area_mm2: float = 200.0, process: str = "7nm",
                 counts: Sequence[int] = (1, 2, 4), integration: str = "MCM",
                 quantity: float = 500_000.0,
                 package_reuse: bool = False) -> List[System]:
    """Build the Fig. 8 scenario: one chiplet reused in `counts`-sized systems."""
    m = Module(name=f"scms_mod_{process}", area_mm2=module_area_mm2,
               process=process)
    chiplet = make_chip("scms_chiplet", [m], process, integration=integration)
    max_count = max(counts)
    systems = []
    for k in counts:
        pkg_name = f"scms_pkg_{integration}" if package_reuse else None
        pkg_area = None
        if package_reuse:
            # The shared package is sized for the largest system.
            pkg_area = (chiplet.area_mm2 * max_count
                        * tech(integration).package_area_factor)
        systems.append(System(
            name=f"scms_{k}x_{integration}",
            chips=tuple([chiplet] * k), integration=integration,
            quantity=quantity, package_name=pkg_name,
            package_area_mm2=pkg_area))
    return systems


def scms_soc_equivalents(module_area_mm2: float = 200.0, process: str = "7nm",
                         counts: Sequence[int] = (1, 2, 4),
                         quantity: float = 500_000.0) -> List[System]:
    """Monolithic SoCs with the same module content (the Fig. 8 baseline).

    Per Eq. (7), the SoC flow still reuses *modules*: every SoC die holds k
    copies of the same module design, so module NRE is paid once across the
    group while each die's chip-level NRE is paid per system.
    """
    m = Module(name=f"scms_mod_{process}", area_mm2=module_area_mm2,
               process=process)
    out = []
    for k in counts:
        die = make_chip(f"scms_{k}x_soc_die", [m] * k, process,
                        integration="SoC")
        out.append(System(name=f"scms_{k}x_soc", chips=(die,),
                          integration="SoC", quantity=quantity))
    return out


# ---------------------------------------------------------------------------
# OCME — center die + same-footprint extensions (Sec. 5.2)
# ---------------------------------------------------------------------------


def ocme_systems(socket_area_mm2: float = 160.0, process: str = "7nm",
                 center_process: Optional[str] = None,
                 integration: str = "MCM", quantity: float = 500_000.0,
                 package_reuse: bool = False,
                 n_sockets: int = 4) -> List[System]:
    """Fig. 9: center chip C + extensions X/Y in a 4-socket package.

    Four systems: [C], [C,X], [C,X,Y], [C,X,X,Y].  ``center_process``
    overrides C's node for the heterogeneous variant (e.g. '14nm' —
    'unscalable' IO/analog modules kept on a mature node).
    """
    cp = center_process or process
    c_mod = Module(name=f"ocme_C_mod_{cp}", area_mm2=socket_area_mm2, process=cp)
    x_mod = Module(name=f"ocme_X_mod_{process}", area_mm2=socket_area_mm2, process=process)
    y_mod = Module(name=f"ocme_Y_mod_{process}", area_mm2=socket_area_mm2, process=process)
    C = make_chip("ocme_C", [c_mod], cp, integration=integration)
    X = make_chip("ocme_X", [x_mod], process, integration=integration)
    Y = make_chip("ocme_Y", [y_mod], process, integration=integration)

    combos: List[Tuple[Chip, ...]] = [(C,), (C, X), (C, X, Y), (C, X, X, Y)]
    combos = [c for c in combos if len(c) <= n_sockets]
    pkg_area = None
    pkg_name = None
    if package_reuse:
        pkg_area = (C.area_mm2 * n_sockets
                    * tech(integration).package_area_factor)
        pkg_name = f"ocme_pkg_{integration}"
    out = []
    for chips in combos:
        label = "".join(ch.name[-1] for ch in chips)
        out.append(System(name=f"ocme_{label}_{integration}",
                          chips=chips, integration=integration,
                          quantity=quantity, package_name=pkg_name,
                          package_area_mm2=pkg_area))
    return out


def ocme_soc_equivalents(socket_area_mm2: float = 160.0, process: str = "7nm",
                         quantity: float = 500_000.0) -> List[System]:
    """Monolithic equivalents of the four OCME systems (all on `process`).

    Modules C/X/Y are shared across the group (Eq. 7 module reuse); each
    system still pays its own chip-level NRE.
    """
    c = Module(name=f"ocme_C_mod_{process}", area_mm2=socket_area_mm2, process=process)
    x = Module(name=f"ocme_X_mod_{process}", area_mm2=socket_area_mm2, process=process)
    y = Module(name=f"ocme_Y_mod_{process}", area_mm2=socket_area_mm2, process=process)
    out = []
    for label, mods in (("C", [c]), ("CX", [c, x]), ("CXY", [c, x, y]),
                        ("CXXY", [c, x, x, y])):
        die = make_chip(f"ocme_{label}_soc_die", mods, process,
                        integration="SoC")
        out.append(System(name=f"ocme_{label}_soc", chips=(die,),
                          integration="SoC", quantity=quantity))
    return out


# ---------------------------------------------------------------------------
# FSMC — n chiplet designs, k sockets (Sec. 5.3)
# ---------------------------------------------------------------------------


def fsmc_num_systems(n_chiplets: int, k_sockets: int) -> int:
    """Paper's count: sum_{i=1..k} C(n+i-1, i) (multisets of size 1..k).

    NOTE: the paper quotes "6 chiplets and one 4-socket package -> up to
    119 systems", but the formula gives 209 for (n=6, k=4); 119 matches
    (n=7, k=3).  We implement the formula; the fig10 benchmark flags the
    discrepancy.
    """
    return sum(math.comb(n_chiplets + i - 1, i) for i in range(1, k_sockets + 1))


def fsmc_enumerate(n_chiplets: int = 6, k_sockets: int = 4,
                   chiplet_area_mm2: float = 100.0, process: str = "7nm",
                   integration: str = "MCM", quantity: float = 500_000.0,
                   package_reuse: bool = True,
                   limit: Optional[int] = None) -> List[System]:
    """Enumerate multiset collocations of n chiplets into <=k sockets."""
    chips = []
    for i in range(n_chiplets):
        m = Module(name=f"fsmc_mod{i}_{process}", area_mm2=chiplet_area_mm2,
                   process=process)
        chips.append(make_chip(f"fsmc_chip{i}", [m], process,
                               integration=integration))
    pkg_area = (chips[0].area_mm2 * k_sockets
                * tech(integration).package_area_factor) if package_reuse else None
    systems = []
    for size in range(1, k_sockets + 1):
        for combo in itertools.combinations_with_replacement(range(n_chiplets), size):
            name = "fsmc_" + "".join(str(i) for i in combo)
            systems.append(System(
                name=name, chips=tuple(chips[i] for i in combo),
                integration=integration, quantity=quantity,
                package_name=f"fsmc_pkg_{k_sockets}s" if package_reuse else None,
                package_area_mm2=pkg_area))
            if limit is not None and len(systems) >= limit:
                return systems
    return systems


# ---------------------------------------------------------------------------
# Portfolio reuse — SCMS generalized to per-SKU socket counts (repro.dse)
# ---------------------------------------------------------------------------


def portfolio_reuse_systems(slice_area_mm2: float, process: str,
                            integration: str, counts: Sequence[int],
                            quantities: Sequence[float],
                            names: Optional[Sequence[str]] = None,
                            package_reuse: bool = False,
                            chip_name: Optional[str] = None) -> List[System]:
    """One shared chiplet design collocated ``counts[i]`` times per SKU.

    The SCMS scheme (Fig. 8) generalized to a product portfolio: SKU ``i``
    is a package of ``counts[i]`` copies of a single ``slice_area_mm2``
    chiplet on ``process``, produced in ``quantities[i]`` units.  Because
    every system names the same chip design, packing the group with
    ``SystemBatch.from_systems(..., share_nre=True)`` (or one dse group)
    amortizes the chiplet NRE over the whole portfolio volume.
    ``package_reuse`` additionally shares one package design sized for the
    largest SKU (the smaller SKUs pay the oversized package, Sec. 5.1).
    """
    if len(counts) != len(quantities):
        raise ValueError("counts and quantities must have equal length")
    if min(counts) < 1:
        raise ValueError("every SKU needs at least one chiplet")
    if names is None:
        names = [f"sku{i}" for i in range(len(counts))]
    elif len(names) != len(counts):
        raise ValueError("names and counts must have equal length")
    if chip_name is None:
        chip_name = f"reuse_{process}_{integration}_{slice_area_mm2:g}mm2"
    m = Module(name=f"{chip_name}_modules", area_mm2=slice_area_mm2,
               process=process)
    chiplet = make_chip(chip_name, [m], process, integration=integration)
    pkg_name = pkg_area = None
    if package_reuse:
        pkg_name = f"{chip_name}_pkg{max(counts)}s"
        pkg_area = (chiplet.area_mm2 * max(counts)
                    * tech(integration).package_area_factor)
    return [System(name=nm, chips=tuple([chiplet] * k),
                   integration=integration, quantity=float(q),
                   package_name=pkg_name, package_area_mm2=pkg_area)
            for nm, k, q in zip(names, counts, quantities)]


def fsmc_situations(n_chiplets: int = 6, k_sockets: int = 4,
                    n_situations: int = 5, **kw) -> Dict[int, List[System]]:
    """Five situations from low to high reuse: build the first N systems of
    the enumeration for N log-spaced between n_chiplets and the maximum."""
    total = fsmc_num_systems(n_chiplets, k_sockets)
    lo, hi = math.log(n_chiplets), math.log(total)
    sizes = sorted({int(round(math.exp(lo + (hi - lo) * i / (n_situations - 1))))
                    for i in range(n_situations)})
    return {n: fsmc_enumerate(n_chiplets, k_sockets, limit=n, **kw)
            for n in sizes}
