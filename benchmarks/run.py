"""Benchmark orchestrator: one section per paper table/figure plus the
roofline, codesign, kernel, engine and DSE benches.

  PYTHONPATH=src python -m benchmarks.run

The engine and DSE benches persist their summaries as BENCH_engine.json /
BENCH_dse.json at the repo root (perf trajectory; CI uploads them as
artifacts and guards them with scripts/check_bench_regression.py).
"""
import sys
import time


def main() -> None:
    from . import (ablations, chaos_bench, codesign, dse_bench,
                   engine_bench, fig2_yield_cost, fig4_re_integration,
                   fig5_amd, fig6_single_system, fig8_scms, fig9_ocme,
                   fig10_fsmc, kernels_bench, restart_bench, roofline,
                   service_bench)

    benches = [
        ("fig2", fig2_yield_cost), ("fig4", fig4_re_integration),
        ("fig5", fig5_amd), ("fig6", fig6_single_system),
        ("fig8", fig8_scms), ("fig9", fig9_ocme), ("fig10", fig10_fsmc),
        ("ablations", ablations),
        ("roofline", roofline), ("codesign", codesign),
        ("kernels", kernels_bench), ("engine", engine_bench),
        ("dse", dse_bench), ("service", service_bench),
        # restart SIGKILLs its own child process; chaos goes LAST: it
        # force-clears fused jit caches and injects faults into its own
        # service — nothing downstream to perturb.
        ("restart", restart_bench), ("chaos", chaos_bench),
    ]
    failures = 0
    for name, mod in benches:
        t0 = time.perf_counter()
        try:
            mod.run()
            print(f"# [{name}] done in {time.perf_counter()-t0:.2f}s\n")
        except Exception as e:  # keep the suite going, report at the end
            failures += 1
            print(f"# [{name}] FAILED: {type(e).__name__}: {e}\n")
    if failures:
        print(f"# {failures} benchmark(s) failed")
        sys.exit(1)
    print("# all benchmarks ok")


if __name__ == "__main__":
    main()
