"""Batched struct-of-arrays representation of heterogeneous systems.

:class:`SystemBatch` encodes N *arbitrary* systems (mixed nodes, unequal
chip areas, different integration technologies, package reuse) as a JAX
pytree of arrays, padded to ``max_chips`` chips per system.  It is the
input type of :class:`repro.core.engine.CostEngine`, which evaluates the
paper's full RE + NRE model (Eqs. 4-8) for the whole batch in one jitted,
vmap/grad-compatible trace — the design-space-sweep representation the
scalar ``System`` dataclasses cannot provide.

Construction happens host-side (cheap, once per sweep shape); everything
after ``from_systems`` / ``from_specs`` is pure array math.  All float
leaves may be swapped (``dataclasses.replace``) for traced values, which
is how the differentiable partitioner sweeps areas/quantities without
rebuilding the batch.

NRE amortization structure (who shares which design entity) is encoded as
integer id arrays + flat (instance -> system) index maps so the Eq. (6)-(8)
entity de-duplication runs in-graph via segment sums:

* chip designs   -> ``chip_entity_id``  (N, C) into ``chip_entity_*``
* package designs-> ``pkg_entity_id``   (N,)   into ``pkg_entity_*``
* modules        -> flat ``mod_sys``/``mod_entity`` instance lists
* D2D interfaces -> flat ``d2d_sys``/``d2d_entity`` instance lists

``share_nre=True`` (default) treats the batch as one co-produced group,
matching ``nre_cost.amortized_costs(systems)``; ``share_nre=False`` prices
every system as its own group (entity keys namespaced per system), which
is what independent design-point sweeps want.  ``share_nre`` may also be a
sequence of integer group ids, one per system: entities are then shared
*within* a group but never across groups — the representation
``repro.dse`` uses to price many candidate portfolios (each amortizing
NRE internally) in one batch.

:func:`pad_batch` pads every axis of a built batch (systems, chip slots,
entity tables, instance lists) with cost-neutral rows so arbitrarily
sized work can be evaluated through constant-shape chunks under a single
retained jit trace.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..resilience.guards import validate_packed_arrays
from .system import System, spec
from .technology import node, tech

_FLOAT = jnp.float32
_INT = jnp.int32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SystemBatch:
    """N heterogeneous systems as a struct-of-arrays pytree.

    Shapes: N = number of systems, C = max_chips (padded), E* = number of
    unique design entities, M/D = total module / D2D instances.
    """

    # --- per chip slot, (N, C) float; padded slots are zeroed + masked ---
    chip_area: jnp.ndarray          # die area incl. D2D share, mm^2
    chip_defect: jnp.ndarray        # defect density, defects/cm^2
    chip_wafer_cost: jnp.ndarray    # USD / wafer
    chip_cluster: jnp.ndarray       # negative-binomial c, Eq. (1)
    chip_wafer_yield: jnp.ndarray   # Y_wafer, Eq. (2)
    chip_sort_cost: jnp.ndarray     # USD / wafer (probe/sort)
    chip_bump_cost: jnp.ndarray     # USD / mm^2 (C4 bumping)
    chip_mask: jnp.ndarray          # 1.0 for a real chip, 0.0 for padding
    # --- per system, (N,) float ---
    package_area: jnp.ndarray       # resolved S_p (respects forced reuse area)
    package_area_factor: jnp.ndarray
    substrate_cost: jnp.ndarray     # USD / mm^2
    substrate_layer: jnp.ndarray    # layer growth factor
    interposer_cost: jnp.ndarray    # USD / mm^2 (0 for SoC/MCM)
    interposer_defect: jnp.ndarray  # defects / cm^2
    interposer_area_factor: jnp.ndarray
    interposer_cluster: jnp.ndarray
    y2_chip_bond: jnp.ndarray
    y3_substrate_bond: jnp.ndarray
    assembly_yield: jnp.ndarray
    bond_cost_per_chip: jnp.ndarray
    quantity: jnp.ndarray
    # --- NRE entity structure ---
    chip_entity_id: jnp.ndarray     # (N, C) int, padded slots point at 0
    chip_entity_area: jnp.ndarray   # (Ec,)
    chip_entity_k: jnp.ndarray      # (Ec,) K_c per mm^2
    chip_entity_fixed: jnp.ndarray  # (Ec,) C per chip design
    pkg_entity_id: jnp.ndarray      # (N,) int
    pkg_entity_area: jnp.ndarray    # (Ep,)
    pkg_entity_k: jnp.ndarray       # (Ep,) K_p per mm^2
    pkg_entity_fixed: jnp.ndarray   # (Ep,) C_p
    mod_sys: jnp.ndarray            # (M,) int — owning system of the instance
    mod_entity: jnp.ndarray         # (M,) int
    mod_entity_area: jnp.ndarray    # (Em,)
    mod_entity_k: jnp.ndarray       # (Em,) K_m per mm^2
    d2d_sys: jnp.ndarray            # (D,) int
    d2d_entity: jnp.ndarray         # (D,) int
    d2d_entity_nre: jnp.ndarray     # (Ed,)
    # --- static metadata (pytree aux) ---
    names: Tuple[str, ...] = ()

    # -- pytree protocol ----------------------------------------------------
    _LEAVES = None  # filled in after class creation

    def tree_flatten(self):
        # names are display-only metadata and deliberately NOT aux data:
        # aux participates in the jit cache key, and two batches that differ
        # only in names must share one compiled trace.  Reconstructed
        # (traced) batches therefore carry empty names.
        children = tuple(getattr(self, f) for f in self._LEAVES)
        return children, None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)

    # -- convenience --------------------------------------------------------
    @property
    def n_systems(self) -> int:
        return self.chip_area.shape[0]

    @property
    def max_chips(self) -> int:
        return self.chip_area.shape[1]

    @property
    def n_chips(self) -> jnp.ndarray:
        """(N,) number of real chips per system."""
        return self.chip_mask.sum(axis=-1)

    def replace(self, **kw) -> "SystemBatch":
        """Functional update — the hook for traced sweeps/gradients."""
        return dataclasses.replace(self, **kw)

    def __len__(self) -> int:
        return self.n_systems

    # -- constructors --------------------------------------------------------
    @classmethod
    def from_systems(cls, systems: Sequence[System],
                     max_chips: Optional[int] = None,
                     share_nre: Union[bool, Sequence[int]] = True,
                     ) -> "SystemBatch":
        """Pack :class:`System` objects into one batch.

        ``share_nre=True`` amortizes design entities across the whole batch
        (the batch is one product group, as in ``amortized_costs``) and
        therefore requires unique system names; ``share_nre=False`` prices
        each system as a standalone group.  A sequence of integer group
        ids (one per system) shares entities within each group only —
        names must be unique within a group.
        """
        systems = list(systems)
        if not systems:
            raise ValueError("empty system batch")
        if isinstance(share_nre, bool):
            groups = [0] * len(systems) if share_nre \
                else list(range(len(systems)))
        else:
            groups = [int(g) for g in share_nre]
            if len(groups) != len(systems):
                raise ValueError(
                    f"share_nre groups ({len(groups)}) != systems "
                    f"({len(systems)})")
        by_group: Dict[int, List[str]] = {}
        for s, g in zip(systems, groups):
            by_group.setdefault(g, []).append(s.name)
        for g, names in by_group.items():
            if len(set(names)) != len(names):
                raise ValueError(
                    "system names must be unique within a shared-NRE "
                    f"group (group {g})")
        n = len(systems)
        c = max(s.n_chips for s in systems)
        if max_chips is not None:
            if max_chips < c:
                raise ValueError(f"max_chips={max_chips} < widest system {c}")
            c = max_chips

        f = {k: np.zeros((n, c), np.float32) for k in
             ("area", "defect", "wafer_cost", "cluster", "wafer_yield",
              "sort_cost", "bump_cost", "mask")}
        f["wafer_yield"][:] = 1.0      # benign padding
        f["cluster"][:] = 1.0
        sysf = {k: np.zeros((n,), np.float32) for k in
                ("package_area", "package_area_factor", "substrate_cost",
                 "substrate_layer", "interposer_cost", "interposer_defect",
                 "interposer_area_factor", "interposer_cluster",
                 "y2_chip_bond", "y3_substrate_bond", "assembly_yield",
                 "bond_cost_per_chip", "quantity")}

        chip_ents: Dict = {}
        chip_ent_rows: List[Tuple[float, float, float]] = []
        pkg_ents: Dict = {}
        pkg_ent_rows: List[Tuple[float, float, float]] = []
        mod_ents: Dict = {}
        mod_ent_rows: List[Tuple[float, float]] = []
        d2d_ents: Dict = {}
        d2d_ent_rows: List[float] = []
        chip_ids = np.zeros((n, c), np.int32)
        mod_sys: List[int] = []
        mod_ent: List[int] = []
        d2d_sys: List[int] = []
        d2d_ent: List[int] = []
        pkg_ids = np.zeros((n,), np.int32)

        def _entity(table, rows, key, make_row):
            if key not in table:
                table[key] = len(rows)
                rows.append(make_row())
            return table[key]

        for i, s in enumerate(systems):
            t = s.tech
            ns = f"#{groups[i]}/"
            sysf["package_area"][i] = s.package_area
            sysf["package_area_factor"][i] = t.package_area_factor
            sysf["substrate_cost"][i] = t.substrate_cost_per_mm2
            sysf["substrate_layer"][i] = t.substrate_layer_factor
            sysf["interposer_cost"][i] = t.interposer_cost_per_mm2
            sysf["interposer_defect"][i] = t.interposer_defect_density
            sysf["interposer_area_factor"][i] = t.interposer_area_factor
            sysf["interposer_cluster"][i] = node(t.interposer_node).cluster_param
            sysf["y2_chip_bond"][i] = t.y2_chip_bond
            sysf["y3_substrate_bond"][i] = t.y3_substrate_bond
            sysf["assembly_yield"][i] = t.assembly_yield
            sysf["bond_cost_per_chip"][i] = t.bond_cost_per_chip
            sysf["quantity"][i] = s.quantity

            pkg_ids[i] = _entity(
                pkg_ents, pkg_ent_rows, ns + s.package_id,
                lambda: (s.package_area, t.nre_package_per_mm2,
                         t.nre_fixed_per_package))

            for j, chip in enumerate(s.chips):
                nd = chip.node
                f["area"][i, j] = chip.area_mm2
                f["defect"][i, j] = chip.defect_density
                f["wafer_cost"][i, j] = nd.wafer_cost
                f["cluster"][i, j] = nd.cluster_param
                f["wafer_yield"][i, j] = nd.wafer_yield
                f["sort_cost"][i, j] = nd.wafer_sort_cost
                f["bump_cost"][i, j] = nd.bump_cost_per_mm2
                f["mask"][i, j] = 1.0
                chip_ids[i, j] = _entity(
                    chip_ents, chip_ent_rows, ns + chip.name,
                    lambda: (chip.area_mm2, nd.nre_chip_per_mm2,
                             nd.nre_fixed_per_chip))
                for m in chip.modules:
                    if m.is_d2d:
                        d2d_sys.append(i)
                        d2d_ent.append(_entity(
                            d2d_ents, d2d_ent_rows, ns + m.process,
                            lambda: node(m.process).nre_d2d))
                    else:
                        mod_sys.append(i)
                        mod_ent.append(_entity(
                            mod_ents, mod_ent_rows, ns + m.name,
                            lambda: (m.area_mm2, m.node.nre_module_per_mm2)))

        # Numerical guardrail at the host/device boundary: a NaN defect
        # density or a yield of 1.3 here would flow silently through the
        # whole RE/NRE graph.  from_arrays (the traced encoder path)
        # skips this — traced values can't be inspected host-side; the
        # fused kernels guard those rows in-graph via engine.finite_rows.
        problems = validate_packed_arrays(
            f, sysf, [s.name for s in systems])
        if problems:
            raise ValueError(
                "invalid system parameters: " + "; ".join(problems))

        def arr(x, dt=_FLOAT):
            return jnp.asarray(np.asarray(x, dtype=np.float32
                                          if dt is _FLOAT else np.int32))

        chip_rows = np.asarray(chip_ent_rows, np.float32).reshape(-1, 3)
        pkg_rows = np.asarray(pkg_ent_rows, np.float32).reshape(-1, 3)
        mod_rows = np.asarray(mod_ent_rows, np.float32).reshape(-1, 2)
        return cls(
            chip_area=arr(f["area"]), chip_defect=arr(f["defect"]),
            chip_wafer_cost=arr(f["wafer_cost"]),
            chip_cluster=arr(f["cluster"]),
            chip_wafer_yield=arr(f["wafer_yield"]),
            chip_sort_cost=arr(f["sort_cost"]),
            chip_bump_cost=arr(f["bump_cost"]), chip_mask=arr(f["mask"]),
            package_area=arr(sysf["package_area"]),
            package_area_factor=arr(sysf["package_area_factor"]),
            substrate_cost=arr(sysf["substrate_cost"]),
            substrate_layer=arr(sysf["substrate_layer"]),
            interposer_cost=arr(sysf["interposer_cost"]),
            interposer_defect=arr(sysf["interposer_defect"]),
            interposer_area_factor=arr(sysf["interposer_area_factor"]),
            interposer_cluster=arr(sysf["interposer_cluster"]),
            y2_chip_bond=arr(sysf["y2_chip_bond"]),
            y3_substrate_bond=arr(sysf["y3_substrate_bond"]),
            assembly_yield=arr(sysf["assembly_yield"]),
            bond_cost_per_chip=arr(sysf["bond_cost_per_chip"]),
            quantity=arr(sysf["quantity"]),
            chip_entity_id=arr(chip_ids, _INT),
            chip_entity_area=arr(chip_rows[:, 0]),
            chip_entity_k=arr(chip_rows[:, 1]),
            chip_entity_fixed=arr(chip_rows[:, 2]),
            pkg_entity_id=arr(pkg_ids, _INT),
            pkg_entity_area=arr(pkg_rows[:, 0]),
            pkg_entity_k=arr(pkg_rows[:, 1]),
            pkg_entity_fixed=arr(pkg_rows[:, 2]),
            mod_sys=arr(mod_sys, _INT), mod_entity=arr(mod_ent, _INT),
            mod_entity_area=arr(mod_rows[:, 0]),
            mod_entity_k=arr(mod_rows[:, 1]),
            d2d_sys=arr(d2d_sys, _INT), d2d_entity=arr(d2d_ent, _INT),
            d2d_entity_nre=arr(d2d_ent_rows),
            names=tuple(s.name for s in systems),
        )

    @classmethod
    def from_arrays(cls, *, names: Tuple[str, ...] = (),
                    **leaves) -> "SystemBatch":
        """Array-native constructor: build a batch straight from its leaf
        arrays with no host-side packing.

        This is the zero-Python path the vectorized candidate encoder
        (:func:`repro.dse.space.encode_batch`) uses to assemble a batch
        *inside* a jit trace — every leaf may be a traced ``jnp`` value.
        All ``_LEAVES`` fields are required; axis sizes are
        cross-checked (shapes are static even under tracing) so a
        mis-assembled batch fails here rather than deep inside the
        engine's segment sums.
        """
        missing = [f for f in cls._LEAVES if f not in leaves]
        extra = [k for k in leaves if k not in cls._LEAVES]
        if missing or extra:
            raise ValueError(
                f"from_arrays: missing leaves {missing}, unknown {extra}")
        a = {k: jnp.asarray(v) for k, v in leaves.items()}
        if a["chip_area"].ndim != 2:
            raise ValueError("from_arrays: chip_area must be (N, C), got "
                             f"shape {a['chip_area'].shape}")
        n, c = a["chip_area"].shape
        checks = {}
        for k in ("chip_defect", "chip_wafer_cost", "chip_cluster",
                  "chip_wafer_yield", "chip_sort_cost", "chip_bump_cost",
                  "chip_mask", "chip_entity_id"):
            checks[k] = (n, c)
        for k in ("package_area", "package_area_factor", "substrate_cost",
                  "substrate_layer", "interposer_cost", "interposer_defect",
                  "interposer_area_factor", "interposer_cluster",
                  "y2_chip_bond", "y3_substrate_bond", "assembly_yield",
                  "bond_cost_per_chip", "quantity", "pkg_entity_id"):
            checks[k] = (n,)
        for grp in (("chip_entity_area", "chip_entity_k",
                     "chip_entity_fixed"),
                    ("pkg_entity_area", "pkg_entity_k", "pkg_entity_fixed"),
                    ("mod_entity_area", "mod_entity_k"),
                    ("d2d_entity_nre",),
                    ("mod_sys", "mod_entity"), ("d2d_sys", "d2d_entity")):
            if a[grp[0]].ndim != 1:
                raise ValueError(
                    f"from_arrays: {grp[0]} must be 1-D, got shape "
                    f"{a[grp[0]].shape}")
            for k in grp[1:]:
                checks[k] = a[grp[0]].shape
        for k, want in checks.items():
            if a[k].shape != tuple(want):
                raise ValueError(
                    f"from_arrays: {k} has shape {a[k].shape}, "
                    f"expected {tuple(want)}")
        return cls(**a, names=tuple(names))

    @classmethod
    def from_specs(cls, specs: Sequence[Mapping],
                   max_chips: Optional[int] = None,
                   share_nre: Union[bool, Sequence[int]] = False,
                   ) -> "SystemBatch":
        """Build a batch straight from declarative spec dicts.

        Specs without a ``name`` get a unique positional one.  Defaults to
        ``share_nre=False`` — spec sweeps are usually independent design
        points, not a co-produced group.
        """
        systems = []
        for i, d in enumerate(specs):
            d = dict(d)
            d.setdefault("name", f"sys{i}")
            systems.append(spec(d))
        return cls.from_systems(systems, max_chips=max_chips,
                                share_nre=share_nre)


SystemBatch._LEAVES = tuple(
    fld.name for fld in dataclasses.fields(SystemBatch)
    if fld.name != "names")


# ---------------------------------------------------------------------------
# Constant-shape padding — the enabler of chunked evaluation (repro.dse).
# ---------------------------------------------------------------------------

# Leaves whose cost-neutral padding value is 1.0, not 0.0 (yields and
# divisors that must stay benign for padded rows).
_PAD_ONE = frozenset({
    "chip_wafer_yield", "chip_cluster", "package_area_factor",
    "y2_chip_bond", "y3_substrate_bond", "assembly_yield",
    "interposer_cluster",
})


def pad_batch(b: SystemBatch, *, n_systems: Optional[int] = None,
              max_chips: Optional[int] = None,
              chip_entities: Optional[int] = None,
              pkg_entities: Optional[int] = None,
              mod_entities: Optional[int] = None,
              mod_instances: Optional[int] = None,
              d2d_entities: Optional[int] = None,
              d2d_instances: Optional[int] = None) -> SystemBatch:
    """Pad every axis of ``b`` to the requested sizes with cost-neutral rows.

    Padded systems have zero area, zero quantity and unit yields, so they
    price to zero RE and contribute nothing to any NRE amortization
    denominator (Eq. 6-8 shares of real systems are unchanged — pinned by
    ``tests/test_dse.py``).  Padded entity rows carry zero NRE; padded
    module/D2D instances point at a padded (zero) entity row, or at a
    padded (zero-quantity) system when no entity row was added.  Padding
    only ever grows an axis; shrinking raises ``ValueError``.

    The point: two batches padded to the same signature share one
    compiled :class:`~repro.core.engine.CostEngine` trace, which is how
    ``repro.dse.evaluate`` prices unbounded candidate streams through
    constant-shape chunks without retracing.
    """
    n0, c0 = b.chip_area.shape
    ec0 = b.chip_entity_area.shape[0]
    ep0 = b.pkg_entity_area.shape[0]
    em0 = b.mod_entity_area.shape[0]
    m0 = b.mod_sys.shape[0]
    ed0 = b.d2d_entity_nre.shape[0]
    d0 = b.d2d_sys.shape[0]
    tgt = {
        "n_systems": (n0, n0 if n_systems is None else int(n_systems)),
        "max_chips": (c0, c0 if max_chips is None else int(max_chips)),
        "chip_entities": (ec0, ec0 if chip_entities is None
                          else int(chip_entities)),
        "pkg_entities": (ep0, ep0 if pkg_entities is None
                         else int(pkg_entities)),
        "mod_entities": (em0, em0 if mod_entities is None
                         else int(mod_entities)),
        "mod_instances": (m0, m0 if mod_instances is None
                          else int(mod_instances)),
        "d2d_entities": (ed0, ed0 if d2d_entities is None
                         else int(d2d_entities)),
        "d2d_instances": (d0, d0 if d2d_instances is None
                          else int(d2d_instances)),
    }
    for k, (cur, want) in tgt.items():
        if want < cur:
            raise ValueError(f"pad_batch cannot shrink {k}: {cur} -> {want}")
    n1, c1 = tgt["n_systems"][1], tgt["max_chips"][1]
    ec1, ep1 = tgt["chip_entities"][1], tgt["pkg_entities"][1]
    em1, m1 = tgt["mod_entities"][1], tgt["mod_instances"][1]
    ed1, d1 = tgt["d2d_entities"][1], tgt["d2d_instances"][1]

    # A padded instance must park its NRE share somewhere harmless: a
    # padded zero-NRE entity row, else a padded zero-quantity system.
    if (m1 > m0 and em1 == em0 and n1 == n0) or \
       (d1 > d0 and ed1 == ed0 and n1 == n0):
        raise ValueError(
            "padding instances requires a padded entity row or a padded "
            "system to absorb them")

    def _np(x):
        return np.asarray(jax.device_get(x))

    def pad1(x, size, value=0.0):
        a = _np(x)
        return np.pad(a, (0, size - a.shape[0]), constant_values=value)

    def pad2(x, value=0.0):
        a = _np(x)
        return np.pad(a, ((0, n1 - n0), (0, c1 - c0)),
                      constant_values=value)

    out = {}
    for f in SystemBatch._LEAVES:
        a = getattr(b, f)
        val = 1.0 if f in _PAD_ONE else 0.0
        if f == "chip_entity_id":
            out[f] = pad2(a, 0)
        elif a.ndim == 2:
            out[f] = pad2(a, val)
        elif f == "pkg_entity_id":
            # padded systems point at a padded (zero-NRE) package entity
            # when one exists; entity 0 is safe regardless because padded
            # systems have quantity 0 (no denominator impact).
            out[f] = pad1(a, n1, ep0 if ep1 > ep0 else 0)
        elif f == "mod_sys":
            out[f] = pad1(a, m1, n0 if n1 > n0 else 0)
        elif f == "mod_entity":
            out[f] = pad1(a, m1, em0 if em1 > em0 else 0)
        elif f == "d2d_sys":
            out[f] = pad1(a, d1, n0 if n1 > n0 else 0)
        elif f == "d2d_entity":
            out[f] = pad1(a, d1, ed0 if ed1 > ed0 else 0)
        elif f in ("chip_entity_area", "chip_entity_k", "chip_entity_fixed"):
            out[f] = pad1(a, ec1)
        elif f in ("pkg_entity_area", "pkg_entity_k", "pkg_entity_fixed"):
            out[f] = pad1(a, ep1)
        elif f in ("mod_entity_area", "mod_entity_k"):
            out[f] = pad1(a, em1)
        elif f == "d2d_entity_nre":
            out[f] = pad1(a, ed1)
        else:                     # (N,) per-system float leaves
            out[f] = pad1(a, n1, val)
    names = b.names
    if names:
        names = tuple(names) + tuple(f"__pad{i}" for i in range(n1 - n0))
    return SystemBatch(**{k: jnp.asarray(v) for k, v in out.items()},
                       names=names)
