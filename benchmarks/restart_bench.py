"""Restart benchmark: SIGKILL a serving *process* mid-search, resume,
and prove recovery — the end-to-end durability oracle.

  PYTHONPATH=src python -m benchmarks.restart_bench [--fast]

The parent spawns a child interpreter (``--child``) that runs a
:class:`~repro.service.PricingService` with a durability directory and
submits one long search.  The parent polls the checkpoint tree until at
least ``--kill-after`` checkpoint steps have been published, then
SIGKILLs the child — a real process death, not an injected fault: no
atexit hooks, no flushes, whatever was mid-write stays mid-write.

It then recovers in-process over the same directory: a fresh service
rescans the journal, re-admits the orphaned search with replayed
provenance, restores the newest readable checkpoint, and finishes it.

Asserts (and writes BENCH_restart.json for
scripts/check_bench_regression.py):
  * ``search_bitexact`` — the recovered search's history AND ranking are
    bit-exact against the uninterrupted ``portfolio_search`` oracle
    (zero tolerance);
  * ``lost_requests`` — after recovery the journal holds no open
    admission: nothing the child acknowledged was silently dropped;
  * ``recovery_s`` — bounded restart-to-answer latency.
"""
import argparse
import asyncio
import os
import pathlib
import shutil
import signal
import subprocess
import sys
import tempfile
import time

import jax

from repro.dse import portfolio_search
from repro.service import (DurabilityConfig, PricingService,
                           RequestJournal, SearchRequest, SearchWarmup,
                           ServiceConfig)

from .common import REPO_ROOT, emit, write_bench_json
from .dse_bench import SPACE

SEED, POP, ELITE = 3, 16, 4


def _cfg(directory: pathlib.Path) -> ServiceConfig:
    return ServiceConfig(
        chunk=32, split=8,
        warm_search=(SearchWarmup(population=POP, elite=ELITE),),
        durability=DurabilityConfig(directory=directory,
                                    checkpoint_every=1),
        sigterm_drain=True)


def child(directory: str, generations: int) -> None:
    """The victim: serve one long search until killed."""
    async def _main():
        svc = PricingService(SPACE, _cfg(pathlib.Path(directory)))
        await svc.start()
        resp = await svc.submit(SearchRequest(
            seed=SEED, population=POP, generations=generations,
            elite=ELITE))
        await svc.stop()
        return resp

    resp = asyncio.run(_main())
    # Reaching this line means the parent never killed us — the run is
    # then meaningless, which the parent detects via our exit.
    print(f"# child finished unkilled: ok={resp.ok}")


def _published_steps(directory: pathlib.Path) -> int:
    root = directory / "checkpoints"
    if not root.exists():
        return 0
    return sum(1 for p in root.glob("search_*/step_*")
               if ".tmp-" not in p.name and (p / "manifest.json").exists())


def run(fast: bool = False, generations: int = 0, kill_after: int = 2,
        timeout_s: float = 180.0) -> dict:
    gens = generations or (300 if fast else 600)
    directory = pathlib.Path(tempfile.mkdtemp(prefix="repro_restart_"))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(REPO_ROOT / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    try:
        proc = subprocess.Popen(
            [sys.executable, "-m", "benchmarks.restart_bench", "--child",
             "--dir", str(directory), "--generations", str(gens)],
            cwd=REPO_ROOT, env=env)
        deadline = time.perf_counter() + timeout_s
        while True:
            if proc.poll() is not None:
                raise RuntimeError(
                    f"child exited (rc={proc.returncode}) before the kill"
                    f" — raise --generations (got {gens})")
            steps = _published_steps(directory)
            if steps >= kill_after:
                break
            if time.perf_counter() > deadline:
                proc.kill()
                proc.wait()
                raise RuntimeError(
                    f"no {kill_after} checkpoints within {timeout_s}s "
                    f"(saw {steps})")
            time.sleep(0.05)
        checkpoints_at_kill = steps
        proc.send_signal(signal.SIGKILL)
        proc.wait()

        # -- recovery: a fresh service over the same directory ----------
        async def _recover():
            svc = PricingService(SPACE, _cfg(directory))
            t0 = time.perf_counter()
            await svc.start()
            replayed = await svc.drain_replayed()
            recovery_s = time.perf_counter() - t0
            await svc.stop()
            return svc, replayed, recovery_s

        svc, replayed, recovery_s = asyncio.run(_recover())
        snap = svc.snapshot()["durability"]
        search_resp = next((r for r in replayed
                            if r.kind == "search" and r.ok), None)
        oracle = portfolio_search(SPACE, jax.random.PRNGKey(SEED),
                                  population=POP, generations=gens,
                                  elite=ELITE)
        bitexact = int(
            search_resp is not None and search_resp.replayed
            and search_resp.result.history == oracle.history
            and [c.label for c in search_resp.result.ranked]
            == [c.label for c in oracle.ranked])
        j = RequestJournal(_cfg(directory).durability.journal_dir)
        lost = len(j.replay())
        j.close()
    finally:
        shutil.rmtree(directory, ignore_errors=True)

    summary = {
        "generations": gens,
        "checkpoints_at_kill": checkpoints_at_kill,
        "child_killed": 1,
        "replayed": len(replayed),
        "checkpoints_restored": snap["checkpoints_restored"],
        "search_bitexact": bitexact,
        "lost_requests": lost,
        "recovery_s": recovery_s,
        "fast": fast,
        "survived": 1.0,
    }
    emit("restart: SIGKILL mid-search -> resume", [{
        "generations": gens, "ckpts_at_kill": checkpoints_at_kill,
        "replayed": len(replayed),
        "ckpt_restored": summary["checkpoints_restored"],
        "bitexact": bitexact, "lost": lost, "recovery_s": recovery_s}])
    write_bench_json("restart", summary)

    assert bitexact == 1, \
        "recovered search is not bit-exact vs the uninterrupted oracle"
    assert lost == 0, f"{lost} journaled requests were silently lost"
    assert snap["checkpoints_restored"] >= 1, \
        "recovery did not restore a checkpoint (resumed from scratch?)"
    print(f"# restart: killed child at {checkpoints_at_kill} checkpoints,"
          f" resumed {len(replayed)} request(s) bit-exact in "
          f"{recovery_s:.2f}s, 0 lost")
    return summary


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: shorter search")
    ap.add_argument("--child", action="store_true",
                    help="internal: run the to-be-killed serving process")
    ap.add_argument("--dir", default="",
                    help="durability directory (child mode)")
    ap.add_argument("--generations", type=int, default=0)
    ap.add_argument("--kill-after", type=int, default=2,
                    help="published checkpoint steps before SIGKILL")
    args = ap.parse_args()
    if args.child:
        if not args.dir or not args.generations:
            ap.error("--child needs --dir and --generations")
        child(args.dir, args.generations)
        return
    run(fast=args.fast, generations=args.generations,
        kill_after=args.kill_after)


if __name__ == "__main__":
    main()
