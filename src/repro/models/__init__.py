from . import api, attention, common, encdec, mla, moe, ssm, transformer, xlstm
