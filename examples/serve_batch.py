"""Continuous-batching serving example: submit a burst of requests to
the slot-based engine and print per-request outputs + latency stats.

  PYTHONPATH=src python examples/serve_batch.py
"""
import sys

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    sys.exit(serve_main([
        "--arch", "deepseek_7b", "--smoke",
        "--requests", "12", "--slots", "4",
        "--cache-len", "96", "--prompt-len", "12", "--max-new", "16",
    ]))
