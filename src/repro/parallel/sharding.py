"""Logical-axis sharding: rules mapping logical names -> mesh axes.

Parameters and activations are annotated with *logical* axis names
("embed", "mlp", "heads", "vocab", "experts", "batch", "seq", ...).
A :class:`AxisRules` table maps those to physical mesh axes.  The same
model code therefore runs on the single-pod (16,16) mesh, the two-pod
(2,16,16) mesh, a CPU smoke test (no mesh at all), or any future shape —
only the rules change.  This is MaxText-style GSPMD sharding.

Default rules implement FSDP("data") x TP("model") with EP on "model"
and the batch spread over ("pod","data") when a pod axis exists.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    table: Tuple[Tuple[str, MeshAxes], ...]

    def get(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        for k, v in self.table:
            if k == logical:
                return v
        return None

    def spec(self, axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None,
             mesh: Optional[Mesh] = None) -> P:
        """PartitionSpec for logical `axes`.

        With `shape` and `mesh` given, any mapping whose mesh-axis product
        does not evenly divide the dimension falls back to replication
        (dropping mesh axes from the left, e.g. ("pod","data")->("data",))
        — tiny dims (4 heads, batch 1) must not break lowering.
        """
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
        phys, used = [], set()
        for i, a in enumerate(axes):
            m = self.get(a)
            if m is None:
                phys.append(None)
                continue
            ms = (m,) if isinstance(m, str) else tuple(m)
            ms = tuple(x for x in ms if x not in used)
            if shape is not None and sizes:
                dim = shape[i]
                while ms:
                    prod = 1
                    for x in ms:
                        prod *= sizes[x]
                    if prod and dim % prod == 0:
                        break
                    ms = ms[1:]
            used.update(ms)
            phys.append(ms if len(ms) > 1 else (ms[0] if ms else None))
        return P(*phys)


def default_rules(multi_pod: bool = False, *, seq_shard_decode: bool = True,
                  act_shard: str = "seq") -> AxisRules:
    """FSDP(data) x TP(model); pod axis extends the data/batch dimension.

    act_shard="seq": Megatron-SP — the residual stream is sharded along
    sequence over the tensor axis (all-gather before attention/FFN,
    reduce-scatter after).  act_shard="batch2d": the batch axis spreads
    over BOTH mesh axes instead (needs global_batch % 256 == 0).
    Both cut the remat-carry memory by |model|; they differ in which
    collectives the backward pass pays.
    """
    if act_shard == "batch2d":
        batch = ("pod", "data", "model") if multi_pod \
            else ("data", "model")
        seq = None
    else:
        batch = ("pod", "data") if multi_pod else ("data",)
        seq = "model"
    table = [
        ("batch", batch),
        ("seq", seq),
        ("embed", "data"),          # FSDP: weight d_model axis over data
        ("mlp", "model"),
        ("heads", "model"),
        # kv heads: when batch occupies "data" (or kv doesn't divide) the
        # per-tensor fallback replicates, as before; for batch=1 decode
        # (long_500k) the idle data axis shards the kv heads instead.
        ("kv", "data"),
        ("vocab", "model"),
        ("experts", "model"),
        ("layers", None),
        ("kv_seq", "model" if seq_shard_decode else None),  # decode cache seq
        ("act_embed", None),        # activations' d_model axis
    ]
    return AxisRules(table=tuple(table))


# --------------------------------------------------------------------------
# Thread-local active (mesh, rules) context used by `constrain`.
# --------------------------------------------------------------------------

_ctx = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[AxisRules]):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules) if mesh is not None else None
    try:
        yield
    finally:
        _ctx.state = prev


def active() -> Optional[Tuple[Mesh, AxisRules]]:
    return getattr(_ctx, "state", None)


def constrain(x, *logical: Optional[str]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    st = active()
    if st is None:
        return x
    mesh, rules = st
    spec = rules.spec(logical, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def sharding_for(axes: Sequence[Optional[str]], mesh: Mesh,
                 rules: AxisRules,
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(axes, shape=shape, mesh=mesh))
