"""Paper Fig. 4: normalized RE cost across integrations x nodes x
chiplet counts (all normalized to the 100 mm^2 SoC of each node)."""
from repro.core import re_cost, soc_system, split_system
from .common import emit


def run():
    rows = []
    for node in ("14nm", "7nm", "5nm"):
        base = re_cost(soc_system("base", 100.0, node)).total
        for area in (300.0, 500.0, 800.0, 900.0):
            soc = re_cost(soc_system("s", area, node))
            rows.append({
                "node": node, "area_mm2": area, "integration": "SoC",
                "n_chiplets": 1, "total_norm": soc.total / base,
                "die_defects_norm": soc.chip_defects / base,
                "packaging_norm": soc.packaging_cost / base,
            })
            for integ in ("MCM", "InFO", "2.5D"):
                for n in (2, 3, 5):
                    br = re_cost(split_system("m", area, node, n, integ))
                    rows.append({
                        "node": node, "area_mm2": area,
                        "integration": integ, "n_chiplets": n,
                        "total_norm": br.total / base,
                        "die_defects_norm": br.chip_defects / base,
                        "packaging_norm": br.packaging_cost / base,
                    })
    emit("fig4_re_cost_normalized", rows)
    return rows


if __name__ == "__main__":
    run()
