"""MoE dispatch and MLA decode-absorption correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep, see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.models.common import init_params
from repro.models import moe as moe_mod
from repro.models import mla as mla_mod

KEY = jax.random.PRNGKey(7)


def _moe_params(d=32, e=8, dff=16, shared=1, key=KEY):
    return init_params(moe_mod.moe_spec(d, e, dff, shared), key)


def test_moe_matches_dense_mixture_when_capacity_ample():
    p = _moe_params()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out = moe_mod.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    want = moe_mod.moe_ref(p, x, top_k=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_moe_dispatch_property(seed, top_k):
    """Property: with capacity >= N*k no token drops; output == mixture."""
    p = _moe_params(key=jax.random.PRNGKey(seed % 1000))
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 24, 32))
    out = moe_mod.moe_apply(p, x, top_k=top_k, capacity_factor=float(8))
    want = moe_mod.moe_ref(p, x, top_k=top_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_moe_capacity_drops_are_bounded_not_catastrophic():
    p = _moe_params()
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 32))
    tight = moe_mod.moe_apply(p, x, top_k=2, capacity_factor=0.5)
    ample = moe_mod.moe_apply(p, x, top_k=2, capacity_factor=8.0)
    # dropped tokens fall back to the shared expert only: finite, smaller
    assert np.isfinite(np.asarray(tight)).all()
    assert float(jnp.abs(tight).mean()) <= \
        float(jnp.abs(ample).mean()) * 1.5


def test_moe_aux_loss_positive_and_uniform_minimizes():
    probs = jnp.full((128, 8), 1 / 8)
    ids = jnp.tile(jnp.arange(8), 32).reshape(128, 2)
    aux_uniform = moe_mod.aux_load_balance_loss(probs, ids, 8)
    skew = jnp.zeros((128, 8)).at[:, 0].set(1.0)
    ids_skew = jnp.zeros((128, 2), jnp.int32)
    aux_skew = moe_mod.aux_load_balance_loss(skew, ids_skew, 8)
    assert float(aux_skew) > float(aux_uniform)
    assert float(aux_uniform) == pytest.approx(1.0, rel=0.3)


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------


def _mla_params(d=64, h=4, key=KEY):
    return init_params(
        mla_mod.mla_spec(d, h, q_lora=32, kv_lora=16, qk_nope=8,
                         qk_rope=8, v_head=16), key), d, h


def test_mla_decode_absorption_matches_full_attention():
    """The compressed-cache decode must equal decompressed attention."""
    p, d, h = _mla_params()
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(3), (b, s + 1, d))
    pos = jnp.broadcast_to(jnp.arange(s + 1), (b, s + 1))
    full = mla_mod.mla_layer(p, x, pos, impl="full")
    want = full[:, -1]

    # build the compressed cache from the first s tokens
    ckv, krope = mla_mod.mla_compress_kv(p, x[:, :s],
                                         pos[:, :s], 10000.0, 16)
    t = s + 4
    cache_ckv = jnp.zeros((b, t, 16)).at[:, :s].set(ckv)
    cache_krope = jnp.zeros((b, t, 8)).at[:, :s].set(krope)
    kv_len = jnp.full((b,), s, jnp.int32)
    got, _, _ = mla_mod.mla_decode_layer(p, x[:, s:s + 1], cache_ckv,
                                         cache_krope, kv_len, kv_len)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_mla_chunked_matches_full():
    p, d, h = _mla_params()
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, d))
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    a = mla_mod.mla_layer(p, x, pos, impl="full")
    b_ = mla_mod.mla_layer(p, x, pos, impl="chunked", chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5,
                               rtol=2e-5)


def test_mla_cache_is_actually_compressed():
    """The decode cache stores kv_lora + qk_rope floats per token —
    independent of head count (the paper-level MLA claim)."""
    p, d, h = _mla_params()
    per_token = 16 + 8                       # kv_lora + qk_rope
    dense_equiv = h * (8 + 8 + 16)           # per-head k_nope+k_rope+v
    assert per_token < dense_equiv / 3
