"""Shared helpers for the benchmark suite (CSV emission + timing)."""
from __future__ import annotations

import time
from typing import Callable, Iterable


def emit(section: str, rows: Iterable[dict]):
    rows = list(rows)
    if not rows:
        print(f"# {section}: (no rows)")
        return
    cols = list(rows[0].keys())
    print(f"# {section}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))
    print()


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def timed(fn: Callable, *args, repeat: int = 3):
    fn(*args)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6           # us per call
