"""NRE (non-recurring engineering) cost model — paper Sec. 3.3, Eqs. (6)-(8).

Design entities (modules, chip designs, package designs, D2D interfaces)
are identified by name: an entity appearing in several systems is designed
once and its NRE is amortized over every unit that uses it —

    per-unit share of entity e in system j =
        NRE_e * n_{j,e} / sum_j' quantity_j' * n_{j',e}

This single rule specializes to Eq. (7) (module reuse only: each SoC die is
its own chip design) and Eq. (8) (chiplet reuse: chips shared across
systems), and also covers package reuse (Sec. 5.1/5.2).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Sequence

from .re_cost import REBreakdown, re_cost
from .system import System
from .technology import node


@dataclasses.dataclass
class NREEntities:
    """Group-level NRE, itemized by design entity kind (USD)."""

    modules: Dict[str, float]
    chips: Dict[str, float]      # chip-level only (K_c*S_c + C), Eq. (6)
    packages: Dict[str, float]
    d2d: Dict[str, float]        # per process node

    @property
    def total(self) -> float:
        return (sum(self.modules.values()) + sum(self.chips.values())
                + sum(self.packages.values()) + sum(self.d2d.values()))


def group_nre(systems: Sequence[System]) -> NREEntities:
    """Total NRE of a group of systems with entity de-duplication."""
    modules: Dict[str, float] = {}
    chips: Dict[str, float] = {}
    packages: Dict[str, float] = {}
    d2d: Dict[str, float] = {}

    for s in systems:
        t = s.tech
        # package design NRE: K_p * S_p + C_p
        packages.setdefault(
            s.package_id,
            t.nre_package_per_mm2 * s.package_area + t.nre_fixed_per_package)
        for c in s.chips:
            n = c.node
            for m in c.modules:
                if m.is_d2d:
                    # D2D interface: one design effort per process node.
                    d2d.setdefault(m.process, node(m.process).nre_d2d)
                else:
                    modules.setdefault(m.name, m.node.nre_module_per_mm2 * m.area_mm2)
            # chip-level NRE (physical design + system verification + masks)
            chips.setdefault(c.name, n.nre_chip_per_mm2 * c.area_mm2
                             + n.nre_fixed_per_chip)
    return NREEntities(modules=modules, chips=chips, packages=packages, d2d=d2d)


@dataclasses.dataclass
class UnitCost:
    """Amortized per-unit cost of one system within a group."""

    system: str
    re: REBreakdown
    nre_modules: float
    nre_chips: float
    nre_packages: float
    nre_d2d: float

    @property
    def nre_total(self) -> float:
        return self.nre_modules + self.nre_chips + self.nre_packages + self.nre_d2d

    @property
    def total(self) -> float:
        return self.re.total + self.nre_total

    def as_dict(self) -> Dict[str, float]:
        d = self.re.as_dict()
        d = {f"re_{k}": v for k, v in d.items()}
        d.update(nre_modules=self.nre_modules, nre_chips=self.nre_chips,
                 nre_packages=self.nre_packages, nre_d2d=self.nre_d2d,
                 total=self.total)
        return d


def _uses(systems: Sequence[System]):
    """Count per-system and total uses of every shared design entity."""
    mod_uses = defaultdict(float); chip_uses = defaultdict(float)
    pkg_uses = defaultdict(float); d2d_uses = defaultdict(float)
    per_system: Dict[str, dict] = {}
    for s in systems:
        counts = {"modules": defaultdict(int), "chips": defaultdict(int),
                  "packages": defaultdict(int), "d2d": defaultdict(int)}
        counts["packages"][s.package_id] += 1
        for c in s.chips:
            counts["chips"][c.name] += 1
            for m in c.modules:
                if m.is_d2d:
                    counts["d2d"][m.process] += 1
                else:
                    counts["modules"][m.name] += 1
        per_system[s.name] = counts
        for k, v in counts["modules"].items():
            mod_uses[k] += v * s.quantity
        for k, v in counts["chips"].items():
            chip_uses[k] += v * s.quantity
        for k, v in counts["packages"].items():
            pkg_uses[k] += v * s.quantity
        for k, v in counts["d2d"].items():
            d2d_uses[k] += v * s.quantity
    return per_system, mod_uses, chip_uses, pkg_uses, d2d_uses


def amortized_costs(systems: Sequence[System],
                    flow: str = "chip-last") -> Dict[str, UnitCost]:
    """Per-unit RE + amortized-NRE cost for every system in the group."""
    names = [s.name for s in systems]
    if len(set(names)) != len(names):
        raise ValueError("system names must be unique within a group")
    ent = group_nre(systems)
    per_system, mod_uses, chip_uses, pkg_uses, d2d_uses = _uses(systems)

    out: Dict[str, UnitCost] = {}
    for s in systems:
        cnt = per_system[s.name]
        nre_m = sum(ent.modules[k] * v / mod_uses[k]
                    for k, v in cnt["modules"].items())
        nre_c = sum(ent.chips[k] * v / chip_uses[k]
                    for k, v in cnt["chips"].items())
        nre_p = sum(ent.packages[k] * v / pkg_uses[k]
                    for k, v in cnt["packages"].items())
        nre_d = sum(ent.d2d[k] * v / d2d_uses[k]
                    for k, v in cnt["d2d"].items())
        out[s.name] = UnitCost(system=s.name, re=re_cost(s, flow=flow),
                               nre_modules=nre_m, nre_chips=nre_c,
                               nre_packages=nre_p, nre_d2d=nre_d)
    return out
