"""Unified, batchable cost engine — one jnp implementation of Eqs. (4)-(8).

:class:`CostEngine` evaluates the paper's full RE model (five-way
breakdown, both chip-last and chip-first flows) and NRE amortization for a
whole :class:`~repro.core.batch.SystemBatch` of *heterogeneous* systems in
a single jit trace.  It subsumes the old ``re_cost_split`` jnp kernel
(which only handled homogeneous even splits and hardcoded a 0.99 wafer
yield) and mirrors the scalar reference path ``re_cost.re_cost`` exactly —
``tests/test_engine.py`` pins the two to 1e-5 relative parity.

The shared primitives (:func:`silicon_unit_costs`,
:func:`package_flow_terms`) are also the building blocks of the
continuous-relaxation kernel in :mod:`repro.core.gradient`, so every
consumer of the model now draws on one source of truth for wafer yield,
sort/bump costs and the flow formulas.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..obs import jaxhooks
from ..obs.registry import TraceCounts
from .batch import SystemBatch
from .re_cost import REBreakdown
from .yield_model import dies_per_wafer, raw_die_cost, yield_negative_binomial

_EPS = 1e-30

# Python-body execution counter: increments only when jax actually traces,
# so benchmarks/tests can assert a sweep compiled exactly once.  The
# TraceCounts shim is a collections.Counter that additionally mirrors
# every increment into the repro.obs metrics registry (trace_* counters).
TRACE_COUNTS: TraceCounts = TraceCounts()


# ---------------------------------------------------------------------------
# Shared primitives (Eq. 2 silicon terms, Eq. 4/5 flow terms)
# ---------------------------------------------------------------------------


def silicon_unit_costs(area_mm2, wafer_cost, defect_density, cluster,
                       wafer_yield, sort_cost, bump_cost):
    """Per-die (raw, defect overhead, KGD, die yield) — Eqs. (1)-(2).

    Matches ``re_cost.chip_costs``: sort and bump are folded into the raw
    die and the die yield includes the per-node wafer yield.
    """
    dpw = dies_per_wafer(area_mm2)
    raw = raw_die_cost(area_mm2, wafer_cost) + sort_cost / dpw \
        + bump_cost * area_mm2
    y_die = yield_negative_binomial(area_mm2, defect_density,
                                    cluster) * wafer_yield
    kgd = raw / y_die
    return raw, kgd - raw, kgd, y_die


def package_flow_terms(flow: str, *, c_interposer, y1, c_substrate, c_bond,
                       kgd_total, y2n, y3):
    """(raw_package, package_defects, wasted_kgd) under one flow — Eq. (4)/(5)."""
    raw_package = c_interposer + c_substrate + c_bond
    if flow == "chip-last":
        package_defects = (c_interposer * (1.0 / (y1 * y2n * y3) - 1.0)
                           + (c_substrate + c_bond) * (1.0 / y3 - 1.0))
        wasted_kgd = kgd_total * (1.0 / (y2n * y3) - 1.0)
    elif flow == "chip-first":
        y_all = y1 * y2n * y3
        package_defects = raw_package * (1.0 / y_all - 1.0)
        wasted_kgd = kgd_total * (1.0 / y_all - 1.0)
    else:
        raise ValueError(f"unknown flow {flow!r}")
    return raw_package, package_defects, wasted_kgd


# ---------------------------------------------------------------------------
# Batched RE / NRE implementations
# ---------------------------------------------------------------------------


def _re_impl(b: SystemBatch, flow: str) -> REBreakdown:
    TRACE_COUNTS["re"] += 1
    mask = b.chip_mask
    raw, defect, kgd, _ = silicon_unit_costs(
        b.chip_area, b.chip_wafer_cost, b.chip_defect, b.chip_cluster,
        b.chip_wafer_yield, b.chip_sort_cost, b.chip_bump_cost)
    raw_chips = (raw * mask).sum(-1)
    chip_defects = (defect * mask).sum(-1)
    kgd_total = (kgd * mask).sum(-1)
    n_chips = mask.sum(-1)

    # Interposer sized for the package *design*'s silicon capacity (Sec. 5.1:
    # a reused oversized package pays its full interposer).
    design_silicon = b.package_area / b.package_area_factor
    int_area = design_silicon * b.interposer_area_factor
    c_interposer = int_area * b.interposer_cost
    y1 = jnp.where(
        b.interposer_area_factor > 0.0,
        yield_negative_binomial(int_area, b.interposer_defect,
                                b.interposer_cluster),
        1.0)
    c_substrate = b.package_area * b.substrate_cost * b.substrate_layer
    c_bond = b.bond_cost_per_chip * n_chips
    y2n = b.y2_chip_bond ** n_chips
    y3 = b.y3_substrate_bond * b.assembly_yield

    raw_package, package_defects, wasted_kgd = package_flow_terms(
        flow, c_interposer=c_interposer, y1=y1, c_substrate=c_substrate,
        c_bond=c_bond, kgd_total=kgd_total, y2n=y2n, y3=y3)
    return REBreakdown(raw_chips=raw_chips, chip_defects=chip_defects,
                       raw_package=raw_package,
                       package_defects=package_defects,
                       wasted_kgd=wasted_kgd)


@dataclasses.dataclass
class NREBreakdown:
    """Per-unit amortized NRE of every system in a batch (array fields)."""

    modules: jnp.ndarray
    chips: jnp.ndarray
    packages: jnp.ndarray
    d2d: jnp.ndarray

    @property
    def total(self):
        return self.modules + self.chips + self.packages + self.d2d

    def as_dict(self) -> Dict[str, jnp.ndarray]:
        return {"nre_modules": self.modules, "nre_chips": self.chips,
                "nre_packages": self.packages, "nre_d2d": self.d2d,
                "nre_total": self.total}


def _nre_impl(b: SystemBatch) -> NREBreakdown:
    TRACE_COUNTS["nre"] += 1
    q = b.quantity
    n_sys = b.chip_area.shape[0]

    # Chip designs: per-use share = NRE_e / sum_j q_j * n_{j,e}  (Eq. 8).
    chip_nre = b.chip_entity_k * b.chip_entity_area + b.chip_entity_fixed
    flat_id = b.chip_entity_id.reshape(-1)
    flat_q = (q[:, None] * b.chip_mask).reshape(-1)
    denom = jax.ops.segment_sum(flat_q, flat_id,
                                num_segments=b.chip_entity_area.shape[0])
    share = chip_nre / jnp.maximum(denom, _EPS)
    chips = (share[b.chip_entity_id] * b.chip_mask).sum(-1)

    # Package designs (one instance per system).
    pkg_nre = b.pkg_entity_k * b.pkg_entity_area + b.pkg_entity_fixed
    pdenom = jax.ops.segment_sum(q, b.pkg_entity_id,
                                 num_segments=b.pkg_entity_area.shape[0])
    packages = (pkg_nre / jnp.maximum(pdenom, _EPS))[b.pkg_entity_id]

    # Modules (Eq. 7) and D2D interfaces: flat instance lists.
    if b.mod_sys.shape[0]:
        mod_nre = b.mod_entity_k * b.mod_entity_area
        mdenom = jax.ops.segment_sum(q[b.mod_sys], b.mod_entity,
                                     num_segments=b.mod_entity_area.shape[0])
        per_inst = (mod_nre / jnp.maximum(mdenom, _EPS))[b.mod_entity]
        modules = jax.ops.segment_sum(per_inst, b.mod_sys,
                                      num_segments=n_sys)
    else:
        modules = jnp.zeros((n_sys,), q.dtype)
    if b.d2d_sys.shape[0]:
        ddenom = jax.ops.segment_sum(q[b.d2d_sys], b.d2d_entity,
                                     num_segments=b.d2d_entity_nre.shape[0])
        per_inst = (b.d2d_entity_nre / jnp.maximum(ddenom, _EPS))[b.d2d_entity]
        d2d = jax.ops.segment_sum(per_inst, b.d2d_sys, num_segments=n_sys)
    else:
        d2d = jnp.zeros((n_sys,), q.dtype)
    return NREBreakdown(modules=modules, chips=chips, packages=packages,
                        d2d=d2d)


@dataclasses.dataclass
class TotalCost:
    """RE + amortized NRE for a batch; all fields array-valued."""

    re: REBreakdown
    nre: NREBreakdown

    @property
    def total(self):
        return self.re.total + self.nre.total


def _total_impl(b: SystemBatch, flow: str) -> TotalCost:
    TRACE_COUNTS["total"] += 1
    return TotalCost(re=_re_impl(b, flow), nre=_nre_impl(b))


def portfolio_totals(unit_totals, quantities):
    """Reduce per-unit totals to per-group portfolio costs in-graph.

    ``unit_totals`` is ``(K * S,)`` or ``(K, S)`` per-unit costs of K
    groups of S systems each (e.g. K candidate portfolios of S SKUs);
    ``quantities`` is the ``(S,)`` production volume of each group
    member.  Returns ``(K,)`` USD totals — the portfolio-reduction stage
    of the fused decode->price->rank pipeline in :mod:`repro.dse`.
    """
    q = jnp.asarray(quantities)
    u = jnp.asarray(unit_totals).reshape(-1, q.shape[0])
    return (u * q[None, :]).sum(-1)


def finite_rows(*arrays) -> jnp.ndarray:
    """(K,) bool mask: True where every given per-row output is finite.

    Each array is ``(K,)`` or ``(K, ...)`` (trailing axes are reduced).
    This is the in-graph numerical guardrail the fused chunk kernels
    append to their outputs: one cheap reduction per tick lets the
    service fail exactly the rows whose cost math produced NaN/Inf —
    with a typed ``numerical_error`` — instead of silently returning
    garbage or failing the whole coalesced tick.
    """
    mask = None
    for a in arrays:
        m = jnp.isfinite(a)
        if m.ndim > 1:
            m = m.reshape(m.shape[0], -1).all(-1)
        mask = m if mask is None else mask & m
    return mask


def _register(cls, fields: Tuple[str, ...]):
    jax.tree_util.register_pytree_node(
        cls,
        lambda x: (tuple(getattr(x, f) for f in fields), None),
        lambda _, ch: cls(*ch))


_register(REBreakdown, ("raw_chips", "chip_defects", "raw_package",
                        "package_defects", "wasted_kgd"))
_register(NREBreakdown, ("modules", "chips", "packages", "d2d"))
_register(TotalCost, ("re", "nre"))

# Module-level jitted entry points so every CostEngine instance shares one
# compilation cache (same batch shapes => exactly one trace).  Each is
# wrapped in an obs probe that attributes per-signature compile vs
# dispatch wall when tracing is enabled (a transparent passthrough when
# it is not — see repro.obs.jaxhooks).
_RE_JIT = jaxhooks.instrument(
    jax.jit(_re_impl, static_argnames=("flow",)), "engine.re",
    trace_key="re", counts=TRACE_COUNTS)
_NRE_JIT = jaxhooks.instrument(
    jax.jit(_nre_impl), "engine.nre", trace_key="nre", counts=TRACE_COUNTS)
_TOTAL_JIT = jaxhooks.instrument(
    jax.jit(_total_impl, static_argnames=("flow",)), "engine.total",
    trace_key="total", counts=TRACE_COUNTS)


def re_split_relaxed(module_area_mm2, n_chiplets, *, wafer_cost,
                     defect_density, cluster, tech_params, wafer_yield=0.99,
                     sort_cost=0.0, bump_cost=0.0, d2d_overhead=None,
                     interposer_cluster=3.0, flow: str = "chip-last"):
    """Continuous-relaxation RE total for an even n-way split.

    ``n_chiplets`` may be a traced float — this is the differentiable
    kernel behind :func:`repro.core.gradient.optimize_chiplet_count`.
    Built from the same primitives as :class:`CostEngine` (one source of
    truth: real wafer yield, sort/bump folded in, Eq. 4/5 flow terms);
    the old standalone ``re_cost_split`` math is gone.  Returns a dict of
    jnp scalars matching ``REBreakdown`` fields plus ``total``.
    """
    t = tech_params
    ovh = t.d2d_area_overhead if d2d_overhead is None else d2d_overhead
    n = jnp.asarray(n_chiplets, jnp.float32)
    chip_area = module_area_mm2 / n
    is_multi = n > 1.0
    chip_area = chip_area * jnp.where(is_multi, 1.0 / (1.0 - ovh), 1.0)
    silicon = chip_area * n

    raw1, defect1, kgd1, _ = silicon_unit_costs(
        chip_area, wafer_cost, defect_density, cluster, wafer_yield,
        sort_cost, bump_cost)
    raw_chips = raw1 * n
    chip_defects = defect1 * n
    kgd_total = kgd1 * n

    interposer_area = silicon * t.interposer_area_factor
    c_interposer = interposer_area * t.interposer_cost_per_mm2
    y1 = jnp.where(
        t.interposer_area_factor > 0,
        yield_negative_binomial(interposer_area, t.interposer_defect_density,
                                interposer_cluster),
        1.0)
    c_substrate = (silicon * t.package_area_factor * t.substrate_cost_per_mm2
                   * t.substrate_layer_factor)
    c_bond = t.bond_cost_per_chip * n
    y2n = t.y2_chip_bond ** n
    y3 = t.y3_substrate_bond * t.assembly_yield

    raw_package, package_defects, wasted_kgd = package_flow_terms(
        flow, c_interposer=c_interposer, y1=y1, c_substrate=c_substrate,
        c_bond=c_bond, kgd_total=kgd_total, y2n=y2n, y3=y3)
    total = (raw_chips + chip_defects + raw_package + package_defects
             + wasted_kgd)
    return {"raw_chips": raw_chips, "chip_defects": chip_defects,
            "raw_package": raw_package, "package_defects": package_defects,
            "wasted_kgd": wasted_kgd, "total": total}


class CostEngine:
    """Single entry point for the batched cost model.

    >>> batch = SystemBatch.from_specs([
    ...     {"kind": "soc", "area": 800.0, "process": "5nm"},
    ...     {"kind": "split", "area": 800.0, "process": "5nm", "n": 3,
    ...      "integration": "MCM"},
    ... ])
    >>> engine = CostEngine()
    >>> engine.re(batch).total          # (2,) RE totals
    >>> engine.total(batch).total       # (2,) RE + amortized NRE

    All methods are jit-compiled over the whole batch; pass ``jit=False``
    to run the un-jitted implementation (e.g. under an outer ``grad``
    with replaced traced leaves).
    """

    def __init__(self, flow: str = "chip-last"):
        self.flow = flow

    def re(self, batch: SystemBatch, flow: str = None,
           jit: bool = True) -> REBreakdown:
        """Itemized RE breakdown, Eqs. (4)-(5); fields are (N,) arrays."""
        f = self.flow if flow is None else flow
        return (_RE_JIT if jit else _re_impl)(batch, f)

    def nre(self, batch: SystemBatch, jit: bool = True) -> NREBreakdown:
        """Per-unit amortized NRE with entity dedup, Eqs. (6)-(8)."""
        return (_NRE_JIT if jit else _nre_impl)(batch)

    def total(self, batch: SystemBatch, flow: str = None,
              jit: bool = True) -> TotalCost:
        """RE + amortized NRE per unit for every system in the batch."""
        f = self.flow if flow is None else flow
        return (_TOTAL_JIT if jit else _total_impl)(batch, f)

    def as_rows(self, batch: SystemBatch, flow: str = None) -> List[Dict]:
        """Host-side list of per-system dicts (benchmark/report helper)."""
        tc = jax.device_get(self.total(batch, flow=flow))
        # names are dropped by tree transforms (they're not pytree data);
        # fall back to positional labels rather than emitting zero rows
        names = batch.names or tuple(f"sys{i}" for i in range(len(batch)))
        rows = []
        for i, name in enumerate(names):
            row = {"system": name}
            row.update({k: float(v[i]) for k, v in tc.re.as_dict().items()
                        if k != "total"})
            row["re_total"] = float(tc.re.total[i])
            row.update({k: float(v[i]) for k, v in tc.nre.as_dict().items()})
            row["total"] = float(tc.total[i])
            rows.append(row)
        return rows

    @staticmethod
    def trace_counts() -> Dict[str, int]:
        """How many times each implementation has been (re)traced."""
        return dict(TRACE_COUNTS)
