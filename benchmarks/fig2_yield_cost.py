"""Paper Fig. 2: yield-area and cost-area relations per process node."""
import jax.numpy as jnp

from repro.core import cost_area_curve
from .common import emit


def run():
    areas = jnp.asarray([25, 50, 100, 200, 400, 600, 800], jnp.float32)
    rows = []
    for node in ("28nm", "14nm", "10nm", "7nm", "5nm"):
        c = cost_area_curve(node, areas)
        for i, a in enumerate(areas):
            rows.append({
                "node": node, "area_mm2": float(a),
                "yield": float(c["yield"][i]),
                "norm_cost_per_area": float(c["norm_cost_per_area"][i]),
            })
    emit("fig2_yield_cost_vs_area", rows)
    # headline check: 5nm 800mm2 die yields poorly and costs >2x per mm2
    c5 = cost_area_curve("5nm", jnp.asarray([800.0]))
    assert float(c5["yield"][0]) < 0.5
    return rows


if __name__ == "__main__":
    run()
