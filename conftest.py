"""Root pytest config: put ``src/`` on sys.path so a bare ``pytest`` /
``python -m pytest`` collects without the manual ``PYTHONPATH=src``
prefix (the tier-1 invocation keeps working unchanged)."""
import pathlib
import sys

_SRC = str(pathlib.Path(__file__).resolve().parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
