"""Pipeline parallelism via shard_map + collective-permute.

GPipe-style microbatched pipeline over a dedicated mesh axis: each
stage owns a slice of the stacked per-stage params; activations flow
stage -> stage+1 through ``lax.ppermute`` while every stage computes its
current microbatch — compute and the permute overlap inside one scan
tick (the classic fill/steady/drain schedule, M + S - 1 ticks total).

This is the "pod" -axis scale-out alternative to pure data parallelism:
cross-pod links carry ONE activation tensor per tick instead of a full
gradient all-reduce.  Used by tests/test_multidevice.py (8 fake devices)
and available to the trainer via --pipeline.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(stage_fn: Callable, mesh: Mesh, axis: str = "stage"):
    """Build fn(stacked_params, microbatches) -> outputs.

    stage_fn(params_slice, x) -> y      one stage's compute
    stacked_params: leaves (S, ...)     stage-sharded on `axis`
    microbatches:   (M, mb, ...)        replicated input
    returns         (M, mb, ...)        outputs from the last stage
    """
    n_stages = mesh.shape[axis]

    def run(params, xs):
        m = xs.shape[0]

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P()), out_specs=P(),
            check_rep=False)
        def inner(local_params, xs_local):
            # local_params leaves: (1, ...) slice for this stage
            lp = jax.tree_util.tree_map(lambda t: t[0], local_params)
            stage = jax.lax.axis_index(axis)
            ticks = m + n_stages - 1
            buf = jnp.zeros_like(xs_local[0])

            def tick(carry, t):
                buf, outs = carry
                # stage 0 injects microbatch t (if in range)
                inject = jnp.where(t < m, t, m - 1)
                x0 = xs_local[inject]
                x_in = jnp.where(stage == 0, x0, buf)
                y = stage_fn(lp, x_in)
                # pass to next stage (ring permute; last->0 discarded)
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                buf_next = jax.lax.ppermute(y, axis, perm)
                # last stage emits microbatch t - (S-1)
                out_idx = t - (n_stages - 1)
                emit = (stage == n_stages - 1) & (out_idx >= 0)
                outs = jnp.where(
                    emit,
                    outs.at[jnp.maximum(out_idx, 0)].set(y),
                    outs)
                return (buf_next, outs), None

            outs0 = jnp.zeros_like(xs_local)
            (_, outs), _ = jax.lax.scan(tick, (buf, outs0),
                                        jnp.arange(ticks))
            # only the last stage holds real outputs; broadcast them
            outs = jax.lax.psum(
                jnp.where(stage == n_stages - 1, outs, 0.0), axis)
            return outs

        return inner(params, xs)

    return run


def mlp_stage(params, x):
    """Reference stage for tests: y = tanh(x @ w1) @ w2."""
    return jnp.tanh(x @ params["w1"]) @ params["w2"]
