"""Cost-model system behaviour: module/chip/package algebra + RE/NRE."""
import pytest

from repro.core import (Module, System, amortized_costs, chip_costs,
                        d2d_module, group_nre, make_chip, re_cost,
                        soc_system, split_system)


def test_module_chip_package_algebra():
    m = Module("cpu", 100.0, "7nm")
    chip = make_chip("die", [m], "7nm", integration="MCM")
    # D2D module attached automatically at the tech's 10% share
    assert any(mod.is_d2d for mod in chip.modules)
    assert chip.area_mm2 == pytest.approx(100.0 / 0.9, rel=1e-6)
    assert chip.module_area_mm2 == pytest.approx(100.0)
    sys_ = System("s", (chip, chip), "MCM", quantity=1e6)
    assert sys_.n_chips == 2
    assert sys_.silicon_area_mm2 == pytest.approx(2 * chip.area_mm2)


def test_soc_has_no_d2d():
    s = soc_system("s", 500.0, "7nm")
    assert all(not m.is_d2d for c in s.chips for m in c.modules)


def test_process_mismatch_rejected():
    m = Module("x", 10.0, "7nm")
    with pytest.raises(ValueError):
        make_chip("bad", [m], "5nm", d2d_overhead=0.0)


def test_re_breakdown_positive_and_consistent():
    s = split_system("m", 600.0, "5nm", 3, "2.5D")
    br = re_cost(s)
    d = br.as_dict()
    for k, v in d.items():
        assert v >= 0.0, k
    assert d["total"] == pytest.approx(
        br.raw_chips + br.chip_defects + br.raw_package
        + br.package_defects + br.wasted_kgd)
    assert br.die_cost + br.packaging_cost == pytest.approx(br.total)


def test_chip_last_beats_chip_first_for_advanced_packaging():
    """Paper Sec 3.2: chip-first wastes KGDs through packaging losses."""
    s = split_system("m", 600.0, "5nm", 3, "2.5D")
    last = re_cost(s, flow="chip-last").total
    first = re_cost(s, flow="chip-first").total
    assert last < first


def test_yield_improvement_saves_die_cost():
    """Splitting a big 5nm die must cut the defect cost (paper Fig 4)."""
    soc = re_cost(soc_system("s", 800.0, "5nm"))
    mcm = re_cost(split_system("m", 800.0, "5nm", 3, "MCM"))
    assert mcm.chip_defects < soc.chip_defects
    assert mcm.die_cost < soc.die_cost


def test_nre_entity_dedup():
    """Chiplet reuse: same chip design in two systems is designed once."""
    m = Module("core", 150.0, "7nm")
    chip = make_chip("shared_die", [m], "7nm", integration="MCM")
    s1 = System("s1", (chip,), "MCM", quantity=1e5)
    s2 = System("s2", (chip, chip), "MCM", quantity=1e5)
    ent = group_nre([s1, s2])
    assert len(ent.chips) == 1
    assert len(ent.modules) == 1
    # separate designs => separate chip NRE
    chip_b = make_chip("other_die", [Module("core2", 150.0, "7nm")], "7nm",
                       integration="MCM")
    ent2 = group_nre([s1, System("s3", (chip_b,), "MCM", quantity=1e5)])
    assert len(ent2.chips) == 2


def test_amortization_scales_with_quantity():
    lo = amortized_costs([soc_system("s", 400.0, "7nm", quantity=1e4)])["s"]
    hi = amortized_costs([soc_system("s", 400.0, "7nm", quantity=1e8)])["s"]
    assert lo.nre_total > hi.nre_total * 100
    assert lo.re.total == pytest.approx(hi.re.total)


def test_package_reuse_shares_nre_but_costs_re():
    from repro.core import scms_systems
    plain = amortized_costs(scms_systems(package_reuse=False))
    reused = amortized_costs(scms_systems(package_reuse=True))
    # 4x system: package NRE drops under reuse
    assert reused["scms_4x_MCM"].nre_packages < \
        plain["scms_4x_MCM"].nre_packages
    # 1x system: oversized package raises RE
    assert reused["scms_1x_MCM"].re.total > plain["scms_1x_MCM"].re.total
