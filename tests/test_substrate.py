"""Data pipeline, optimizer, compression, checkpointing."""
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep, see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (AsyncCheckpointer, CheckpointManager,
                              latest_step, restore, save)
from repro.data import (BinaryShardWriter, DataConfig, TokenDataset,
                        make_batches, synthetic_batch)
from repro.optim import (adamw_init, adamw_update, compress_topk_int8,
                         decompress_topk_int8, error_feedback_update,
                         linear_warmup_cosine)


# -- data -------------------------------------------------------------------


def test_synthetic_deterministic_and_shard_disjoint():
    cfg = DataConfig(seq_len=16, global_batch=8, vocab=100, n_shards=2,
                     shard_id=0)
    a = synthetic_batch(cfg, 5)
    b = synthetic_batch(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    other = synthetic_batch(
        DataConfig(seq_len=16, global_batch=8, vocab=100, n_shards=2,
                   shard_id=1), 5)
    assert not np.array_equal(a["tokens"], other["tokens"])
    # labels are next-token shifted
    full = synthetic_batch(cfg, 0)
    assert full["tokens"].shape == (4, 16)
    assert full["labels"].shape == (4, 16)


def test_skip_ahead_equals_sequential():
    cfg = DataConfig(seq_len=8, global_batch=4, vocab=50)
    seq = [b["tokens"] for _, b in zip(range(5), make_batches(cfg))]
    jumped = next(make_batches(cfg, start_step=4))["tokens"]
    np.testing.assert_array_equal(seq[4], jumped)


def test_binary_roundtrip(tmp_path):
    w = BinaryShardWriter(tmp_path / "shard.bin", seq_len=8)
    rng = np.random.default_rng(0)
    recs = rng.integers(0, 1000, (10, 9))
    for r in recs:
        w.add(r)
    w.close()
    ds = TokenDataset(tmp_path / "shard.bin")
    assert ds.n_records == 10
    cfg = DataConfig(seq_len=8, global_batch=2, vocab=1000)
    b0 = ds.batch(cfg, 0)
    np.testing.assert_array_equal(b0["tokens"], recs[:2, :-1])
    np.testing.assert_array_equal(b0["labels"], recs[:2, 1:])


# -- optimizer ----------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.asarray([1.0, 2.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    p = params
    for _ in range(300):
        g = jax.grad(loss)(p)
        p, state = adamw_update(g, state, 0.05, weight_decay=0.0,
                                param_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(target),
                               atol=0.05)


def test_grad_clip_scales_large_gradients():
    params = {"w": jnp.ones(4)}
    state = adamw_init(params)
    huge = {"w": jnp.full(4, 1e6)}
    p1, s1 = adamw_update(huge, state, 1e-3, max_norm=1.0,
                          param_dtype=jnp.float32)
    # with clipping the first Adam step is bounded by ~lr
    assert float(jnp.abs(p1["w"] - params["w"]).max()) < 2e-3


def test_schedule_warmup_then_decay():
    lrs = [float(linear_warmup_cosine(jnp.asarray(s), 1e-3, 10, 100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert lrs[50] > lrs[99]


# -- compression --------------------------------------------------------------


def test_topk_int8_roundtrip_preserves_big_coords():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    comp, err = compress_topk_int8(g, k_fraction=0.1)
    recon = decompress_topk_int8(comp)
    np.testing.assert_allclose(np.asarray(recon + err), np.asarray(g),
                               atol=1e-6)
    assert comp.values_i8.dtype == jnp.int8
    assert comp.values_i8.shape[0] == 100


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_error_feedback_telescopes_exactly(seed):
    """EF invariant: sum of transmitted gradients + final residual ==
    n * g exactly (nothing is ever lost, only delayed)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256), jnp.float32)
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 25
    for _ in range(n):
        out, err = error_feedback_update(g, err, k_fraction=0.05)
        acc = acc + out
    np.testing.assert_allclose(np.asarray(acc + err), np.asarray(n * g),
                               atol=5e-4 * n)
    # the residual stays bounded (no divergence): it never exceeds the
    # worst case of a few rounds of the largest coordinate
    assert float(jnp.abs(err).max()) < 30 * float(jnp.abs(g).max())


# -- checkpointing -------------------------------------------------------------


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    save(tmp_path, 3, t)
    assert latest_step(tmp_path) == 3
    out = restore(tmp_path, 3, t)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corruption_detected(tmp_path):
    t = _tree()
    save(tmp_path, 1, t)
    npz = tmp_path / "step_00000001" / "arrays.npz"
    raw = bytearray(npz.read_bytes())
    for i in range(len(raw) // 3, len(raw) // 3 + 64):  # stomp 64 bytes
        raw[i % len(raw)] ^= 0xFF
    npz.write_bytes(bytes(raw))
    with pytest.raises(Exception):
        restore(tmp_path, 1, t)


def test_incomplete_tmp_ignored_and_retention(tmp_path):
    m = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3):
        m.save(s, t)
    (tmp_path / "step_00000099.tmp-dead").mkdir()
    assert m.latest() == 3
    kept = sorted(p.name for p in tmp_path.iterdir()
                  if p.name.startswith("step_") and ".tmp-" not in p.name)
    assert kept == ["step_00000002", "step_00000003"]


def test_async_checkpointer(tmp_path):
    m = CheckpointManager(tmp_path, keep=5)
    w = AsyncCheckpointer(m)
    t = _tree()
    for s in (10, 20):
        w.submit(s, t)
    w.wait()
    w.close()
    assert m.latest() == 20


def test_elastic_restore_changes_nothing_numerically(tmp_path):
    """restore() re-commits onto the current device set; values equal."""
    t = _tree()
    save(tmp_path, 7, t)
    out = restore(tmp_path, 7, t, shardings=None)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_resume_bitexact(tmp_path):
    """Full-loop property: train 6 steps straight == 3 + resume + 3."""
    from repro.configs import get_config
    from repro.parallel import steps as st
    from repro.data import DataConfig, synthetic_batch

    cfg = get_config("xlstm_125m").reduced().replace(dtype="float32")
    dc = DataConfig(seq_len=16, global_batch=2, vocab=cfg.vocab)
    step = jax.jit(st.make_train_step(cfg, total_steps=6))

    def run(state, lo, hi):
        for s in range(lo, hi):
            b = {k: jnp.asarray(v) for k, v in
                 synthetic_batch(dc, s).items()}
            state, m = step(state, b)
        return state, m

    s0 = st.init_train_state(cfg, jax.random.PRNGKey(0))
    straight, m_straight = run(s0, 0, 6)

    s1 = st.init_train_state(cfg, jax.random.PRNGKey(0))
    half, _ = run(s1, 0, 3)
    save(tmp_path, 3, half)
    restored = restore(tmp_path, 3, half)
    resumed, m_resumed = run(restored, 3, 6)

    for a, b in zip(jax.tree_util.tree_leaves(straight.params),
                    jax.tree_util.tree_leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=1e-6)


def test_compressed_training_still_learns():
    """End-to-end: EF top-k+int8 gradient compression in the train step
    reduces loss on a tiny model (quality survives the wire model)."""
    from repro.configs import get_config
    from repro.parallel import steps as st
    from repro.data import DataConfig, synthetic_batch

    cfg = get_config("xlstm_125m").reduced().replace(dtype="float32")
    dc = DataConfig(seq_len=16, global_batch=2, vocab=cfg.vocab)
    state = st.init_train_state(cfg, jax.random.PRNGKey(0), compress=True)
    assert state.ef_err is not None
    step = jax.jit(st.make_train_step(cfg, base_lr=3e-3, warmup=2,
                                      total_steps=20,
                                      compress_fraction=0.1))
    # fixed batch: random-token streams sit at the ln(V) entropy floor,
    # so memorization is the learnability signal
    b = {k: jnp.asarray(v) for k, v in synthetic_batch(dc, 0).items()}
    losses = []
    for s in range(20):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < losses[0] - 0.05
    # residuals are alive (compression actually on the path)
    err_norm = sum(float(jnp.abs(e).sum())
                   for e in jax.tree_util.tree_leaves(state.ef_err))
    assert err_norm > 0.0
