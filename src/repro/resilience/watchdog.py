"""Stuck-tick watchdog: a heartbeat thread with a one-shot-per-stall
callback.

The service brackets every tick with ``enter()`` / ``exit()``; the
watchdog thread polls and, when a tick has been in flight longer than
``timeout_s``, fires ``on_stall(elapsed_s)`` exactly once for that tick
(the trip latch re-arms on ``exit()``).  The callback runs on the
watchdog thread — it cannot preempt the blocked tick (CPython offers no
safe way to kill a thread mid-dispatch), so its job is evidence and
escalation: the service uses it to auto-dump the flight recorder, and
the tick loop itself is restart-safe (escaped exceptions are contained
per tick, and a dead loop task is relaunched on the next submit).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class Watchdog:
    def __init__(self, timeout_s: float,
                 on_stall: Callable[[float], None],
                 poll_s: Optional[float] = None):
        if timeout_s <= 0:
            raise ValueError(f"watchdog timeout_s must be > 0, got {timeout_s}")
        self.timeout_s = float(timeout_s)
        self.on_stall = on_stall
        self.poll_s = float(poll_s) if poll_s is not None \
            else max(0.01, self.timeout_s / 4.0)
        self.trips = 0
        self._busy_since: Optional[float] = None
        self._tripped = False        # latch: one trip per enter/exit pair
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._poll, name="repro-watchdog", daemon=True)
        self._thread.start()

    def stop(self):
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=2.0)
        self._thread = None

    def enter(self):
        """A tick is starting."""
        self._tripped = False
        self._busy_since = time.monotonic()

    def exit(self):
        """The tick finished (however it ended)."""
        self._busy_since = None
        self._tripped = False

    def _poll(self):
        while not self._stop.wait(self.poll_s):
            since = self._busy_since
            if since is None or self._tripped:
                continue
            elapsed = time.monotonic() - since
            if elapsed < self.timeout_s:
                continue
            # Latch before the callback: a slow on_stall must not
            # double-fire for the same stuck tick.
            self._tripped = True
            self.trips += 1
            try:
                self.on_stall(elapsed)
            except Exception:  # noqa: BLE001 - watchdog must survive
                pass

    def snapshot(self) -> dict:
        since = self._busy_since
        return {
            "timeout_s": self.timeout_s,
            "trips": self.trips,
            "busy_for_s": (round(time.monotonic() - since, 6)
                           if since is not None else None),
            "running": self._thread is not None,
        }
