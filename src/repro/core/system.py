"""Module / Chip / Package abstraction (paper Sec. 3.1, Eq. (3)).

    m_i in {m_1, ..., m_D2D} = M
    c_i  = Chip({m_i, m_D2D})
    SoC_j = Package(Chip({m_k1, m_k2, ...}))
    MCM_j = Package({c_k1, c_k2, ...})

A :class:`Module` is an indivisible group of functional units; the D2D
interface is a special module automatically attached to every chiplet (its
area is a technology-dependent fraction of the chiplet, Sec. 3.2).  A
:class:`Chip` is a set of modules fabricated on one process node.  A
:class:`System` is a package holding one chip (SoC) or several chiplets.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .technology import IntegrationTech, ProcessNode, node, tech

D2D_MODULE_PREFIX = "__d2d__"


@dataclasses.dataclass(frozen=True)
class Module:
    """An indivisible functional block, tied to a process node."""

    name: str
    area_mm2: float
    process: str  # key into PROCESS_NODES

    @property
    def node(self) -> ProcessNode:
        return node(self.process)

    @property
    def is_d2d(self) -> bool:
        return self.name.startswith(D2D_MODULE_PREFIX)


def d2d_module(process: str, area_mm2: float) -> Module:
    """The D2D interface module for one process node (Sec. 3.1: D2D
    interfaces under different nodes are diverse modules)."""
    return Module(name=f"{D2D_MODULE_PREFIX}{process}", area_mm2=area_mm2,
                  process=process)


@dataclasses.dataclass(frozen=True)
class Chip:
    """A die: a tuple of modules on a single node.

    ``name`` identifies the *design* — two systems containing chips of the
    same name reuse one NRE effort (chiplet reuse).
    """

    name: str
    modules: Tuple[Module, ...]
    process: str
    early_defects: bool = False  # use early-ramp defect density (AMD study)

    def __post_init__(self):
        for m in self.modules:
            if m.process != self.process:
                raise ValueError(
                    f"module {m.name} on {m.process} cannot sit on a "
                    f"{self.process} chip {self.name}")

    @property
    def node(self) -> ProcessNode:
        return node(self.process)

    @property
    def area_mm2(self) -> float:
        return float(sum(m.area_mm2 for m in self.modules))

    @property
    def module_area_mm2(self) -> float:
        """Area of functional (non-D2D) modules."""
        return float(sum(m.area_mm2 for m in self.modules if not m.is_d2d))

    @property
    def defect_density(self) -> float:
        n = self.node
        return n.defect_density_early if self.early_defects else n.defect_density


def make_chip(name: str, modules: Sequence[Module], process: str,
              integration: str = "SoC", early_defects: bool = False,
              d2d_overhead: Optional[float] = None) -> Chip:
    """Build a chip, automatically attaching the D2D module for multi-chip
    integration technologies (Sec. 3.2: D2D takes a fixed share of the chip
    area, 10% in the paper's EPYC-calibrated experiments)."""
    t = tech(integration)
    overhead = t.d2d_area_overhead if d2d_overhead is None else d2d_overhead
    mods = tuple(modules)
    if overhead > 0.0:
        func_area = sum(m.area_mm2 for m in mods)
        # D2D occupies `overhead` fraction of the final chip area:
        # d2d = overhead/(1-overhead) * functional area.
        d2d_area = func_area * overhead / (1.0 - overhead)
        mods = mods + (d2d_module(process, d2d_area),)
    return Chip(name=name, modules=mods, process=process,
                early_defects=early_defects)


@dataclasses.dataclass(frozen=True)
class System:
    """One product: a package with chips inside, made in some quantity."""

    name: str
    chips: Tuple[Chip, ...]
    integration: str            # key into INTEGRATION_TECHS
    quantity: float = 1.0       # production quantity (for NRE amortization)
    package_name: Optional[str] = None  # shared name => package reuse
    package_area_mm2: Optional[float] = None  # forced area (package reuse)

    @property
    def tech(self) -> IntegrationTech:
        return tech(self.integration)

    @property
    def silicon_area_mm2(self) -> float:
        return float(sum(c.area_mm2 for c in self.chips))

    @property
    def package_area(self) -> float:
        if self.package_area_mm2 is not None:
            return self.package_area_mm2
        return self.silicon_area_mm2 * self.tech.package_area_factor

    @property
    def package_id(self) -> str:
        """Identity of the package *design* for NRE sharing."""
        return self.package_name or f"pkg:{self.name}"

    @property
    def n_chips(self) -> int:
        return len(self.chips)


def soc_system(name: str, module_area_mm2: float, process: str,
               quantity: float = 1.0, early_defects: bool = False) -> System:
    """Monolithic SoC holding `module_area` worth of modules on one die."""
    m = Module(name=f"{name}_modules", area_mm2=module_area_mm2, process=process)
    chip = make_chip(f"{name}_die", [m], process, integration="SoC",
                     early_defects=early_defects)
    return System(name=name, chips=(chip,), integration="SoC", quantity=quantity)


def split_system(name: str, module_area_mm2: float, process: str,
                 n_chiplets: int, integration: str, quantity: float = 1.0,
                 early_defects: bool = False,
                 d2d_overhead: Optional[float] = None,
                 reuse_chiplet: bool = False) -> System:
    """Partition `module_area` evenly into n chiplets (Fig. 4 experiments).

    ``reuse_chiplet=True`` gives every chiplet the same design name so NRE
    is paid once (homogeneous split); otherwise each slice is its own design
    (the paper's Fig. 4/6 'no reuse' assumption).
    """
    per = module_area_mm2 / n_chiplets
    chips = []
    for i in range(n_chiplets):
        cname = f"{name}_slice" if reuse_chiplet else f"{name}_slice{i}"
        m = Module(name=f"{cname}_modules", area_mm2=per, process=process)
        chips.append(make_chip(cname, [m], process, integration=integration,
                               early_defects=early_defects,
                               d2d_overhead=d2d_overhead))
    return System(name=name, chips=tuple(chips), integration=integration,
                  quantity=quantity)
