"""Uncertainty-aware pricing: Monte Carlo distributions + sensitivities.

The technology numbers behind the cost model (defect densities, wafer
prices, bond yields) are estimates, and the *ranking* of candidate
architectures can flip within their error bars — big monolithic dies are
exposed to defect-density risk, many-chiplet systems to bonding-yield
risk.  This module prices that exposure:

* :func:`mc_totals` vmaps the (un-jitted) engine implementation over
  ``n_draws`` sampled parameter scenarios inside one module-level jit —
  a (draws, N) matrix of per-unit totals from a single retained trace
  per batch shape.  Draws are *systematic* by default (one multiplier
  per scenario applied batch-wide, i.e. "what if 7nm defect density is
  20% worse than assumed"), which is the correlated, ranking-relevant
  kind of uncertainty; ``correlated=False`` switches to per-element
  idiosyncratic jitter.  Lognormal multipliers are median-preserving, so
  the q50 scenario reproduces the nominal model.
* :func:`mc_summary` reduces the draw matrix to mean/std/quantiles.
* :func:`sensitivities` reuses the engine's differentiability: one
  reverse-mode gradient gives per-system elasticities d(cost)/d(ln p)
  for every uncertain parameter — the local, deterministic complement to
  the Monte Carlo picture.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.batch import SystemBatch
from ..core.engine import TRACE_COUNTS, _re_impl, _total_impl
from ..obs import jaxhooks


@dataclasses.dataclass(frozen=True)
class Uncertainty:
    """Lognormal sigmas of the uncertain technology parameters.

    ``defect_sigma`` scales chip defect densities, ``wafer_cost_sigma``
    wafer prices, ``bond_sigma`` the *failure rates* ``1 - y2`` /
    ``1 - y3`` (so yields stay <= 1), ``interposer_sigma`` the
    interposer defect density.
    """

    defect_sigma: float = 0.20
    wafer_cost_sigma: float = 0.10
    bond_sigma: float = 0.25
    interposer_sigma: float = 0.20

    def as_array(self) -> jnp.ndarray:
        return jnp.asarray([self.defect_sigma, self.wafer_cost_sigma,
                            self.bond_sigma, self.interposer_sigma],
                           jnp.float32)


def perturb_batch(batch: SystemBatch, key, sig,
                  correlated: bool = True) -> SystemBatch:
    """One sampled parameter scenario: lognormal multipliers on the
    uncertain RE parameters (median-preserving; yields perturbed via
    their failure rates so they stay in (0, 1])."""
    kd, kw, kb, ks, ki = jax.random.split(key, 5)

    def mult(kk, like, s):
        shape = () if correlated else like.shape
        return jnp.exp(s * jax.random.normal(kk, shape))

    def fail(kk, y, s):
        # perturb the failure rate so yields stay in (0, 1]
        return jnp.clip(1.0 - (1.0 - y) * mult(kk, y, s), 1e-3, 1.0)

    return batch.replace(
        chip_defect=batch.chip_defect * mult(kd, batch.chip_defect,
                                             sig[0]),
        chip_wafer_cost=batch.chip_wafer_cost
        * mult(kw, batch.chip_wafer_cost, sig[1]),
        y2_chip_bond=fail(kb, batch.y2_chip_bond, sig[2]),
        y3_substrate_bond=fail(ks, batch.y3_substrate_bond, sig[2]),
        interposer_defect=batch.interposer_defect
        * mult(ki, batch.interposer_defect, sig[3]),
    )


def _mc_impl(batch: SystemBatch, key, sig, flow: str, n_draws: int,
             correlated: bool):
    TRACE_COUNTS["mc"] += 1

    def one(k):
        return _total_impl(perturb_batch(batch, k, sig, correlated),
                           flow).total

    return jax.vmap(one)(jax.random.split(key, n_draws))


def mc_re_totals_impl(batch: SystemBatch, key, sig, flow: str,
                      n_draws: int, correlated: bool = True):
    """(n_draws, N) *RE-only* totals under sampled scenarios (un-jitted,
    composable inside a caller's graph).

    None of the perturbed parameters enters the NRE model, so the fused
    pipeline prices uncertainty as ``re_draws + nre[None, :]`` — the
    amortization (and its segment sums or closed forms) runs once per
    batch instead of once per draw."""
    TRACE_COUNTS["mc_re"] += 1

    def one(k):
        return _re_impl(perturb_batch(batch, k, sig, correlated),
                        flow).total

    return jax.vmap(one)(jax.random.split(key, n_draws))


_MC_JIT = jaxhooks.instrument(
    jax.jit(_mc_impl, static_argnames=("flow", "n_draws", "correlated")),
    "dse.mc", trace_key="mc", counts=TRACE_COUNTS)


def mc_totals(batch: SystemBatch, key, *, n_draws: int = 128,
              flow: str = "chip-last", sigmas: Uncertainty = None,
              correlated: bool = True) -> jnp.ndarray:
    """(n_draws, N) per-unit totals under sampled parameter scenarios."""
    sig = (sigmas or Uncertainty()).as_array()
    return _MC_JIT(batch, key, sig, flow, int(n_draws), bool(correlated))


def mc_summary(batch: SystemBatch, key, *, n_draws: int = 128,
               flow: str = "chip-last", sigmas: Uncertainty = None,
               correlated: bool = True,
               quantiles: Sequence[float] = (0.05, 0.5, 0.95),
               ) -> Dict[str, jnp.ndarray]:
    """Per-system cost distribution stats: mean/std + requested quantiles."""
    draws = mc_totals(batch, key, n_draws=n_draws, flow=flow, sigmas=sigmas,
                      correlated=correlated)
    out = {"mean": draws.mean(axis=0), "std": draws.std(axis=0)}
    qs = jnp.quantile(draws, jnp.asarray(list(quantiles)), axis=0)
    for i, q in enumerate(quantiles):
        out[f"q{int(round(q * 100))}"] = qs[i]
    return out


# Parameters whose local elasticity we report: every (N, C) chip leaf is
# reduced over the chip axis to a per-system number.
SENSITIVITY_PARAMS: Tuple[str, ...] = (
    "chip_defect", "chip_wafer_cost", "y2_chip_bond", "y3_substrate_bond",
    "interposer_defect", "substrate_cost", "assembly_yield",
)


def _sens_impl(batch: SystemBatch, flow: str, params: Tuple[str, ...]):
    TRACE_COUNTS["sens"] += 1

    def f(leaves):
        # Each system's cost depends only on its own rows of these RE
        # parameters, so the gradient of the sum is the per-system grad.
        return _total_impl(batch.replace(**leaves), flow).total.sum()

    leaves = {p: getattr(batch, p) for p in params}
    g = jax.grad(f)(leaves)
    out = {}
    for p, gv in g.items():
        elast = gv * leaves[p]          # d cost / d ln(p)
        out[p] = elast.sum(-1) if elast.ndim == 2 else elast
    return out


_SENS_JIT = jaxhooks.instrument(
    jax.jit(_sens_impl, static_argnames=("flow", "params")),
    "dse.sens", trace_key="sens", counts=TRACE_COUNTS)


def sensitivities(batch: SystemBatch, flow: str = "chip-last",
                  params: Sequence[str] = SENSITIVITY_PARAMS,
                  ) -> Dict[str, jnp.ndarray]:
    """Per-system elasticities d(total)/d(ln p) — USD per 100% parameter
    move, from one reverse-mode gradient through the engine."""
    return _SENS_JIT(batch, flow, tuple(params))


def portfolio_draws(draws, quantities, n_skus: int):
    """Fold (draws, K*S) per-unit totals into (draws, K) portfolio costs."""
    d = jnp.asarray(draws)
    n = d.shape[1] // n_skus
    q = jnp.asarray(quantities, d.dtype)
    return (d[:, :n * n_skus].reshape(d.shape[0], n, n_skus)
            * q[None, None, :]).sum(-1)


def portfolio_risk_stats(pf_draws, quantiles: Sequence[float]
                         ) -> Dict[str, jnp.ndarray]:
    """In-graph reduction of (draws, K) portfolio costs to per-candidate
    risk stats (mean/std + requested quantiles), each a (K,) array.

    This is the Monte-Carlo tail of the fused DSE pipeline: the quantile
    objective is computed on-device inside the same jit as candidate
    decode + pricing, so risk-aware search never ships the draw matrix to
    the host (see :mod:`repro.dse.evaluate` / ``search``)."""
    pf = jnp.asarray(pf_draws)
    out = {"mean": pf.mean(axis=0), "std": pf.std(axis=0)}
    qs = jnp.quantile(pf, jnp.asarray(list(quantiles)), axis=0)
    for i, q in enumerate(quantiles):
        out[f"q{int(round(q * 100))}"] = qs[i]
    return out


def trace_counts() -> Dict[str, int]:
    """Snapshot of the shared engine trace counters (incl. mc/sens)."""
    return dict(collections.Counter(TRACE_COUNTS))
