"""Quickstart: the Chiplet Actuary cost model in five minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (amortized_costs, best_partition, re_cost,
                        soc_system, split_system)


def main():
    # 1. Price a monolithic 800 mm^2 5nm SoC.
    soc = soc_system("my_soc", 800.0, "5nm", quantity=1e6)
    br = re_cost(soc)
    print(f"monolithic 800mm2 5nm RE: ${br.total:,.0f}"
          f"  (defects: ${br.chip_defects:,.0f} = "
          f"{br.chip_defects/br.total:.0%})")

    # 2. Split it into chiplets — how many is optimal?
    for integ in ("MCM", "InFO", "2.5D"):
        b = best_partition("5nm", integ, 800.0)
        print(f"{integ:5s}: best n={b['best_n']}  "
              f"${b['best_cost']:,.0f}  saving {b['saving']:.1%}")

    # 3. Total cost including NRE amortization at 1M units.
    mcm = split_system("my_mcm", 800.0, "5nm", 3, "MCM", quantity=1e6)
    costs = amortized_costs([soc, mcm])
    for name, c in costs.items():
        print(f"{name}: RE ${c.re.total:,.0f} + NRE/unit "
              f"${c.nre_total:,.0f} = ${c.total:,.0f}")


if __name__ == "__main__":
    main()
