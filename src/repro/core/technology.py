"""Technology database for the Chiplet Actuary cost model.

Every number the model consumes lives here, with provenance:

* wafer prices           -- CSET "AI Chips: What They Are and Why They Matter"
                            (Khan & Mann 2020), paper reference [3].
* defect densities       -- TSMC public statements via AnandTech (paper ref [2]);
                            the paper's AMD validation explicitly uses the
                            "early ramp" values 0.13 (7nm) / 0.12 (12nm).
* packaging parameters   -- calibrated so the model reproduces the paper's
                            stated results (Figs. 4-10); the paper's own
                            in-house/IC-Knowledge numbers are not public.
* NRE parameters         -- magnitudes anchored on IBS/CSET design-cost
                            estimates (~$540M full 5nm design, ~$300M 7nm),
                            split into module/chip/fixed shares and
                            calibrated to the paper's Fig. 6 ratios.

Units: areas mm^2, defect density defects/cm^2, money in USD.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

# --------------------------------------------------------------------------
# Process nodes
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ProcessNode:
    """Parameters of one silicon process node."""

    name: str
    wafer_cost: float          # USD per 300 mm wafer (processed)  [CSET]
    defect_density: float      # defects / cm^2 (mature)           [TSMC/AnandTech]
    defect_density_early: float  # defects / cm^2 (early ramp)     [TSMC/AnandTech]
    cluster_param: float       # c in Eq.(1) (negative binomial)
    # ---- NRE (USD) ----
    nre_module_per_mm2: float  # K_m: module RTL + block verification
    nre_chip_per_mm2: float    # K_c: physical design + system verification
    nre_fixed_per_chip: float  # C : full mask set, IP licensing, tapeout
    nre_d2d: float             # one-time D2D interface design for this node
    # ---- misc RE ----
    wafer_yield: float = 0.99  # Y_wafer in Eq.(2)
    wafer_sort_cost: float = 500.0   # USD per wafer (probe/sort; folded, not itemized)
    bump_cost_per_mm2: float = 0.005  # C4 bumping, per die mm^2


# 300 mm wafer prices from CSET (Khan & Mann 2020), Table "wafer price".
# Mature defect densities ~0.05-0.10 def/cm^2; early values per AnandTech.
PROCESS_NODES: Dict[str, ProcessNode] = {
    "5nm": ProcessNode(
        name="5nm", wafer_cost=16988.0,
        defect_density=0.11, defect_density_early=0.13, cluster_param=3.0,
        nre_module_per_mm2=0.34e6, nre_chip_per_mm2=0.30e6,
        nre_fixed_per_chip=55.0e6, nre_d2d=15.0e6,
    ),
    "7nm": ProcessNode(
        name="7nm", wafer_cost=9346.0,
        defect_density=0.09, defect_density_early=0.13, cluster_param=3.0,
        nre_module_per_mm2=0.19e6, nre_chip_per_mm2=0.15e6,
        nre_fixed_per_chip=15.0e6, nre_d2d=8.0e6,
    ),
    "10nm": ProcessNode(
        name="10nm", wafer_cost=5992.0,
        defect_density=0.10, defect_density_early=0.13, cluster_param=3.0,
        nre_module_per_mm2=0.12e6, nre_chip_per_mm2=0.10e6,
        nre_fixed_per_chip=10.0e6, nre_d2d=6.0e6,
    ),
    "12nm": ProcessNode(
        name="12nm", wafer_cost=3984.0,
        defect_density=0.09, defect_density_early=0.12, cluster_param=3.0,
        nre_module_per_mm2=0.06e6, nre_chip_per_mm2=0.05e6,
        nre_fixed_per_chip=6.0e6, nre_d2d=5.0e6,
    ),
    "14nm": ProcessNode(
        name="14nm", wafer_cost=3984.0,
        defect_density=0.08, defect_density_early=0.12, cluster_param=3.0,
        nre_module_per_mm2=0.05e6, nre_chip_per_mm2=0.04e6,
        nre_fixed_per_chip=5.0e6, nre_d2d=5.0e6,
    ),
    "28nm": ProcessNode(
        name="28nm", wafer_cost=2891.0,
        defect_density=0.06, defect_density_early=0.09, cluster_param=3.0,
        nre_module_per_mm2=0.02e6, nre_chip_per_mm2=0.015e6,
        nre_fixed_per_chip=2.0e6, nre_d2d=3.0e6,
    ),
    # 65 nm exists mostly as the silicon-interposer process.
    "65nm": ProcessNode(
        name="65nm", wafer_cost=1937.0,
        defect_density=0.04, defect_density_early=0.06, cluster_param=3.0,
        nre_module_per_mm2=0.005e6, nre_chip_per_mm2=0.004e6,
        nre_fixed_per_chip=0.5e6, nre_d2d=1.0e6,
    ),
}

# --------------------------------------------------------------------------
# Integration technologies (packaging)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IntegrationTech:
    """Parameters of one integration/packaging technology.

    The paper's three multi-chip schemes (MCM, InFO, 2.5D) plus the SoC
    single-die flip-chip baseline.  Interposer-bearing technologies (InFO's
    RDL counts as a thin interposer; 2.5D a full silicon interposer) follow
    Eq.(4)/(5); MCM/SoC have no interposer term.
    """

    name: str
    # Substrate (organic, FC-BGA style)
    substrate_cost_per_mm2: float     # USD / mm^2 of package substrate
    substrate_layer_factor: float     # MCM growth factor on substrate RE cost
    package_area_factor: float        # package area / total silicon area
    # Interposer (silicon 2.5D or RDL InFO); zero-cost for SoC / MCM
    interposer_cost_per_mm2: float    # fabricated, incl. TSV/RDL adders
    interposer_defect_density: float  # defects / cm^2
    interposer_area_factor: float     # interposer area / total silicon area
    interposer_node: str = "65nm"     # process used for NRE of the interposer
    # Yields (Eq. 4 notation)
    y1_interposer: float = 1.0        # interposer fab yield handled via defects; extra scalar
    y2_chip_bond: float = 1.0         # per-chip bonding yield
    y3_substrate_bond: float = 1.0    # interposer/module <-> substrate bond yield
    assembly_yield: float = 0.99      # final assembly / package test yield
    bond_cost_per_chip: float = 0.5   # USD per placed die (chip-last bond step)
    # D2D interface area overhead (fraction of each chiplet's area),
    # EPYC-calibrated 10% default per the paper Sec. 4.1.  SoC has none.
    d2d_area_overhead: float = 0.10
    # NRE
    nre_package_per_mm2: float = 1.0e3   # K_p
    nre_fixed_per_package: float = 1.0e6  # C_p


INTEGRATION_TECHS: Dict[str, IntegrationTech] = {
    # Monolithic SoC in a standard flip-chip package.
    "SoC": IntegrationTech(
        name="SoC",
        substrate_cost_per_mm2=0.005, substrate_layer_factor=1.0,
        package_area_factor=2.0,
        interposer_cost_per_mm2=0.0, interposer_defect_density=0.0,
        interposer_area_factor=0.0,
        y2_chip_bond=0.99, y3_substrate_bond=1.0, assembly_yield=0.99,
        d2d_area_overhead=0.0,
        nre_package_per_mm2=0.5e3, nre_fixed_per_package=0.5e6,
    ),
    # Classic multi-chip module: flip chips on a (thicker) organic substrate.
    "MCM": IntegrationTech(
        name="MCM",
        substrate_cost_per_mm2=0.008, substrate_layer_factor=2.0,
        package_area_factor=2.2,
        interposer_cost_per_mm2=0.0, interposer_defect_density=0.0,
        interposer_area_factor=0.0,
        y2_chip_bond=0.975, y3_substrate_bond=1.0, assembly_yield=0.99,
        bond_cost_per_chip=3.0,
        nre_package_per_mm2=1.0e3, nre_fixed_per_package=1.0e6,
    ),
    # Integrated fan-out, chip-first (dies placed, then RDL built on top).
    "InFO": IntegrationTech(
        name="InFO",
        substrate_cost_per_mm2=0.005, substrate_layer_factor=1.5,
        package_area_factor=2.0,
        interposer_cost_per_mm2=0.02,   # RDL, no TSV
        interposer_defect_density=0.05, interposer_area_factor=1.2,
        y2_chip_bond=0.98, y3_substrate_bond=0.99, assembly_yield=0.99,
        nre_package_per_mm2=2.0e3, nre_fixed_per_package=2.0e6,
    ),
    # 2.5D CoWoS: full silicon interposer with TSVs on a 65nm-class line.
    "2.5D": IntegrationTech(
        name="2.5D",
        substrate_cost_per_mm2=0.005, substrate_layer_factor=1.5,
        package_area_factor=2.4,
        interposer_cost_per_mm2=0.07,   # 65nm wafer + TSV + uBump adders
        interposer_defect_density=0.06, interposer_area_factor=1.15,
        y2_chip_bond=0.97, y3_substrate_bond=0.98, assembly_yield=0.99,
        nre_package_per_mm2=3.0e3, nre_fixed_per_package=5.0e6,
    ),
}


def node(name: str) -> ProcessNode:
    try:
        return PROCESS_NODES[name]
    except KeyError as e:
        raise KeyError(f"unknown process node {name!r}; have {sorted(PROCESS_NODES)}") from e


def tech(name: str) -> IntegrationTech:
    try:
        return INTEGRATION_TECHS[name]
    except KeyError as e:
        raise KeyError(f"unknown integration tech {name!r}; have {sorted(INTEGRATION_TECHS)}") from e
