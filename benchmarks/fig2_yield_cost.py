"""Paper Fig. 2: yield-area and cost-area relations per process node."""
import jax.numpy as jnp

from repro.core import CostEngine, SystemBatch, cost_area_curve

from .common import emit

NODES = ("28nm", "14nm", "10nm", "7nm", "5nm")
AREAS = (25.0, 50.0, 100.0, 200.0, 400.0, 600.0, 800.0)


def run():
    areas = jnp.asarray(AREAS, jnp.float32)
    rows = []
    for node in NODES:
        c = cost_area_curve(node, areas)
        for i, a in enumerate(areas):
            rows.append({
                "node": node, "area_mm2": float(a),
                "yield": float(c["yield"][i]),
                "norm_cost_per_area": float(c["norm_cost_per_area"][i]),
            })
    emit("fig2_yield_cost_vs_area", rows)

    # headline check: 5nm 800mm2 die yields poorly and costs >2x per mm2
    c5 = cost_area_curve("5nm", jnp.asarray([800.0]))
    assert float(c5["yield"][0]) < 0.5

    # API-drift guard: the batched engine must agree with the figure's
    # claims — past the ~100mm2 sweet spot, SoC RE per mm^2 grows with
    # area on every node (yield dominates), the 5nm 800mm2 die costs >2x
    # per mm^2 vs 100mm2, and advanced nodes cost more per mm^2.
    batch = SystemBatch.from_specs(
        [{"kind": "soc", "area": float(a), "process": n}
         for n in NODES for a in AREAS])
    per_mm2 = (CostEngine().re(batch).total
               / batch.chip_area.sum(-1)).reshape(len(NODES), len(AREAS))
    big = per_mm2[:, AREAS.index(100.0):]
    assert bool((big[:, 1:] >= big[:, :-1]).all()), \
        "engine cost/area not monotone past 100mm2"
    assert float(per_mm2[-1, -1]) > 2.0 * float(per_mm2[-1, AREAS.index(100.0)])
    assert bool((per_mm2[1:] >= per_mm2[:-1]).all()), \
        "newer node should cost more per mm^2"
    return rows


if __name__ == "__main__":
    run()
