"""RE (recurring engineering) cost model — paper Sec. 3.2, Eqs. (4)-(5).

The total RE cost of a system is broken into the paper's five itemized
components:

  1. cost of raw chips,
  2. cost of chip defects,
  3. cost of raw packages (substrate + interposer/RDL + bonding + assembly),
  4. cost of package defects,
  5. cost of wasted known-good-dies (KGDs) destroyed by packaging defects.

Bumping / wafer sort / package test are folded into the raw-chip and
raw-package terms (the paper includes but does not itemize them).

Two packaging flows (Eq. 5) are modeled; chip-last is the default, as in
the paper's experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp

from .system import Chip, System
from .technology import IntegrationTech, node, tech
from .yield_model import (dies_per_wafer, raw_die_cost,
                          yield_negative_binomial)


@dataclasses.dataclass
class REBreakdown:
    """Itemized RE cost of one unit of a system (USD)."""

    raw_chips: float
    chip_defects: float
    raw_package: float
    package_defects: float
    wasted_kgd: float

    @property
    def total(self) -> float:
        return (self.raw_chips + self.chip_defects + self.raw_package
                + self.package_defects + self.wasted_kgd)

    @property
    def die_cost(self) -> float:
        """Cost attributable to silicon (what AMD's Fig. 5 compares)."""
        return self.raw_chips + self.chip_defects

    @property
    def packaging_cost(self) -> float:
        """Footnote 2: raw package + package defects + wasted KGDs."""
        return self.raw_package + self.package_defects + self.wasted_kgd

    def as_dict(self) -> Dict[str, float]:
        return {
            "raw_chips": self.raw_chips,
            "chip_defects": self.chip_defects,
            "raw_package": self.raw_package,
            "package_defects": self.package_defects,
            "wasted_kgd": self.wasted_kgd,
            "total": self.total,
        }

    def scaled(self, f: float) -> "REBreakdown":
        return REBreakdown(*(f * x for x in dataclasses.astuple(self)))


# ---------------------------------------------------------------------------
# Per-chip silicon cost
# ---------------------------------------------------------------------------


def chip_costs(chip: Chip) -> Dict[str, float]:
    """Raw die cost, defect overhead and KGD cost for one die."""
    n = chip.node
    area = chip.area_mm2
    raw = float(raw_die_cost(area, n.wafer_cost))
    # sort + bump folded into the raw die (not itemized, per the paper)
    raw += n.wafer_sort_cost / float(dies_per_wafer(area))
    raw += n.bump_cost_per_mm2 * area
    y_die = float(yield_negative_binomial(area, chip.defect_density,
                                          n.cluster_param)) * n.wafer_yield
    kgd = raw / y_die
    return {"raw": raw, "defect": kgd - raw, "kgd": kgd, "yield": y_die}


# ---------------------------------------------------------------------------
# Package-level model
# ---------------------------------------------------------------------------


def _interposer_cost(system: System) -> tuple[float, float]:
    """(raw interposer cost, interposer yield y1) for InFO/2.5D, else (0,1).

    When a package design is reused (``package_area_mm2`` forced), the
    interposer is sized for the *design's* silicon capacity, not for the
    chips actually bonded — Sec. 5.1: reusing a 4x interposer in a 1x
    system pays the full 4x interposer.
    """
    t = system.tech
    if t.interposer_area_factor <= 0.0:
        return 0.0, 1.0
    design_silicon = system.package_area / t.package_area_factor
    area = design_silicon * t.interposer_area_factor
    inode = node(t.interposer_node)
    raw = area * t.interposer_cost_per_mm2
    y1 = float(yield_negative_binomial(area, t.interposer_defect_density,
                                       inode.cluster_param))
    return raw, y1


def _substrate_cost(system: System) -> float:
    t = system.tech
    return (system.package_area * t.substrate_cost_per_mm2
            * t.substrate_layer_factor)


def re_cost(system: System, flow: str = "chip-last") -> REBreakdown:
    """Full Eq. (4)/(5) RE breakdown for one unit of ``system``.

    flow: 'chip-last' (default, paper's choice) or 'chip-first'.
    """
    t: IntegrationTech = system.tech
    n_chips = system.n_chips

    per_chip = [chip_costs(c) for c in system.chips]
    raw_chips = sum(c["raw"] for c in per_chip)
    chip_defects = sum(c["defect"] for c in per_chip)
    kgd_total = sum(c["kgd"] for c in per_chip)

    c_interposer, y1 = _interposer_cost(system)
    c_substrate = _substrate_cost(system)
    c_bond = t.bond_cost_per_chip * n_chips

    y2n = t.y2_chip_bond ** n_chips
    y3 = t.y3_substrate_bond * t.assembly_yield

    if flow == "chip-last":
        # Eq. (4): the interposer/RDL ("package") is fabricated and yielded
        # first, then KGDs are bonded (y2 each), then the assembly is mated
        # to the substrate (y3).
        raw_package = c_interposer + c_substrate + c_bond
        package_defects = (c_interposer * (1.0 / (y1 * y2n * y3) - 1.0)
                           + (c_substrate + c_bond) * (1.0 / y3 - 1.0))
        wasted_kgd = kgd_total * (1.0 / (y2n * y3) - 1.0)
    elif flow == "chip-first":
        # Eq. (5) top: everything rides through the whole flow; KGDs are
        # exposed to interposer-fab losses as well.
        y_all = y1 * y2n * y3
        raw_package = c_interposer + c_substrate + c_bond
        package_defects = raw_package * (1.0 / y_all - 1.0)
        wasted_kgd = kgd_total * (1.0 / y_all - 1.0)
    else:
        raise ValueError(f"unknown flow {flow!r}")

    return REBreakdown(
        raw_chips=raw_chips,
        chip_defects=chip_defects,
        raw_package=raw_package,
        package_defects=package_defects,
        wasted_kgd=wasted_kgd,
    )


# ---------------------------------------------------------------------------
# Functional (jnp, vmap-able) kernel for homogeneous splits — used by the
# explorer and the differentiable partitioner.  Mirrors re_cost() for the
# `split_system` case: `module_area` split into n chiplets with D2D overhead.
# ---------------------------------------------------------------------------


def re_cost_split(module_area_mm2, n_chiplets, *, wafer_cost, defect_density,
                  cluster, tech_params, d2d_overhead=None):
    """jnp RE total for an even n-way split; differentiable in areas.

    ``tech_params`` is an :class:`IntegrationTech`; n_chiplets may be a
    traced float (the differentiable relaxation treats it continuously).
    Returns a dict of jnp scalars matching REBreakdown fields.
    """
    t = tech_params
    ovh = t.d2d_area_overhead if d2d_overhead is None else d2d_overhead
    n = n_chiplets
    chip_area = module_area_mm2 / n
    is_multi = jnp.asarray(n, jnp.float32) > 1.0
    chip_area = chip_area * jnp.where(is_multi, 1.0 / (1.0 - ovh), 1.0)
    silicon = chip_area * n

    raw1 = raw_die_cost(chip_area, wafer_cost)
    y_die = yield_negative_binomial(chip_area, defect_density, cluster) * 0.99
    raw_chips = raw1 * n
    chip_defects = raw1 * (1.0 / y_die - 1.0) * n
    kgd = raw1 / y_die * n

    interposer_area = silicon * t.interposer_area_factor
    c_interposer = interposer_area * t.interposer_cost_per_mm2
    y1 = jnp.where(
        t.interposer_area_factor > 0,
        yield_negative_binomial(interposer_area, t.interposer_defect_density, cluster),
        1.0)
    c_substrate = (silicon * t.package_area_factor * t.substrate_cost_per_mm2
                   * t.substrate_layer_factor)
    c_bond = t.bond_cost_per_chip * n

    y2n = t.y2_chip_bond ** n
    y3 = t.y3_substrate_bond * t.assembly_yield

    raw_package = c_interposer + c_substrate + c_bond
    package_defects = (c_interposer * (1.0 / (y1 * y2n * y3) - 1.0)
                       + (c_substrate + c_bond) * (1.0 / y3 - 1.0))
    wasted_kgd = kgd * (1.0 / (y2n * y3) - 1.0)

    total = raw_chips + chip_defects + raw_package + package_defects + wasted_kgd
    return {
        "raw_chips": raw_chips, "chip_defects": chip_defects,
        "raw_package": raw_package, "package_defects": package_defects,
        "wasted_kgd": wasted_kgd, "total": total,
    }
