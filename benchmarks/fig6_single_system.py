"""Paper Fig. 6: total (RE + amortized NRE) cost structure of a single
800 mm^2 5nm system vs production quantity."""
from repro.core import amortized_costs, soc_system, split_system
from .common import emit


def run():
    rows = []
    for qty in (2e5, 5e5, 1e6, 2e6, 5e6, 1e7):
        soc = amortized_costs(
            [soc_system("soc", 800.0, "5nm", quantity=qty)])["soc"]
        base = soc.re.total
        for label, sys_ in (
                ("SoC", soc_system("s", 800.0, "5nm", quantity=qty)),
                ("MCM-2", split_system("s", 800.0, "5nm", 2, "MCM",
                                       quantity=qty)),
                ("InFO-2", split_system("s", 800.0, "5nm", 2, "InFO",
                                        quantity=qty)),
                ("2.5D-2", split_system("s", 800.0, "5nm", 2, "2.5D",
                                        quantity=qty))):
            c = amortized_costs([sys_])["s"]
            rows.append({
                "quantity": qty, "system": label,
                "re_norm": c.re.total / base,
                "nre_modules_norm": c.nre_modules / base,
                "nre_chips_norm": c.nre_chips / base,
                "nre_pkg_norm": c.nre_packages / base,
                "nre_d2d_norm": c.nre_d2d / base,
                "total_norm": c.total / base,
            })
    emit("fig6_single_system_total_cost", rows)
    return rows


if __name__ == "__main__":
    run()
