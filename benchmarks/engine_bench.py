"""Microbenchmark: legacy per-system `re_cost` looping vs the jitted
batched `CostEngine.total` on a 10k-system heterogeneous sweep.

  PYTHONPATH=src python -m benchmarks.engine_bench [n_systems]

Asserts (acceptance criteria of the API redesign):
  * the engine matches the scalar reference within 1e-5 relative on a
    sampled subset of the heterogeneous batch, and
  * repeated engine sweeps over same-shaped batches add ZERO new traces —
    the whole 10k-system evaluation is a single `jax.jit` trace with no
    Python-loop fallback.
"""
import sys
import time

import jax

from repro.core import CostEngine, SystemBatch, amortized_costs, re_cost, spec
from repro.core.engine import TRACE_COUNTS

from .common import obs_summary, write_bench_json

NODES = ("5nm", "7nm", "12nm", "14nm", "28nm")
INTEGRATIONS = ("SoC", "MCM", "InFO", "2.5D")


def make_specs(n: int):
    """n deterministic heterogeneous design points (no RNG: index-derived)."""
    specs = []
    for i in range(n):
        integ = INTEGRATIONS[i % len(INTEGRATIONS)]
        area = 150.0 + (i * 7919) % 700          # 150..850 mm^2
        qty = 1e5 * (1 + i % 50)
        if integ == "SoC":
            specs.append({"kind": "soc", "name": f"s{i}", "area": float(area),
                          "process": NODES[i % len(NODES)], "quantity": qty})
        else:
            k = 2 + i % 4                        # 2..5 chiplets
            fracs = [1.0 + ((i + j) % 3) for j in range(k)]  # unequal slices
            procs = [NODES[(i + j) % len(NODES)] for j in range(k)]
            specs.append({"kind": "split", "name": f"s{i}",
                          "area": float(area), "fractions": fracs,
                          "processes": procs, "integration": integ,
                          "quantity": qty})
    return specs


def run(n_systems: int = 10_000):
    specs = make_specs(n_systems)
    systems = [spec(d) for d in specs]

    t0 = time.perf_counter()
    batch = SystemBatch.from_systems(systems, share_nre=False)
    t_pack = time.perf_counter() - t0

    engine = CostEngine()
    t0 = time.perf_counter()
    tc = jax.block_until_ready(engine.total(batch))
    t_first = time.perf_counter() - t0          # includes the jit trace

    traces_after_first = dict(TRACE_COUNTS)
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        tc = jax.block_until_ready(engine.total(batch))
    t_engine = (time.perf_counter() - t0) / reps
    assert dict(TRACE_COUNTS) == traces_after_first, \
        "engine retraced on a same-shaped batch"

    # Legacy path: Python loop, one system at a time (cap the loop so the
    # benchmark stays polite at large n; extrapolate linearly).
    n_legacy = min(n_systems, 1000)
    t0 = time.perf_counter()
    legacy = [amortized_costs([s])[s.name].total for s in systems[:n_legacy]]
    t_loop = (time.perf_counter() - t0) * (n_systems / n_legacy)

    # Parity spot-check on a stride through the heterogeneous batch.
    worst = 0.0
    for i in range(0, n_systems, max(1, n_systems // 97)):
        ref = amortized_costs([systems[i]])[systems[i].name].total
        rel = abs(ref - float(tc.total[i])) / ref
        worst = max(worst, rel)
    assert worst < 1e-5, f"engine/legacy mismatch: {worst:.2e}"

    print(f"n_systems            : {n_systems}")
    print(f"pack batch           : {t_pack*1e3:9.1f} ms (host, once per sweep shape)")
    print(f"engine first call    : {t_first*1e3:9.1f} ms (includes jit trace)")
    print(f"engine steady-state  : {t_engine*1e3:9.1f} ms / sweep")
    print(f"legacy re_cost loop  : {t_loop*1e3:9.1f} ms "
          f"(measured on {n_legacy}, extrapolated)")
    print(f"speedup (steady)     : {t_loop/t_engine:9.0f}x")
    print(f"parity worst rel err : {worst:.2e}")
    print(f"trace counts         : {dict(TRACE_COUNTS)} (no retrace across "
          f"{reps} repeat sweeps)")
    summary = {"n": n_systems, "t_pack_s": t_pack, "t_first_s": t_first,
               "t_engine_s": t_engine, "t_loop_s": t_loop,
               "systems_per_sec": n_systems / t_engine,
               "speedup": t_loop / t_engine, "worst_rel": worst}
    # traced runs (REPRO_TRACE=1) ride per-phase compile/dispatch/
    # device_get breakdowns along; untraced keys are unchanged.
    summary.update(obs_summary())
    write_bench_json("engine", summary)
    return summary


if __name__ == "__main__":
    run(int(sys.argv[1]) if len(sys.argv) > 1 else 10_000)
