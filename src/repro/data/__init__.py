from .pipeline import (BinaryShardWriter, DataConfig, make_batches,
                       synthetic_batch, TokenDataset)
