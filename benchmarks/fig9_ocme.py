"""Paper Fig. 9: OCME (one center, multiple extensions) + heterogeneity."""
from repro.core import (amortized_costs, ocme_soc_equivalents, ocme_systems,
                        re_cost)
from .common import emit


def run():
    rows = []
    base = re_cost(ocme_systems()[-1]).total     # largest MCM RE
    variants = [
        ("SoC", ocme_soc_equivalents()),
        ("MCM", ocme_systems()),
        ("MCM+pkg-reuse", ocme_systems(package_reuse=True)),
        ("MCM+pkg+hetero14nm", ocme_systems(center_process="14nm",
                                            package_reuse=True)),
    ]
    for label, systems in variants:
        costs = amortized_costs(systems)
        for s in systems:
            c = costs[s.name]
            rows.append({
                "variant": label, "system": s.name,
                "re_norm": c.re.total / base,
                "nre_norm": c.nre_total / base,
                "total_norm": c.total / base,
            })
    emit("fig9_ocme_reuse", rows)
    return rows


if __name__ == "__main__":
    run()
