"""Pallas kernel sweeps: shapes x dtypes, allclose vs the ref.py oracle
(interpret=True executes kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def rnd(*shape, dtype=jnp.float32):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(atol=2e-5, rtol=2e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,s,t,d", [
    (1, 2, 2, 64, 64, 32),      # MHA, square
    (2, 4, 2, 128, 128, 32),    # GQA 2x
    (1, 8, 2, 64, 128, 64),     # GQA 4x, longer KV than Q
    (2, 2, 1, 256, 256, 16),    # MQA
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(b, h, hkv, s, t, d, dtype, causal):
    q, k, v = rnd(b, s, h, d, dtype=dtype), rnd(b, t, hkv, d, dtype=dtype), \
        rnd(b, t, hkv, d, dtype=dtype)
    if causal and t != s:
        pytest.skip("causal requires t == s in this contract")
    out = ops.flash_attention(q, k, v, causal=causal, interpret=True)
    want = ops.flash_attention(q, k, v, causal=causal, impl="xla")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,t,d", [
    (2, 4, 2, 128, 32), (1, 8, 8, 256, 64), (3, 4, 1, 512, 16),
])
def test_flash_decode(b, h, hkv, t, d, dtype):
    q = rnd(b, 1, h, d, dtype=dtype)
    k, v = rnd(b, t, hkv, d, dtype=dtype), rnd(b, t, hkv, d, dtype=dtype)
    kv_len = jnp.asarray(RNG.integers(1, t, b), jnp.int32)
    out = ops.flash_decode(q, k, v, kv_len, interpret=True)
    want = ops.flash_decode(q, k, v, kv_len, impl="xla")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 32, 2, 8, 4, 8), (2, 64, 3, 16, 8, 16), (1, 128, 1, 32, 16, 32),
    (2, 64, 2, 16, 8, 64),     # chunk == seq (single chunk)
])
def test_mamba_scan(b, s, h, p, n, chunk, dtype):
    xh = rnd(b, s, h, p, dtype=dtype)
    dt = jnp.abs(rnd(b, s, h)) * 0.1
    a_log = rnd(h) * 0.5
    bm, cm = rnd(b, s, n), rnd(b, s, n)
    y, _ = ops.mamba_scan(xh, dt, a_log, bm, cm, chunk=chunk,
                          interpret=True)
    want, _ = ref.ssd_ref(xh, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               atol=2e-4, rtol=2e-4)


def test_mamba_chunked_xla_matches_recurrent_oracle():
    """models.ssm.ssd_chunked (the XLA path) vs the sequential oracle."""
    from repro.models.ssm import ssd_chunked
    xh = rnd(2, 64, 3, 16)
    dt = jnp.abs(rnd(2, 64, 3)) * 0.1
    a_log = rnd(3) * 0.5
    bm, cm = rnd(2, 64, 8), rnd(2, 64, 8)
    y, state = ssd_chunked(xh, dt, a_log, bm, cm, chunk=16)
    want_y, want_state = ref.ssd_ref(xh, dt, a_log, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want_y),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(want_state),
                               atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f", [
    (2, 64, 32, 64), (4, 100, 64, 128), (1, 128, 128, 256),
    (8, 7, 32, 64),            # capacity smaller than block (padding)
])
def test_moe_gmm(e, c, d, f, dtype):
    x, w = rnd(e, c, d, dtype=dtype), rnd(e, d, f, dtype=dtype)
    out = ops.moe_gmm(x, w, interpret=True)
    want = ref.gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d", [(8, 64), (100, 256), (256, 128), (1, 32)])
def test_rmsnorm(n, d, dtype):
    x, s = rnd(n, d, dtype=dtype), rnd(d)
    out = ops.fused_rmsnorm(x, s, interpret=True)
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_flash_attention_custom_vjp_matches_oracle_grad():
    q, k, v = rnd(1, 64, 4, 32), rnd(1, 64, 2, 32), rnd(1, 64, 2, 32)

    def loss_pallas(q, k, v):
        return (ops.flash_attention(q, k, v, interpret=True) ** 2).sum()

    def loss_ref(q, k, v):
        return (ops.flash_attention(q, k, v, impl="xla") ** 2).sum()

    g1 = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_attention_module_paths_agree():
    """models.attention full vs chunked vs kernel on GQA shapes."""
    from repro.models.attention import attend_chunked, attend_full
    q, k, v = rnd(2, 96, 4, 32), rnd(2, 96, 2, 32), rnd(2, 96, 2, 32)
    a = attend_full(q, k, v)
    b = attend_chunked(q, k, v, chunk=32)
    c = ops.flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("b,s,h,dh,chunk", [
    (2, 32, 3, 8, 16), (1, 64, 2, 16, 64), (2, 48, 1, 8, 16),
])
def test_slstm_kernel(b, s, h, dh, chunk):
    xg = rnd(b, s, 4, h, dh)
    r = rnd(4, h, dh, dh) * 0.1
    bias = rnd(4, h, dh) * 0.1
    out = ops.slstm_seq(xg, r, bias, interpret=True)
    want = ops.slstm_seq(xg, r, bias, impl="xla")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_mlstm_chunked_matches_parallel():
    from repro.models.xlstm import (mlstm_chunked, mlstm_parallel,
                                    mlstm_spec)
    from repro.models.common import init_params
    p = init_params(mlstm_spec(64, 4), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
    a = mlstm_parallel(p, x)
    b, _ = mlstm_chunked(p, x, chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)
