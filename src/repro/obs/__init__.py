"""repro.obs — stack-wide observability: span tracing, metrics, JAX
cost attribution, and the service flight recorder.

The paper's argument is quantitative cost attribution; this package
applies the same discipline to the stack's own compute cost.  Four
surfaces, used by engine, DSE, search and service alike:

* :mod:`repro.obs.trace` — nestable labeled spans (``pack`` /
  ``jit_compile`` / ``kernel_dispatch`` / ``device_get`` / ``chunk`` /
  ``generation`` / ``tick``), exportable as Chrome/Perfetto
  ``trace_event`` JSON and aggregate per-phase wall tables;
* :mod:`repro.obs.registry` — named counters/gauges/histograms with
  JSON + Prometheus-style text exposition (and the ``TRACE_COUNTS``
  compatibility shim);
* :mod:`repro.obs.jaxhooks` — per-signature compile-vs-dispatch
  attribution of the module-level jit entry points plus a
  ``jax.device_get`` transfer hook;
* :mod:`repro.obs.flight` — the service's bounded black-box ring,
  dumped as a trace file on error or on demand;
* :mod:`repro.obs.ledger` — per-request serving-cost bills: each
  coalesced tick's measured wall pro-rated to the requests that rode
  it by rows contributed, rolled up into per-kind/per-lane
  cost-per-query aggregates (always on; independent of tracing);
* :mod:`repro.obs.slo` — declarative latency/availability objectives
  per request kind with sliding-window error-budget burn rates; a burn
  excursion latches a flight-recorder auto-dump.

Tracing is **off by default and zero-cost when off**; turn it on with
``REPRO_TRACE=1`` in the environment or :func:`enable`.  It never adds
host syncs and never retraces a warmed signature (pinned by the
trace-count oracle in ``tests/test_obs.py``).
"""
from __future__ import annotations

from . import jaxhooks
from .flight import FlightRecorder
from .ledger import Bill, Ledger
from .registry import (Counter, Gauge, Histogram, REGISTRY, Registry,
                       TraceCounts)
from .slo import SLObjective, SLOTracker
from .trace import TRACER, Tracer, span

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry", "REGISTRY", "TraceCounts",
    "Tracer", "TRACER", "span", "FlightRecorder", "jaxhooks",
    "Bill", "Ledger", "SLObjective", "SLOTracker",
    "enabled", "enable", "disable", "export_chrome", "phase_table",
]


def enabled() -> bool:
    """Is the observability layer currently recording?"""
    return TRACER.enabled()


def enable(on: bool = True):
    """Turn span tracing + the jit probes + the device_get hook on/off
    at runtime (the programmatic twin of ``REPRO_TRACE=1``)."""
    TRACER.enable(on)
    if on:
        jaxhooks.install_device_get_hook()
    else:
        jaxhooks.uninstall_device_get_hook()


def disable():
    enable(False)


def export_chrome(path):
    """Write everything the span tracer collected as a Chrome/Perfetto
    ``trace_event`` JSON file."""
    return TRACER.export_chrome(path)


def phase_table():
    """Aggregate per-phase wall table (count/total/mean/max seconds)."""
    return TRACER.phase_table()


# REPRO_TRACE=1 in the environment enables the full layer at import —
# the tracer itself already read the env var; finish the job by
# installing the device_get hook.
if TRACER.enabled():
    jaxhooks.install_device_get_hook()
