"""JAX cost attribution: per-signature compile/dispatch probes and a
``jax.device_get`` hook.

The stack's hot paths are module-level ``jax.jit`` entry points
(``repro.core.engine``, ``repro.dse.evaluate`` / ``search`` /
``uncertainty``).  :func:`instrument` wraps each of them in a
:class:`JitProbe` that attributes every call's host-side wall to either
**jit_compile** (the call traced — detected via the impl body's
``TRACE_COUNTS`` key, which only increments while jax executes the
Python body) or **kernel_dispatch** (steady state), keyed by the call's
argument *signature* (leaf shapes/dtypes + static arguments).  That
turns "zero hot-path recompiles" from an asserted invariant into a
measured, queryable one: ``stats()`` reports compiles per signature and
:func:`recompiles_since` / the tracer's ``jit_compile``-inside-``tick``
count expose any warm-path retrace.

:func:`install_device_get_hook` wraps ``jax.device_get`` so every
device->host sync is counted and its transferred bytes summed — the
third axis (transfer) next to compile and dispatch.

Everything is **off while tracing is off**: probes forward with a single
predicate check, and the device_get hook is only installed by
:func:`repro.obs.enable`.  Probes never call ``block_until_ready`` —
dispatch time is the host-side dispatch wall, device waits show up where
they always did, in ``device_get``.
"""
from __future__ import annotations

import dataclasses
from time import perf_counter
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax

from . import trace
from .registry import REGISTRY


@dataclasses.dataclass
class SignatureStats:
    """Wall attribution of one (probe, argument-signature) pair."""

    compiles: int = 0
    compile_s: float = 0.0
    calls: int = 0              # post-compile (steady-state) calls
    dispatch_s: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _leaf_sig(leaf) -> Any:
    # Keep this fast: it runs per probe call on every pytree leaf.
    # dtype objects hash/compare fine and avoid str(dtype) (~3us each);
    # statics are hashable by jit's own contract.
    shape = getattr(leaf, "shape", None)
    if shape is not None and hasattr(leaf, "dtype"):
        return (tuple(shape), leaf.dtype)
    try:
        return (type(leaf).__name__, hash(leaf))
    except TypeError:
        return repr(leaf)[:80]


class JitProbe:
    """Transparent wrapper over a jitted callable (see module docstring).

    ``trace_key`` names the ``TRACE_COUNTS`` entry the wrapped function's
    Python body increments; a call that bumps it was a (re)trace.  With
    no ``trace_key``, a first call per signature counts as the compile.
    """

    def __init__(self, fn: Callable, name: str,
                 trace_key: Optional[str] = None,
                 counts: Optional[Mapping] = None):
        self.fn = fn
        self.name = name
        self.trace_key = trace_key
        self.counts = counts if counts is not None else {}
        self.stats: Dict[Any, SignatureStats] = {}
        m = self._mname
        self._counter_names = (f"jit_{m}_compiles", f"jit_{m}_compile_s",
                               f"jit_{m}_calls", f"jit_{m}_dispatch_s")
        _PROBES.append(self)

    def __call__(self, *args, **kwargs):
        if not trace.TRACER.enabled():
            return self.fn(*args, **kwargs)
        before = self.counts.get(self.trace_key, 0) if self.trace_key \
            else 0
        # the signature walk is inside the timed window on purpose: it is
        # tracing-induced dispatch cost and must show up as covered span
        # wall, not as an unattributed hole in the tick.
        t0 = perf_counter()
        out = self.fn(*args, **kwargs)
        sig = self._signature(args, kwargs)
        dt = perf_counter() - t0
        if self.trace_key:
            compiled = self.counts.get(self.trace_key, 0) > before
        else:
            compiled = sig not in self.stats
        st = self.stats.setdefault(sig, SignatureStats())
        n_compiles, n_compile_s, n_calls, n_dispatch_s = self._counter_names
        if compiled:
            st.compiles += 1
            st.compile_s += dt
            trace.TRACER.add_complete("jit_compile", dt, fn=self.name)
            REGISTRY.counter(n_compiles).inc()
            REGISTRY.counter(n_compile_s).inc(dt)
        else:
            st.calls += 1
            st.dispatch_s += dt
            trace.TRACER.add_complete("kernel_dispatch", dt, fn=self.name)
            REGISTRY.counter(n_calls).inc()
            REGISTRY.counter(n_dispatch_s).inc(dt)
        return out

    @property
    def _mname(self) -> str:
        return self.name.replace(".", "_").replace("-", "_")

    @staticmethod
    def _signature(args, kwargs) -> Tuple:
        leaves, _ = jax.tree_util.tree_flatten((args, kwargs))
        return tuple(_leaf_sig(l) for l in leaves)

    def summary(self) -> Dict[str, float]:
        """Aggregate over signatures: total compiles / walls / calls."""
        out = {"signatures": len(self.stats), "compiles": 0,
               "compile_s": 0.0, "calls": 0, "dispatch_s": 0.0}
        for st in self.stats.values():
            out["compiles"] += st.compiles
            out["compile_s"] += st.compile_s
            out["calls"] += st.calls
            out["dispatch_s"] += st.dispatch_s
        return out

    def reset(self):
        self.stats.clear()


_PROBES: List[JitProbe] = []


def instrument(fn: Callable, name: str, trace_key: Optional[str] = None,
               counts: Optional[Mapping] = None) -> JitProbe:
    """Wrap a jitted entry point in a :class:`JitProbe` (registered for
    :func:`stats` aggregation)."""
    return JitProbe(fn, name, trace_key=trace_key, counts=counts)


def probes() -> List[JitProbe]:
    return list(_PROBES)


def stats() -> Dict[str, Dict[str, float]]:
    """Per-probe compile/dispatch attribution (aggregated signatures)."""
    return {p.name: p.summary() for p in _PROBES}


def reset():
    """Clear all probe stats and the warm-compile marker."""
    for p in _PROBES:
        p.reset()


def total_compiles() -> int:
    return sum(p.summary()["compiles"] for p in _PROBES)


def total_dispatch_s() -> float:
    """Total probe-attributed jit wall (compile + steady-state dispatch)
    across every probe.  Deltas of this marker give the measured "device
    ms inside this tick" the serving-cost ledger pro-rates per request.
    Only meaningful while tracing is on (probes forward untimed when
    off); callers fall back to tick wall otherwise."""
    total = 0.0
    for p in _PROBES:
        s = p.summary()
        total += s["compile_s"] + s["dispatch_s"]
    return total


def recompiles_since(marker: int) -> int:
    """Compiles measured since a ``total_compiles()`` marker — the
    queryable "recompiles after warmup" invariant."""
    return total_compiles() - marker


# ---------------------------------------------------------------------------
# device_get hook: count syncs + transferred bytes
# ---------------------------------------------------------------------------

_ORIG_DEVICE_GET: Optional[Callable] = None


def _tree_nbytes(x) -> int:
    leaves, _ = jax.tree_util.tree_flatten(x)
    return sum(int(getattr(l, "nbytes", 0)) for l in leaves)


def install_device_get_hook():
    """Patch ``jax.device_get`` so every device->host transfer records a
    ``device_get`` span plus call/byte counters.  Idempotent."""
    global _ORIG_DEVICE_GET
    if _ORIG_DEVICE_GET is not None:
        return
    orig = jax.device_get
    _ORIG_DEVICE_GET = orig
    calls = REGISTRY.counter("device_get_calls",
                             help="jax.device_get invocations")
    nbytes = REGISTRY.counter("device_get_bytes",
                              help="bytes transferred device->host")
    wall = REGISTRY.counter("device_get_s",
                            help="wall seconds inside jax.device_get")

    def traced_device_get(x):
        t0 = perf_counter()
        out = orig(x)
        dt = perf_counter() - t0
        b = _tree_nbytes(out)
        trace.TRACER.add_complete("device_get", dt, bytes=b)
        calls.inc()
        nbytes.inc(b)
        wall.inc(dt)
        return out

    traced_device_get._repro_obs_hook = True
    jax.device_get = traced_device_get


def uninstall_device_get_hook():
    """Restore the original ``jax.device_get``."""
    global _ORIG_DEVICE_GET
    if _ORIG_DEVICE_GET is not None:
        jax.device_get = _ORIG_DEVICE_GET
        _ORIG_DEVICE_GET = None


def device_get_stats() -> Dict[str, float]:
    """Totals collected by the device_get hook (zeros if never installed)."""
    def val(name):
        m = REGISTRY.get(name)
        return m.get() if m is not None else 0.0
    return {"calls": int(val("device_get_calls")),
            "bytes": int(val("device_get_bytes")),
            "total_s": val("device_get_s")}
