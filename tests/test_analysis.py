"""HLO analyzer + roofline unit tests on synthetic HLO text."""
import pytest

from repro.analysis.hlo import (_shape_bytes, analyze_hlo_text,
                                parse_computations)
from repro.analysis.roofline import (HW, RooflineTerms,
                                     roofline_from_report)

HLO = """
HloModule test

%fused_add (p0: f32[128,256], p1: f32[128,256]) -> f32[128,256] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = f32[128,256]{1,0} parameter(1)
  ROOT %add.1 = f32[128,256]{1,0} add(%p0, %p1)
}

%body (arg: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %arg = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%arg), index=1
  %w = f32[256,256]{1,0} constant({...})
  %dot.5 = f32[128,256]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,256]{1,0} all-reduce(%dot.5), replica_groups={}, to_apply=%fused_add
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%ip, %ar)
}

%cond (arg: (s32[], f32[128,256])) -> pred[] {
  %arg = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%zero, %x)
  %loop = (s32[], f32[128,256]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[128,256]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_shape_bytes():
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(s32[], f32[4,4])") == 4 + 64
    assert _shape_bytes("pred[]") == 1


def test_parse_and_trip_count():
    comps, entry = parse_computations(HLO)
    assert entry == "main"
    assert set(comps) >= {"main", "body", "cond", "fused_add"}


def test_analyzer_multiplies_loop_body():
    rep = analyze_hlo_text(HLO)
    # dot: 2*128*256*256 per iteration, 12 iterations
    assert rep.flops == 12 * 2 * 128 * 256 * 256
    assert rep.trip_counts == [12]
    # all-reduce of 128x256 f32: 2x operand bytes x 12
    assert rep.collective_bytes["all-reduce"] == 12 * 2 * 128 * 256 * 4
    assert rep.collective_counts["all-reduce"] == 12


def test_roofline_terms_and_bound():
    rep = analyze_hlo_text(HLO)
    t = roofline_from_report(rep, chips=256, model_flops=1e15)
    assert t.t_compute == pytest.approx(rep.flops / HW.peak_flops_bf16)
    assert t.t_collective == pytest.approx(
        rep.total_collective_bytes / (HW.ici_bw_per_link * HW.ici_links))
    assert t.bound in ("compute", "memory", "collective")
    assert 0 <= t.roofline_fraction
    d = t.as_dict()
    assert d["bound"] == t.bound


def test_model_flops_definitions():
    from repro.analysis.roofline import active_params, model_flops
    from repro.configs import SHAPES, get_config
    dense = get_config("deepseek_7b")
    moe = get_config("deepseek_moe_16b")
    n_dense = active_params(dense)
    n_moe_total = active_params(moe)
    # MoE active << total: top-6 of 64 experts
    from repro.models import api
    from repro.models.common import count_params
    assert n_moe_total < count_params(api.param_spec(moe)) * 0.5
    mf_train = model_flops(dense, SHAPES["train_4k"])
    mf_decode = model_flops(dense, SHAPES["decode_32k"])
    assert mf_train == pytest.approx(6 * n_dense * 256 * 4096)
    assert mf_decode == pytest.approx(2 * n_dense * 128)
