from . import ops, ref
from .flash_attention import flash_attention_fwd
from .flash_decode import flash_decode
from .mamba_scan import mamba_scan
from .moe_gmm import gmm
from .rmsnorm import rmsnorm
from .slstm_cell import slstm_seq
