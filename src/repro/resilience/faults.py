"""Deterministic, seed-keyed fault injection behind ``REPRO_FAULTS``.

The service asks the injector "does fault *kind* fire now?" at fixed
call sites; the injector answers from a per-kind ``random.Random``
stream keyed ``f"{seed}:{kind}:{check_number}"``.  String seeding goes
through SHA-512 inside :class:`random.Random`, so the same spec produces
the same fire/no-fire sequence in every process regardless of
``PYTHONHASHSEED`` — a chaos run is a *schedule*, not a dice roll.

Spec grammar (the value of the ``REPRO_FAULTS`` env var)::

    seed=42;dispatch_error:p=0.3;stall:p=1.0,ms=1500,n=1;poison:p=0.2

``seed=N`` (optional, default 0) keys every stream; each remaining
``kind:opts`` token enables one fault kind with per-check probability
``p`` (required), an optional payload ``ms`` (stall duration), and an
optional lifetime cap ``n`` (max total fires).  Kinds:

===============  ============================================================
dispatch_error   raise :class:`InjectedFault` from the fused kernel dispatch
stall            sleep ``ms`` inside a tick (drives the watchdog)
poison           overwrite one priced row with NaN after the host fetch
flood            force one admission to report queue_full (backpressure)
recompile        drop the fused jit's executable cache before a dispatch
crash            simulate process death at a tick boundary: in-flight
                 futures get typed ``shutting_down`` envelopes, NO journal
                 terminals are written, and the loop halts — a subsequent
                 resume must replay the journal (drives chaos/restart
                 benches; usually ``n=1``)
===============  ============================================================

A constructed injector with no rules is **falsy**; every production call
site guards with ``if self.faults:`` first, so the disabled path costs
one truthiness check and the hot loop stays allocation-free.
"""
from __future__ import annotations

import dataclasses
import os
import random
from typing import Dict, Optional, Tuple

ENV_VAR = "REPRO_FAULTS"

FAULT_KINDS = ("dispatch_error", "stall", "poison", "flood", "recompile",
               "crash")


class InjectedFault(RuntimeError):
    """Raised by injected ``dispatch_error`` faults (and only by them —
    catching it specifically lets tests distinguish injected failures
    from real ones)."""

    def __init__(self, kind: str, message: str = ""):
        super().__init__(message or f"injected fault: {kind}")
        self.kind = kind


@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One enabled fault kind: fire with probability ``prob`` per check,
    at most ``max_fires`` times total; ``ms`` is the stall payload."""

    kind: str
    prob: float
    ms: float = 0.0
    max_fires: Optional[int] = None


def parse_fault_spec(spec: str) -> Tuple[int, Dict[str, FaultRule]]:
    """Parse a ``REPRO_FAULTS`` spec into ``(seed, {kind: rule})``.

    Raises :class:`ValueError` on unknown kinds/options or malformed
    numbers — a chaos run with a typo'd schedule must fail loudly, not
    silently run fault-free.
    """
    seed = 0
    rules: Dict[str, FaultRule] = {}
    for token in spec.split(";"):
        token = token.strip()
        if not token:
            continue
        if token.startswith("seed="):
            seed = int(token[len("seed="):])
            continue
        kind, _, opt_str = token.partition(":")
        kind = kind.strip()
        if kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} in {ENV_VAR} spec "
                f"(known: {', '.join(FAULT_KINDS)})")
        opts: Dict[str, float] = {}
        for opt in opt_str.split(","):
            opt = opt.strip()
            if not opt:
                continue
            name, _, val = opt.partition("=")
            if name not in ("p", "ms", "n") or not val:
                raise ValueError(
                    f"bad option {opt!r} for fault {kind!r} "
                    f"(expected p=<prob>[,ms=<millis>][,n=<max fires>])")
            opts[name] = float(val)
        if "p" not in opts:
            raise ValueError(f"fault {kind!r} needs p=<prob>")
        prob = opts["p"]
        if not 0.0 <= prob <= 1.0:
            raise ValueError(f"fault {kind!r}: p={prob} outside [0, 1]")
        rules[kind] = FaultRule(
            kind=kind, prob=prob, ms=float(opts.get("ms", 0.0)),
            max_fires=int(opts["n"]) if "n" in opts else None)
    return seed, rules


class FaultInjector:
    """Seed-keyed fault scheduler (see module docstring).

    ``fire(kind)`` returns the kind's :class:`FaultRule` when the fault
    fires at this check and ``None`` otherwise; the caller enacts the
    fault (raise / sleep / mutate).  Check counts and fire counts are
    tracked per kind for ``stats()``.
    """

    def __init__(self, spec: str = ""):
        self.spec = spec or ""
        self.seed, self.rules = parse_fault_spec(self.spec)
        self.checked: Dict[str, int] = {k: 0 for k in self.rules}
        self.fired: Dict[str, int] = {k: 0 for k in self.rules}

    @classmethod
    def from_env(cls) -> "FaultInjector":
        return cls(os.environ.get(ENV_VAR, ""))

    def __bool__(self) -> bool:
        return bool(self.rules)

    def fire(self, kind: str) -> Optional[FaultRule]:
        rule = self.rules.get(kind)
        if rule is None:
            return None
        n = self.checked[kind]
        self.checked[kind] = n + 1
        if rule.max_fires is not None and self.fired[kind] >= rule.max_fires:
            return None
        # One fresh, deterministically keyed stream per check: outcome
        # number n for a kind never depends on how often *other* kinds
        # were checked, so interleaving changes don't reshuffle the
        # schedule.
        if random.Random(f"{self.seed}:{kind}:{n}").random() >= rule.prob:
            return None
        self.fired[kind] += 1
        return rule

    def rng(self, kind: str, n: int) -> random.Random:
        """A deterministic side-stream for fault payloads (e.g. which
        row to poison), keyed like the fire streams."""
        return random.Random(f"{self.seed}:{kind}#payload:{n}")

    def stats(self) -> Dict[str, object]:
        return {
            "enabled": bool(self.rules),
            "spec": self.spec,
            "seed": self.seed,
            "checked": dict(self.checked),
            "fired": dict(self.fired),
        }
