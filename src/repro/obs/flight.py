"""Service flight recorder: a bounded ring buffer of recent ticks and
request lifecycle events, dumpable as a Chrome/Perfetto trace file.

Unlike the span tracer (off by default, timing-oriented), the flight
recorder is **always on and allocation-bounded**: the
:class:`~repro.service.server.PricingService` records every tick and
request event into the ring, so when something goes wrong there is a
recent-history black box to dump — on demand via
``PricingService.dump_flight_recorder()``, or automatically on a tick
failure when ``REPRO_FLIGHT_DIR`` points at a directory.  Recording one
event is a deque append of a small tuple; nothing is serialized until a
dump is requested.
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from collections import deque
from typing import Dict, List, Optional

_ENV_DIR = "REPRO_FLIGHT_DIR"


class FlightRecorder:
    """Bounded ring of ``(t, event, fields)`` records (see module
    docstring).

    Event conventions used by the service: ``tick`` (with
    lane/slots/used/rows/wall_s), ``request`` / ``request_error`` (with
    uid/kind), and ``tick_error`` (with lane/error).  Durationful events
    carry their wall in a ``wall_s`` field and export as complete trace
    events; everything else exports as instants.
    """

    def __init__(self, capacity: int = 2048):
        self.capacity = int(capacity)
        self._events: deque = deque(maxlen=self.capacity)
        self._t0 = time.perf_counter()
        self.n_recorded = 0          # total ever, beyond the ring bound
        self.n_dumps = 0

    def record(self, event: str, **fields):
        """Record one ``event`` (the event name is positional-only by
        convention so ``fields`` can freely carry a ``kind`` key)."""
        self._events.append((time.perf_counter() - self._t0, event, fields))
        self.n_recorded += 1

    def records(self, event: Optional[str] = None) -> List[Dict]:
        return [{"t_s": t, "event": k, **f}
                for t, k, f in list(self._events)
                if event is None or k == event]

    def clear(self):
        self._events.clear()

    def __len__(self) -> int:
        return len(self._events)

    # -- export --------------------------------------------------------------
    def chrome_events(self) -> List[Dict]:
        """The ring as Chrome ``trace_event`` dicts: events that carry a
        ``wall_s`` become complete ("X") spans ending at their record
        time, the rest become instants ("i")."""
        pid = os.getpid()
        out = []
        for t, event, fields in list(self._events):
            args = {k: v for k, v in fields.items() if k != "wall_s"}
            wall = fields.get("wall_s")
            if wall is not None:
                out.append({"name": event, "ph": "X", "cat": "flight",
                            "ts": (t - wall) * 1e6, "dur": wall * 1e6,
                            "pid": pid, "tid": 1, "args": args})
            else:
                out.append({"name": event, "ph": "i", "cat": "flight",
                            "ts": t * 1e6, "s": "t", "pid": pid, "tid": 1,
                            "args": args})
        return out

    def dump(self, path=None, extra_events: Optional[List[Dict]] = None
             ) -> pathlib.Path:
        """Write the ring (plus optional extra trace events, e.g. the span
        tracer's) as one ``trace_event`` JSON file.  Default filename:
        ``flight_<pid>.json`` under ``REPRO_FLIGHT_DIR`` or the cwd."""
        if path is None:
            base = pathlib.Path(os.environ.get(_ENV_DIR) or ".")
            path = base / f"flight_{os.getpid()}_{self.n_dumps}.json"
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        events = self.chrome_events() + list(extra_events or [])
        path.write_text(json.dumps(
            {"traceEvents": events, "displayTimeUnit": "ms"},
            default=float) + "\n")
        self.n_dumps += 1
        return path

    @staticmethod
    def auto_dump_dir() -> Optional[str]:
        """Directory for automatic on-error dumps (``REPRO_FLIGHT_DIR``),
        or None when auto-dumping is disabled."""
        d = os.environ.get(_ENV_DIR, "").strip()
        return d or None
