"""Actuary-as-a-service walkthrough: one in-process PricingService
answering a packaging/node what-if sweep (MCM vs InFO vs 2.5D across
nodes), an evolutionary portfolio search, a Monte-Carlo risk sweep and a
raw spec()-list group — all submitted CONCURRENTLY, coalesced into
shared device ticks, and merged into one report.

  PYTHONPATH=src python examples/pricing_service.py

Every answer is bit-exact against the direct ChunkedEvaluator /
portfolio_search call for the same inputs; the service adds continuous
batching, fairness and observability on top, not a different model.
"""
import asyncio

from repro.dse import DesignSpace, RiskConfig, SKU, Uncertainty
from repro.dse.report import format_table, result_rows, search_summary
from repro.service import (McSpec, MCRiskRequest, PriceSystemsRequest,
                           PricingService, SearchRequest, ServiceConfig,
                           SearchWarmup, WhatIfRequest)

SPACE = DesignSpace(
    skus=(SKU("laptop", 300.0, 2e6), SKU("desktop", 600.0, 1e6),
          SKU("server", 900.0, 3e5)),
    processes=("5nm", "7nm", "12nm"),
    integrations=("MCM", "InFO", "2.5D"),
    chiplet_counts=(1, 2, 3, 4, 6),
    allow_reuse=True, reuse_package_options=(False, True))


async def run():
    svc = PricingService(SPACE, ServiceConfig(
        chunk=128, split=32,
        warm_mc=((128, (0.5, 0.9)),),
        warm_search=(SearchWarmup(population=64, elite=12),)))
    await svc.start()

    # a mid-range candidate to interrogate: 2-way split at 7nm, MCM
    base = next(i for i in range(SPACE.size())
                if SPACE.candidate_at(i).label()
                == "2x/7nm/MCM | 2x/7nm/MCM | 2x/7nm/MCM")

    # four clients, one service: the scheduler coalesces whatever is
    # pending into each tick, so the sweep, the search, the risk query
    # and the raw group interleave instead of queueing head-to-tail.
    what_if, search, risk, raw = await asyncio.gather(
        svc.submit(WhatIfRequest(base=base)),          # full tech grid
        svc.submit(SearchRequest(seed=0, population=64, generations=10,
                                 elite=12)),
        svc.submit(MCRiskRequest(
            indices=[base],
            mc=McSpec(draws=128, quantiles=(0.5, 0.9),
                      sigmas=Uncertainty(defect_sigma=0.3)))),
        svc.submit(PriceSystemsRequest(specs=(
            {"kind": "soc", "name": "mono_server", "area": 900.0,
             "process": "5nm", "quantity": 3e5},
            {"kind": "split", "name": "quad_server", "area": 900.0,
             "n_chiplets": 4, "process": "5nm", "integration": "2.5D",
             "quantity": 3e5},))))
    await svc.stop()
    for r in (what_if, search, risk, raw):
        assert r.ok, r.error

    wi = what_if.result
    print(f"\n== what-if grid around {wi.base_label} "
          f"(${wi.base_cost:,.0f} portfolio) ==")
    print(format_table(sorted(wi.rows, key=lambda r: r["portfolio_cost"]),
                       columns=("process", "integration", "candidate",
                                "portfolio_cost", "delta_vs_base")))
    if wi.skipped:
        print(f"({len(wi.skipped)} combinations outside the space)")

    sr = search.result
    summ = search_summary(sr, top=5)
    print(f"\n== portfolio search: best {summ['best']['candidate']} "
          f"(${summ['best']['portfolio_cost']:,.0f}, "
          f"{summ['n_evaluated']} candidates priced) ==")
    print(format_table(result_rows(sr.top(5)),
                       columns=("candidate", "reuse", "portfolio_cost")))

    stats = risk.result.risk
    print(f"\n== MC risk at the base point ({wi.base_label}) ==")
    print(format_table([{"stat": k, "portfolio_cost": float(v[0])}
                        for k, v in stats.items()]))

    print("\n== raw spec()-group (priced outside the DesignSpace) ==")
    print(format_table(raw.result.rows))

    snap = svc.snapshot()
    print(f"\nservice: {snap['ticks']} ticks "
          f"({snap['device_gets']} device syncs), "
          f"occupancy {snap['slot_occupancy']:.0%}, "
          f"{snap['recompiles_after_warmup']} hot-path recompiles, "
          f"p95 latency {snap['latency_s']['p95']*1e3:.1f} ms")


def main():
    asyncio.run(run())


if __name__ == "__main__":
    main()
