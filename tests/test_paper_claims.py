"""EXPERIMENTS.md validation targets: the paper's stated numbers.

Each test pins one quantitative claim from the paper (Secs. 4-5) to the
model's output — the 'reproduction' evidence.  scripts/calibrate.py
prints the same checks with values.
"""
import pytest

from repro.core import (Module, System, amortized_costs, make_chip,
                        ocme_soc_equivalents, ocme_systems, re_cost,
                        scms_soc_equivalents, scms_systems, soc_system,
                        split_system)


def _amd(cores, n_ccd, iod_area):
    ccd = make_chip("amd_ccd", [Module("amd_ccd_mod", 74.0, "7nm")], "7nm",
                    integration="MCM", early_defects=True)
    iod = make_chip(f"amd_iod_{iod_area}",
                    [Module(f"amd_iod_mod_{iod_area}", iod_area, "12nm")],
                    "12nm", integration="MCM", early_defects=True)
    mcm = System(f"amd{cores}_mcm", tuple([ccd] * n_ccd + [iod]), "MCM")
    mono = soc_system(f"amd{cores}_soc", 74.0 * n_ccd + iod_area, "7nm",
                      early_defects=True)
    return re_cost(mcm), re_cost(mono)


class TestFig5AMD:
    def test_die_cost_saving_up_to_half(self):
        savings = []
        for cores, n_ccd, iod in ((8, 1, 125.0), (16, 2, 125.0),
                                  (32, 4, 416.0)):
            mcm, soc = _amd(cores, n_ccd, iod)
            savings.append(1.0 - mcm.die_cost / soc.die_cost)
        assert 0.42 <= max(savings) <= 0.60          # "up to 50%"
        assert all(s > 0 for s in savings)

    def test_16core_packaging_share_about_30pct(self):
        mcm, _ = _amd(16, 2, 125.0)
        assert 0.22 <= mcm.packaging_cost / mcm.total <= 0.38


class TestFig4Integration:
    def test_5nm_defect_share_exceeds_half_at_800mm2(self):
        soc = re_cost(soc_system("s", 800.0, "5nm"))
        assert soc.chip_defects / soc.total > 0.50

    def test_14nm_multichip_overhead(self):
        mcm3 = re_cost(split_system("m", 900.0, "14nm", 3, "MCM"))
        d25 = re_cost(split_system("d", 900.0, "14nm", 3, "2.5D"))
        mcm_ovh = mcm3.packaging_cost / mcm3.total + 0.10 * \
            mcm3.die_cost / mcm3.total
        d25_ovh = d25.packaging_cost / d25.total + 0.10
        assert mcm_ovh > 0.25                         # ">25% for MCM"
        assert d25_ovh > 0.50                         # ">50% for 2.5D"

    def test_granularity_marginal_utility(self):
        m3 = re_cost(split_system("m3", 800.0, "5nm", 3, "MCM"))
        m5 = re_cost(split_system("m5", 800.0, "5nm", 5, "MCM"))
        defect_saving = (m3.chip_defects - m5.chip_defects) / m3.total
        total_saving = (m3.total - m5.total) / m3.total
        assert defect_saving < 0.12                   # "<10%" + bar slack
        assert total_saving < defect_saving           # "overhead is higher"

    def test_benefit_grows_with_area(self):
        def saving(area):
            soc = re_cost(soc_system("s", area, "5nm")).total
            mcm = re_cost(split_system("m", area, "5nm", 3, "MCM")).total
            return 1 - mcm / soc
        assert saving(800.0) > saving(400.0) > saving(200.0)


class TestFig6SingleSystem:
    def test_nre_shares(self):
        qty = 500_000.0
        cm = amortized_costs(
            [split_system("m", 800.0, "5nm", 2, "MCM", quantity=qty)])["m"]
        assert cm.nre_d2d / cm.total <= 0.025         # "no more than 2%"
        assert cm.nre_packages / cm.total <= 0.09     # "<= 9%"
        assert 0.25 <= cm.nre_chips / cm.total <= 0.45  # "36%"

    def test_soc_wins_at_500k_multichip_pays_back_in_millions(self):
        def ratio(q):
            s = amortized_costs(
                [soc_system("s", 800.0, "5nm", quantity=q)])["s"].total
            m = amortized_costs(
                [split_system("m", 800.0, "5nm", 2, "MCM",
                              quantity=q)])["m"].total
            return s / m
        assert ratio(5e5) < 1.0                       # SoC cheaper at 500k
        assert ratio(4e6) > 1.0                       # multi-chip by ~2M+


class TestFig8SCMS:
    def test_chip_nre_saving_three_quarters(self):
        cm = amortized_costs(scms_systems(integration="MCM"))
        cs = amortized_costs(scms_soc_equivalents())
        saving = 1 - cm["scms_4x_MCM"].nre_chips / \
            cs["scms_4x_soc"].nre_chips
        assert 0.6 <= saving <= 0.9                   # "nearly 3/4"

    def test_package_reuse_tradeoff(self):
        plain = amortized_costs(scms_systems(integration="MCM"))
        reused = amortized_costs(
            scms_systems(integration="MCM", package_reuse=True))
        drop = 1 - reused["scms_4x_MCM"].nre_packages / \
            plain["scms_4x_MCM"].nre_packages
        assert 0.5 <= drop <= 0.8                     # "by two-thirds"
        rise = reused["scms_1x_MCM"].total / plain["scms_1x_MCM"].total - 1
        assert rise > 0.10                            # ">20%" (band)

    def test_25d_interposer_reuse_uneconomic(self):
        reused = amortized_costs(
            scms_systems(integration="2.5D", package_reuse=True))
        share = reused["scms_1x_2.5D"].re.packaging_cost / \
            reused["scms_1x_2.5D"].re.total
        assert share > 0.50                           # "more than 50%"


class TestFig9OCME:
    def test_nre_saving_below_half(self):
        om = amortized_costs(ocme_systems())
        os_ = amortized_costs(ocme_soc_equivalents())
        saving = 1 - om["ocme_CXXY_MCM"].nre_total / \
            os_["ocme_CXXY_soc"].nre_total
        assert 0.0 < saving < 0.55                    # "< 50%"

    def test_heterogeneity_saves_further(self):
        het = amortized_costs(
            ocme_systems(center_process="14nm", package_reuse=True))
        hom = amortized_costs(ocme_systems(package_reuse=True))
        drop = 1 - het["ocme_CXXY_MCM"].total / hom["ocme_CXXY_MCM"].total
        assert drop >= 0.05                           # "more than 10%" band
        drop_c = 1 - het["ocme_C_MCM"].total / hom["ocme_C_MCM"].total
        assert drop_c >= 0.25                         # "almost half"


class TestFig10FSMC:
    def test_count_formula(self):
        from repro.core import fsmc_num_systems
        # paper's formula sum_{i=1..k} C(n+i-1, i)
        assert fsmc_num_systems(6, 4) == 209
        assert fsmc_num_systems(7, 3) == 119   # the paper's quoted "119"

    def test_more_reuse_lower_amortized_nre(self):
        from repro.core import fsmc_situations
        sits = fsmc_situations(n_chiplets=4, k_sockets=3, n_situations=3,
                               quantity=500_000.0)
        avg_nre = []
        for n, systems in sorted(sits.items()):
            costs = amortized_costs(systems)
            avg_nre.append(sum(c.nre_total for c in costs.values())
                           / len(costs))
        assert avg_nre == sorted(avg_nre, reverse=True)
