"""MiniCPM3-4B — Multi-head Latent Attention. [hf:openbmb/MiniCPM3-4B; hf]

62L, d_model 2560, 40 heads, d_ff 6400, vocab 73448.  MLA: q_lora 768,
kv_lora 256, qk_nope 64, qk_rope 32, v_head 64 (per HF config.json).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense", attn="mla",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, head_dim=64,
    q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64,
    subquadratic=False,
)
