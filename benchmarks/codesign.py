"""Beyond-paper bridge: price TPU-class accelerator packagings with the
faithful Chiplet Actuary model and combine with the dry-run rooflines
into $/step — the paper's early-stage decision method applied to the
hardware this framework targets."""
import json
from pathlib import Path

from repro.core import AcceleratorSpec, cost_per_step, price_accelerators
from .common import emit

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun.json"


def run():
    spec = AcceleratorSpec(name="tpu_v5e_class", compute_area=300.0,
                           uncore_area=60.0, phy_area=80.0, process="5nm",
                           phy_process="14nm")
    prices = price_accelerators(spec, quantity=1e6)
    rows = [{"packaging": k, **{kk: vv for kk, vv in v.items()}}
            for k, v in prices.items()]
    emit("codesign_accelerator_pricing", rows)

    if RESULTS.exists():
        results = json.loads(RESULTS.read_text())
        cheapest = min(prices.items(), key=lambda kv: kv[1]["unit_cost"])
        rows2 = []
        for key in ("glm4_9b|train_4k|16x16",
                    "mistral_large_123b|train_4k|16x16",
                    "deepseek_v2_236b|prefill_32k|16x16"):
            v = results.get(key)
            if not v or v["status"] != "ok":
                continue
            r = v["roofline"]
            cell = {"t_compute": r["t_compute"], "t_memory": r["t_memory"],
                    "t_collective": r["t_collective"],
                    "hlo_flops": r["flops_per_device"] * r["chips"]}
            cps = cost_per_step(cell, cheapest[1]["unit_cost"], r["chips"])
            rows2.append({"cell": key, "packaging": cheapest[0], **cps})
        emit("codesign_cost_per_step", rows2)
    return rows


if __name__ == "__main__":
    run()
