"""Service observability: per-request latency, tick occupancy, cache and
recompile counters — exported as a JSON snapshot for the bench and tests.

Three measurement surfaces:

* **requests** — submit -> first-result -> done latencies per request
  (the continuous-batching promise: point queries stay fast while sweeps
  stream), split by request kind.
* **ticks** — slot occupancy vs padded waste per device tick — reported
  **per lane** (chunk / mc / gen / raw) and in aggregate, so search
  (``gen``) work is no longer a blind spot — plus the
  one-``device_get``-per-tick invariant counter.
* **caches/traces** — result-cache hit rates and post-warmup recompile
  counts (folded in from the cache layer at snapshot time).

Every counter is also mirrored into the stack-wide
:data:`repro.obs.registry.REGISTRY` (``service_*`` instruments), so one
text/JSON scrape of the registry sees the service next to the engine's
trace counters and the jit probes.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

from ..obs.registry import REGISTRY


@dataclasses.dataclass
class RequestRecord:
    kind: str
    n_rows: int
    t_submit: float
    t_first: float = 0.0
    t_done: float = 0.0
    ok: bool = True
    cached: bool = False
    trace_id: str = ""

    @property
    def latency_s(self) -> float:
        return max(0.0, self.t_done - self.t_submit)

    @property
    def ttfr_s(self) -> float:
        return max(0.0, self.t_first - self.t_submit)


def _quantiles(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0}
    a = np.asarray(xs, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean())}


@dataclasses.dataclass
class LaneStats:
    """Per-lane tick accounting (one row per lane kind)."""

    ticks: int = 0
    slots_used: int = 0
    slots_total: int = 0
    rows_priced: int = 0
    busy_s: float = 0.0

    @property
    def occupancy(self) -> float:
        return self.slots_used / self.slots_total if self.slots_total \
            else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"ticks": self.ticks, "slots_used": self.slots_used,
                "slots_total": self.slots_total,
                "rows_priced": self.rows_priced, "busy_s": self.busy_s,
                "occupancy": self.occupancy,
                "padded_waste_frac": (1.0 - self.occupancy
                                      if self.slots_total else 0.0)}


# Every resilience counter the service tracks, with its registry help
# string.  One flat namespace: the snapshot block and the Prometheus
# mirror (service_<name>) stay in lockstep by construction.
_RESILIENCE_COUNTERS = {
    "retries": "fused dispatch retry attempts",
    "fused_failures": "fused dispatch attempts that raised",
    "fallback_ticks": "ticks priced via the legacy host-packing fallback",
    "fallback_rows": "rows priced in degraded (fallback) mode",
    "fallback_busy_s": "wall seconds inside fallback pricing",
    "breaker_opens": "circuit breaker closed/half_open -> open transitions",
    "breaker_closes": "circuit breaker -> closed transitions",
    "breaker_probes": "circuit breaker half-open probe admissions",
    "deadline_rejected": "requests failed with deadline_exceeded",
    "numerical_errors": "requests failed with numerical_error",
    "cancelled": "requests cancelled by the client before completion",
    "watchdog_trips": "stuck-tick watchdog trips",
    "watchdog_dumps": "flight-recorder dumps triggered by the watchdog",
    "loop_errors": "exceptions that escaped a tick into the loop guard",
    "loop_restarts": "tick-loop tasks relaunched after dying",
    "faults_injected": "REPRO_FAULTS faults actually fired",
}


# Durability counters: the crash-safety mirror of the resilience block.
# Journal I/O counters are forwarded by the RequestJournal's stats_hook;
# the lifecycle counters are bumped by the service directly.
_DURABILITY_COUNTERS = {
    "journal_appends": "journal records appended (admit/done/meta)",
    "journal_fsyncs": "journal fsync barriers issued",
    "journal_rotations": "journal segment rotations",
    "journal_replayed": "admitted requests re-admitted from the journal",
    "checkpoints_written": "search checkpoints published (atomic rename)",
    "checkpoints_restored": "search lanes restored from a checkpoint",
    "checkpoint_corrupt_fallbacks":
        "corrupt checkpoint steps skipped during restore",
    "checkpoints_removed": "search checkpoint dirs removed on completion",
    "drain_calls": "stop() invocations that entered the drain path",
    "drain_timeouts": "drains that hit drain_timeout_s",
    "drain_rejected": "in-flight requests typed-rejected at drain deadline",
    "drain_checkpointed": "searches checkpointed at the drain deadline",
    "crashes": "simulated crashes (REPRO_FAULTS crash kind) enacted",
}


class DurabilityStats:
    """Crash-safety counters owned by one :class:`PricingService`.

    Same contract as :class:`ResilienceStats`: ``bump(name)`` updates the
    local field and mirrors ``service_<name>`` into the registry, so
    ``svc.snapshot()["durability"]`` and a scrape always agree.
    """

    def __init__(self):
        self.reset()

    def reset(self):
        for name in _DURABILITY_COUNTERS:
            setattr(self, name, 0)

    def bump(self, name: str, n=1):
        if name not in _DURABILITY_COUNTERS:
            raise KeyError(f"unknown durability counter {name!r}")
        setattr(self, name, getattr(self, name) + n)
        REGISTRY.counter(f"service_{name}",
                         help=_DURABILITY_COUNTERS[name]).inc(n)

    def snapshot(self) -> Dict:
        return {name: getattr(self, name) for name in _DURABILITY_COUNTERS}


class ResilienceStats:
    """Failure-handling counters owned by one :class:`PricingService`.

    ``bump(name)`` increments the local field and mirrors it into the
    stack-wide registry as ``service_<name>`` — the satellite obs
    contract: ``svc.snapshot()["resilience"]`` and a Prometheus scrape
    always agree.
    """

    def __init__(self):
        self.reset()

    def reset(self):
        for name in _RESILIENCE_COUNTERS:
            setattr(self, name, 0.0 if name.endswith("_s") else 0)

    def bump(self, name: str, n=1):
        if name not in _RESILIENCE_COUNTERS:
            raise KeyError(f"unknown resilience counter {name!r}")
        setattr(self, name, getattr(self, name) + n)
        REGISTRY.counter(f"service_{name}",
                         help=_RESILIENCE_COUNTERS[name]).inc(n)

    def snapshot(self) -> Dict:
        return {name: getattr(self, name) for name in _RESILIENCE_COUNTERS}


class ServiceMetrics:
    """Mutable counters owned by one :class:`PricingService`."""

    def __init__(self):
        self.reset()

    def reset(self):
        self.requests: List[RequestRecord] = []
        self.n_errors = 0
        self.n_rejected = 0                  # backpressure rejections
        self.ticks = 0
        self.device_gets = 0
        self.slots_used = 0
        self.slots_total = 0
        self.gen_ticks = 0
        self.rows_priced = 0                 # candidate rows through kernels
        self.busy_s = 0.0                    # wall inside ticks
        self.per_lane: Dict[str, LaneStats] = {}
        self.t_start = time.perf_counter()

    # -- request lifecycle ---------------------------------------------------
    def start_request(self, kind: str, n_rows: int, t_submit: float,
                      trace_id: str = "") -> RequestRecord:
        rec = RequestRecord(kind=kind, n_rows=n_rows, t_submit=t_submit,
                            trace_id=trace_id)
        self.requests.append(rec)
        REGISTRY.counter("service_requests",
                         help="requests submitted").inc()
        return rec

    def reject(self):
        self.n_rejected += 1
        REGISTRY.counter("service_rejected",
                         help="backpressure rejections").inc()

    def finish_request(self, rec: RequestRecord, ok: bool,
                       cached: bool = False):
        rec.t_done = time.perf_counter()
        if not rec.t_first:
            rec.t_first = rec.t_done
        rec.ok = ok
        rec.cached = cached
        if not ok:
            self.n_errors += 1
            REGISTRY.counter("service_errors",
                             help="requests finished not-ok").inc()
        else:
            # the trace_id exemplar ties the latency distribution back to
            # concrete traced requests (OpenMetrics-style)
            REGISTRY.histogram("service_latency_s",
                               help="ok-request latency").observe(
                rec.latency_s, exemplar=rec.trace_id or None)

    # -- tick accounting -----------------------------------------------------
    def record_tick(self, lane_kind: str, slots: int, used: int,
                    rows_priced: int, wall_s: float):
        """One device tick.  ``gen`` lanes price their whole population
        every tick, so callers pass ``slots == used == rows_priced`` for
        them — search work counts toward occupancy and rows like every
        other lane instead of being silently excluded."""
        self.ticks += 1
        self.device_gets += 1        # the tick loop does exactly one get
        self.busy_s += wall_s
        self.rows_priced += rows_priced
        self.slots_used += used
        self.slots_total += slots
        lane = self.per_lane.setdefault(lane_kind, LaneStats())
        lane.ticks += 1
        lane.slots_used += used
        lane.slots_total += slots
        lane.rows_priced += rows_priced
        lane.busy_s += wall_s
        if lane_kind == "gen":
            self.gen_ticks += 1
        REGISTRY.counter("service_ticks", help="device ticks").inc()
        REGISTRY.counter("service_rows_priced",
                         help="candidate rows priced").inc(rows_priced)
        REGISTRY.counter(f"service_ticks_{lane_kind}").inc()

    # -- snapshot ------------------------------------------------------------
    def snapshot(self, trace_stats: Optional[Dict] = None,
                 cache_stats: Optional[Dict] = None) -> Dict:
        done = [r for r in self.requests if r.t_done]
        ok = [r for r in done if r.ok]
        snap = {
            "n_requests": len(self.requests),
            "n_done": len(done),
            "n_ok": len(ok),
            "n_errors": self.n_errors,
            "n_rejected": self.n_rejected,
            "requests_by_kind": {
                k: sum(1 for r in done if r.kind == k)
                for k in sorted({r.kind for r in done})},
            "latency_s": _quantiles([r.latency_s for r in ok]),
            "ttfr_s": _quantiles([r.ttfr_s for r in ok]),
            "ticks": self.ticks,
            "device_gets": self.device_gets,
            "gen_ticks": self.gen_ticks,
            "ticks_by_lane": {k: v.ticks for k, v in self.per_lane.items()},
            "per_lane": {k: v.as_dict() for k, v in self.per_lane.items()},
            "slot_occupancy": (self.slots_used / self.slots_total
                               if self.slots_total else 0.0),
            "padded_waste_frac": (1.0 - self.slots_used / self.slots_total
                                  if self.slots_total else 0.0),
            "rows_priced": self.rows_priced,
            "busy_s": self.busy_s,
            "rows_per_sec_busy": (self.rows_priced / self.busy_s
                                  if self.busy_s > 0 else 0.0),
            "wall_s": time.perf_counter() - self.t_start,
        }
        if trace_stats is not None:
            snap["trace"] = dict(trace_stats)
            snap["recompiles_after_warmup"] = \
                trace_stats.get("tick_recompiles", 0)
        if cache_stats is not None:
            snap["result_cache"] = dict(cache_stats)
        return snap

    def write_json(self, path, trace_stats=None, cache_stats=None
                   ) -> pathlib.Path:
        path = pathlib.Path(path)
        path.write_text(json.dumps(
            self.snapshot(trace_stats, cache_stats), indent=2,
            sort_keys=True, default=float) + "\n")
        return path
