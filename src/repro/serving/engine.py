"""Slot-based continuous-batching serving engine.

vLLM-style scheduling adapted to JAX's static shapes:

* a fixed pool of `n_slots` sequence slots shares one decode KV cache
  (slot = batch row; cache rows are reused after a sequence finishes);
* arriving requests are prefilled one at a time (prefill_fn), and their
  KV is spliced into the slot row; decode ticks run the whole pool every
  step (serve_step), so new sequences join mid-flight — continuous
  batching without recompilation;
* finished sequences (EOS or max_new_tokens) free their slot.

The same engine drives the dry-run decode cells (serve_step) and the CPU
example (examples/serve_batch.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig, InputShape
from ..models import api


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never stops early
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    n_slots: int = 8
    cache_len: int = 512


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, serve_cfg: ServeConfig):
        if cfg.family == "encdec":
            raise NotImplementedError(
                "engine drives decoder-only LMs; whisper uses launch/serve")
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        shape = InputShape("engine", serve_cfg.cache_len,
                           serve_cfg.n_slots, "decode")
        from ..models.common import init_params
        self.cache = init_params(api.cache_spec(cfg, shape),
                                 jax.random.PRNGKey(0))
        self.kv_len = jnp.zeros((serve_cfg.n_slots,), jnp.int32)
        self.tokens = jnp.zeros((serve_cfg.n_slots, 1), jnp.int32)
        self.active: List[Optional[Request]] = [None] * serve_cfg.n_slots
        self.queue: deque = deque()
        self._decode = jax.jit(api.decode_fn(cfg))
        self._prefill = {}
        self.steps = 0
        self.finished: List[Request] = []

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        req.t_submit = time.time()
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill:
            self._prefill[plen] = jax.jit(
                api.prefill_fn(self.cfg, self.sc.cache_len))
        return self._prefill[plen]

    def _splice(self, slot: int, req: Request):
        """Prefill one request and write its KV/state into `slot`."""
        plen = int(req.prompt.shape[0])
        batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
        logits, cache1 = self._prefill_fn(plen)(self.params, batch)
        # copy row 0 of the single-seq cache into slot row of pool cache
        def put(pool, one):
            # batch axis = the dim where the pool (n_slots) differs from
            # the single-sequence cache (1); handles any stacking depth.
            diffs = [i for i, (p, o) in enumerate(zip(pool.shape, one.shape))
                     if p != o]
            if diffs:
                b_axis = diffs[0]
            else:
                cands = [i for i, p in enumerate(pool.shape)
                         if p == self.sc.n_slots]
                b_axis = cands[0] if cands else 0
            idx = [slice(None)] * pool.ndim
            idx[b_axis] = slot
            src = jnp.take(one, 0, axis=b_axis)
            return pool.at[tuple(idx)].set(src.astype(pool.dtype))
        self.cache = jax.tree_util.tree_map(put, self.cache, cache1)
        next_tok = int(jnp.argmax(logits[0]))
        req.output.append(next_tok)
        req.t_first = time.time()
        self.active[slot] = req
        self.kv_len = self.kv_len.at[slot].set(plen)
        self.tokens = self.tokens.at[slot, 0].set(next_tok)

    # -- main loop ---------------------------------------------------------

    def step(self) -> int:
        """Admit + one decode tick for the whole pool. Returns #active."""
        for slot in self._free_slots():
            if not self.queue:
                break
            self._splice(slot, self.queue.popleft())
        if not any(r is not None for r in self.active):
            return 0
        logits, self.cache = self._decode(self.params, self.tokens,
                                          self.cache, self.kv_len)
        self.kv_len = self.kv_len + jnp.asarray(
            [1 if r is not None else 0 for r in self.active], jnp.int32)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.steps += 1
        for i, r in enumerate(self.active):
            if r is None:
                continue
            tok = int(nxt[i])
            r.output.append(tok)
            done = (len(r.output) >= r.max_new_tokens
                    or tok == r.eos_id
                    or int(self.kv_len[i]) >= self.sc.cache_len - 1)
            if done:
                r.t_done = time.time()
                self.finished.append(r)
                self.active[i] = None
            else:
                self.tokens = self.tokens.at[i, 0].set(tok)
        return sum(r is not None for r in self.active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        while (self.queue or any(r is not None for r in self.active)) \
                and self.steps < max_steps:
            self.step()
        return self.finished
