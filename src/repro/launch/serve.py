"""Serving launcher: continuous-batching engine over a synthetic
request trace; reports throughput / TTFT / latency percentiles.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm_125m --smoke \
      --requests 24 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ARCH_IDS, get_config
from repro.models.common import init_params
from repro.models import api
from repro.serving import Request, ServeConfig, ServingEngine


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS, default="xlstm_125m")
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--cache-len", type=int, default=128)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-new", type=int, default=24)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    cfg = cfg.replace(dtype="float32")
    if cfg.family in ("encdec",):
        raise SystemExit("serve drives decoder-only archs")

    params = init_params(api.param_spec(cfg), jax.random.PRNGKey(args.seed))
    engine = ServingEngine(cfg, params,
                           ServeConfig(n_slots=args.slots,
                                       cache_len=args.cache_len))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for uid in range(args.requests):
        plen = int(rng.integers(4, args.prompt_len + 1))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.max_new))
    finished = engine.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in finished)
    ttft = sorted(r.t_first - r.t_submit for r in finished)
    lat = sorted(r.t_done - r.t_submit for r in finished)
    print(f"served {len(finished)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {engine.steps} decode ticks)")
    if finished:
        print(f"TTFT p50 {ttft[len(ttft)//2]*1e3:.0f}ms  "
              f"p95 {ttft[int(len(ttft)*0.95)-1]*1e3:.0f}ms   "
              f"latency p50 {lat[len(lat)//2]*1e3:.0f}ms  "
              f"p95 {lat[int(len(lat)*0.95)-1]*1e3:.0f}ms")
    return 0 if len(finished) == args.requests else 1


if __name__ == "__main__":
    raise SystemExit(main())
