"""Fine-grained Mixture-of-Experts (DeepSeekMoE-style).

Shared experts (always-on dense SwiGLU) + routed experts with top-k
softmax routing, implemented with the sort-based capacity dispatch:

  1. flatten tokens, top-k expert ids per token;
  2. stable-sort the (token, expert) pairs by expert id;
  3. position-in-expert = rank within the sorted run; slots >= capacity drop;
  4. gather into an (E, C, D) buffer, batched expert SwiGLU (einsum over E —
     expert-parallel under GSPMD), scatter back, weighted combine.

This avoids the O(N·E·C) one-hot dispatch tensor of GShard-style code and
maps onto the all-to-all the TPU mesh wants.  ``moe_ref`` (dense
every-expert evaluation) is the oracle for tests; with a generous
capacity factor the two agree exactly.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, swiglu, swiglu_spec


def moe_spec(d_model: int, n_experts: int, d_ff_expert: int,
             n_shared: int) -> Dict:
    sp = {
        "router": ParamSpec((d_model, n_experts), ("embed", None),
                            scale=0.02),
        "w_gate": ParamSpec((n_experts, d_model, d_ff_expert),
                            ("experts", "embed", "mlp")),
        "w_up": ParamSpec((n_experts, d_model, d_ff_expert),
                          ("experts", "embed", "mlp")),
        "w_down": ParamSpec((n_experts, d_ff_expert, d_model),
                            ("experts", "mlp", "embed")),
    }
    if n_shared > 0:
        sp["shared"] = swiglu_spec(d_model, d_ff_expert * n_shared)
    return sp


def route(params, x_flat, top_k: int):
    """Router probs -> (weights, ids), weights renormalized over top-k."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, top_k)          # (N,k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    return weights, ids, probs


def aux_load_balance_loss(probs, ids, n_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * p_e."""
    n = probs.shape[0]
    counts = jnp.zeros((n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    frac = counts / jnp.maximum(ids.size, 1)
    mean_p = probs.mean(axis=0)
    return n_experts * jnp.sum(frac * mean_p)


def moe_apply(params, x, top_k: int, capacity_factor: float = 1.25,
              return_aux: bool = False):
    """x: (B,S,D) -> (B,S,D).  Sort-based dispatch, see module docstring."""
    from ..parallel.sharding import constrain
    b, s, d = x.shape
    e = params["router"].shape[1]
    n = b * s
    # Dispatch layout: token rows replicated, FEATURE axis model-sharded
    # — row gathers/scatters stay local (no per-block all-gather of the
    # token table); one reshard in, one out.
    xf = constrain(x.reshape(n, d), None, "mlp")
    weights, ids, probs = route(params, xf, top_k)

    nk = n * top_k
    cap = int(max(1, (n * top_k / e) * capacity_factor))
    flat_ids = ids.reshape(nk)
    flat_w = weights.reshape(nk)
    tok = jnp.repeat(jnp.arange(n), top_k)

    order = jnp.argsort(flat_ids, stable=True)
    s_ids = flat_ids[order]
    s_tok = tok[order]
    s_w = flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_ids].add(1)
    starts = jnp.cumsum(counts) - counts                # exclusive prefix
    pos = jnp.arange(nk) - starts[s_ids]
    # over-capacity slots get pos == cap: out of bounds => mode="drop"
    # on the write, fill 0 on the read — no (NK, D) mask multiplies.
    pos_c = jnp.where(pos < cap, pos, cap)

    # Gather tokens into the (E, C, D) expert buffer.  The (NK, D)
    # gather transient is feature-sharded (constrain above), so its
    # per-device footprint is NK x D/|model| — bounded.
    gathered = constrain(xf[s_tok], None, "mlp")
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[s_ids, pos_c].add(gathered, mode="drop")

    # Batched expert SwiGLU (einsum over the expert axis => EP shardable).
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    # Weighted combine: scatter-add straight into the (N, D) output
    # (skips the (NK, D) un-permute buffer and the (N, k, D) sum).
    slot_out = constrain(
        out_buf.at[s_ids, pos_c].get(mode="fill", fill_value=0),
        None, "mlp")
    y = constrain(jnp.zeros((n, d), x.dtype), None, "mlp").at[s_tok].add(
        slot_out * s_w[:, None].astype(x.dtype))

    if "shared" in params:
        y = y + swiglu(params["shared"], xf)
    y = y.reshape(b, s, d)
    if return_aux:
        return y, aux_load_balance_loss(probs, ids, e)
    return y


def moe_ref(params, x, top_k: int):
    """Oracle: evaluate EVERY expert for every token, dense mixture."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    weights, ids, _ = route(params, xf, top_k)
    g = jnp.einsum("nd,edf->nef", xf, params["w_gate"])
    u = jnp.einsum("nd,edf->nef", xf, params["w_up"])
    h = jax.nn.silu(g) * u
    all_out = jnp.einsum("nef,efd->ned", h, params["w_down"])  # (N,E,D)
    sel = jnp.take_along_axis(all_out, ids[..., None], axis=1)  # (N,k,D)
    y = (sel * weights[..., None]).sum(axis=1).astype(x.dtype)
    if "shared" in params:
        y = y + swiglu(params["shared"], xf)
    return y.reshape(b, s, d)
