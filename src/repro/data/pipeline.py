"""Deterministic sharded data pipeline.

Two sources behind one interface:

* synthetic    — stateless hash-based token streams: batch(step, shard)
                 is a pure function, so restarts NEVER replay or skip
                 data and any host can regenerate any shard (the
                 determinism property the fault-tolerance story needs).
* binary file  — fixed-record uint16/uint32 token shards, memory-mapped,
                 with the same (step, shard) -> records indexing.

Skip-ahead is O(1): resuming at step N just evaluates the index map at N.
"""
from __future__ import annotations

import dataclasses
import hashlib
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    n_shards: int = 1           # data-parallel shards (hosts)
    shard_id: int = 0
    seed: int = 1234


# ---------------------------------------------------------------------------
# Synthetic source
# ---------------------------------------------------------------------------


def _philox(seed: int, step: int, shard: int, n: int) -> np.ndarray:
    """Counter-based deterministic stream (Philox via numpy Generator)."""
    key = np.uint64((seed << 24) ^ (step << 8) ^ shard)
    return np.random.Generator(np.random.Philox(key=key)).integers(
        0, 2 ** 31 - 1, size=n, dtype=np.int64)


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Tokens + next-token labels for (step, shard); pure function."""
    per_shard = cfg.global_batch // cfg.n_shards
    n = per_shard * (cfg.seq_len + 1)
    raw = _philox(cfg.seed, step, cfg.shard_id, n) % cfg.vocab
    raw = raw.reshape(per_shard, cfg.seq_len + 1)
    return {"tokens": raw[:, :-1].astype(np.int32),
            "labels": raw[:, 1:].astype(np.int32)}


# ---------------------------------------------------------------------------
# Binary-file source (fixed-record token shards)
# ---------------------------------------------------------------------------

MAGIC = b"RPTK0001"


class BinaryShardWriter:
    """Write a token shard: header (magic, dtype, seq_len+1) + records."""

    def __init__(self, path: Path, seq_len: int, dtype=np.uint16):
        self.path = Path(path)
        self.seq_len = seq_len
        self.dtype = np.dtype(dtype)
        self._f = open(self.path, "wb")
        self._f.write(MAGIC)
        self._f.write(np.uint32(self.dtype.itemsize).tobytes())
        self._f.write(np.uint32(seq_len + 1).tobytes())
        self.n = 0

    def add(self, record: np.ndarray):
        assert record.shape == (self.seq_len + 1,)
        self._f.write(record.astype(self.dtype).tobytes())
        self.n += 1

    def close(self):
        self._f.close()


class TokenDataset:
    """Memory-mapped fixed-record reader with (step, shard) indexing."""

    def __init__(self, path: Path):
        self.path = Path(path)
        with open(self.path, "rb") as f:
            magic = f.read(8)
            if magic != MAGIC:
                raise ValueError(f"{path}: bad magic {magic!r}")
            itemsize = int(np.frombuffer(f.read(4), np.uint32)[0])
            self.record_len = int(np.frombuffer(f.read(4), np.uint32)[0])
        self.dtype = {2: np.uint16, 4: np.uint32}[itemsize]
        header = 16
        self._mm = np.memmap(self.path, dtype=self.dtype, mode="r",
                             offset=header)
        self.n_records = self._mm.size // self.record_len
        self._mm = self._mm[:self.n_records * self.record_len].reshape(
            self.n_records, self.record_len)

    def batch(self, cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
        per_shard = cfg.global_batch // cfg.n_shards
        base = (step * cfg.global_batch + cfg.shard_id * per_shard)
        idx = (base + np.arange(per_shard)) % self.n_records
        recs = np.asarray(self._mm[idx], dtype=np.int64)
        return {"tokens": recs[:, :-1].astype(np.int32),
                "labels": recs[:, 1:].astype(np.int32)}


def make_batches(cfg: DataConfig, start_step: int = 0,
                 dataset: Optional[TokenDataset] = None
                 ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite deterministic batch iterator with O(1) skip-ahead."""
    step = start_step
    while True:
        if dataset is not None:
            yield dataset.batch(cfg, step)
        else:
            yield synthetic_batch(cfg, step)
        step += 1
