"""Calibration check: does the model reproduce the paper's stated numbers?

Run: PYTHONPATH=src python scripts/calibrate.py
"""
import sys

from repro.core import (amortized_costs, best_partition, re_cost,
                        soc_system, split_system, scms_systems,
                        scms_soc_equivalents, ocme_systems,
                        ocme_soc_equivalents)


def check(label, value, lo, hi):
    ok = lo <= value <= hi
    print(f"{'OK ' if ok else 'FAIL'} {label}: {value:.3f} (target [{lo},{hi}])")
    return ok


def main():
    ok = True
    print("== Fig 5: AMD 16-core (7nm CCDs early-D0 0.13, 12nm IOD 0.12) ==")
    # Chiplet version: 2x 80mm^2 CCD (7nm) + 125mm^2 IOD (12nm), MCM.
    from repro.core import Module, System, make_chip
    ccd_m = Module("amd_ccd_mod", 74.0, "7nm")
    ccd = make_chip("amd_ccd", [ccd_m], "7nm", integration="MCM", early_defects=True)

    savings = {}
    for cores, n_ccd, iod_area in ((8, 1, 125.0), (16, 2, 125.0), (32, 4, 416.0)):
        iod_m = Module(f"amd_iod_mod_{iod_area}", iod_area, "12nm")
        iod = make_chip(f"amd_iod_{iod_area}", [iod_m], "12nm",
                        integration="MCM", early_defects=True)
        mcm = System(f"amd{cores}_mcm", tuple([ccd] * n_ccd + [iod]), "MCM", 1.0)
        # Hypothetical monolithic on 7nm; IO/analog area does not scale.
        mono = soc_system(f"amd{cores}_soc", 74.0 * n_ccd + iod_area, "7nm",
                          early_defects=True)
        re_mcm, re_soc = re_cost(mcm), re_cost(mono)
        savings[cores] = 1.0 - re_mcm.die_cost / re_soc.die_cost
        if cores == 16:
            pkg_share = re_mcm.packaging_cost / re_mcm.total
        print(f"   {cores}-core: die saving {savings[cores]:.3f}")
    ok &= check("max die-cost saving across family (~50%)", max(savings.values()), 0.42, 0.60)
    ok &= check("16-core die saving positive/sizable", savings[16], 0.25, 0.55)
    ok &= check("packaging share of 16c (~30%)", pkg_share, 0.22, 0.38)

    print("== Fig 4: 14nm 900mm^2 ==")
    soc = re_cost(soc_system("s14", 900.0, "14nm"))
    mcm3 = re_cost(split_system("m14", 900.0, "14nm", 3, "MCM"))
    d25 = re_cost(split_system("d14", 900.0, "14nm", 3, "2.5D"))
    # overhead = packaging share + D2D silicon share (10% of die area)
    ok &= check("MCM overhead >25% (pkg+d2d share)",
                mcm3.packaging_cost / mcm3.total + 0.10 * mcm3.die_cost / mcm3.total,
                0.25, 0.60)
    ok &= check("2.5D overhead >50%", d25.packaging_cost / d25.total + 0.10, 0.45, 0.75)
    ok &= check("14nm yield-saving up to 35% (die only)",
                1 - (mcm3.die_cost) / (soc.die_cost), 0.10, 0.40)

    print("== Fig 4: 5nm 800mm^2 defect share >50% ==")
    soc5 = re_cost(soc_system("s5", 800.0, "5nm"))
    ok &= check("die-defect share of monolithic total", soc5.chip_defects / soc5.total, 0.50, 0.70)

    print("== granularity: 3->5 chiplets at 5nm 800mm^2 MCM <10% ==")
    m3 = re_cost(split_system("m3", 800.0, "5nm", 3, "MCM"))
    m5 = re_cost(split_system("m5", 800.0, "5nm", 5, "MCM"))
    # paper: "the cost-saving of die defects is more negligible (<10%) ...
    # and the overhead is higher".  Two assertions: the die-defect saving
    # is ~<10% (bar-chart reading slack to 0.12), and packaging overhead
    # GROWS with n so the net total saving is strictly below the die-defect
    # saving (overhead eats part of it).
    defect_saving = (m3.chip_defects - m5.chip_defects) / m3.total
    total_saving = (m3.total - m5.total) / m3.total
    ok &= check("3->5 die-defect cost saving <~10%", defect_saving, -0.05, 0.12)
    ok &= check("3->5 overhead higher (total saving < defect saving)",
                total_saving - defect_saving, -0.20, -0.005)
    ok &= check("3->5 total saving marginal", total_saving, -0.20, 0.10)

    print("== Fig 6: 800mm^2 5nm single system, 500k qty ==")
    qty = 500_000.0
    soc_sys = soc_system("single_soc", 800.0, "5nm", quantity=qty)
    mcm_sys = split_system("single_mcm", 800.0, "5nm", 2, "MCM", quantity=qty)
    cs = amortized_costs([soc_sys])["single_soc"]
    cm = amortized_costs([mcm_sys])["single_mcm"]
    ok &= check("D2D NRE share <=2%", cm.nre_d2d / cm.total, 0.0, 0.025)
    ok &= check("package NRE share <=9%", cm.nre_packages / cm.total, 0.0, 0.09)
    ok &= check("chip NRE share ~36%", cm.nre_chips / cm.total, 0.25, 0.45)
    print(f"   SoC total {cs.total:.0f} vs MCM total {cm.total:.0f} (SoC should win at 500k)")
    ok &= check("SoC cheaper at 500k", cs.total / cm.total, 0.0, 1.0)
    def ratio(q, integ):
        s = soc_system("s", 800.0, "5nm", quantity=q)
        m = split_system("m", 800.0, "5nm", 2, integ, quantity=q)
        return amortized_costs([s])["s"].total / amortized_costs([m])["m"].total

    for q in (1e6, 2e6, 4e6, 8e6):
        print(f"   qty {q:.0e}: SoC/MCM = {ratio(q, 'MCM'):.3f}")

    def crossing(integ):
        lo_q, hi_q = 1e5, 1e9
        if ratio(hi_q, integ) < 1.0:
            return float("inf")
        for _ in range(60):
            mid = (lo_q * hi_q) ** 0.5
            if ratio(mid, integ) < 1.0:
                lo_q = mid
            else:
                hi_q = mid
        return lo_q / 1e6

    xs = {integ: crossing(integ) for integ in ("MCM", "InFO", "2.5D")}
    print(f"   pay-back crossings (M units): {xs} (paper: ~2M)")
    # MCM crosses earliest; the paper's ~2M lands between our MCM and
    # InFO crossings — exact position depends on confidential NRE constants.
    ok &= check("MCM pay-back crossing (M units)", xs["MCM"], 0.3, 3.0)
    ok &= check("some integration crosses near 2M",
                min(abs(v - 2.0) for v in xs.values() if v != float("inf")),
                0.0, 1.5)

    print("== Fig 8 SCMS ==")
    mcm = scms_systems(integration="MCM")
    socs = scms_soc_equivalents()
    cm_ = amortized_costs(mcm)
    cs_ = amortized_costs(socs)
    nre_chip_saving = 1 - cm_["scms_4x_MCM"].nre_chips / cs_["scms_4x_soc"].nre_chips
    ok &= check("4x chip-NRE saving ~3/4", nre_chip_saving, 0.6, 0.9)
    reused = amortized_costs(scms_systems(integration="MCM", package_reuse=True))
    pkg_nre_drop = 1 - reused["scms_4x_MCM"].nre_packages / cm_["scms_4x_MCM"].nre_packages
    ok &= check("package reuse cuts 4x pkg NRE by ~2/3", pkg_nre_drop, 0.5, 0.8)
    small_up = reused["scms_1x_MCM"].total / cm_["scms_1x_MCM"].total - 1
    ok &= check("1x total rises >20% under package reuse", small_up, 0.10, 0.60)
    d25r = amortized_costs(scms_systems(integration="2.5D", package_reuse=True))
    ok &= check("2.5D 4x-interposer-in-1x packaging >50%",
                d25r["scms_1x_2.5D"].re.packaging_cost / d25r["scms_1x_2.5D"].re.total,
                0.45, 0.85)

    print("== Fig 9 OCME ==")
    om = amortized_costs(ocme_systems())
    os_ = amortized_costs(ocme_soc_equivalents())
    big = 1 - om["ocme_CXXY_MCM"].nre_total / os_["ocme_CXXY_soc"].nre_total
    ok &= check("OCME NRE saving <50% (largest system)", big, 0.10, 0.55)
    het = amortized_costs(ocme_systems(center_process="14nm", package_reuse=True))
    hom = amortized_costs(ocme_systems(package_reuse=True))
    drop = 1 - het["ocme_CXXY_MCM"].total / hom["ocme_CXXY_MCM"].total
    ok &= check("heterogeneity saves >=10% (largest)", drop, 0.05, 0.40)
    dropC = 1 - het["ocme_C_MCM"].total / hom["ocme_C_MCM"].total
    ok &= check("single-C hetero saving ~half", dropC, 0.25, 0.60)

    print("ALL OK" if ok else "CALIBRATION FAILURES")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
