"""Foundation for the functional model zoo.

Params are plain nested dicts of ``jnp`` arrays.  Every model defines a
*spec tree* — the same nesting, with :class:`ParamSpec` leaves carrying
shape, dtype and **logical axis names**.  From one spec tree we derive:

* ``init_params``      — materialized random weights (smoke tests, training);
* ``abstract_params``  — ``jax.ShapeDtypeStruct`` leaves with a
  ``NamedSharding`` attached (the multi-pod dry-run: no allocation);
* ``param_pspecs``     — the ``PartitionSpec`` tree for pjit.

Logical axis vocabulary (mapped to mesh axes by ``parallel.sharding``):

  "vocab"   embedding rows / logits classes
  "embed"   the d_model axis of weight matrices (FSDP axis)
  "mlp"     FFN hidden axis (tensor-parallel)
  "heads"   attention-head axis (tensor-parallel)
  "kv"      kv-head axis (replicated when it does not divide the mesh)
  "experts" MoE expert axis (expert-parallel)
  "layers"  stacked-scan layer axis (never sharded)
  None      replicated
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape + dtype + logical axes of one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"     # "normal" | "zeros" | "ones" | "embed"
    scale: Optional[float] = None  # override fan-in scale

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_map(fn: Callable[[ParamSpec], Any], tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def _fan_in(shape: Tuple[int, ...]) -> int:
    # Last axis is the output axis by convention; everything else is fan-in.
    if len(shape) <= 1:
        return max(shape[0] if shape else 1, 1)
    return max(int(jnp.prod(jnp.asarray(shape[:-1]))), 1)


def init_params(spec_tree: PyTree, key: jax.Array,
                dtype_override=None) -> PyTree:
    """Materialize a spec tree into real arrays (truncated-normal fan-in)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        dt = dtype_override or s.dtype
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dt))
        else:
            scale = s.scale
            if scale is None:
                scale = 1.0 if s.init == "embed" else 1.0 / math.sqrt(_fan_in(s.shape))
            out.append((scale * jax.random.truncated_normal(
                k, -2.0, 2.0, s.shape, jnp.float32)).astype(dt))
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(spec_tree: PyTree, sharding_fn=None,
                    dtype_override=None) -> PyTree:
    """ShapeDtypeStruct tree; `sharding_fn(axes, shape)` optional."""
    def one(s: ParamSpec):
        dt = dtype_override or s.dtype
        if sharding_fn is None:
            return jax.ShapeDtypeStruct(s.shape, dt)
        return jax.ShapeDtypeStruct(s.shape, dt,
                                    sharding=sharding_fn(s.axes, s.shape))
    return spec_map(one, spec_tree)


def param_axes(spec_tree: PyTree) -> PyTree:
    return spec_map(lambda s: s.axes, spec_tree)


def count_params(spec_tree: PyTree) -> int:
    total = 0
    for s in jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec):
        total += int(math.prod(s.shape))
    return total


# ---------------------------------------------------------------------------
# Common neural pieces (pure functions over param dicts)
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((dim,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def embed_spec(vocab: int, dim: int) -> Dict[str, ParamSpec]:
    return {"embedding": ParamSpec((vocab, dim), ("vocab", "embed"),
                                   init="embed", scale=0.02)}


def embed(params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params, x):
    """Logits via the (tied or untied) output table: (..., D) -> (..., V)."""
    return jnp.einsum("...d,vd->...v", x, params["embedding"])


def dense_spec(d_in: int, d_out: int, axes: Tuple[Optional[str], Optional[str]],
               init: str = "normal") -> ParamSpec:
    return ParamSpec((d_in, d_out), axes, init=init)


def swiglu_spec(d_model: int, d_ff: int) -> Dict[str, ParamSpec]:
    return {
        "w_gate": dense_spec(d_model, d_ff, ("embed", "mlp")),
        "w_up": dense_spec(d_model, d_ff, ("embed", "mlp")),
        "w_down": dense_spec(d_ff, d_model, ("mlp", "embed")),
    }


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                     # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    if x.ndim == ang.ndim + 1:                        # has a heads axis
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mask_padded_vocab(logits, vocab: int):
    """-inf the padded tail of the vocab axis (see ArchConfig.padded_vocab)."""
    if logits.shape[-1] == vocab:
        return logits
    valid = jnp.arange(logits.shape[-1]) < vocab
    return jnp.where(valid, logits, jnp.asarray(-1e30, logits.dtype))


def cross_entropy(logits, labels, mask=None):
    """Mean token-level CE in fp32; labels < 0 are ignored."""
    logits = logits.astype(jnp.float32)
    valid = labels >= 0 if mask is None else mask & (labels >= 0)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * valid.astype(jnp.float32)
    denom = jnp.maximum(valid.sum(), 1)
    return nll.sum() / denom
