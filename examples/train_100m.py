"""End-to-end driver: train a ~100M-param xLSTM for a few hundred steps
on CPU with checkpointing + resume (deliverable (b)'s training example).

  PYTHONPATH=src python examples/train_100m.py [--steps 300]

Uses the REAL xlstm-125m config at reduced sequence length (the full
4k x 256 batch is a pod-scale workload; the model itself is full-size).
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    args = p.parse_args()
    return train_main([
        "--arch", "xlstm_125m",          # ~100M params, full config
        "--steps", str(args.steps),
        "--batch", "4", "--seq", "256",
        "--lr", "1e-3",
        "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100",
    ])


if __name__ == "__main__":
    sys.exit(main())
