"""repro.service: the hard parity oracle (coalesced responses bit-exact
against direct ChunkedEvaluator / portfolio_search calls), seeded
arrival-interleaving determinism, per-request error isolation, constant
trace counts after warmup, backpressure envelopes, and the scheduler's
fairness/occupancy policy in isolation."""
import asyncio
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import CostEngine, SystemBatch
from repro.core.engine import TRACE_COUNTS
from repro.core.system import spec
from repro.dse import (ChunkedEvaluator, DesignSpace, RiskConfig, SKU,
                       Uncertainty, portfolio_search)
from repro.service import (INVALID_REQUEST, Lane, McSpec, MCRiskRequest,
                           PriceRequest, PriceSystemsRequest, PricingService,
                           QUEUE_FULL, RankRequest, Scheduler, SearchRequest,
                           ServiceConfig, SpanWork, WhatIfRequest, serve)
from repro.service.server import PricingService as _PS


def _space(**kw):
    d = dict(skus=(SKU("laptop", 200.0, 2e6), SKU("server", 400.0, 5e5)),
             processes=("7nm", "12nm"), integrations=("MCM",),
             chiplet_counts=(1, 2, 4), allow_reuse=True)
    d.update(kw)
    return DesignSpace(**d)


@pytest.fixture(scope="module")
def space():
    return _space()


@pytest.fixture(scope="module")
def evaluator(space):
    # same chunk size as CFG below => the service and the direct path
    # share one compiled trace per lane
    return ChunkedEvaluator(space, candidates_per_chunk=16)


CFG = ServiceConfig(chunk=16, split=4, warm_mc=((64, (0.5, 0.9)),))


def _arrays_equal(a, b):
    assert np.array_equal(a.idx, b.idx)
    assert np.array_equal(a.sku_unit_total, b.sku_unit_total)
    assert np.array_equal(a.sku_unit_re, b.sku_unit_re)
    assert np.array_equal(a.sku_unit_nre, b.sku_unit_nre)
    assert np.array_equal(a.portfolio_cost, b.portfolio_cost)
    if a.risk is None:
        assert b.risk is None
    else:
        assert set(a.risk) == set(b.risk)
        for k in a.risk:
            assert np.array_equal(a.risk[k], b.risk[k]), k


# ---------------------------------------------------------------------------
# The hard parity oracle: coalesced == direct, bit for bit
# ---------------------------------------------------------------------------


def test_mixed_workload_bit_exact_parity(space, evaluator):
    """Heterogeneous concurrent requests — coalesced into shared ticks —
    must answer bit-exactly what the direct single-request APIs answer."""
    mc = McSpec(draws=64, quantiles=(0.5, 0.9), seed=7)
    reqs = [
        PriceRequest(indices=[0, 3, 5, 7, 9]),
        PriceRequest(indices=list(range(space.size()))),
        MCRiskRequest(indices=[1, 2, 3, 8], mc=mc),
        RankRequest(indices=list(range(0, space.size(), 2)), top_k=4),
        SearchRequest(seed=3, population=8, generations=4, elite=3),
    ]
    resps, svc = serve(space, reqs, CFG)
    assert all(r.ok for r in resps), [r.error for r in resps]

    _arrays_equal(resps[0].result,
                  evaluator.evaluate_indices(np.asarray([0, 3, 5, 7, 9])))
    _arrays_equal(resps[1].result,
                  evaluator.evaluate_indices(np.arange(space.size())))
    _arrays_equal(resps[2].result, evaluator.evaluate_indices(
        np.asarray([1, 2, 3, 8]), mc_key=jax.random.PRNGKey(7),
        mc_draws=64, mc_quantiles=(0.5, 0.9)))

    # rank: same order/values as a host argsort of the direct arrays
    direct = evaluator.evaluate_indices(np.arange(0, space.size(), 2))
    obj = direct.portfolio_cost
    order = np.lexsort((direct.idx, obj))
    rk = resps[3].result
    assert np.array_equal(rk.order, direct.idx[order])
    assert np.array_equal(rk.values, obj[order])
    assert [r.label for r in rk.top] == [
        space.candidate_at(int(i)).label() for i in direct.idx[order[:4]]]

    # search: identical to the direct portfolio_search call
    ds = portfolio_search(space, jax.random.PRNGKey(3), population=8,
                          generations=4, elite=3)
    gs = resps[4].result
    assert gs.best.label == ds.best.label
    assert gs.best.portfolio_cost == ds.best.portfolio_cost
    assert gs.history == ds.history
    assert [r.label for r in gs.ranked] == [r.label for r in ds.ranked]
    assert [r.portfolio_cost for r in gs.ranked] == \
        [r.portfolio_cost for r in ds.ranked]

    # the tick loop syncs exactly once per tick
    snap = svc.snapshot()
    assert snap["device_gets"] == snap["ticks"]
    assert snap["n_ok"] == len(reqs)


def test_risk_search_parity(space):
    """Risk-objective search (MC lane end to end) equals the direct call."""
    risk = RiskConfig(n_draws=32, quantile=0.9,
                      sigmas=Uncertainty(defect_sigma=0.3))
    resps, _ = serve(space, [SearchRequest(seed=11, population=8,
                                           generations=3, elite=2,
                                           risk=risk)], CFG)
    assert resps[0].ok, resps[0].error
    ds = portfolio_search(space, jax.random.PRNGKey(11), population=8,
                          generations=3, elite=2, risk=risk)
    gs = resps[0].result
    assert gs.objective_key == "q90" == ds.objective_key
    assert gs.history == ds.history
    assert [r.label for r in gs.ranked] == [r.label for r in ds.ranked]
    assert gs.best.risk == ds.best.risk


def test_what_if_parity_and_skips(space, evaluator):
    """What-if rows re-price the base architecture under each tech combo
    (bit-exact vs direct pricing); combos outside the space are skipped,
    not errored."""
    base_idx = 5
    req = WhatIfRequest(base=base_idx, processes=("7nm", "12nm"),
                        integrations=("MCM", "2.5D"))   # 2.5D not in space
    resps, _ = serve(space, [req], CFG)
    assert resps[0].ok, resps[0].error
    wi = resps[0].result
    base = space.candidate_at(base_idx)
    assert wi.base_label == base.label()
    assert wi.base_cost == float(
        evaluator.evaluate_indices(np.asarray([base_idx]))
        .portfolio_cost[0])
    assert wi.rows, "grid empty"
    for row in wi.rows:
        gi = None
        for cand_i in range(space.size()):
            if space.candidate_at(cand_i).label() == row["candidate"]:
                gi = cand_i
                break
        assert gi is not None
        direct = float(evaluator.evaluate_indices(
            np.asarray([gi])).portfolio_cost[0])
        assert row["portfolio_cost"] == direct
        assert row["delta_vs_base"] == row["portfolio_cost"] - wi.base_cost
    reasons = {(s["process"], s["integration"]) for s in wi.skipped}
    assert ("7nm", "2.5D") in reasons       # outside the space's menu


def test_raw_systems_lane(space):
    """Raw spec()-list groups price like CostEngine on the same batch."""
    specs = (
        {"kind": "soc", "name": "a", "area": 150.0, "process": "7nm",
         "quantity": 1e6},
        {"kind": "split", "name": "b", "area": 300.0, "process": "7nm",
         "n_chiplets": 2, "integration": "MCM", "quantity": 5e5},
    )
    resps, _ = serve(space, [PriceSystemsRequest(specs=specs)], CFG)
    assert resps[0].ok, resps[0].error
    rows = resps[0].result.rows
    systems = [spec(dict(d)) for d in specs]
    tot = CostEngine().total(
        SystemBatch.from_systems(systems, share_nre=[0, 0]))
    direct = np.asarray(jax.device_get(tot.total), np.float64)
    for i, row in enumerate(rows):
        assert row["system"] == systems[i].name
        np.testing.assert_allclose(row["total"], direct[i], rtol=1e-6)


# ---------------------------------------------------------------------------
# Determinism under arrival interleavings
# ---------------------------------------------------------------------------


def test_interleaving_determinism(space):
    """The same request set must produce identical payloads no matter the
    (seeded, randomized) submission order and inter-arrival delays —
    coalescing changes which rows share a tick, never the rows."""
    base_reqs = [
        PriceRequest(indices=[0, 1, 2, 3, 4, 5, 6, 7]),
        MCRiskRequest(indices=[2, 4, 6], mc=McSpec(draws=64, seed=5)),
        RankRequest(indices=[9, 1, 5, 3], top_k=2),
        SearchRequest(seed=2, population=8, generations=3, elite=2),
        PriceRequest(indices=[7, 7, 1]),
    ]
    cfg = dataclasses.replace(CFG, result_cache_entries=0)  # no short-cuts

    def run(order_seed: int):
        rng = np.random.default_rng(order_seed)
        order = rng.permutation(len(base_reqs))

        async def _main():
            svc = PricingService(space, cfg)
            await svc.start()

            async def client(j):
                await asyncio.sleep(float(rng.integers(0, 4)) * 1e-3)
                return j, await svc.submit(base_reqs[j])

            pairs = await asyncio.gather(*(client(int(j)) for j in order))
            await svc.stop()
            return dict(pairs)

        return asyncio.run(_main())

    runs = [run(s) for s in (0, 1, 2)]
    for other in runs[1:]:
        for j in range(len(base_reqs)):
            a, b = runs[0][j], other[j]
            assert a.ok and b.ok
            if base_reqs[j].kind in ("price", "mc_risk"):
                _arrays_equal(a.result, b.result)
            elif base_reqs[j].kind == "rank":
                assert np.array_equal(a.result.order, b.result.order)
                assert np.array_equal(a.result.values, b.result.values)
            else:  # search
                assert a.result.history == b.result.history
                assert [r.label for r in a.result.ranked] == \
                    [r.label for r in b.result.ranked]


# ---------------------------------------------------------------------------
# Error isolation / validation envelopes
# ---------------------------------------------------------------------------


def test_error_envelope_isolation(space, evaluator, monkeypatch):
    """A request that blows up server-side fails ALONE with a typed
    envelope; coalesced siblings still answer bit-exactly."""
    orig = _PS._rank_payload

    def poisoned(self, arrays, objective, top_k):
        if top_k == 13:
            raise RuntimeError("poisoned request")
        return orig(self, arrays, objective, top_k)

    monkeypatch.setattr(_PS, "_rank_payload", poisoned)
    reqs = [
        PriceRequest(indices=[0, 1, 2, 3]),
        RankRequest(indices=[4, 5, 6], top_k=13),       # the poisoned one
        MCRiskRequest(indices=[7, 8], mc=McSpec(draws=64, seed=1)),
    ]
    resps, svc = serve(space, reqs, CFG)
    assert resps[0].ok and resps[2].ok
    assert not resps[1].ok
    assert resps[1].error.code == "internal"
    assert "poisoned" in resps[1].error.message
    _arrays_equal(resps[0].result,
                  evaluator.evaluate_indices(np.asarray([0, 1, 2, 3])))
    _arrays_equal(resps[2].result, evaluator.evaluate_indices(
        np.asarray([7, 8]), mc_key=jax.random.PRNGKey(1), mc_draws=64,
        mc_quantiles=(0.5, 0.9)))
    assert svc.snapshot()["n_errors"] == 1
    # the failure is in the request log, typed
    assert svc.log.records(event="error")


def test_invalid_requests_are_enveloped(space):
    reqs = [
        PriceRequest(indices=[0, space.size() + 7]),      # out of range
        PriceRequest(),                                   # nothing to price
        RankRequest(indices=[1], objective="q90"),        # objective w/o mc
        SearchRequest(population=4, elite=9),             # elite > population
        PriceSystemsRequest(specs=({"kind": "nope", "name": "x"},)),
        PriceRequest(indices=[1], flow="no-such-flow"),
        PriceSystemsRequest(specs=()),
    ]
    resps, svc = serve(space, reqs, CFG)
    for r in resps:
        assert not r.ok
        assert r.error.code == INVALID_REQUEST
    # admission rejections never reach the device
    assert svc.snapshot()["ticks"] == 0


def test_backpressure_queue_full(space):
    """The bounded queue refuses work past the row budget with a typed
    queue_full envelope — and recovers once the backlog drains."""
    cfg = dataclasses.replace(CFG, max_pending=space.size() + 4)

    async def _main():
        svc = PricingService(space, cfg)
        await svc.start()
        big = asyncio.ensure_future(
            svc.submit(PriceRequest(indices=list(range(space.size())))))
        await asyncio.sleep(0)            # let `big` admit, no ticks yet
        burst = await svc.submit(PriceRequest(indices=[0, 1, 2, 3, 4, 5]))
        r_big = await big
        # after draining, the same burst request is admitted again
        retry = await svc.submit(PriceRequest(indices=[0, 1, 2, 3, 4, 5]))
        await svc.stop()
        return burst, r_big, retry, svc

    burst, r_big, retry, svc = asyncio.run(_main())
    assert not burst.ok and burst.error.code == QUEUE_FULL
    assert r_big.ok and retry.ok
    assert svc.snapshot()["n_rejected"] == 1


# ---------------------------------------------------------------------------
# Warmup / trace discipline / caching / fairness
# ---------------------------------------------------------------------------


def test_trace_counts_constant_after_warmup(space):
    """After start() warms the configured lanes, a mixed workload leaves
    the jit trace counters untouched (no hot-path recompiles)."""

    async def _main():
        svc = PricingService(space, CFG)
        await svc.start()                 # warmup happens here
        before = dict(TRACE_COUNTS)
        reqs = [
            PriceRequest(indices=[0, 1, 2]),
            MCRiskRequest(indices=[3, 4], mc=McSpec(draws=64, seed=9)),
            RankRequest(indices=list(range(10)), top_k=3),
            WhatIfRequest(base=2),
            PriceSystemsRequest(specs=(
                {"kind": "soc", "name": "s", "area": 120.0,
                 "process": "7nm", "quantity": 1e6},)),
        ]
        resps = await asyncio.gather(*(svc.submit(r) for r in reqs))
        await svc.stop()
        return svc, before, dict(TRACE_COUNTS), resps

    svc, before, after, resps = asyncio.run(_main())
    assert all(r.ok for r in resps), [r.error for r in resps]
    assert after == before
    assert svc.snapshot()["recompiles_after_warmup"] == 0


def test_result_cache_hit(space, evaluator):
    """Re-submitting an identical sweep answers from the host cache —
    flagged, bit-exact, and without new device ticks."""

    async def _main():
        svc = PricingService(space, CFG)
        await svc.start()
        r1 = await svc.submit(PriceRequest(indices=[1, 3, 5]))
        ticks = svc.metrics.ticks
        r2 = await svc.submit(PriceRequest(indices=[1, 3, 5]))
        r3 = await svc.submit(PriceRequest(indices=[5, 3, 1]))  # order != hit
        await svc.stop()
        return svc, r1, ticks, r2, r3

    svc, r1, ticks, r2, r3 = asyncio.run(_main())
    assert r1.ok and r2.ok and r3.ok
    assert not r1.cached and r2.cached and not r3.cached
    assert svc.metrics.ticks > ticks     # r3 went to the device again
    _arrays_equal(r1.result, r2.result)
    _arrays_equal(r3.result,
                  evaluator.evaluate_indices(np.asarray([5, 3, 1])))
    assert svc.snapshot()["result_cache"]["hits"] == 1


def test_point_query_not_starved_by_sweep(space):
    """FIFO + chunk splitting: a point query submitted behind a
    space-sized sweep completes before the sweep does."""
    cfg = dataclasses.replace(CFG, chunk=8, split=2)
    done_order = []

    async def _main():
        svc = PricingService(space, cfg)
        await svc.start()

        async def client(tag, req):
            r = await svc.submit(req)
            done_order.append(tag)
            return r

        big, point = await asyncio.gather(
            client("big", PriceRequest(
                indices=list(range(space.size())) * 3)),
            client("point", PriceRequest(indices=[7])))
        await svc.stop()
        return big, point

    big, point = asyncio.run(_main())
    assert big.ok and point.ok
    assert done_order[0] == "point"
    assert point.latency_s <= big.latency_s


# ---------------------------------------------------------------------------
# Scheduler policy in isolation (no device work)
# ---------------------------------------------------------------------------


def _span(lane, n, start=0):
    return SpanWork(owner=object(), lane=lane,
                    idx=np.arange(start, start + n, dtype=np.int64))


def test_scheduler_split_fairness_and_rotation():
    sched = Scheduler(slots=8, split=2, max_pending=100)
    lane = Lane(kind="chunk")
    big = _span(lane, 20)
    small = _span(lane, 2, start=100)
    assert sched.admit([big], 20) and sched.admit([small], 2)
    plan = sched.plan()
    # pass 1 gives each item <= split slots; later passes refill from the
    # survivors, so the chunk still runs full
    assert plan.used == 8
    by_item = {}
    for a in plan.assignments:
        by_item.setdefault(id(a.item), 0)
        by_item[id(a.item)] += a.n
    assert by_item[id(small)] == 2          # the point query fully served
    assert by_item[id(big)] == 6
    # the big survivor rotated behind any newcomers
    assert sched.queue[0] is big and big.remaining == 14
    newcomer = _span(lane, 1, start=200)
    sched.admit([newcomer], 1)
    plan2 = sched.plan()
    served = {id(a.item) for a in plan2.assignments}
    assert id(newcomer) in served           # not starved by the sweep


def test_scheduler_lane_exclusivity_and_budget():
    sched = Scheduler(slots=8, split=8, max_pending=10)
    a = _span(Lane(kind="chunk", flow="chip-last"), 4)
    b = _span(Lane(kind="chunk", flow="chip-first"), 4)
    assert sched.admit([a], 4) and sched.admit([b], 4)
    assert not sched.admit([_span(Lane(kind="chunk"), 4)], 4)  # budget full
    plan = sched.plan()
    assert {id(x.item) for x in plan.assignments} == {id(a)}   # one lane
    assert plan.used == 4                   # no cross-lane fill
    sched.release(4)
    assert sched.admit([_span(Lane(kind="chunk"), 2)], 2)
    plan2 = sched.plan()
    assert plan2.lane == b.lane             # FIFO head defines the lane


def test_scheduler_drop_owned_by():
    sched = Scheduler(slots=4, max_pending=100)
    lane = Lane(kind="chunk")
    owner = object()
    w1 = SpanWork(owner=owner, lane=lane, idx=np.arange(3, dtype=np.int64))
    w2 = _span(lane, 2)
    sched.admit([w1, w2], 5)
    sched.drop_owned_by(owner)
    assert list(sched.queue) == [w2]
