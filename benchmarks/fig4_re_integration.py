"""Paper Fig. 4: normalized RE cost across integrations x nodes x
chiplet counts (all normalized to the 100 mm^2 SoC of each node).

The whole figure — every (node, area, integration, n) cell plus the
per-node normalization baselines — is priced by one CostEngine call on a
single heterogeneous SystemBatch.
"""
from repro.core import CostEngine, SystemBatch

from .common import emit

NODES = ("14nm", "7nm", "5nm")
AREAS = (300.0, 500.0, 800.0, 900.0)
INTEGRATIONS = ("MCM", "InFO", "2.5D")
NS = (2, 3, 5)


def run():
    specs, meta = [], []
    for node in NODES:
        specs.append({"kind": "soc", "area": 100.0, "process": node})
        meta.append((node, "base", None, None))
        for area in AREAS:
            specs.append({"kind": "soc", "area": area, "process": node})
            meta.append((node, "SoC", area, 1))
            for integ in INTEGRATIONS:
                for n in NS:
                    specs.append({"kind": "split", "area": area,
                                  "process": node, "n": n,
                                  "integration": integ})
                    meta.append((node, integ, area, n))

    batch = SystemBatch.from_specs(specs)
    br = CostEngine().re(batch)
    total, defects = br.total, br.chip_defects
    packaging = br.packaging_cost

    base = {m[0]: float(total[i]) for i, m in enumerate(meta)
            if m[1] == "base"}
    rows = []
    for i, (node, integ, area, n) in enumerate(meta):
        if integ == "base":
            continue
        rows.append({
            "node": node, "area_mm2": area, "integration": integ,
            "n_chiplets": n, "total_norm": float(total[i]) / base[node],
            "die_defects_norm": float(defects[i]) / base[node],
            "packaging_norm": float(packaging[i]) / base[node],
        })
    emit("fig4_re_cost_normalized", rows)
    return rows


if __name__ == "__main__":
    run()
