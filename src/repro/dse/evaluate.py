"""Chunked, fixed-shape batched candidate pricing (repro.dse).

Arbitrarily long candidate streams are priced through constant-shape
:class:`~repro.core.batch.SystemBatch` chunks: each chunk holds up to
``candidates_per_chunk`` candidate portfolios (one ``share_nre`` group
per candidate, so NRE amortizes within a candidate but never across
candidates), padded by :func:`~repro.core.batch.pad_batch` to the
space's worst-case shape signature.  Every chunk therefore hits the same
compiled :class:`~repro.core.engine.CostEngine` trace — pricing 10k+
candidates is exactly one retained jit trace per (chunk-shape, flow),
which ``benchmarks/dse_bench.py`` and ``tests/test_dse.py`` assert via
``CostEngine.trace_counts()``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core.batch import SystemBatch, pad_batch
from ..core.engine import CostEngine
from .space import Candidate, DesignSpace, candidate_systems
from .uncertainty import mc_totals, portfolio_draws


@dataclasses.dataclass(frozen=True)
class ChunkShape:
    """Worst-case array signature of one evaluation chunk."""

    candidates: int
    n_systems: int
    max_chips: int
    chip_entities: int
    pkg_entities: int
    mod_entities: int
    mod_instances: int
    d2d_entities: int
    d2d_instances: int

    def pad_kwargs(self) -> Dict[str, int]:
        d = dataclasses.asdict(self)
        d.pop("candidates")
        return d


def chunk_shape(space: DesignSpace, candidates_per_chunk: int) -> ChunkShape:
    """Upper-bound shapes for any ``candidates_per_chunk`` candidates.

    Per candidate: S systems (one per SKU), each at most ``max_chips``
    chips; each chip carries one functional module and at most one D2D
    module instance; chip/module design entities are bounded by the chip
    instances, package entities by S, D2D entities by the process menu.
    Entity tables get one slack row so padded instances always have a
    zero-NRE row to point at.
    """
    k = int(candidates_per_chunk)
    s = len(space.skus)
    c = space.max_chips()
    per_cand_chips = s * c
    return ChunkShape(
        candidates=k,
        n_systems=k * s,
        max_chips=c,
        chip_entities=k * per_cand_chips + 1,
        pkg_entities=k * s + 1,
        mod_entities=k * per_cand_chips + 1,
        mod_instances=k * per_cand_chips,
        d2d_entities=k * len(space.processes) + 1,
        d2d_instances=k * per_cand_chips,
    )


@dataclasses.dataclass
class CandidateResult:
    """Priced candidate: per-SKU unit economics + the portfolio total."""

    candidate: Candidate
    label: str
    sku_names: Sequence[str]
    sku_unit_total: np.ndarray   # (S,) USD per unit, RE + amortized NRE
    sku_unit_re: np.ndarray      # (S,)
    sku_unit_nre: np.ndarray     # (S,)
    portfolio_cost: float        # sum_i quantity_i * unit_total_i, USD
    risk: Optional[Dict[str, float]] = None  # filled by uncertainty pass

    def objective(self, key: str = "cost") -> float:
        """Scalar ranking objective: 'cost' or a risk stat (e.g. 'q90')."""
        if key == "cost":
            return self.portfolio_cost
        if self.risk is None or key not in self.risk:
            raise KeyError(f"no risk stat {key!r} on {self.label}; "
                           "evaluate with mc_key set")
        return self.risk[key]


class ChunkedEvaluator:
    """Prices candidate streams in constant-shape chunks.

    >>> ev = ChunkedEvaluator(space, candidates_per_chunk=64)
    >>> results = ev.evaluate(space.sample(rng, 10_000))
    >>> ev.systems_per_sec
    """

    def __init__(self, space: DesignSpace, candidates_per_chunk: int = 64,
                 engine: Optional[CostEngine] = None,
                 flow: str = "chip-last"):
        self.space = space
        self.engine = engine or CostEngine()
        self.flow = flow
        self.shape = chunk_shape(space, candidates_per_chunk)
        self.reset_stats()

    # -- throughput bookkeeping ---------------------------------------------
    def reset_stats(self):
        self.n_candidates = 0
        self.n_systems = 0
        self.n_chunks = 0
        self.elapsed_s = 0.0

    @property
    def candidates_per_sec(self) -> float:
        return self.n_candidates / max(self.elapsed_s, 1e-12)

    @property
    def systems_per_sec(self) -> float:
        return self.n_systems / max(self.elapsed_s, 1e-12)

    def stats(self) -> Dict[str, float]:
        return {"n_candidates": self.n_candidates,
                "n_systems": self.n_systems, "n_chunks": self.n_chunks,
                "elapsed_s": self.elapsed_s,
                "candidates_per_sec": self.candidates_per_sec,
                "systems_per_sec": self.systems_per_sec}

    # -- chunk assembly ------------------------------------------------------
    def pack_chunk(self, chunk: Sequence[Candidate]) -> SystemBatch:
        """Pack <= candidates_per_chunk candidates into one padded batch."""
        if len(chunk) > self.shape.candidates:
            raise ValueError(f"chunk of {len(chunk)} exceeds "
                             f"{self.shape.candidates} candidates")
        systems, groups = [], []
        for j, cand in enumerate(chunk):
            grp = candidate_systems(self.space, cand)
            systems += grp
            groups += [j] * len(grp)
        batch = SystemBatch.from_systems(systems, share_nre=groups,
                                         max_chips=self.shape.max_chips)
        return pad_batch(batch, **self.shape.pad_kwargs())

    def evaluate(self, candidates: Sequence[Candidate],
                 mc_key=None, mc_draws: int = 128, mc_sigmas=None,
                 mc_quantiles: Sequence[float] = (0.5, 0.9),
                 ) -> List[CandidateResult]:
        """Price every candidate; optionally attach Monte Carlo risk stats.

        With ``mc_key`` set, each chunk is additionally priced under
        ``mc_draws`` correlated parameter scenarios (see
        :mod:`repro.dse.uncertainty`) — the *same* key (common random
        numbers) is reused for every chunk so candidates are compared
        under identical scenarios regardless of chunking.
        """
        candidates = list(candidates)
        s = len(self.space.skus)
        qty = np.asarray([sk.quantity for sk in self.space.skus], np.float64)
        names = [sk.name for sk in self.space.skus]
        out: List[CandidateResult] = []
        k = self.shape.candidates
        for lo in range(0, len(candidates), k):
            chunk = candidates[lo:lo + k]
            t0 = time.perf_counter()
            batch = self.pack_chunk(chunk)
            tc = jax.device_get(self.engine.total(batch, flow=self.flow))
            pf_draws = None
            if mc_key is not None:
                draws = mc_totals(batch, mc_key, n_draws=mc_draws,
                                  flow=self.flow, sigmas=mc_sigmas)
                # fold the real (unpadded) rows into per-candidate
                # portfolio costs: (draws, len(chunk))
                pf_draws = np.asarray(jax.device_get(portfolio_draws(
                    draws[:, :len(chunk) * s], qty, s)), np.float64)
            self.elapsed_s += time.perf_counter() - t0
            total = np.asarray(tc.total, np.float64)
            re_tot = np.asarray(tc.re.total, np.float64)
            nre_tot = np.asarray(tc.nre.total, np.float64)
            for j, cand in enumerate(chunk):
                rows = slice(j * s, (j + 1) * s)
                unit = total[rows]
                risk = None
                if pf_draws is not None:
                    pf = pf_draws[:, j]
                    risk = {"mean": float(pf.mean()),
                            "std": float(pf.std())}
                    for q in mc_quantiles:
                        risk[f"q{int(round(q * 100))}"] = \
                            float(np.quantile(pf, q))
                out.append(CandidateResult(
                    candidate=cand, label=cand.label(), sku_names=names,
                    sku_unit_total=unit, sku_unit_re=re_tot[rows],
                    sku_unit_nre=nre_tot[rows],
                    portfolio_cost=float((qty * unit).sum()), risk=risk))
            self.n_candidates += len(chunk)
            self.n_systems += len(chunk) * s
            self.n_chunks += 1
        return out


def evaluate_direct(space: DesignSpace, cand: Candidate,
                    engine: Optional[CostEngine] = None,
                    flow: str = "chip-last") -> CandidateResult:
    """Unchunked, unpadded single-candidate pricing (reference path).

    Builds the candidate's group as its own ``share_nre=True`` batch and
    prices it directly — the cross-check the padded-chunk parity tests
    compare against.
    """
    engine = engine or CostEngine()
    grp = candidate_systems(space, cand)
    tc = jax.device_get(engine.total(
        SystemBatch.from_systems(grp, share_nre=True), flow=flow))
    qty = np.asarray([sk.quantity for sk in space.skus], np.float64)
    unit = np.asarray(tc.total, np.float64)
    return CandidateResult(
        candidate=cand, label=cand.label(),
        sku_names=[sk.name for sk in space.skus], sku_unit_total=unit,
        sku_unit_re=np.asarray(tc.re.total, np.float64),
        sku_unit_nre=np.asarray(tc.nre.total, np.float64),
        portfolio_cost=float((qty * unit).sum()))
