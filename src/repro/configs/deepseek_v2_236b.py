"""DeepSeek-V2-236B — MLA + fine-grained MoE. [arXiv:2405.04434; hf]

60L, d_model 5120, 128 heads, vocab 102400.  MLA: q_lora 1536, kv_lora
512, qk_nope 128, qk_rope 64, v_head 128.  FFN: 2 shared + 160 routed
top-6 experts, expert d_ff 1536; first layer dense (d_ff 12288).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe", attn="mla",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_ff=12288,
    vocab=102400, head_dim=128,
    q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128,
    n_experts=160, top_k=6, n_shared=2, d_ff_expert=1536, first_dense=1,
    accum=4,
    subquadratic=False,
)
