"""Chiplet Actuary — quantitative cost model (Feng & Ma, DAC 2022) in JAX.

Public API of the paper's contribution:

  technology   -- process-node / integration-technology parameter DB
  yield_model  -- Eq. (1) yield curves + wafer geometry
  system       -- module / chip / package algebra (Eq. 3) + spec() builder
  batch        -- SystemBatch: N heterogeneous systems as one pytree
  engine       -- CostEngine: batched, jit/vmap/grad-able Eqs. (4)-(8)
  re_cost      -- scalar reference RE path, Eqs. (4)-(5), five-way breakdown
  nre_cost     -- scalar reference NRE path, Eqs. (6)-(8), amortization
  reuse        -- SCMS / OCME / FSMC scheme builders (Sec. 5)
  explorer     -- engine-backed design-space sweeps and partition search
  gradient     -- (beyond paper) differentiable partitioning
  codesign     -- (beyond paper) accelerator perf-per-dollar bridge

The batched path (``SystemBatch`` + ``CostEngine``) is the primary API;
the scalar ``re_cost``/``amortized_costs`` path is kept as the readable
reference implementation and is pinned to the engine by parity tests.
``re_cost_split`` is deprecated (use the engine, or
``engine.re_split_relaxed`` for the continuous relaxation).
"""
from .technology import (INTEGRATION_TECHS, PROCESS_NODES, IntegrationTech,
                         ProcessNode, node, tech)
from .yield_model import (dies_per_wafer, good_die_cost, raw_die_cost,
                          yield_murphy, yield_negative_binomial, yield_poisson)
from .system import (Chip, Module, System, d2d_module, make_chip, soc_system,
                     spec, split_system)
from .batch import SystemBatch, pad_batch
from .engine import (CostEngine, NREBreakdown, TotalCost, package_flow_terms,
                     re_split_relaxed, silicon_unit_costs)
from .re_cost import REBreakdown, chip_costs, re_cost, re_cost_split
from .nre_cost import NREEntities, UnitCost, amortized_costs, group_nre
from .reuse import (fsmc_enumerate, fsmc_num_systems, fsmc_situations,
                    ocme_soc_equivalents, ocme_systems,
                    portfolio_reuse_systems, scms_soc_equivalents,
                    scms_systems)
from .explorer import (best_partition, cost_area_curve, pareto_front,
                       sweep_hetero_partitions, sweep_partitions, sweep_specs)
from .codesign import (AcceleratorSpec, accelerator_systems, cost_per_step,
                       price_accelerators)

__all__ = [
    "INTEGRATION_TECHS", "PROCESS_NODES", "IntegrationTech", "ProcessNode",
    "node", "tech", "dies_per_wafer", "good_die_cost", "raw_die_cost",
    "yield_murphy", "yield_negative_binomial", "yield_poisson", "Chip",
    "Module", "System", "d2d_module", "make_chip", "soc_system", "spec",
    "split_system", "SystemBatch", "pad_batch", "CostEngine", "NREBreakdown",
    "TotalCost",
    "package_flow_terms", "re_split_relaxed", "silicon_unit_costs",
    "REBreakdown", "chip_costs", "re_cost", "re_cost_split",
    "NREEntities", "UnitCost", "amortized_costs", "group_nre",
    "fsmc_enumerate", "fsmc_num_systems", "fsmc_situations",
    "ocme_soc_equivalents", "ocme_systems", "portfolio_reuse_systems",
    "scms_soc_equivalents",
    "scms_systems", "best_partition", "cost_area_curve", "pareto_front",
    "sweep_hetero_partitions", "sweep_partitions", "sweep_specs",
    "AcceleratorSpec", "accelerator_systems", "cost_per_step",
    "price_accelerators",
]
