"""Quickstart: the Chiplet Actuary cost model in five minutes.

  PYTHONPATH=src python examples/quickstart.py

The batched API (spec dicts -> SystemBatch -> CostEngine) is the primary
path; the scalar `re_cost(System)` reference is shown once at the end.
"""
from repro.core import (CostEngine, SystemBatch, best_partition, re_cost,
                        soc_system)


def main():
    engine = CostEngine()

    # 1. Price a monolithic 800 mm^2 5nm SoC.
    batch = SystemBatch.from_specs(
        [{"kind": "soc", "name": "my_soc", "area": 800.0, "process": "5nm",
          "quantity": 1e6}])
    br = engine.re(batch)
    total, defects = float(br.total[0]), float(br.chip_defects[0])
    print(f"monolithic 800mm2 5nm RE: ${total:,.0f}"
          f"  (defects: ${defects:,.0f} = {defects/total:.0%})")

    # 2. Split it into chiplets — how many is optimal?
    for integ in ("MCM", "InFO", "2.5D"):
        b = best_partition("5nm", integ, 800.0)
        print(f"{integ:5s}: best n={b['best_n']}  "
              f"${b['best_cost']:,.0f}  saving {b['saving']:.1%}")

    # 3. Total cost including NRE amortization at 1M units — one engine
    #    call prices the whole heterogeneous batch (even a mixed-node
    #    split: half the module on 5nm, the rest on two 7nm chiplets).
    group = SystemBatch.from_specs([
        {"kind": "soc", "name": "my_soc", "area": 800.0, "process": "5nm",
         "quantity": 1e6},
        {"kind": "split", "name": "my_mcm", "area": 800.0, "process": "5nm",
         "n": 3, "integration": "MCM", "quantity": 1e6},
        {"kind": "split", "name": "my_hetero", "area": 800.0,
         "fractions": [0.5, 0.25, 0.25], "processes": ["5nm", "7nm", "7nm"],
         "integration": "MCM", "quantity": 1e6},
    ], share_nre=True)
    tc = engine.total(group)
    for i, name in enumerate(group.names):
        print(f"{name}: RE ${float(tc.re.total[i]):,.0f} + NRE/unit "
              f"${float(tc.nre.total[i]):,.0f} = ${float(tc.total[i]):,.0f}")

    # 4. The scalar reference path gives the same answer, one system at a
    #    time (pinned to the engine by tests/test_engine.py).
    ref = re_cost(soc_system("my_soc", 800.0, "5nm", quantity=1e6))
    print(f"scalar reference RE: ${ref.total:,.0f}")


if __name__ == "__main__":
    main()
