"""DSE throughput benchmark: the fused on-device candidate pipeline vs
the legacy host-packing path, plus fused Monte Carlo and search stepping.

  PYTHONPATH=src python -m benchmarks.dse_bench [n_candidates] [chunk]
  PYTHONPATH=src python -m benchmarks.dse_bench --fast      # CI smoke

Asserts (acceptance criteria of the fused pipeline):
  * the fused index-native path (jit-fused decode -> price -> portfolio
    reduction, async chunk dispatch, one host sync per sweep) streams the
    candidate set with EXACTLY one retained trace per (chunk-shape,
    flow) — no retrace at any chunk boundary, including the final padded
    chunk;
  * fused-vs-legacy objective parity <= 1e-6 relative, and a sampled
    spot-check against the direct unchunked engine path <= 1e-5;
  * fused candidate throughput >= 30x the legacy path (>= 10x under
    --fast, where the CI box is noisy and the sample small).

Reports candidates/sec for both paths, fused Monte-Carlo risk pricing,
the jitted generation-step rate of the evolutionary search, and writes
the summary to BENCH_dse.json for CI trend tracking (guarded against
benchmarks/baselines/BENCH_dse.json by scripts/check_bench_regression.py).
"""
import json
import sys
import time

import jax
import numpy as np

from repro.core.engine import TRACE_COUNTS
from repro.dse import (ChunkedEvaluator, DesignSpace, SKU, evaluate_direct,
                       portfolio_search)

from .common import obs_summary, write_bench_json

SPACE = DesignSpace(
    skus=(SKU("laptop", 300.0, 2e6), SKU("desktop", 600.0, 1e6),
          SKU("server", 900.0, 3e5)),
    processes=("5nm", "7nm", "12nm"),
    integrations=("MCM", "2.5D"),
    chiplet_counts=(1, 2, 3, 4, 6),
    allow_reuse=True, reuse_package_options=(False, True))

# PR 2 shipped the host-packed chunk evaluator at ~2.8k candidates/s on a
# CI-class CPU — the floor the fused pipeline is measured against.
PR2_BASELINE_CANDIDATES_PER_SEC = 2800.0


def run(n_candidates: int = 10_000, chunk: int = 512, fast: bool = False,
        min_speedup: float = None):
    min_speedup = (10.0 if fast else 30.0) if min_speedup is None \
        else min_speedup
    rng = np.random.default_rng(0)
    idx = rng.integers(0, SPACE.size(), n_candidates)
    ev = ChunkedEvaluator(SPACE, candidates_per_chunk=chunk)

    # Warm the single (chunk-shape, chip-last) trace, then stream.  The
    # fused sweep is repeated; best-of-N is reported (the box-noise-robust
    # estimator for a fixed workload).
    ev.evaluate_indices(idx[:chunk])
    warm = dict(TRACE_COUNTS)
    sweeps = 2 if fast else 3
    best_cps, wall = 0.0, None
    for _ in range(sweeps):
        ev.reset_stats()
        arrays = ev.evaluate_indices(idx)
        if best_cps < ev.candidates_per_sec:
            best_cps, wall = ev.candidates_per_sec, ev.elapsed_s
    delta = {k: TRACE_COUNTS[k] - warm.get(k, 0) for k in TRACE_COUNTS
             if TRACE_COUNTS[k] != warm.get(k, 0)}
    assert not delta, f"retraced across chunk boundaries: {delta}"
    systems_per_sec = best_cps * len(SPACE.skus)

    # The other flow is its own single retained trace.
    before = dict(TRACE_COUNTS)
    ChunkedEvaluator(SPACE, candidates_per_chunk=chunk,
                     flow="chip-first").evaluate_indices(idx[:2 * chunk])
    ff = {k: TRACE_COUNTS[k] - before.get(k, 0) for k in ("fused_chunk",)}
    assert ff == {"fused_chunk": 1}, f"chip-first flow traces: {ff}"
    stream_traces = dict(TRACE_COUNTS)

    # Legacy host-packing path on a subset (extrapolation-free ratio: both
    # rates are per-candidate).
    n_legacy = min(n_candidates, 2 * chunk if fast else 4 * chunk)
    legacy = ChunkedEvaluator(SPACE, candidates_per_chunk=chunk, fused=False)
    legacy_cands = [SPACE.candidate_at(int(i)) for i in idx[:n_legacy]]
    legacy.evaluate(legacy_cands[:chunk])       # warm the shared trace
    legacy.reset_stats()
    legacy_results = legacy.evaluate(legacy_cands)
    legacy_cps = legacy.candidates_per_sec
    speedup = best_cps / legacy_cps

    # Objective parity: fused arrays vs legacy results on the subset ...
    pf_legacy = np.asarray([r.portfolio_cost for r in legacy_results])
    pf_fused = np.asarray(arrays.portfolio_cost[:n_legacy], np.float64)
    parity_legacy = float(np.max(np.abs(pf_fused - pf_legacy) / pf_legacy))
    assert parity_legacy < 1e-6, \
        f"fused/legacy objective mismatch: {parity_legacy:.2e}"
    # ... and a direct unchunked engine-oracle spot-check.
    worst = 0.0
    step = max(1, n_candidates // (7 if fast else 29))
    for i in range(0, n_candidates, step):
        d = evaluate_direct(SPACE, SPACE.candidate_at(int(idx[i])))
        rel = float(np.max(np.abs(arrays.sku_unit_total[i]
                                  - d.sku_unit_total) / d.sku_unit_total))
        worst = max(worst, rel)
    assert worst < 1e-5, f"fused/direct mismatch: {worst:.2e}"

    assert speedup >= min_speedup, \
        f"fused pipeline only {speedup:.1f}x legacy (< {min_speedup}x)"

    order = np.argsort(arrays.portfolio_cost, kind="stable")
    best_i = int(arrays.idx[order[0]])
    best_label = SPACE.candidate_at(best_i).label()
    best_cost = float(arrays.portfolio_cost[order[0]])

    # Fused Monte Carlo: risk quantiles per candidate, in-graph.
    n_draws = 128 if fast else 256
    n_mc = min(n_candidates, 4 * chunk)
    key = jax.random.PRNGKey(0)
    ev.evaluate_indices(idx[:chunk], mc_key=key, mc_draws=n_draws)  # trace
    ev.reset_stats()
    ev.evaluate_indices(idx[:n_mc], mc_key=key, mc_draws=n_draws)
    mc_cps = ev.candidates_per_sec
    mc_draw_systems_per_sec = mc_cps * n_draws * len(SPACE.skus)

    # Fused evolutionary search: one jitted generation step per generation.
    pop, gens = (128, 4) if fast else (256, 8)
    search_kw = dict(population=pop, elite=max(4, pop // 8),
                     evaluator=ChunkedEvaluator(SPACE,
                                                candidates_per_chunk=chunk))
    portfolio_search(SPACE, jax.random.PRNGKey(1), generations=1,
                     **search_kw)               # warm the gen-step trace
    t0 = time.perf_counter()
    sr = portfolio_search(SPACE, jax.random.PRNGKey(1), generations=gens,
                          **search_kw)
    t_search = time.perf_counter() - t0
    gens_per_sec = gens / t_search

    summary = {
        "mode": "fast" if fast else "full",
        "n_candidates": n_candidates,
        "n_systems": n_candidates * len(SPACE.skus),
        "chunk": chunk,
        "wall_s": round(wall, 4),
        "candidates_per_sec": round(best_cps, 1),
        "systems_per_sec": round(systems_per_sec, 1),
        "legacy_candidates_per_sec": round(legacy_cps, 1),
        "fused_vs_legacy": round(speedup, 1),
        "vs_pr2_baseline": round(
            best_cps / PR2_BASELINE_CANDIDATES_PER_SEC, 1),
        "trace_counts_stream": stream_traces,
        "parity_vs_legacy_rel": parity_legacy,
        "parity_worst_rel": worst,
        "best_candidate": best_label,
        "best_portfolio_cost": best_cost,
        "mc_draws": n_draws,
        "mc_candidates_per_sec": round(mc_cps, 1),
        "mc_draw_systems_per_sec": round(mc_draw_systems_per_sec, 1),
        "search_population": pop,
        "search_generations_per_sec": round(gens_per_sec, 2),
        "search_best": sr.best.label,
    }
    # traced runs (REPRO_TRACE=1) ride per-phase compile/dispatch/
    # device_get breakdowns along; untraced keys are unchanged.
    summary.update(obs_summary())
    print(f"candidates           : {n_candidates} "
          f"({summary['n_systems']} systems, chunk={chunk})")
    print(f"fused pipeline       : {wall*1e3:9.1f} ms best-of-{sweeps} "
          f"({best_cps:,.0f} candidates/s, {systems_per_sec:,.0f} systems/s)")
    print(f"legacy host packing  : {legacy_cps:,.0f} candidates/s "
          f"(measured on {n_legacy})")
    print(f"speedup              : {speedup:9.1f}x fused vs legacy "
          f"({summary['vs_pr2_baseline']:.1f}x the PR 2 "
          f"{PR2_BASELINE_CANDIDATES_PER_SEC:,.0f}/s baseline)")
    print(f"trace counts (stream): {stream_traces} "
          f"(one fused_chunk per (chunk-shape, flow))")
    print(f"parity               : {parity_legacy:.2e} vs legacy, "
          f"{worst:.2e} vs direct oracle")
    print(f"best candidate       : {best_label} (${best_cost:,.0f} "
          f"portfolio)")
    print(f"fused monte carlo    : {mc_cps:,.0f} candidates/s at "
          f"{n_draws} draws ({mc_draw_systems_per_sec:,.0f} "
          f"system-draws/s, risk quantiles in-graph)")
    print(f"fused search         : {gens_per_sec:,.2f} generations/s at "
          f"population {pop} (winner {sr.best.label})")
    print("JSON:", json.dumps(summary))
    write_bench_json("dse", summary)
    return summary


def main(argv):
    if "--fast" in argv:
        return run(1536, 128, fast=True)
    args = [int(a) for a in argv if not a.startswith("-")]
    return run(args[0] if args else 10_000,
               args[1] if len(args) > 1 else 512)


if __name__ == "__main__":
    main(sys.argv[1:])
