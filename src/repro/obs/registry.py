"""Named metrics registry: counters / gauges / histograms with JSON and
Prometheus-style text exposition.

This is the stack-wide successor of the raw ``TRACE_COUNTS`` dict and
the ad-hoc counter fields scattered through the service: every subsystem
registers named instruments against one process-wide :data:`REGISTRY`
and exporters (``svc.snapshot()``, the benchmarks' ``BENCH_*.json``, a
text scrape) read one coherent snapshot.

The legacy ``TRACE_COUNTS`` surface stays source-compatible through
:class:`TraceCounts`, a :class:`collections.Counter` subclass that
mirrors every increment into the registry — all existing
``TRACE_COUNTS["re"] += 1`` sites, ``dict(TRACE_COUNTS)`` oracles and
trace-count assertions keep working unchanged while the same counts
become scrapeable ``trace_<key>`` counters.
"""
from __future__ import annotations

import collections
import json
import math
import threading
import time
from typing import Dict, List, Optional


class Counter:
    """Monotonic counter."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0):
        if n < 0:
            raise ValueError("counters only go up")
        self.value += n

    def get(self) -> float:
        return self.value

    def sample(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)

    def inc(self, n: float = 1.0):
        self.value += n

    def dec(self, n: float = 1.0):
        self.value -= n

    def get(self) -> float:
        return self.value

    def sample(self) -> Dict[str, float]:
        return {"value": self.value}


class Histogram:
    """Streaming distribution: count/sum/min/max plus a bounded sample
    reservoir for quantiles (deterministic decimation, no RNG).

    Observations may carry an *exemplar* — an opaque reference string
    (here: a request ``trace_id``) tying the distribution back to a
    concrete traced request, OpenMetrics-style.  Exemplars live in a
    small bounded deque (latest wins) so the cost is O(1) per observe.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str = "", max_samples: int = 4096,
                 max_exemplars: int = 8):
        self.name = name
        self.help = help
        self.max_samples = int(max_samples)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._samples: List[float] = []
        self._stride = 1
        self._exemplars: collections.deque = \
            collections.deque(maxlen=int(max_exemplars))

    def observe(self, v: float, exemplar: Optional[str] = None):
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if exemplar:
            self._exemplars.append((str(exemplar), v))
        if (self.count - 1) % self._stride == 0:
            self._samples.append(v)
            if len(self._samples) > self.max_samples:
                # decimate: keep every other sample, double the stride
                self._samples = self._samples[::2]
                self._stride *= 2

    def exemplars(self) -> List[Dict[str, float]]:
        """Recent ``{"ref", "value"}`` exemplar pairs (oldest first)."""
        return [{"ref": r, "value": v} for r, v in self._exemplars]

    def quantile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        xs = sorted(self._samples)
        i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
        return xs[i]

    def sample(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        out = {"count": self.count, "sum": self.sum, "min": self.min,
               "max": self.max, "mean": self.sum / self.count,
               "p50": self.quantile(0.50), "p95": self.quantile(0.95),
               "p99": self.quantile(0.99)}
        # Key is present only when exemplars were attached, so snapshots
        # from exemplar-free instruments stay byte-identical.
        if self._exemplars:
            out["exemplars"] = self.exemplars()
        return out


class Registry:
    """Get-or-create instrument store; snapshot + text exposition."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: "collections.OrderedDict[str, object]" = \
            collections.OrderedDict()
        self._t0 = time.time()

    def _get_or_create(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, help, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  max_samples: int = 4096) -> Histogram:
        return self._get_or_create(Histogram, name, help,
                                   max_samples=max_samples)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return list(self._metrics)

    def reset(self):
        """Drop every registered instrument (tests / fresh benchmarks)."""
        with self._lock:
            self._metrics.clear()

    # -- exposition ----------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict]:
        """JSON-ready ``{name: {kind, ...samples}}`` of every instrument."""
        out = {}
        for name, m in list(self._metrics.items()):
            row = {"kind": m.kind}
            row.update(m.sample())
            out[name] = row
        return out

    def exposition(self) -> str:
        """Prometheus-style text format (one scrape of the registry)."""
        lines = []
        for name, m in list(self._metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if m.kind == "histogram":
                s = m.sample()
                lines.append(f"{name}_count {s['count']:g}")
                lines.append(f"{name}_sum {s['sum']:g}")
                for q in ("p50", "p95", "p99"):
                    lines.append(
                        f'{name}{{quantile="{q[1:]}"}} {s[q]:g}')
                # OpenMetrics-flavoured exemplars as comment lines so
                # classic Prometheus text parsers skip them cleanly.
                for ex in m.exemplars():
                    lines.append(
                        f'# EXEMPLAR {name}{{trace_id="{ex["ref"]}"}} '
                        f'{ex["value"]:g}')
            else:
                lines.append(f"{name} {m.get():g}")
        return "\n".join(lines) + "\n"

    def write_json(self, path) -> "pathlib.Path":
        import pathlib
        path = pathlib.Path(path)
        path.write_text(json.dumps(self.snapshot(), indent=2,
                                   sort_keys=True, default=float) + "\n")
        return path


# The process-wide registry every instrumented module shares.
REGISTRY = Registry()


class TraceCounts(collections.Counter):
    """Drop-in ``collections.Counter`` whose increments also land in the
    metrics registry as ``trace_<key>`` counters.

    This keeps every existing ``TRACE_COUNTS`` consumer — the engine's
    Python-body trace counters, the service's :class:`TraceCache`
    metering, the bench/test "no retrace" oracles — byte-for-byte
    compatible while making the same counts available to scrapes and
    ``BENCH_*.json``.  Decrements (which the trace counters never do)
    are deliberately not mirrored: registry counters are monotonic.
    """

    def __init__(self, registry: Optional[Registry] = None,
                 prefix: str = "trace"):
        super().__init__()
        self._registry = registry if registry is not None else REGISTRY
        self._prefix = prefix

    def __setitem__(self, key, value):
        delta = value - self.get(key, 0)
        super().__setitem__(key, value)
        if delta > 0:
            self._registry.counter(
                f"{self._prefix}_{key}",
                help="jax trace count (python impl-body executions)",
            ).inc(delta)
