"""Portfolio optimizer: evolutionary search over the candidate space.

Answers "what is the cheapest multi-chiplet architecture for this SKU
portfolio at these volumes?" — optionally under parameter uncertainty,
where the objective becomes a high quantile of the Monte Carlo portfolio
cost and the result carries a cost-vs-risk Pareto front.

The loop is a (mu + lambda) evolutionary search with elitism, and its
inner iteration is ONE jitted **generation step**: decode the population
indices (:func:`~repro.dse.space.encode_arrays`), price them with the
engine, reduce to the (possibly Monte-Carlo-quantile) objective, rank
with ``lax.top_k``, and breed the next population with vectorized
index-space crossover + mutation — all in a single retained jit trace
whose population/objective buffers are donated (where the backend
supports donation).  The host syncs once per generation for history
bookkeeping; nothing per-candidate ever crosses the device boundary.

All randomness flows from one explicit ``jax.random`` PRNG key, so the
same key always returns the same winner (pinned by ``tests/test_dse.py``).
For brute-forceable spaces, :func:`exhaustive_search` enumerates — the
cross-check that the evolutionary loop recovers the true optimum.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.store import CheckpointManager
from ..core.engine import TRACE_COUNTS, portfolio_totals
from ..core.explorer import pareto_front
from ..obs import jaxhooks
from ..obs.trace import TRACER as _TRACER
from .evaluate import (CandidateResult, ChunkedEvaluator, _fused_risk_draws,
                       _fused_totals)
from .space import Candidate, DesignSpace, EncoderMeta
from .uncertainty import Uncertainty, portfolio_risk_stats


@dataclasses.dataclass(frozen=True)
class RiskConfig:
    """Turns the search uncertainty-aware: optimize a cost quantile."""

    n_draws: int = 128
    sigmas: Uncertainty = dataclasses.field(default_factory=Uncertainty)
    quantile: float = 0.9

    @property
    def objective_key(self) -> str:
        return f"q{int(round(self.quantile * 100))}"


@dataclasses.dataclass
class SearchResult:
    best: CandidateResult
    ranked: List[CandidateResult]      # every priced candidate, best first
    pareto: List[Dict]                 # cost-vs-risk front (risk runs only)
    history: List[Dict]                # per-generation progress
    n_evaluated: int                   # distinct candidates priced
    objective_key: str = "cost"

    def top(self, k: int = 10) -> List[CandidateResult]:
        return self.ranked[:k]


def _rank(results: Sequence[CandidateResult], key: str
          ) -> List[CandidateResult]:
    # label is the deterministic tie-breaker: equal-cost candidates
    # always rank in the same order regardless of arrival order.
    return sorted(results, key=lambda r: (r.objective(key), r.label))


def _front(results: Sequence[CandidateResult], key: str) -> List[Dict]:
    if key == "cost":
        return []
    pts = [{"label": r.label, "mean": r.risk["mean"], key: r.risk[key],
            "candidate": r.candidate} for r in results if r.risk]
    return pareto_front(pts, "mean", key)


def _check_evaluator(space: DesignSpace, flow: str,
                     ev: ChunkedEvaluator) -> ChunkedEvaluator:
    """A passed-in evaluator must agree with the search's space/flow —
    it binds both, and a mismatch would silently price the wrong
    portfolio."""
    if ev.space != space:
        raise ValueError("evaluator was built for a different DesignSpace")
    if ev.flow != flow:
        raise ValueError(
            f"evaluator flow {ev.flow!r} != requested flow {flow!r}")
    return ev


def _mc_kwargs(risk: RiskConfig, mc_key) -> Dict:
    return dict(mc_key=mc_key, mc_draws=risk.n_draws, mc_sigmas=risk.sigmas,
                mc_quantiles=(0.5, risk.quantile))


def _default_mc_key(key):
    """The one shared derivation of the Monte Carlo key from a search key:
    exhaustive and evolutionary runs given the same ``key`` price every
    candidate under identical scenarios, so their quantile objectives are
    directly comparable (common random numbers)."""
    return jax.random.fold_in(key, 1)


# ---------------------------------------------------------------------------
# Search state: the checkpointable loop carrier
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SearchState:
    """Everything the evolutionary loop needs to continue from
    generation ``gen`` — and nothing else.

    Because the key schedule is ``k_loop, k_gen = split(k_loop)`` each
    generation and the final ranking sweep depends only on ``seen`` and
    ``mc_key``, restoring this state reproduces an uninterrupted run
    **bit-exactly**: same populations, same history floats, same ranked
    result (the zero-tolerance oracle in ``tests/test_durability.py``).

    The device leaves (``pop``/``k_loop``/``mc_key``/``sig``) have fixed
    shapes given the population, so they ride
    :mod:`repro.checkpoint.store`'s array protocol; the variable-size
    host state (``seen``, ``history``, best-so-far) travels in the
    manifest's ``extra`` JSON, which roundtrips Python floats exactly.
    """

    pop: Any                       # (population,) int32 candidate indices
    k_loop: Any                    # uint32 (2,) loop PRNG key
    mc_key: Any                    # uint32 (2,) Monte-Carlo key
    sig: Any                       # (4,) float32 sigma vector
    seen: set
    history: List[Dict]
    best_obj: float = np.inf
    best_idx: int = -1
    gen: int = 0                   # completed generations
    trace_id: str = ""             # request trace id (durable: rides the
                                   # checkpoint manifest, so a resumed
                                   # search keeps its original trace)

    @classmethod
    def init(cls, key, population: int, size: int,
             risk: Optional[RiskConfig]) -> "SearchState":
        """The one shared derivation of a fresh search state from a PRNG
        key — ``portfolio_search`` and the service's ``SearchTask`` both
        start here, which is what makes served searches bit-exact
        against direct calls."""
        mc_key, sig = key, jnp.zeros((4,), jnp.float32)  # placeholders
        if risk is not None:
            mc_key = _default_mc_key(key)
            sig = risk.sigmas.as_array()
        k_init, k_loop = jax.random.split(key)
        pop = jax.random.randint(k_init, (population,), 0, size,
                                 dtype=jnp.int32)
        return cls(pop=pop, k_loop=k_loop, mc_key=mc_key, sig=sig,
                   seen=set(), history=[])

    def consume(self, host, label_fn) -> None:
        """Fold one generation's host results (priced population, gen
        best index/objective) into the state."""
        pop_h, gen_idx, gen_obj = host
        self.seen.update(int(i) for i in pop_h)
        if float(gen_obj) < self.best_obj:
            self.best_obj, self.best_idx = float(gen_obj), int(gen_idx)
        self.history.append({
            "generation": self.gen,
            "evaluated": len(self.seen),
            "best_objective": self.best_obj,
            "best_label": label_fn(self.best_idx),
            "gen_best": float(gen_obj)})
        self.gen += 1

    # -- checkpoint protocol -------------------------------------------------

    def tree(self) -> Dict[str, Any]:
        return {"pop": self.pop, "k_loop": self.k_loop,
                "mc_key": self.mc_key, "sig": self.sig}

    def extra(self) -> Dict[str, Any]:
        return {"gen": self.gen, "best_obj": float(self.best_obj),
                "best_idx": int(self.best_idx),
                "trace_id": self.trace_id,
                "seen": sorted(int(i) for i in self.seen),
                "history": list(self.history)}

    @staticmethod
    def like(population: int) -> Dict[str, Any]:
        """The fixed-shape restore template for a given population."""
        return {"pop": jnp.zeros((population,), jnp.int32),
                "k_loop": jnp.zeros((2,), jnp.uint32),
                "mc_key": jnp.zeros((2,), jnp.uint32),
                "sig": jnp.zeros((4,), jnp.float32)}

    def save(self, manager: CheckpointManager):
        """Publish this state as checkpoint step ``gen`` (atomic
        rename, digest-stamped, retention-K via the manager)."""
        return manager.save(self.gen, self.tree(), extra=self.extra())

    @classmethod
    def restore_latest(cls, manager: CheckpointManager,
                       population: int) -> Optional["SearchState"]:
        """Newest readable checkpoint as a live state, or None when the
        directory holds none.  Corrupt steps fall back to the previous
        retained step (``manager.corrupt_fallbacks`` counts them)."""
        step, tree = manager.restore_latest(cls.like(population))
        if step is None:
            return None
        manifest = manager.directory / f"step_{step:08d}" / "manifest.json"
        extra = json.loads(manifest.read_text()).get("extra", {})
        return cls(pop=tree["pop"], k_loop=tree["k_loop"],
                   mc_key=tree["mc_key"], sig=tree["sig"],
                   seen=set(int(i) for i in extra.get("seen", [])),
                   history=list(extra.get("history", [])),
                   best_obj=float(extra.get("best_obj", np.inf)),
                   best_idx=int(extra.get("best_idx", -1)),
                   gen=int(extra.get("gen", step)),
                   trace_id=str(extra.get("trace_id", "")))


def exhaustive_search(space: DesignSpace,
                      evaluator: Optional[ChunkedEvaluator] = None,
                      flow: str = "chip-last",
                      risk: Optional[RiskConfig] = None,
                      mc_key=None, key=None) -> SearchResult:
    """Price every candidate in the space (small spaces only).

    In risk mode the Monte Carlo scenarios come from ``mc_key`` (or are
    derived from ``key`` exactly as :func:`portfolio_search` does, so
    passing the same ``key`` to both makes their quantile objectives
    comparable).
    """
    ev = _check_evaluator(space, flow, evaluator) if evaluator \
        else ChunkedEvaluator(space, flow=flow)
    kw = {}
    obj = "cost"
    if risk is not None:
        if mc_key is None:
            mc_key = _default_mc_key(key if key is not None
                                     else jax.random.PRNGKey(0))
        kw = _mc_kwargs(risk, mc_key)
        obj = risk.objective_key
    results = ev.evaluate(list(space.enumerate_candidates()), **kw)
    ranked = _rank(results, obj)
    return SearchResult(best=ranked[0], ranked=ranked,
                        pareto=_front(results, obj), history=[],
                        n_evaluated=len(results), objective_key=obj)


# ---------------------------------------------------------------------------
# Vectorized index-space genetic operators (pure jnp, static meta)
# ---------------------------------------------------------------------------


def _digits(i, meta: EncoderMeta, pows):
    """(n,) arch index -> (n, S) per-SKU choice digits (SKU 0 is most
    significant), garbage-but-bounded for reuse indices (callers mask)."""
    safe = jnp.where(i >= meta.n_arch, 0, i)
    return (safe[:, None] // pows[None, :]) % meta.n_arch_choices


def _compose(digits, pows):
    return (digits * pows[None, :]).sum(-1).astype(jnp.int32)


def _crossover_vec(key, ia, ib, meta: EncoderMeta, pows):
    """Per-SKU uniform crossover of two index vectors; any reuse parent
    passes through (mutation supplies reuse-family exploration)."""
    picks = jax.random.bernoulli(key, 0.5, ia.shape + (meta.n_skus,))
    d = jnp.where(picks, _digits(ia, meta, pows), _digits(ib, meta, pows))
    either_reuse = (ia >= meta.n_arch) | (ib >= meta.n_arch)
    return jnp.where(either_reuse, ia, _compose(d, pows))


def _mutate_vec(key, i, meta: EncoderMeta, pows, jump_prob: float):
    """Random neighbor in index space, mirroring ``DesignSpace.mutate``:
    occasionally jump anywhere; reuse candidates hop within the reuse
    family (p=0.7) or back to the arch family; arch candidates hop into
    the reuse family (p=0.15) or tweak one SKU's digit."""
    n = i.shape[0]
    a, r, s = meta.n_arch_choices, meta.n_reuse_choices, meta.n_skus
    (k_jump, k_jto, k_rbranch, k_abranch, k_hop, k_back, k_sku, k_delta,
     k_rto) = jax.random.split(key, 9)

    is_reuse = i >= meta.n_arch
    # -- reuse family: hop to a different reuse choice or leave ------------
    if r > 1:
        ri = jnp.clip(i - meta.n_arch, 0, r - 1)
        r2 = (ri + 1 + jax.random.randint(k_hop, (n,), 0, r - 1)) % r
        back = jax.random.randint(k_back, (n,), 0, meta.n_arch)
        reuse_next = jnp.where(
            jax.random.uniform(k_rbranch, (n,)) < 0.7,
            meta.n_arch + r2, back)
    else:
        reuse_next = jax.random.randint(k_back, (n,), 0, meta.n_arch)

    # -- arch family: hop into reuse or tweak one SKU digit ----------------
    d = _digits(i, meta, pows)
    sku = jax.random.randint(k_sku, (n,), 0, s)
    delta = jax.random.randint(k_delta, (n,), 1, max(a, 2))
    row = jnp.arange(n)
    d2 = d.at[row, sku].set((d[row, sku] + delta) % a)
    arch_next = _compose(d2, pows)
    if r > 0:
        to_reuse = meta.n_arch + jax.random.randint(k_rto, (n,), 0, r)
        arch_next = jnp.where(
            jax.random.uniform(k_abranch, (n,)) < 0.15, to_reuse, arch_next)

    out = jnp.where(is_reuse, reuse_next, arch_next)
    jump = jax.random.uniform(k_jump, (n,)) < jump_prob
    return jnp.where(jump,
                     jax.random.randint(k_jto, (n,), 0, meta.size), out)


# ---------------------------------------------------------------------------
# The fused generation step: price -> rank -> breed, one jit trace
# ---------------------------------------------------------------------------


def _gen_step_impl(tables, key, pop, qty, mc_key, sig, *, meta: EncoderMeta,
                   flow: str, population: int, elite: int,
                   jump_prob: float, n_draws: int, quantile: float):
    TRACE_COUNTS["gen_step"] += 1
    # the same fused decode->price composition the evaluator chunks use,
    # so the step's objective and the final ranking sweep agree exactly
    batch, _, nre_tot, total = _fused_totals(tables, pop, meta=meta,
                                             flow=flow)
    if n_draws:
        pf_draws = _fused_risk_draws(batch, nre_tot, qty, mc_key, sig,
                                     flow, n_draws, meta.n_skus)
        obj = portfolio_risk_stats(pf_draws, (quantile,))[
            f"q{int(round(quantile * 100))}"]
    else:
        obj = portfolio_totals(total, qty)

    # deterministic ranking: objective, position-stable on exact ties
    neg, order = jax.lax.top_k(-obj, elite)
    elite_idx = pop[order]
    elite_obj = -neg

    n_child = population - elite
    pows = tables["digit_pow"]      # the encoder's mixed-radix layout
    kpa, kpb, kx, kmut, kgate = jax.random.split(key, 5)
    pa = elite_idx[jax.random.randint(kpa, (n_child,), 0, elite)]
    pb = elite_idx[jax.random.randint(kpb, (n_child,), 0, elite)]
    child = _crossover_vec(kx, pa, pb, meta, pows)
    mutated = _mutate_vec(kmut, child, meta, pows, jump_prob)
    child = jnp.where(jax.random.bernoulli(kgate, 0.8, (n_child,)),
                      mutated, child)
    next_pop = jnp.concatenate([elite_idx, child])
    # `pop` is returned (aliasing its donated buffer) so the host can read
    # the priced generation without holding the pre-donation reference.
    return pop, next_pop, elite_idx[0], elite_obj[0]


# One module-level jit; the population buffer is donated so the
# generation loop recycles device memory (donation is a no-op on backends
# like CPU that do not implement it — gated to keep the warning away).
# Built lazily: jax.default_backend() initializes the backend, which must
# not happen as an import side effect.
_GEN_STEP = None


def _gen_step():
    global _GEN_STEP
    if _GEN_STEP is None:
        donate = (2,) if jax.default_backend() != "cpu" else ()
        _GEN_STEP = jaxhooks.instrument(
            jax.jit(
                _gen_step_impl,
                static_argnames=("meta", "flow", "population", "elite",
                                 "jump_prob", "n_draws", "quantile"),
                donate_argnums=donate),
            "search.gen_step", trace_key="gen_step", counts=TRACE_COUNTS)
    return _GEN_STEP


def portfolio_search(space: DesignSpace, key, *,
                     population: int = 32, generations: int = 12,
                     elite: int = 6, jump_prob: float = 0.15,
                     risk: Optional[RiskConfig] = None,
                     evaluator: Optional[ChunkedEvaluator] = None,
                     flow: str = "chip-last",
                     checkpoint_dir=None, checkpoint_every: int = 1,
                     checkpoint_keep: int = 3,
                     resume: bool = True) -> SearchResult:
    """Evolutionary portfolio search, deterministic in ``key``.

    ``risk=RiskConfig(...)`` switches the objective from nominal
    portfolio cost to the configured Monte Carlo quantile (common random
    numbers across all candidates, derived from ``key``).

    Every generation is one jitted step (decode + price + rank + breed on
    device); the trace is retained across generations and across
    same-shaped searches, which ``tests/test_fused.py`` pins via
    ``TRACE_COUNTS['gen_step']``.

    ``checkpoint_dir`` makes the run crash-safe: every
    ``checkpoint_every`` completed generations the loop state
    (:class:`SearchState`) is published atomically (retention
    ``checkpoint_keep``), and — with ``resume=True`` — a rerun pointed
    at the same directory continues from the newest readable step and
    returns a **bit-exact** copy of the uninterrupted run's result.
    """
    if elite < 1 or elite > population:
        raise ValueError("need 1 <= elite <= population")
    ev = _check_evaluator(space, flow, evaluator) if evaluator \
        else ChunkedEvaluator(space, candidates_per_chunk=min(population, 64),
                              flow=flow)
    enc = space.encoder()
    qty = jnp.asarray([sk.quantity for sk in space.skus], jnp.float32)
    obj = "cost"
    ev_kw: Dict = {}
    n_draws, quantile = 0, 0.5
    if risk is not None:
        obj = risk.objective_key
        n_draws, quantile = int(risk.n_draws), float(risk.quantile)
        ev_kw = _mc_kwargs(risk, _default_mc_key(key))

    state = SearchState.init(key, population, space.size(), risk)
    manager = None
    if checkpoint_dir is not None:
        manager = CheckpointManager(checkpoint_dir, keep=checkpoint_keep)
        if resume:
            restored = SearchState.restore_latest(manager, population)
            if restored is not None:
                state = restored
    step = _gen_step()
    label_fn = lambda i: space.candidate_at(i).label()  # noqa: E731
    for gen in range(state.gen, generations):
        with _TRACER.span("generation", gen=gen):
            state.k_loop, k_gen = jax.random.split(state.k_loop)
            pop_out, pop_next, gen_idx, gen_obj = step(
                enc.tables, k_gen, state.pop, qty, state.mc_key,
                state.sig, meta=enc.meta,
                flow=flow, population=population, elite=elite,
                jump_prob=float(jump_prob), n_draws=n_draws,
                quantile=quantile)
            # one host sync per generation: priced population + gen best
            host = jax.device_get((pop_out, gen_idx, gen_obj))
        state.consume(host, label_fn)
        state.pop = pop_next
        if manager is not None and checkpoint_every > 0 \
                and state.gen % checkpoint_every == 0 \
                and state.gen < generations:
            state.save(manager)

    # materialize every distinct priced candidate through the fused
    # evaluator (same engine graph => identical objectives), rank on host
    uniq = np.asarray(sorted(state.seen), np.int64)
    if ev.fused:
        arrays = ev.evaluate_indices(uniq, **ev_kw)
        results = ev.results_from_arrays(arrays)
    else:
        results = ev.evaluate([space.candidate_at(int(i)) for i in uniq],
                              **ev_kw)
    ranked = _rank(results, obj)
    return SearchResult(best=ranked[0], ranked=ranked,
                        pareto=_front(ranked, obj), history=state.history,
                        n_evaluated=len(results), objective_key=obj)
