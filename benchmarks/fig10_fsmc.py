"""Paper Fig. 10: FSMC (few sockets, multiple collocations) reuse curve.

NOTE: the paper quotes '6 chiplets and one 4-socket package -> up to 119
systems'; its own formula sum_{i=1..k} C(n+i-1,i) gives 209 for (6,4)
(119 corresponds to (7,3)). We implement the formula and flag this.
"""
from repro.core import amortized_costs, fsmc_num_systems, fsmc_situations
from .common import emit


def run():
    print(f"# fsmc count check: f(6,4)={fsmc_num_systems(6, 4)} "
          f"(paper text says 119; f(7,3)={fsmc_num_systems(7, 3)})")
    sits = fsmc_situations(n_chiplets=6, k_sockets=4, n_situations=5)
    rows = []
    base = None
    for n_systems, systems in sorted(sits.items()):
        costs = amortized_costs(systems)
        avg_re = sum(c.re.total for c in costs.values()) / len(costs)
        avg_nre = sum(c.nre_total for c in costs.values()) / len(costs)
        if base is None:
            base = avg_re + avg_nre
        rows.append({
            "reused_systems": n_systems,
            "avg_re_norm": avg_re / base,
            "avg_nre_norm": avg_nre / base,
            "avg_total_norm": (avg_re + avg_nre) / base,
        })
    emit("fig10_fsmc_reuse", rows)
    # paper claim: amortized NRE -> negligible at max reuse
    assert rows[-1]["avg_nre_norm"] < rows[0]["avg_nre_norm"] / 4
    return rows


if __name__ == "__main__":
    run()
