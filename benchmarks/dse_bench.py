"""DSE throughput benchmark: chunked candidate pricing + Monte Carlo.

  PYTHONPATH=src python -m benchmarks.dse_bench [n_candidates] [chunk]

Asserts (acceptance criteria of the dse subsystem):
  * >= 10k candidate portfolios (default) stream through the chunked
    evaluator with EXACTLY one retained jit trace per (chunk-shape,
    flow) — no retrace at any chunk boundary, including the final
    partially-filled (padded) chunk;
  * a sampled subset of the padded-chunk prices matches the direct
    unchunked `CostEngine.total` path to <= 1e-5 relative.

Reports candidates/sec and systems/sec for nominal pricing, Monte Carlo
draw throughput (draws/sec, draw-systems/sec), and emits a JSON summary
line for CI trend tracking.
"""
import json
import sys
import time

import jax
import numpy as np

from repro.core.engine import TRACE_COUNTS
from repro.dse import (ChunkedEvaluator, DesignSpace, SKU, evaluate_direct,
                       mc_totals)

SPACE = DesignSpace(
    skus=(SKU("laptop", 300.0, 2e6), SKU("desktop", 600.0, 1e6),
          SKU("server", 900.0, 3e5)),
    processes=("5nm", "7nm", "12nm"),
    integrations=("MCM", "2.5D"),
    chiplet_counts=(1, 2, 3, 4, 6),
    allow_reuse=True, reuse_package_options=(False, True))


def run(n_candidates: int = 10_000, chunk: int = 256):
    rng = np.random.default_rng(0)
    cands = SPACE.sample(rng, n_candidates)
    ev = ChunkedEvaluator(SPACE, candidates_per_chunk=chunk)

    # Warm the single (chunk-shape, chip-last) trace, then stream.
    ev.evaluate(cands[:chunk])
    warm = dict(TRACE_COUNTS)
    ev.reset_stats()
    t0 = time.perf_counter()
    results = ev.evaluate(cands)
    wall = time.perf_counter() - t0
    delta = {k: TRACE_COUNTS[k] - warm.get(k, 0) for k in TRACE_COUNTS
             if TRACE_COUNTS[k] != warm.get(k, 0)}
    assert not delta, f"retraced across chunk boundaries: {delta}"

    # The other flow is its own single retained trace.
    before = dict(TRACE_COUNTS)
    ChunkedEvaluator(SPACE, candidates_per_chunk=chunk,
                     flow="chip-first").evaluate(cands[:2 * chunk])
    ff = {k: TRACE_COUNTS[k] - before.get(k, 0) for k in ("total",)}
    assert ff == {"total": 1}, f"chip-first flow traces: {ff}"
    # One retained trace per (chunk-shape, flow) for the whole stream;
    # snapshot before the parity loop below adds per-candidate direct
    # (unchunked, differently-shaped) traces.
    stream_traces = dict(TRACE_COUNTS)

    # Parity spot-check vs the direct unchunked engine path.
    worst = 0.0
    for i in range(0, n_candidates, max(1, n_candidates // 29)):
        d = evaluate_direct(SPACE, results[i].candidate)
        rel = float(np.max(np.abs(results[i].sku_unit_total
                                  - d.sku_unit_total) / d.sku_unit_total))
        worst = max(worst, rel)
    assert worst < 1e-5, f"chunked/direct mismatch: {worst:.2e}"

    best = min(results, key=lambda r: (r.portfolio_cost, r.label))

    # Monte Carlo throughput on one retained chunk trace.
    n_draws, reps = 512, 3
    batch = ev.pack_chunk(cands[:chunk])
    key = jax.random.PRNGKey(0)
    jax.block_until_ready(mc_totals(batch, key, n_draws=n_draws))  # trace
    t0 = time.perf_counter()
    for r in range(reps):
        jax.block_until_ready(mc_totals(batch, jax.random.fold_in(key, r),
                                        n_draws=n_draws))
    t_mc = (time.perf_counter() - t0) / reps
    draws_per_sec = n_draws / t_mc
    draw_systems_per_sec = n_draws * batch.n_systems / t_mc

    summary = {
        "n_candidates": n_candidates,
        "n_systems": ev.n_systems,
        "chunk": chunk,
        "wall_s": round(wall, 3),
        "candidates_per_sec": round(ev.candidates_per_sec, 1),
        "systems_per_sec": round(ev.systems_per_sec, 1),
        "trace_counts_stream": stream_traces,
        "parity_worst_rel": worst,
        "best_candidate": best.label,
        "best_portfolio_cost": best.portfolio_cost,
        "mc_draws": n_draws,
        "mc_draws_per_sec": round(draws_per_sec, 1),
        "mc_draw_systems_per_sec": round(draw_systems_per_sec, 1),
    }
    print(f"candidates           : {n_candidates} "
          f"({ev.n_systems} systems, chunk={chunk})")
    print(f"pricing wall         : {wall*1e3:9.1f} ms "
          f"({ev.candidates_per_sec:,.0f} candidates/s, "
          f"{ev.systems_per_sec:,.0f} systems/s)")
    print(f"trace counts (stream): {stream_traces} "
          f"(one per (chunk-shape, flow): chip-last + chip-first)")
    print(f"parity worst rel err : {worst:.2e}")
    print(f"best candidate       : {best.label} "
          f"(${best.portfolio_cost:,.0f} portfolio)")
    print(f"monte carlo          : {draws_per_sec:,.0f} draws/s "
          f"({draw_systems_per_sec:,.0f} system-draws/s, "
          f"{n_draws} draws x {batch.n_systems} systems)")
    print("JSON:", json.dumps(summary))
    return summary


if __name__ == "__main__":
    run(int(sys.argv[1]) if len(sys.argv) > 1 else 10_000,
        int(sys.argv[2]) if len(sys.argv) > 2 else 256)
