"""repro.dse — portfolio-scale design-space exploration.

The search layer on top of :class:`~repro.core.engine.CostEngine`:

  space        -- declarative DesignSpace (SKUs, nodes, integrations,
                  chiplet counts, cross-SKU reuse) + candidate algebra
  evaluate     -- ChunkedEvaluator: constant-shape padded SystemBatch
                  chunks, one retained jit trace per (chunk-shape, flow)
  uncertainty  -- Monte Carlo cost distributions (vmapped engine) and
                  grad-based parameter sensitivities
  search       -- evolutionary portfolio optimizer (+ exhaustive
                  cross-check), deterministic in an explicit PRNG key
  report       -- candidate/SKU result tables, CostEngine.as_rows
                  compatible, JSON-ready

Quickstart::

    import jax
    from repro.dse import (DesignSpace, SKU, portfolio_search,
                           search_summary)

    space = DesignSpace(
        skus=(SKU("laptop", 300.0, 2e6), SKU("desktop", 600.0, 1e6),
              SKU("server", 900.0, 3e5)),
        processes=("5nm", "7nm"), integrations=("MCM", "2.5D"),
        chiplet_counts=(1, 2, 3, 4, 6))
    res = portfolio_search(space, jax.random.PRNGKey(0))
    print(res.best.label, res.best.portfolio_cost)
"""
from .space import (ArchChoice, Candidate, CandidateEncoder, DesignSpace,
                    EncoderMeta, ReuseChoice, SKU, candidate_systems,
                    encode_arrays, encode_batch)
from .evaluate import (CandidateResult, ChunkShape, ChunkedEvaluator,
                       EvalArrays, chunk_shape, evaluate_direct)
from .uncertainty import (SENSITIVITY_PARAMS, Uncertainty, mc_summary,
                          mc_totals, portfolio_draws, portfolio_risk_stats,
                          sensitivities)
from .search import (RiskConfig, SearchResult, SearchState,
                     exhaustive_search, portfolio_search)
from .report import (detail_rows, format_table, result_rows, search_summary,
                     to_json)

__all__ = [
    "ArchChoice", "Candidate", "CandidateEncoder", "DesignSpace",
    "EncoderMeta", "ReuseChoice", "SKU", "candidate_systems",
    "encode_arrays", "encode_batch", "CandidateResult", "ChunkShape",
    "ChunkedEvaluator", "EvalArrays", "chunk_shape", "evaluate_direct",
    "SENSITIVITY_PARAMS", "Uncertainty", "mc_summary", "mc_totals",
    "portfolio_draws", "portfolio_risk_stats", "sensitivities",
    "RiskConfig", "SearchResult", "SearchState", "exhaustive_search",
    "portfolio_search",
    "detail_rows", "format_table", "result_rows", "search_summary",
    "to_json",
]
