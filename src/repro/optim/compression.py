"""Gradient compression for cross-pod reduction (top-k + int8, with
error feedback).

At 1000+ nodes the pod-to-pod (DCN/ICI-over-optics) all-reduce is the
scarce resource.  The classic fix: reduce full-precision *within* a pod,
then compress the cross-pod leg.  We implement

  * top-k sparsification (per-tensor, magnitude),
  * int8 quantization of the surviving values (per-tensor scale),
  * error feedback (the residual is added back next step) so the
    compression bias does not accumulate — Karimireddy et al. 2019.

Both ops are pure jnp and differentiably irrelevant (applied to grads).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    values_i8: Any     # int8 quantized surviving values
    indices: Any       # int32 flat indices
    scale: Any         # fp32 per-tensor scale
    shape: Any         # static


def compress_topk_int8(g, k_fraction: float = 0.05) -> Tuple[Compressed, Any]:
    """Compress one tensor; returns (compressed, residual_error)."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    k = max(1, int(n * k_fraction))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    scale = jnp.maximum(jnp.abs(kept).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(kept / scale), -127, 127).astype(jnp.int8)
    # residual: what the wire did NOT carry (top-k misses + quant error)
    recon = jnp.zeros_like(flat).at[idx].set(q.astype(jnp.float32) * scale)
    err = (flat - recon).reshape(g.shape)
    return Compressed(values_i8=q, indices=idx, scale=scale,
                      shape=g.shape), err


def decompress_topk_int8(c: Compressed):
    n = 1
    for d in c.shape:
        n *= d
    flat = jnp.zeros((n,), jnp.float32).at[c.indices].set(
        c.values_i8.astype(jnp.float32) * c.scale)
    return flat.reshape(c.shape)


def error_feedback_update(g, err_state, k_fraction: float = 0.05):
    """One error-feedback round for a single tensor.

    Returns (decompressed_gradient, new_error_state).  The caller
    all-reduces the *compressed* representation across pods; here (single
    process) compress->decompress models the wire losslessly.
    """
    comp, err = compress_topk_int8(g + err_state, k_fraction)
    return decompress_topk_int8(comp), err


def compressed_bytes(c: Compressed) -> int:
    """Wire size of one compressed tensor (int8 vals + int32 idx + scale)."""
    k = c.values_i8.shape[0]
    return k * 1 + k * 4 + 4
