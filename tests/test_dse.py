"""repro.dse: padded-chunk parity with the direct engine path, constant
trace counts across chunk boundaries, seeded search determinism, and the
exhaustive-enumeration cross-check of the portfolio optimizer."""
import jax
import numpy as np
import pytest

from repro.core import CostEngine, SystemBatch, pad_batch, split_system
from repro.core.engine import TRACE_COUNTS
from repro.dse import (Candidate, ChunkedEvaluator, DesignSpace, SKU,
                       Uncertainty, candidate_systems, chunk_shape,
                       detail_rows, evaluate_direct, exhaustive_search,
                       mc_summary, mc_totals, portfolio_search, result_rows,
                       RiskConfig, sensitivities, search_summary, to_json)

ENGINE = CostEngine()


def _space(**kw):
    d = dict(skus=(SKU("laptop", 200.0, 2e6), SKU("server", 400.0, 5e5)),
             processes=("7nm", "12nm"), integrations=("MCM",),
             chiplet_counts=(1, 2, 4), allow_reuse=True)
    d.update(kw)
    return DesignSpace(**d)


# One module-scoped evaluator so every test reuses the same chunk shape
# (and therefore the same compiled trace) — mirrors real usage.
@pytest.fixture(scope="module")
def space():
    return _space()


@pytest.fixture(scope="module")
def evaluator(space):
    return ChunkedEvaluator(space, candidates_per_chunk=8)


# ---------------------------------------------------------------------------
# Space algebra
# ---------------------------------------------------------------------------


def test_space_is_countable_and_decodable(space):
    cands = list(space.enumerate_candidates())
    assert len(cands) == space.size()
    assert cands == [space.candidate_at(i) for i in range(space.size())]
    # valid reuse slices: every SKU area is an in-range integer multiple
    for r in space.reuse_choices():
        counts = space.reuse_counts(r)
        for sku, k in zip(space.skus, counts):
            assert k in space.chiplet_counts
            assert sku.module_area_mm2 == pytest.approx(
                k * r.slice_area_mm2, rel=1e-6)


def test_candidate_systems_reuse_shares_one_design(space):
    r = space.reuse_choices()[0]
    systems = candidate_systems(space, Candidate(reuse=r))
    names = {c.name for s in systems for c in s.chips}
    assert len(names) == 1                      # one chiplet design
    assert [s.n_chips for s in systems] == list(space.reuse_counts(r))
    assert [s.quantity for s in systems] == [s2.quantity
                                             for s2 in space.skus]


def test_space_rejects_bad_configs():
    with pytest.raises(ValueError):
        _space(integrations=("SoC",))
    with pytest.raises(ValueError):
        _space(skus=(SKU("a", 100.0, 1.0), SKU("a", 200.0, 1.0)))
    with pytest.raises(KeyError):
        _space(processes=("3nm",))
    with pytest.raises(ValueError):
        _space(processes=())
    with pytest.raises(ValueError):
        _space(integrations=())


def test_foreign_reuse_candidate_and_short_names_are_rejected(space):
    from repro.core import portfolio_reuse_systems
    from repro.dse import ReuseChoice
    # a slice that does not tile the SKU inventories must not price
    with pytest.raises(ValueError):
        candidate_systems(space, Candidate(reuse=ReuseChoice(
            70.0, "7nm", "MCM")))
    with pytest.raises(ValueError):
        portfolio_reuse_systems(100.0, "7nm", "MCM", counts=[1, 2],
                                quantities=[1e6, 5e5], names=["only_one"])


def test_result_rows_top_zero_means_zero(space, evaluator):
    res = evaluator.evaluate([space.candidate_at(0)])
    assert result_rows(res, top=0) == []
    assert len(result_rows(res)) == 1


def test_mismatched_candidate_and_evaluator_are_rejected(space):
    three = _space(skus=(SKU("a", 100.0, 1.0), SKU("b", 200.0, 1.0),
                         SKU("c", 400.0, 1.0)))
    foreign = three.candidate_at(0)          # 3 per-SKU choices
    with pytest.raises(ValueError):
        candidate_systems(space, foreign)    # 2-SKU space
    ev = ChunkedEvaluator(three, candidates_per_chunk=4)
    with pytest.raises(ValueError):
        exhaustive_search(space, evaluator=ev)
    with pytest.raises(ValueError):
        portfolio_search(space, jax.random.PRNGKey(0),
                         evaluator=ChunkedEvaluator(space),
                         flow="chip-first")  # evaluator bound chip-last


# ---------------------------------------------------------------------------
# pad_batch — cost-neutral padding
# ---------------------------------------------------------------------------


def test_pad_batch_preserves_real_rows_and_zeroes_padding():
    a = split_system("a", 400.0, "7nm", 2, "MCM", quantity=1e6)
    b = split_system("b", 600.0, "5nm", 3, "2.5D", quantity=5e5)
    batch = SystemBatch.from_systems([a, b], share_nre=True)
    tc = ENGINE.total(batch)
    padded = pad_batch(batch, n_systems=5, max_chips=6, chip_entities=9,
                       pkg_entities=6, mod_entities=9, mod_instances=12,
                       d2d_entities=5, d2d_instances=12)
    tp = ENGINE.total(padded)
    for i in range(2):
        assert float(tp.total[i]) == pytest.approx(float(tc.total[i]),
                                                   rel=1e-6)
    for i in range(2, 5):
        assert float(tp.total[i]) == 0.0
    assert padded.names[2:] == ("__pad0", "__pad1", "__pad2")


def test_pad_batch_refuses_to_shrink_or_strand_instances():
    batch = SystemBatch.from_systems(
        [split_system("a", 400.0, "7nm", 2, "MCM")])
    with pytest.raises(ValueError):
        pad_batch(batch, n_systems=0)
    with pytest.raises(ValueError):
        # more instances but nowhere harmless to park them
        pad_batch(batch, mod_instances=batch.mod_sys.shape[0] + 2)


def test_share_nre_groups_match_independent_shared_batches():
    a = split_system("a", 400.0, "7nm", 2, "MCM", quantity=1e6)
    b = split_system("b", 600.0, "5nm", 3, "MCM", quantity=5e5)
    grouped = ENGINE.total(
        SystemBatch.from_systems([a, b, a, b], share_nre=[0, 0, 1, 1]))
    ref = ENGINE.total(SystemBatch.from_systems([a, b], share_nre=True))
    for i in range(4):
        assert float(grouped.total[i]) == pytest.approx(
            float(ref.total[i % 2]), rel=1e-6)
    with pytest.raises(ValueError):   # duplicate name inside one group
        SystemBatch.from_systems([a, a], share_nre=[0, 0])
    with pytest.raises(ValueError):   # group list length mismatch
        SystemBatch.from_systems([a, b], share_nre=[0])


# ---------------------------------------------------------------------------
# Chunked evaluation: parity + single-trace contract
# ---------------------------------------------------------------------------


def test_padded_chunk_pricing_matches_direct_engine_total(space, evaluator):
    cands = list(space.enumerate_candidates())
    results = evaluator.evaluate(cands)
    assert len(results) == len(cands)
    stride = max(1, len(results) // 11)
    for r in results[::stride]:
        direct = evaluate_direct(space, r.candidate)
        np.testing.assert_allclose(r.sku_unit_total, direct.sku_unit_total,
                                   rtol=1e-5)
        assert r.portfolio_cost == pytest.approx(direct.portfolio_cost,
                                                 rel=1e-5)


def test_trace_counts_constant_across_chunk_boundaries(space, evaluator):
    cands = list(space.enumerate_candidates())
    k = evaluator.shape.candidates
    assert len(cands) > 3 * k          # the stream really spans chunks
    evaluator.evaluate(cands[:k])      # warm (or reuse) the chunk trace
    before = dict(TRACE_COUNTS)
    evaluator.evaluate(cands)          # full + partially-filled chunks
    assert dict(TRACE_COUNTS) == before


def test_chunk_shape_bounds_are_sufficient(space):
    # the widest candidates must fit the declared signature
    sh = chunk_shape(space, 4)
    ev = ChunkedEvaluator(space, candidates_per_chunk=4)
    widest = sorted(space.enumerate_candidates(),
                    key=lambda c: -sum(s.n_chips
                                       for s in candidate_systems(space, c)))
    batch = ev.pack_chunk(widest[:4])
    assert batch.chip_area.shape == (sh.n_systems, sh.max_chips)
    assert batch.mod_sys.shape[0] == sh.mod_instances


# ---------------------------------------------------------------------------
# Uncertainty: Monte Carlo + sensitivities
# ---------------------------------------------------------------------------


def test_mc_is_deterministic_and_median_preserving(space):
    batch = SystemBatch.from_systems(
        candidate_systems(space, space.candidate_at(0)), share_nre=True)
    key = jax.random.PRNGKey(7)
    d1 = np.asarray(mc_totals(batch, key, n_draws=96))
    d2 = np.asarray(mc_totals(batch, key, n_draws=96))
    np.testing.assert_array_equal(d1, d2)
    assert d1.shape == (96, len(batch))
    s = mc_summary(batch, key, n_draws=96, quantiles=(0.05, 0.5, 0.95))
    nominal = np.asarray(ENGINE.total(batch).total)
    # lognormal multipliers are median-preserving: q50 ~ nominal
    np.testing.assert_allclose(np.asarray(s["q50"]), nominal, rtol=0.08)
    assert np.all(np.asarray(s["q5"]) <= np.asarray(s["q95"]))
    # zero sigmas collapse the distribution onto the nominal model
    z = Uncertainty(0.0, 0.0, 0.0, 0.0)
    dz = np.asarray(mc_totals(batch, key, n_draws=8, sigmas=z))
    np.testing.assert_allclose(dz, np.broadcast_to(nominal, dz.shape),
                               rtol=1e-5)


def test_sensitivities_signs_and_shapes(space):
    batch = SystemBatch.from_systems(
        candidate_systems(space, space.candidate_at(1)), share_nre=True)
    g = sensitivities(batch)
    n = len(batch)
    for k, v in g.items():
        assert v.shape == (n,), k
        assert bool(np.all(np.isfinite(np.asarray(v)))), k
    # more defects / pricier wafers cost money; better bond yield saves it
    assert np.all(np.asarray(g["chip_defect"]) > 0.0)
    assert np.all(np.asarray(g["chip_wafer_cost"]) > 0.0)
    assert np.all(np.asarray(g["y2_chip_bond"]) <= 0.0)


# ---------------------------------------------------------------------------
# Search: exhaustive cross-check + seeded determinism
# ---------------------------------------------------------------------------


def test_search_recovers_exhaustive_best(space, evaluator):
    ex = exhaustive_search(space, evaluator=evaluator)
    assert ex.n_evaluated == space.size()
    # independent cross-check of the exhaustive winner via the direct,
    # unchunked engine path
    direct_best = min((evaluate_direct(space, c)
                       for c in space.enumerate_candidates()),
                      key=lambda r: (r.portfolio_cost, r.label))
    assert ex.best.label == direct_best.label
    assert ex.best.portfolio_cost == pytest.approx(
        direct_best.portfolio_cost, rel=1e-5)

    sr = portfolio_search(space, jax.random.PRNGKey(0), population=12,
                          generations=6, elite=4, evaluator=evaluator)
    assert sr.best.label == ex.best.label
    assert sr.best.portfolio_cost == pytest.approx(ex.best.portfolio_cost,
                                                   rel=1e-6)
    assert sr.n_evaluated <= space.size()


def test_search_same_key_same_winner(space, evaluator):
    key = jax.random.PRNGKey(123)
    r1 = portfolio_search(space, key, population=10, generations=4,
                          elite=3, evaluator=evaluator)
    r2 = portfolio_search(space, key, population=10, generations=4,
                          elite=3, evaluator=evaluator)
    assert r1.best.label == r2.best.label
    assert r1.best.portfolio_cost == r2.best.portfolio_cost
    assert [h["best_label"] for h in r1.history] == \
        [h["best_label"] for h in r2.history]
    assert r1.n_evaluated == r2.n_evaluated


def test_risk_aware_search_produces_quantile_objective_and_front(space):
    ev = ChunkedEvaluator(space, candidates_per_chunk=8)
    sr = portfolio_search(space, jax.random.PRNGKey(5), population=10,
                          generations=3, elite=3, evaluator=ev,
                          risk=RiskConfig(n_draws=48, quantile=0.9))
    assert sr.objective_key == "q90"
    assert sr.best.risk is not None
    assert sr.best.risk["q90"] >= sr.best.risk["q50"] - 1e-6
    assert sr.pareto and all("q90" in p for p in sr.pareto)
    # the common-random-numbers quantile ordering is internally consistent
    assert sr.best.objective("q90") == min(
        r.objective("q90") for r in sr.ranked)
    # same search key => identical MC scenarios in the exhaustive run, so
    # the quantile objectives of shared candidates agree exactly
    ex = exhaustive_search(space, evaluator=ev, key=jax.random.PRNGKey(5),
                           risk=RiskConfig(n_draws=48, quantile=0.9))
    ex_by_label = {r.label: r for r in ex.ranked}
    for r in sr.ranked:
        assert r.risk["q90"] == pytest.approx(
            ex_by_label[r.label].risk["q90"], rel=1e-6)
    assert ex.best.objective("q90") <= sr.best.objective("q90") + 1e-6


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------


def test_report_rows_and_json(space, evaluator):
    res = evaluator.evaluate([space.candidate_at(0), space.candidate_at(1)])
    rows = result_rows(res)
    assert len(rows) == 2
    for sku in space.skus:
        assert f"{sku.name}:unit" in rows[0]
    # detail rows follow the CostEngine.as_rows column contract
    det = detail_rows(space, res[0].candidate)
    assert [r["system"] for r in det] == [s.name for s in space.skus]
    assert {"raw_chips", "nre_total", "re_total", "total"} <= set(det[0])
    sr = exhaustive_search(space, evaluator=evaluator)
    js = to_json(search_summary(sr, top=3))
    assert sr.best.label in js
