"""Paper Fig. 6: total (RE + amortized NRE) cost structure of a single
800 mm^2 5nm system vs production quantity.

All (quantity x packaging) cells are priced in one CostEngine call;
``share_nre=False`` keeps every cell its own standalone product group,
as in the paper's single-system experiment.
"""
from repro.core import CostEngine, SystemBatch

from .common import emit

QUANTITIES = (2e5, 5e5, 1e6, 2e6, 5e6, 1e7)
VARIANTS = (("SoC", {"kind": "soc", "area": 800.0, "process": "5nm"}),
            ("MCM-2", {"kind": "split", "area": 800.0, "process": "5nm",
                       "n": 2, "integration": "MCM"}),
            ("InFO-2", {"kind": "split", "area": 800.0, "process": "5nm",
                        "n": 2, "integration": "InFO"}),
            ("2.5D-2", {"kind": "split", "area": 800.0, "process": "5nm",
                        "n": 2, "integration": "2.5D"}))


def run():
    specs, meta = [], []
    for qty in QUANTITIES:
        for label, s in VARIANTS:
            specs.append(dict(s, quantity=qty))
            meta.append((qty, label))

    batch = SystemBatch.from_specs(specs, share_nre=False)
    tc = CostEngine().total(batch)

    rows = []
    for i, (qty, label) in enumerate(meta):
        if label == "SoC":
            base = float(tc.re.total[i])   # per-quantity RE baseline
        rows.append({
            "quantity": qty, "system": label,
            "re_norm": float(tc.re.total[i]) / base,
            "nre_modules_norm": float(tc.nre.modules[i]) / base,
            "nre_chips_norm": float(tc.nre.chips[i]) / base,
            "nre_pkg_norm": float(tc.nre.packages[i]) / base,
            "nre_d2d_norm": float(tc.nre.d2d[i]) / base,
            "total_norm": float(tc.total[i]) / base,
        })
    emit("fig6_single_system_total_cost", rows)
    return rows


if __name__ == "__main__":
    run()
