"""Hypothesis property tests on the cost model's invariants."""
import math

import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep, see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import (amortized_costs, dies_per_wafer, re_cost,
                        soc_system, split_system, yield_murphy,
                        yield_negative_binomial, yield_poisson)

areas = st.floats(min_value=1.0, max_value=900.0)
d0s = st.floats(min_value=0.01, max_value=0.5)
clusters = st.floats(min_value=1.0, max_value=10.0)


@given(areas, d0s, clusters)
@settings(max_examples=60, deadline=None)
def test_yield_in_unit_interval(a, d0, c):
    for f in (lambda: yield_negative_binomial(a, d0, c),
              lambda: yield_poisson(a, d0),
              lambda: yield_murphy(a, d0)):
        y = float(f())
        assert 0.0 < y <= 1.0


@given(areas, areas, d0s, clusters)
@settings(max_examples=60, deadline=None)
def test_yield_monotone_decreasing_in_area(a1, a2, d0, c):
    lo, hi = sorted((a1, a2))
    assert float(yield_negative_binomial(hi, d0, c)) <= \
        float(yield_negative_binomial(lo, d0, c)) + 1e-12


@given(areas, d0s)
@settings(max_examples=60, deadline=None)
def test_negative_binomial_bounds_poisson(a, d0):
    """Clustering helps: NB yield >= Poisson yield (c finite)."""
    assert float(yield_negative_binomial(a, d0, 3.0)) >= \
        float(yield_poisson(a, d0)) - 1e-6    # f32 rounding at tiny DS


@given(areas, areas)
@settings(max_examples=60, deadline=None)
def test_dies_per_wafer_monotone(a1, a2):
    lo, hi = sorted((a1, a2))
    assert float(dies_per_wafer(hi)) <= float(dies_per_wafer(lo))


@given(st.floats(min_value=50.0, max_value=900.0),
       st.integers(min_value=1, max_value=8),
       st.sampled_from(["MCM", "InFO", "2.5D"]),
       st.sampled_from(["5nm", "7nm", "14nm"]))
@settings(max_examples=40, deadline=None)
def test_re_cost_always_positive_and_itemized(area, n, tech, node):
    s = split_system("s", area, node, n, tech)
    br = re_cost(s)
    assert br.total > 0
    for v in br.as_dict().values():
        assert v >= 0.0
    # multi-chip systems must carry D2D area overhead
    assert s.silicon_area_mm2 >= area


@given(st.floats(min_value=1e4, max_value=1e9),
       st.floats(min_value=1e4, max_value=1e9))
@settings(max_examples=40, deadline=None)
def test_amortized_total_monotone_in_quantity(q1, q2):
    lo, hi = sorted((q1, q2))
    c_lo = amortized_costs([soc_system("s", 300.0, "7nm", quantity=lo)])["s"]
    c_hi = amortized_costs([soc_system("s", 300.0, "7nm", quantity=hi)])["s"]
    assert c_hi.total <= c_lo.total + 1e-9


@given(st.floats(min_value=100.0, max_value=800.0),
       st.sampled_from(["5nm", "7nm"]))
@settings(max_examples=30, deadline=None)
def test_chip_last_never_worse_than_chip_first(area, node):
    s = split_system("s", area, node, 3, "2.5D")
    assert re_cost(s, "chip-last").total <= re_cost(s, "chip-first").total
