"""Chunked Mamba2 SSD scan as a Pallas kernel.

Grid (B, H, nChunks): the chunk axis innermost; the (N, P) state matrix
lives in VMEM scratch and is carried across chunks — the inter-chunk
recurrence never touches HBM.  Per chunk (L = chunk length):

  intra:  (C·Bᵀ ⊙ decay ⊙ dt) @ X       one (L,L)x(L,P) MXU matmul
  inter:  exp(cum) ⊙ (C @ state)        (L,N)x(N,P)
  state:  exp(cum_L)·state + (B ⊙ dt·exp(cum_L - cum))ᵀ @ X

B/C are head-shared (G=1): their blocks ignore the head grid index, so
VMEM holds one (L, N) copy per chunk regardless of head count.

TPU alignment: L=128 chunk, N=64..128 state, P=64 headdim — all MXU
native tile multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # (L,)
    a = a_ref[0, 0]                                    # scalar -exp(A_log)
    bm = b_ref[0].astype(jnp.float32)                  # (L, N)
    cm = c_ref[0].astype(jnp.float32)                  # (L, N)

    da = dt * a                                        # (L,)
    cum = jnp.cumsum(da)                               # (L,)
    # decay[t, s] = exp(cum_t - cum_s) for s <= t
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.where(tri, jnp.exp(seg), 0.0)          # (L, L)

    cb = cm @ bm.T                                     # (L, L) head-shared
    y_intra = (cb * decay * dt[None, :]) @ x           # (L, P)

    state = state_ref[...]                             # (N, P)
    y_inter = (jnp.exp(cum)[:, None] * (cm @ state))   # (L,N)@(N,P)

    total = jnp.exp(cum[-1])
    w = dt * jnp.exp(cum[-1] - cum)                    # (L,)
    state_ref[...] = total * state + (bm * w[:, None]).T @ x

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)


def mamba_scan(xh, dt, a_log, bm, cm, *, chunk: int = 128,
               interpret: bool = False):
    """xh:(B,S,H,P) dt:(B,S,H) a_log:(H,) bm/cm:(B,S,N) -> (B,S,H,P).

    Returns y only (final state recomputed by the XLA path when needed;
    the kernel targets the training/prefill hot loop).
    """
    b, s, h, p = xh.shape
    n = bm.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32)).reshape(h, 1)

    grid = (b, h, nc)
    kernel = functools.partial(_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda bb, hh, ic: (bb, ic, hh, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, hh, ic: (bb, ic, hh)),
            pl.BlockSpec((1, 1), lambda bb, hh, ic: (hh, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ic: (bb, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, ic: (bb, ic, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda bb, hh, ic: (bb, ic, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), xh.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xh, dt, a, bm, cm)
    return y, None
