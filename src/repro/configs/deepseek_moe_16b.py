"""DeepSeekMoE-16B — fine-grained MoE. [arXiv:2401.06066; hf]

28L, d_model 2048, 16 heads (MHA), vocab 102400.  FFN: 2 shared experts +
64 routed experts (top-6), expert d_ff 1408; first layer dense (d_ff
10944 per HF config).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab=102400,
    n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408, first_dense=1,
    subquadratic=False,
)
