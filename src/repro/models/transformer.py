"""Decoder-only LM assembly for the dense / MoE / MLA / hybrid / xLSTM
families.  One spec builder + three entry points per family:

  lm_spec(cfg)                      -> ParamSpec tree (stacked for scan)
  lm_forward(cfg, params, tokens)   -> logits          (training path)
  lm_prefill(cfg, params, tokens)   -> (last_logits, cache)
  lm_decode(cfg, params, tok, cache, kv_len) -> (logits, cache)

Layers are stacked on a leading "layers" axis and executed with
``lax.scan`` (+ per-layer ``jax.checkpoint`` remat) so the HLO stays
small enough to compile 88-layer/123B graphs in the multi-pod dry-run.

Caches are ParamSpec trees too (zeros-init), so the dry-run can turn
them into sharded ShapeDtypeStructs without allocating 500k-token KV.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import constrain
from .attention import gqa_decode_layer, gqa_layer, gqa_spec
from .common import (ParamSpec, cross_entropy, embed, embed_spec, is_spec,
                     mask_padded_vocab, rmsnorm, rmsnorm_spec, spec_map,
                     swiglu, swiglu_spec, unembed)
from .mla import mla_decode_layer, mla_layer, mla_spec
from .moe import moe_apply, moe_spec
from .ssm import (mamba_decode_layer, mamba_layer, mamba_spec,
                  _mamba_dims)
from .xlstm import (mlstm_chunked, mlstm_decode, mlstm_parallel,
                    mlstm_spec, slstm_decode, slstm_layer, slstm_spec)


def stack_specs(tree, n: int):
    """Prepend a ('layers',) axis of size n to every leaf spec."""
    return spec_map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                            dtype=s.dtype, init=s.init, scale=s.scale), tree)


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Attention + FFN block (dense / mla / moe)
# ---------------------------------------------------------------------------


def _attn_spec(cfg):
    if cfg.attn == "mla":
        return mla_spec(cfg.d_model, cfg.n_heads, q_lora=cfg.q_lora,
                        kv_lora=cfg.kv_lora, qk_nope=cfg.qk_nope,
                        qk_rope=cfg.qk_rope, v_head=cfg.v_head)
    return gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh)


def block_spec(cfg, moe_layer: bool) -> Dict:
    sp = {"ln1": rmsnorm_spec(cfg.d_model), "ln2": rmsnorm_spec(cfg.d_model),
          "attn": _attn_spec(cfg)}
    if moe_layer:
        sp["ffn"] = moe_spec(cfg.d_model, cfg.n_experts, cfg.d_ff_expert,
                             cfg.n_shared)
    else:
        sp["ffn"] = swiglu_spec(cfg.d_model, cfg.d_ff)
    return sp


def block_apply(cfg, p, x, positions, moe_layer: bool):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attn == "mla":
        a = mla_layer(p["attn"], h, positions, rope_theta=cfg.rope_theta,
                      impl=cfg.attn_impl if cfg.attn_impl != "pallas" else "chunked",
                      chunk=cfg.attn_chunk)
    else:
        a = gqa_layer(p["attn"], h, positions, impl=cfg.attn_impl,
                      rope_theta=cfg.rope_theta, chunk=cfg.attn_chunk)
    x = x + a
    x = constrain(x, "batch", "seq", "act_embed")
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe_layer:
        y = moe_apply(p["ffn"], h, cfg.top_k, cfg.capacity_factor)
    else:
        y = swiglu(p["ffn"], h)
    x = x + y
    return constrain(x, "batch", "seq", "act_embed")


def block_decode(cfg, p, x, cache, position, kv_len):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.attn == "mla":
        a, ckv, krope = mla_decode_layer(p["attn"], h, cache["ckv"],
                                         cache["krope"], position, kv_len,
                                         cfg.rope_theta)
        new_cache = {"ckv": ckv, "krope": krope}
    else:
        a, ck, cv = gqa_decode_layer(p["attn"], h, cache["k"], cache["v"],
                                     position, kv_len, cfg.rope_theta)
        new_cache = {"k": ck, "v": cv}
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "router" in p["ffn"]:
        y = moe_apply(p["ffn"], h, cfg.top_k, capacity_factor=4.0)
    else:
        y = swiglu(p["ffn"], h)
    return x + y, new_cache


def _attn_cache_spec(cfg, batch: int, cache_len: int, dtype) -> Dict:
    if cfg.attn == "mla":
        return {
            "ckv": ParamSpec((batch, cache_len, cfg.kv_lora),
                             ("batch", "kv_seq", None), dtype, init="zeros"),
            "krope": ParamSpec((batch, cache_len, cfg.qk_rope),
                               ("batch", "kv_seq", None), dtype, init="zeros"),
        }
    return {
        "k": ParamSpec((batch, cache_len, cfg.n_kv_heads, cfg.dh),
                       ("batch", "kv_seq", "kv", None), dtype, init="zeros"),
        "v": ParamSpec((batch, cache_len, cfg.n_kv_heads, cfg.dh),
                       ("batch", "kv_seq", "kv", None), dtype, init="zeros"),
    }


# ---------------------------------------------------------------------------
# Hybrid (Zamba2-style) and xLSTM structure helpers
# ---------------------------------------------------------------------------


def _hybrid_layout(cfg) -> Tuple[int, int, int]:
    group = cfg.attn_every
    n_groups = cfg.n_layers // group
    rest = cfg.n_layers - n_groups * group
    return n_groups, group, rest


def _mamba_block_spec(cfg) -> Dict:
    return {"ln": rmsnorm_spec(cfg.d_model),
            "mixer": mamba_spec(cfg.d_model, expand=cfg.ssm_expand,
                                headdim=cfg.ssm_headdim, state=cfg.ssm_state)}


def _shared_attn_spec(cfg) -> Dict:
    return {"ln1": rmsnorm_spec(cfg.d_model), "ln2": rmsnorm_spec(cfg.d_model),
            "attn": gqa_spec(cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh),
            "ffn": swiglu_spec(cfg.d_model, cfg.d_ff)}


def _xlstm_layout(cfg) -> Tuple[int, int]:
    group = cfg.slstm_every
    n_groups = cfg.n_layers // group
    return n_groups, group


# ---------------------------------------------------------------------------
# Spec builder
# ---------------------------------------------------------------------------


def lm_spec(cfg) -> Dict:
    sp: Dict[str, Any] = {"embed": embed_spec(cfg.padded_vocab, cfg.d_model),
                          "final_norm": rmsnorm_spec(cfg.d_model)}
    if cfg.family in ("dense", "vlm"):
        sp["blocks"] = stack_specs(block_spec(cfg, False), cfg.n_layers)
    elif cfg.family == "moe":
        if cfg.first_dense:
            sp["dense_blocks"] = stack_specs(
                {"ln1": rmsnorm_spec(cfg.d_model),
                 "ln2": rmsnorm_spec(cfg.d_model), "attn": _attn_spec(cfg),
                 "ffn": swiglu_spec(cfg.d_model, cfg.d_ff)}, cfg.first_dense)
        sp["blocks"] = stack_specs(block_spec(cfg, True),
                                   cfg.n_layers - cfg.first_dense)
    elif cfg.family == "hybrid":
        n_groups, group, rest = _hybrid_layout(cfg)
        sp["groups"] = stack_specs(stack_specs(_mamba_block_spec(cfg), group),
                                   n_groups)
        if rest:
            sp["rest"] = stack_specs(_mamba_block_spec(cfg), rest)
        sp["shared_attn"] = stack_specs(_shared_attn_spec(cfg),
                                        cfg.n_shared_attn)
    elif cfg.family == "ssm":
        n_groups, group = _xlstm_layout(cfg)
        sp["groups"] = {
            "mlstm": stack_specs(stack_specs(
                {"ln": rmsnorm_spec(cfg.d_model),
                 "mixer": mlstm_spec(cfg.d_model, cfg.n_heads)}, group - 1),
                n_groups),
            "slstm": stack_specs(
                {"ln": rmsnorm_spec(cfg.d_model),
                 "mixer": slstm_spec(cfg.d_model, cfg.n_heads)}, n_groups),
        }
    else:
        raise ValueError(f"lm_spec does not handle family {cfg.family!r}")
    return sp


# ---------------------------------------------------------------------------
# Forward (training / prefill shared trunk)
# ---------------------------------------------------------------------------


def _embed_inputs(cfg, params, tokens, img_embeds=None):
    x = embed(params["embed"], tokens).astype(cfg.jdtype)
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(cfg.jdtype), x], axis=1)
    return constrain(x, "batch", "seq", "act_embed")


def _trunk(cfg, params, x, positions):
    """Everything between embedding and final norm (family dispatch)."""
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.family == "moe" and cfg.first_dense:
            def dense_body(h, p):
                return block_apply(cfg, p, h, positions, False), None
            x, _ = jax.lax.scan(_remat(dense_body, cfg), x,
                                params["dense_blocks"])
        moe_layer = cfg.family == "moe"

        def body(h, p):
            return block_apply(cfg, p, h, positions, moe_layer), None
        x, _ = jax.lax.scan(_remat(body, cfg), x, params["blocks"])

    elif cfg.family == "hybrid":
        n_groups, group, rest = _hybrid_layout(cfg)
        shared = params["shared_attn"]

        def mamba_body(h, p):
            y = mamba_layer(p["mixer"], rmsnorm(p["ln"], h, cfg.norm_eps),
                            chunk=cfg.ssm_chunk)
            return constrain(h + y, "batch", "seq", "act_embed"), None

        def group_body(h, inp):
            gp, gi = inp
            h, _ = jax.lax.scan(_remat(mamba_body, cfg), h, gp)
            sel = jax.tree_util.tree_map(
                lambda s: jax.lax.dynamic_index_in_dim(
                    s, gi % cfg.n_shared_attn, 0, keepdims=False), shared)
            h = block_apply(cfg, sel, h, positions, False)
            return h, None

        x, _ = jax.lax.scan(group_body, x,
                            (params["groups"], jnp.arange(n_groups)))
        if rest:
            x, _ = jax.lax.scan(_remat(mamba_body, cfg), x, params["rest"])

    elif cfg.family == "ssm":
        def mlstm_body(h, p):
            y, _ = mlstm_chunked(p["mixer"],
                                 rmsnorm(p["ln"], h, cfg.norm_eps),
                                 chunk=cfg.attn_chunk)
            return constrain(h + y, "batch", "seq", "act_embed"), None

        def group_body(h, gp):
            h, _ = jax.lax.scan(_remat(mlstm_body, cfg), h, gp["mlstm"])
            y = slstm_layer(gp["slstm"]["mixer"],
                            rmsnorm(gp["slstm"]["ln"], h, cfg.norm_eps))
            return h + y, None

        x, _ = jax.lax.scan(group_body, x, params["groups"])
    else:
        raise ValueError(cfg.family)
    return x


def lm_forward(cfg, params, tokens, img_embeds=None):
    """Full-sequence logits. tokens:(B,S_text) [+ img (B,P,D)] -> (B,S,V)."""
    x = _embed_inputs(cfg, params, tokens, img_embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    x = _trunk(cfg, params, x, positions)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = mask_padded_vocab(unembed(params["embed"], x), cfg.vocab)
    return constrain(logits, "batch", "seq", "vocab")


def lm_loss(cfg, params, batch) -> jnp.ndarray:
    """batch: {'tokens','labels'[, 'img_embeds']}. Image positions get -1."""
    img = batch.get("img_embeds")
    logits = lm_forward(cfg, params, batch["tokens"], img)
    labels = batch["labels"]
    if img is not None:
        pad = jnp.full(img.shape[:2], -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return cross_entropy(logits, labels)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def decode_cache_spec(cfg, batch: int, cache_len: int) -> Dict:
    dt = cfg.jdtype
    if cfg.family in ("dense", "vlm", "moe"):
        cache: Dict[str, Any] = {"layers": stack_specs(
            _attn_cache_spec(cfg, batch, cache_len, dt),
            cfg.n_layers - cfg.first_dense)}
        if cfg.first_dense:
            cache["dense_layers"] = stack_specs(
                _attn_cache_spec(cfg, batch, cache_len, dt), cfg.first_dense)
        return cache
    if cfg.family == "hybrid":
        n_groups, group, rest = _hybrid_layout(cfg)
        d_inner = cfg.ssm_expand * cfg.d_model
        h = d_inner // cfg.ssm_headdim
        conv_dim = d_inner + 2 * cfg.ssm_state
        mamba_cache = {
            "conv": ParamSpec((batch, 3, conv_dim), ("batch", None, "mlp"),
                              dt, init="zeros"),
            "ssm": ParamSpec((batch, h, cfg.ssm_state, cfg.ssm_headdim),
                             ("batch", "heads", None, None), jnp.float32,
                             init="zeros"),
        }
        cache = {"groups": stack_specs(stack_specs(mamba_cache, group),
                                       n_groups),
                 "attn": stack_specs(
                     _attn_cache_spec(cfg, batch, cache_len, dt), n_groups)}
        if rest:
            cache["rest"] = stack_specs(mamba_cache, rest)
        return cache
    if cfg.family == "ssm":
        n_groups, group = _xlstm_layout(cfg)
        dh = cfg.d_model // cfg.n_heads
        mlstm_cache = {
            "C": ParamSpec((batch, cfg.n_heads, dh, dh),
                           ("batch", "heads", None, None), jnp.float32,
                           init="zeros"),
            "n": ParamSpec((batch, cfg.n_heads, dh),
                           ("batch", "heads", None), jnp.float32, init="zeros"),
            "m": ParamSpec((batch, cfg.n_heads), ("batch", "heads"),
                           jnp.float32, init="zeros"),
        }
        slstm_cache = {
            k: ParamSpec((batch, cfg.n_heads, dh), ("batch", "heads", None),
                         jnp.float32, init="zeros")
            for k in ("c", "n", "h", "m")
        }
        return {"mlstm": stack_specs(stack_specs(mlstm_cache, group - 1),
                                     n_groups),
                "slstm": stack_specs(slstm_cache, n_groups)}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def lm_prefill(cfg, params, tokens, cache_len: int, img_embeds=None):
    """Process the prompt; return (last-token logits, populated cache).

    For attention families the per-layer K/V computed during the forward
    pass are collected as scan outputs and written into the cache.  For
    recurrent families the final states are the cache.
    """
    x = _embed_inputs(cfg, params, tokens, img_embeds)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    dt = cfg.jdtype

    def pad_to_cache(kv):
        pad = cache_len - kv.shape[1]
        return jnp.pad(kv, ((0, 0), (0, pad)) + ((0, 0),) * (kv.ndim - 2))

    if cfg.family in ("dense", "vlm", "moe"):
        from .attention import gqa_project_qkv
        from .mla import mla_compress_kv

        def body_with_kv(moe_layer):
            def body(h, p):
                hn = rmsnorm(p["ln1"], h, cfg.norm_eps)
                if cfg.attn == "mla":
                    ckv, krope = mla_compress_kv(p["attn"], hn, positions,
                                                 cfg.rope_theta, cfg.kv_lora)
                    kv_out = {"ckv": pad_to_cache(ckv.astype(dt)),
                              "krope": pad_to_cache(krope.astype(dt))}
                else:
                    _, k, v = gqa_project_qkv(p["attn"], hn, positions,
                                              cfg.rope_theta)
                    kv_out = {"k": pad_to_cache(k.astype(dt)),
                              "v": pad_to_cache(v.astype(dt))}
                h = block_apply(cfg, p, h, positions, moe_layer)
                return h, kv_out
            return body

        cache: Dict[str, Any] = {}
        if cfg.family == "moe" and cfg.first_dense:
            x, kv_d = jax.lax.scan(_remat(body_with_kv(False), cfg), x,
                                   params["dense_blocks"])
            cache["dense_layers"] = kv_d
        x, kv = jax.lax.scan(
            _remat(body_with_kv(cfg.family == "moe"), cfg), x,
            params["blocks"])
        cache["layers"] = kv

    elif cfg.family == "hybrid":
        # Run chunked SSD keeping final states; shared-attn KV per group.
        n_groups, group, rest = _hybrid_layout(cfg)
        shared = params["shared_attn"]
        from .attention import gqa_project_qkv
        from .ssm import causal_conv, ssd_chunked, _project

        def mamba_body(h, p):
            hn = rmsnorm(p["ln"], h, cfg.norm_eps)
            z, xbc, dtp, (d_inner, nh, hd, st) = _project(p["mixer"], hn)
            xbc_c, conv_tail = causal_conv(xbc, p["mixer"]["conv_w"],
                                           p["mixer"]["conv_b"])
            xbc_c = jax.nn.silu(xbc_c)
            xh, bm, cm = jnp.split(xbc_c, [d_inner, d_inner + st], -1)
            xh = xh.reshape(b, s, nh, hd)
            dtp = jax.nn.softplus(dtp + p["mixer"]["dt_bias"][None, None, :])
            y, state = ssd_chunked(xh, dtp, p["mixer"]["A_log"], bm, cm,
                                   chunk=cfg.ssm_chunk)
            y = y + p["mixer"]["D"][None, None, :, None].astype(y.dtype) * xh
            y = y.reshape(b, s, d_inner)
            y = rmsnorm({"scale": p["mixer"]["norm"]}, y * jax.nn.silu(z))
            y = jnp.einsum("bsk,kd->bsd", y, p["mixer"]["out_proj"])
            st_out = {"conv": conv_tail.astype(dt), "ssm": state}
            return h + y, st_out

        def group_body(h, inp):
            gp, gi = inp
            h, states = jax.lax.scan(_remat(mamba_body, cfg), h, gp)
            sel = jax.tree_util.tree_map(
                lambda t: jax.lax.dynamic_index_in_dim(
                    t, gi % cfg.n_shared_attn, 0, keepdims=False), shared)
            hn = rmsnorm(sel["ln1"], h, cfg.norm_eps)
            _, k, v = gqa_project_qkv(sel["attn"], hn, positions,
                                      cfg.rope_theta)
            kv = {"k": pad_to_cache(k.astype(dt)),
                  "v": pad_to_cache(v.astype(dt))}
            h = block_apply(cfg, sel, h, positions, False)
            return h, (states, kv)

        x, (g_states, attn_kv) = jax.lax.scan(
            group_body, x, (params["groups"], jnp.arange(n_groups)))
        cache = {"groups": g_states, "attn": attn_kv}
        if rest:
            x, r_states = jax.lax.scan(_remat(mamba_body, cfg), x,
                                       params["rest"])
            cache["rest"] = r_states

    elif cfg.family == "ssm":
        # Recompute final recurrent states via the decode cells after the
        # parallel forward (prefill of recurrent nets = run the recurrence;
        # we fold it into the same scan for the sLSTM and use a one-shot
        # recurrent pass for the mLSTM states).
        def mlstm_body(h, p):
            hn = rmsnorm(p["ln"], h, cfg.norm_eps)
            y, state = mlstm_chunked(p["mixer"], hn, chunk=cfg.attn_chunk)
            return constrain(h + y, "batch", "seq", "act_embed"), state

        def group_body(h, gp):
            h, mstates = jax.lax.scan(_remat(mlstm_body, cfg), h, gp["mlstm"])
            hn = rmsnorm(gp["slstm"]["ln"], h, cfg.norm_eps)
            y, sstate = _slstm_layer_with_state(gp["slstm"]["mixer"], hn)
            return h + y, (mstates, sstate)

        x, (mlstm_states, slstm_states) = jax.lax.scan(group_body, x,
                                                       params["groups"])
        cache = {"mlstm": mlstm_states, "slstm": slstm_states}
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = mask_padded_vocab(unembed(params["embed"], x[:, -1:, :])[:, 0],
                               cfg.vocab)
    return logits, cache


def _mlstm_final_state(p, x):
    """Final (C, n, m) of an mLSTM over x — recurrence in closed form."""
    b, s, d = x.shape
    h = p["wi"].shape[1]
    dh = d // h
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"]).astype(jnp.float32)
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"]).astype(jnp.float32)
    i, logf = _mlstm_gates_import(p, x)
    cumf = jnp.cumsum(logf, axis=1)                    # (B,S,H)
    tail = cumf[:, -1:, :] - cumf                      # decay to seq end
    w = i + tail                                       # log-weight of each s
    m = w.max(axis=1)                                  # (B,H)
    wexp = jnp.exp(w - m[:, None, :])                  # (B,S,H)
    c = jnp.einsum("bsh,bhsk,bhsv->bhkv", wexp, k, v)
    n = jnp.einsum("bsh,bhsk->bhk", wexp, k)
    return {"C": c, "n": n, "m": m}


def _mlstm_gates_import(p, x):
    from .xlstm import _mlstm_gates
    return _mlstm_gates(p, x)


def _slstm_layer_with_state(p, x):
    from .xlstm import _slstm_cell, slstm_init_cache
    from .common import rmsnorm as _rms
    b, s, d = x.shape
    _, h, dh, _ = p["rh"].shape
    xg = jnp.einsum("bsd,dghe->bsghe", x, p["wx"])
    state = slstm_init_cache(p, b)

    def body(st, xg_t):
        st = _slstm_cell(p, st, xg_t)
        return st, st["h"]

    state, hs = jax.lax.scan(body, state, jnp.moveaxis(xg, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1)
    hs = _rms({"scale": p["norm"].reshape(-1)},
              hs.reshape(b, s, h * dh)).reshape(b, s, h, dh)
    return jnp.einsum("bshk,hkd->bsd", hs.astype(x.dtype), p["wo"]), state


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def lm_decode(cfg, params, token, cache, kv_len):
    """One decode step. token:(B,1) int32; kv_len:(B,) current cache fill.

    Returns (logits (B,V), new_cache).
    """
    x = embed(params["embed"], token).astype(cfg.jdtype)
    b = x.shape[0]
    position = kv_len

    if cfg.family in ("dense", "vlm", "moe"):
        def body(h, inp):
            p, c = inp
            h, nc = block_decode(cfg, p, h, c, position, kv_len)
            return h, nc

        new_cache: Dict[str, Any] = {}
        if cfg.family == "moe" and cfg.first_dense:
            x, nc_d = jax.lax.scan(body, x, (params["dense_blocks"],
                                             cache["dense_layers"]))
            new_cache["dense_layers"] = nc_d
        x, nc = jax.lax.scan(body, x, (params["blocks"], cache["layers"]))
        new_cache["layers"] = nc

    elif cfg.family == "hybrid":
        n_groups, group, rest = _hybrid_layout(cfg)
        shared = params["shared_attn"]

        def mamba_body(h, inp):
            p, c = inp
            y, nc = mamba_decode_layer(
                p["mixer"], rmsnorm(p["ln"], h, cfg.norm_eps), c)
            return h + y, nc

        def group_body(h, inp):
            gp, gc, akv, gi = inp
            h, nstates = jax.lax.scan(mamba_body, h, (gp, gc))
            sel = jax.tree_util.tree_map(
                lambda t: jax.lax.dynamic_index_in_dim(
                    t, gi % cfg.n_shared_attn, 0, keepdims=False), shared)
            hn = rmsnorm(sel["ln1"], h, cfg.norm_eps)
            a, ck, cv = gqa_decode_layer(sel["attn"], hn, akv["k"], akv["v"],
                                         position, kv_len, cfg.rope_theta)
            h = h + a
            hn2 = rmsnorm(sel["ln2"], h, cfg.norm_eps)
            h = h + swiglu(sel["ffn"], hn2)
            return h, (nstates, {"k": ck, "v": cv})

        x, (g_states, attn_kv) = jax.lax.scan(
            group_body, x, (params["groups"], cache["groups"],
                            cache["attn"], jnp.arange(n_groups)))
        new_cache = {"groups": g_states, "attn": attn_kv}
        if rest:
            x, r_states = jax.lax.scan(mamba_body, x,
                                       (params["rest"], cache["rest"]))
            new_cache["rest"] = r_states

    elif cfg.family == "ssm":
        def mlstm_body(h, inp):
            p, c = inp
            y, nc = mlstm_decode(p["mixer"],
                                 rmsnorm(p["ln"], h, cfg.norm_eps), c)
            return h + y, nc

        def group_body(h, inp):
            gp, gc_m, gc_s = inp
            h, m_new = jax.lax.scan(mlstm_body, h, (gp["mlstm"], gc_m))
            hn = rmsnorm(gp["slstm"]["ln"], h, cfg.norm_eps)
            y, s_new = slstm_decode(gp["slstm"]["mixer"], hn, gc_s)
            return h + y, (m_new, s_new)

        x, (m_states, s_states) = jax.lax.scan(
            group_body, x, (params["groups"], cache["mlstm"],
                            cache["slstm"]))
        new_cache = {"mlstm": m_states, "slstm": s_states}
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = mask_padded_vocab(unembed(params["embed"], x[:, 0]), cfg.vocab)
    return logits, new_cache
