"""Shared helpers for the benchmark suite (CSV emission, timing, and the
BENCH_*.json perf-trajectory files CI tracks)."""
from __future__ import annotations

import json
import os
import pathlib
import platform
import subprocess
import time
from typing import Callable, Iterable

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def git_sha() -> str:
    """Best-effort HEAD SHA of the repo this bench ran from, or
    ``"unknown"`` outside a git checkout / without a git binary — a
    stamp must never fail a bench."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        if out.returncode == 0 and sha:
            return sha
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def env_metadata() -> dict:
    """Environment stamp for BENCH_*.json: the facts needed to judge
    whether two runs of the perf trajectory are comparable (JAX version
    and backend, device kind, host CPU budget, the commit the numbers
    came from, and whether the run was traced — tracing is designed to
    be near-free but a stamped run never has to argue about it)."""
    meta = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "cpu_count": os.cpu_count(),
        "git_sha": git_sha(),
        "repro_trace": os.environ.get("REPRO_TRACE", ""),
    }
    try:
        import jax
        meta["jax_version"] = jax.__version__
        meta["backend"] = jax.default_backend()
        devs = jax.devices()
        meta["n_devices"] = len(devs)
        meta["device_kind"] = devs[0].device_kind if devs else "none"
    except Exception as e:            # never fail a bench over a stamp
        meta["jax_version"] = f"unavailable: {type(e).__name__}"
    try:
        from repro import obs
        meta["trace_enabled"] = bool(obs.enabled())
    except Exception:
        meta["trace_enabled"] = None
    return meta


def write_bench_json(name: str, summary: dict) -> pathlib.Path:
    """Persist a benchmark summary as ``BENCH_<name>.json`` at the repo
    root.  CI uploads these as artifacts and
    ``scripts/check_bench_regression.py`` guards them against the
    committed baselines in ``benchmarks/baselines/``.  Every file is
    stamped with :func:`env_metadata` under ``"env"`` (existing keys are
    left untouched; a caller-provided ``env`` wins)."""
    summary = dict(summary)
    summary.setdefault("env", env_metadata())
    path = REPO_ROOT / f"BENCH_{name}.json"
    path.write_text(json.dumps(summary, indent=2, sort_keys=True,
                               default=float) + "\n")
    print(f"# wrote {path}")
    return path


def obs_summary() -> dict:
    """Per-phase observability breakdown for a bench summary, or ``{}``
    when tracing is off (so existing BENCH_*.json keys never change on
    an untraced run): the tracer's per-phase wall table, per-jit
    compile/dispatch attribution, and ``device_get`` totals."""
    from repro import obs
    if not obs.enabled():
        return {}
    from repro.obs import jaxhooks
    from repro.obs.trace import TRACER
    return {"phases": TRACER.phase_table(),
            "jit": jaxhooks.stats(),
            "device_get": jaxhooks.device_get_stats()}


def emit(section: str, rows: Iterable[dict]):
    rows = list(rows)
    if not rows:
        print(f"# {section}: (no rows)")
        return
    cols = list(rows[0].keys())
    print(f"# {section}")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))
    print()


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def timed(fn: Callable, *args, repeat: int = 3):
    fn(*args)                      # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt * 1e6           # us per call
