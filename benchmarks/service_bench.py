"""Pricing-service throughput benchmark: N concurrent clients with a
mixed request diet against one continuous-batching PricingService.

  PYTHONPATH=src python -m benchmarks.service_bench [--fast] [--clients N]

Each client interleaves large price sweeps with point queries; dedicated
clients add Monte-Carlo risk sweeps, ranking, what-if grids and an
evolutionary search, so every service lane (chunk / mc / gen / raw) sees
traffic while the scheduler coalesces across clients.

Asserts (acceptance criteria of the service):
  * ZERO jit recompiles after the warmup tick — every lane the workload
    touches was compiled at startup or admission, never on the tick loop;
  * aggregate coalesced throughput >= 0.5x the single-client fused
    ``ChunkedEvaluator`` rate under >= 8 concurrent clients (the
    continuous-batching overhead bound; skipped under --fast where the
    sample is too small to be stable, which instead enforces a loose p95
    latency ceiling for CI smoke).

Reports aggregate candidates/s, request latency p50/p95/p99, padded-slot
waste, and cache/recompile counters, and writes BENCH_service.json for
CI trend tracking (guarded against benchmarks/baselines/ by
scripts/check_bench_regression.py).
"""
import argparse
import asyncio
import json
import time

import numpy as np

from repro import obs
from repro.dse import ChunkedEvaluator
from repro.service import (McSpec, MCRiskRequest, PriceRequest,
                           PriceSystemsRequest, PricingService, RankRequest,
                           SearchRequest, SearchWarmup, ServiceConfig,
                           WhatIfRequest)

from .common import REPO_ROOT, emit, write_bench_json
from .dse_bench import SPACE


def _client_requests(i: int, rng: np.random.Generator, size: int,
                     sweeps: int, sweep_rows: int, fast: bool):
    """The mixed diet of client ``i`` (deterministic in the seed)."""
    reqs = []
    for _ in range(sweeps):
        reqs.append(PriceRequest(
            indices=rng.integers(0, size, sweep_rows).tolist()))
        reqs.append(PriceRequest(indices=rng.integers(0, size, 4).tolist()))
    if i == 0:
        reqs.append(SearchRequest(seed=1, population=32,
                                  generations=3 if fast else 8, elite=8))
    elif i == 1:
        reqs.append(MCRiskRequest(
            indices=rng.integers(0, size, 64).tolist(),
            mc=McSpec(draws=64, quantiles=(0.5, 0.9), seed=0)))
    elif i == 2:
        reqs.append(WhatIfRequest(base=int(rng.integers(0, size))))
    elif i == 3:
        reqs.append(RankRequest(indices=rng.integers(0, size, 128).tolist(),
                                top_k=5))
    elif i == 4:
        reqs.append(PriceSystemsRequest(specs=(
            {"kind": "soc", "name": "soc_a", "area": 250.0,
             "process": "7nm", "quantity": 1e6},
            {"kind": "split", "name": "mcm_b", "area": 500.0,
             "process": "7nm", "n_chiplets": 2, "integration": "MCM",
             "quantity": 5e5},)))
    return reqs


def run(fast: bool = False, clients: int = 8) -> dict:
    size = SPACE.size()
    chunk = 64 if fast else 128
    sweep_rows = 256 if fast else 2048
    sweeps = 2 if fast else 4
    cfg = ServiceConfig(
        chunk=chunk, split=max(8, chunk // 4),
        warm_mc=((64, (0.5, 0.9)),),
        warm_search=(SearchWarmup(population=32, elite=8),),
        max_pending=10_000_000)

    # -- single-client fused baseline (the 0.5x yardstick) -----------------
    ev = ChunkedEvaluator(SPACE, candidates_per_chunk=chunk)
    rng = np.random.default_rng(0)
    idx = rng.integers(0, size, 4 * sweep_rows)
    ev.evaluate_indices(idx[:chunk])                       # compile
    t0 = time.perf_counter()
    ev.evaluate_indices(idx)
    single = idx.size / (time.perf_counter() - t0)

    # -- the concurrent mixed workload -------------------------------------
    async def _main():
        svc = PricingService(SPACE, cfg)
        await svc.start()                                  # warmup

        async def client(i: int):
            crng = np.random.default_rng(100 + i)
            out = []
            for req in _client_requests(i, crng, size, sweeps, sweep_rows,
                                        fast):
                out.append(await svc.submit(req))
            return out

        t0 = time.perf_counter()
        per_client = await asyncio.gather(*(client(i)
                                            for i in range(clients)))
        wall = time.perf_counter() - t0
        await svc.stop()
        return per_client, wall, svc

    per_client, wall, svc = asyncio.run(_main())
    flat = [r for rs in per_client for r in rs]
    bad = [r for r in flat if not r.ok]
    assert not bad, f"{len(bad)} requests failed: {bad[0].error}"

    snap = svc.snapshot()
    agg = snap["rows_priced"] / wall
    summary = {
        "clients": clients,
        "n_requests": snap["n_ok"],
        "rows_priced": snap["rows_priced"],
        "wall_s": wall,
        "agg_candidates_per_sec": agg,
        "single_client_candidates_per_sec": single,
        "vs_single_client": agg / single,
        "latency_p50_s": snap["latency_s"]["p50"],
        "latency_p95_s": snap["latency_s"]["p95"],
        "latency_p99_s": snap["latency_s"]["p99"],
        "ttfr_p50_s": snap["ttfr_s"]["p50"],
        "ticks": snap["ticks"],
        "device_gets": snap["device_gets"],
        "slot_occupancy": snap["slot_occupancy"],
        "padded_waste_frac": snap["padded_waste_frac"],
        "recompiles_after_warmup": snap["recompiles_after_warmup"],
        "result_cache_hits": snap["result_cache"]["hits"],
        "fast": fast,
    }
    if obs.enabled():
        # per-phase breakdown (compile / dispatch / device_get / pack /
        # scatter) rides along only on traced runs, so untraced
        # BENCH_service.json keys never change.
        summary["phases"] = snap["obs"]["phases"]
        summary["jit"] = snap["obs"]["jit"]
        summary["device_get"] = snap["obs"]["device_get"]
        summary["tick_coverage"] = snap["obs"]["tick_coverage"]
        summary["recompiles_in_ticks"] = snap["obs"]["recompiles_in_ticks"]
    emit("service: mixed workload", [{
        "clients": clients, "requests": summary["n_requests"],
        "rows": summary["rows_priced"],
        "agg_cands_per_sec": agg, "single_client": single,
        "vs_single": summary["vs_single_client"],
        "p50_ms": summary["latency_p50_s"] * 1e3,
        "p95_ms": summary["latency_p95_s"] * 1e3,
        "p99_ms": summary["latency_p99_s"] * 1e3,
        "occupancy": summary["slot_occupancy"],
        "recompiles": summary["recompiles_after_warmup"]}])
    write_bench_json("service", summary)

    # -- acceptance --------------------------------------------------------
    assert snap["device_gets"] == snap["ticks"], \
        "tick loop must sync exactly once per tick"
    assert summary["recompiles_after_warmup"] == 0, \
        f"hot path recompiled {summary['recompiles_after_warmup']}x"
    if obs.enabled():
        # traced run: export the Perfetto trace + registry snapshot and
        # hold the tracer to its own acceptance bar — spans must account
        # for >= 90% of measured tick wall, and the tracer's independent
        # compile attribution must agree that warmed ticks never retrace.
        from repro.obs.registry import REGISTRY
        trace_path = svc.dump_flight_recorder(
            REPO_ROOT / "BENCH_service_trace.json")
        doc = json.loads(trace_path.read_text())
        assert doc.get("traceEvents"), "trace export produced no events"
        REGISTRY.write_json(REPO_ROOT / "BENCH_service_metrics.json")
        print(f"# wrote {trace_path}")
        print(f"# wrote {REPO_ROOT / 'BENCH_service_metrics.json'}")
        cov = summary["tick_coverage"]
        assert cov >= 0.9, \
            f"trace spans cover {cov:.1%} of tick wall (need >= 90%)"
        assert summary["recompiles_in_ticks"] == 0, \
            (f"tracer attributed {summary['recompiles_in_ticks']} "
             f"jit compiles to warmed ticks")
        print(f"# service: traced run — {cov:.1%} tick coverage, "
              f"0 tracer-attributed tick recompiles")
    if fast:
        # CI smoke: tiny sample, shared boxes — just a sanity ceiling
        assert summary["latency_p95_s"] < 30.0, \
            f"p95 {summary['latency_p95_s']:.2f}s absurd for the smoke load"
    else:
        assert summary["vs_single_client"] >= 0.5, \
            (f"coalesced throughput {agg:,.0f} cands/s is "
             f"{summary['vs_single_client']:.2f}x the single-client rate "
             f"{single:,.0f} (need >= 0.5x)")
    print(f"# service: {agg:,.0f} cands/s across {clients} clients "
          f"({summary['vs_single_client']:.2f}x single-client), "
          f"p95 {summary['latency_p95_s']*1e3:.1f} ms, "
          f"0 hot-path recompiles")
    return summary


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: small sweeps, loose bounds")
    ap.add_argument("--clients", type=int, default=8)
    args = ap.parse_args()
    run(fast=args.fast, clients=args.clients)


if __name__ == "__main__":
    main()
