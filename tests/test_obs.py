"""repro.obs: span tracer semantics (off-by-default no-op, nesting /
parent attribution, Chrome export), metrics registry + TraceCounts shim,
JitProbe compile-vs-dispatch attribution, the device_get hook, the
flight recorder ring, and the trace-count oracle — tracing a warmed
fused sweep must not retrace anything and must leave results bit-exact.
"""
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import jaxhooks
from repro.obs.flight import FlightRecorder
from repro.obs.registry import Registry, REGISTRY, TraceCounts
from repro.obs.trace import TRACER, Tracer, _NULL_SPAN


@pytest.fixture
def traced():
    """Globally enable tracing for one test, restoring prior state."""
    was = obs.enabled()
    obs.enable()
    TRACER.clear()
    yield
    TRACER.clear()
    if not was:
        obs.disable()


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    assert tr.span("tick") is _NULL_SPAN
    with tr.span("tick", lane="chunk"):
        pass
    tr.add_complete("kernel_dispatch", 0.5)
    tr.instant("marker")
    assert tr.events() == []
    assert tr.phase_table() == {}


def test_span_nesting_records_parents():
    tr = Tracer(enabled=True)
    with tr.span("tick", lane="chunk"):
        with tr.span("pack"):
            pass
        tr.add_complete("kernel_dispatch", 1e-4, fn="dse.chunk")
    evs = tr.events()
    by_name = {e["name"]: e for e in evs}
    assert by_name["pack"]["parent"] == "tick"
    assert by_name["kernel_dispatch"]["parent"] == "tick"
    assert by_name["tick"]["parent"] is None
    assert by_name["tick"]["labels"] == {"lane": "chunk"}
    # children close before the parent -> ordering in the ring
    assert [e["name"] for e in evs] == ["pack", "kernel_dispatch", "tick"]


def test_phase_table_coverage_and_count():
    tr = Tracer(enabled=True)
    for _ in range(3):
        with tr.span("tick"):
            t0 = time.perf_counter()
            time.sleep(0.002)
            tr.add_complete("kernel_dispatch", time.perf_counter() - t0)
    tbl = tr.phase_table()
    assert tbl["tick"]["count"] == 3
    assert tbl["kernel_dispatch"]["count"] == 3
    assert tbl["kernel_dispatch"]["total_s"] >= 6e-3
    assert tbl["kernel_dispatch"]["mean_s"] >= 2e-3
    # the dispatch child dominates the tick wall here
    assert 0.5 < tr.coverage("tick") <= 1.0
    assert tr.count("kernel_dispatch") == 3
    assert tr.count("kernel_dispatch", parent="tick") == 3
    assert tr.count("kernel_dispatch", parent="pack") == 0


def test_ring_capacity_bounds_memory():
    tr = Tracer(capacity=8, enabled=True)
    for i in range(50):
        tr.add_complete("x", 0.0, i=i)
    evs = tr.events()
    assert len(evs) == 8
    assert evs[-1]["labels"] == {"i": 49}


def test_chrome_export_is_valid_trace_event_json(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("tick", lane="mc"):
        tr.add_complete("device_get", 2e-4, bytes=128)
    path = tr.export_chrome(tmp_path / "trace.json")
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs and all(e["ph"] == "X" for e in evs)
    for e in evs:
        assert {"name", "ts", "dur", "pid", "tid", "cat"} <= set(e)
    dg = next(e for e in evs if e["name"] == "device_get")
    assert dg["args"]["bytes"] == 128 and dg["args"]["parent"] == "tick"


def test_enable_disable_runtime_toggle():
    was = obs.enabled()
    try:
        obs.enable()
        assert obs.enabled()
        obs.disable()
        assert not obs.enabled()
        assert TRACER.span("x") is _NULL_SPAN
    finally:
        TRACER.clear()
        obs.enable(was)
        if not was:
            obs.disable()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram():
    reg = Registry()
    c = reg.counter("reqs", help="requests")
    c.inc()
    c.inc(4)
    assert c.get() == 5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(7)
    g.dec(2)
    assert g.get() == 5
    h = reg.histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    s = h.sample()
    assert s["count"] == 4 and s["sum"] == 10.0
    assert s["min"] == 1.0 and s["max"] == 4.0
    # get-or-create returns the same instrument; kind clashes are errors
    assert reg.counter("reqs") is c
    with pytest.raises(TypeError):
        reg.gauge("reqs")


def test_histogram_reservoir_decimates_deterministically():
    h = Registry().histogram("h", max_samples=64)
    for i in range(10_000):
        h.observe(float(i))
    assert h.count == 10_000
    assert len(h._samples) <= 64
    # quantiles stay ordered and within the observed range
    q = [h.quantile(x) for x in (0.0, 0.5, 0.95, 1.0)]
    assert q == sorted(q)
    assert 0.0 <= q[0] and q[-1] <= 9999.0


def test_registry_snapshot_and_exposition():
    reg = Registry()
    reg.counter("ticks", help="device ticks").inc(3)
    reg.histogram("lat").observe(0.5)
    snap = reg.snapshot()
    assert snap["ticks"] == {"kind": "counter", "value": 3.0}
    assert snap["lat"]["count"] == 1
    text = reg.exposition()
    assert "# HELP ticks device ticks" in text
    assert "# TYPE ticks counter" in text
    assert "ticks 3" in text
    assert "# TYPE lat histogram" in text
    assert "lat_count 1" in text
    assert 'lat{quantile="50"}' in text


def test_registry_write_json(tmp_path):
    reg = Registry()
    reg.counter("a").inc()
    path = reg.write_json(tmp_path / "metrics.json")
    assert json.loads(path.read_text())["a"]["value"] == 1.0


def test_trace_counts_is_counter_compatible_and_mirrors():
    reg = Registry()
    tc = TraceCounts(registry=reg, prefix="trace")
    tc["re"] += 1
    tc["re"] += 1
    tc["nre"] += 1
    assert dict(tc) == {"re": 2, "nre": 1}
    assert tc["missing"] == 0                      # Counter semantics
    assert dict(TraceCounts(registry=reg)) == {}
    assert reg.get("trace_re").get() == 2
    assert reg.get("trace_nre").get() == 1
    # the bench/test oracle idiom stays byte-compatible
    before = dict(tc)
    assert before == {"re": 2, "nre": 1}


# ---------------------------------------------------------------------------
# JitProbe + device_get hook
# ---------------------------------------------------------------------------


def test_jit_probe_attributes_compile_then_dispatch(traced):
    reg = Registry()
    counts = TraceCounts(registry=reg)

    def impl(x):
        counts["k"] += 1
        return x * 2.0

    probe = jaxhooks.instrument(jax.jit(impl), "test.fn",
                                trace_key="k", counts=counts)
    try:
        x = jnp.arange(4.0)
        probe(x)                                   # first call: traces
        probe(x)
        probe(x)                                   # steady state
        st = probe.summary()
        assert st["signatures"] == 1
        assert st["compiles"] == 1 and st["calls"] == 2
        assert st["compile_s"] > 0 and st["dispatch_s"] > 0
        # a new shape is a new signature and a fresh compile
        probe(jnp.arange(8.0))
        st = probe.summary()
        assert st["signatures"] == 2 and st["compiles"] == 2
        assert TRACER.count("jit_compile") == 2
        assert TRACER.count("kernel_dispatch") == 2
    finally:
        jaxhooks._PROBES.remove(probe)


def test_jit_probe_disabled_is_passthrough():
    assert not obs.enabled()

    def impl(x):
        return x + 1

    probe = jaxhooks.instrument(jax.jit(impl), "test.off")
    try:
        out = probe(jnp.arange(3))
        assert np.array_equal(np.asarray(out), [1, 2, 3])
        assert probe.stats == {}                   # nothing recorded
    finally:
        jaxhooks._PROBES.remove(probe)


def test_device_get_hook_counts_calls_and_bytes(traced):
    # `traced` installed the hook via obs.enable()
    before = jaxhooks.device_get_stats()
    x = jnp.arange(16, dtype=jnp.float32)
    host = jax.device_get(x)
    assert np.array_equal(host, np.arange(16, dtype=np.float32))
    after = jaxhooks.device_get_stats()
    assert after["calls"] == before["calls"] + 1
    assert after["bytes"] == before["bytes"] + 64
    assert TRACER.count("device_get") >= 1


def test_device_get_hook_uninstall_restores():
    obs.enable()
    hooked = jax.device_get
    assert getattr(hooked, "_repro_obs_hook", False)
    obs.disable()
    assert not getattr(jax.device_get, "_repro_obs_hook", False)
    # double-uninstall is harmless
    jaxhooks.uninstall_device_get_hook()
    TRACER.clear()


# ---------------------------------------------------------------------------
# Flight recorder
# ---------------------------------------------------------------------------


def test_flight_recorder_ring_and_dump(tmp_path):
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record("tick", lane="chunk", rows=i, wall_s=1e-3)
    fr.record("request_error", uid=9, kind="price", error="boom")
    assert len(fr) == 4
    assert fr.n_recorded == 11
    recs = fr.records()
    assert recs[-1]["event"] == "request_error"
    assert fr.records(event="tick")[-1]["rows"] == 9
    path = fr.dump(tmp_path / "flight.json")
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == 4
    # durationful records export as complete spans, the rest as instants
    phs = {e["name"]: e["ph"] for e in evs}
    assert phs["tick"] == "X" and phs["request_error"] == "i"


def test_flight_recorder_dump_merges_extra_events(tmp_path):
    fr = FlightRecorder()
    fr.record("tick", wall_s=1e-3)
    extra = [{"name": "kernel_dispatch", "ph": "X", "ts": 0.0, "dur": 1.0,
              "pid": 1, "tid": 1, "cat": "repro", "args": {}}]
    doc = json.loads(fr.dump(tmp_path / "f.json",
                             extra_events=extra).read_text())
    assert {e["name"] for e in doc["traceEvents"]} == \
        {"tick", "kernel_dispatch"}


# ---------------------------------------------------------------------------
# The oracle: tracing a warmed sweep neither retraces nor changes results
# ---------------------------------------------------------------------------


def test_tracing_warmed_sweep_no_retrace_bit_exact():
    from repro.core.engine import TRACE_COUNTS
    from repro.dse import ChunkedEvaluator, DesignSpace, SKU

    space = DesignSpace(
        skus=(SKU("a", 200.0, 1e6),), processes=("7nm",),
        integrations=("MCM",), chiplet_counts=(1, 2), allow_reuse=False)
    ev = ChunkedEvaluator(space, candidates_per_chunk=8)
    idx = np.arange(space.size(), dtype=np.int64)
    ev.evaluate_indices(idx)                       # warm the trace
    baseline = ev.evaluate_indices(idx)            # untraced reference
    warm = dict(TRACE_COUNTS)

    obs.enable()
    TRACER.clear()
    try:
        traced = ev.evaluate_indices(idx)
    finally:
        obs.disable()
        TRACER.clear()

    assert dict(TRACE_COUNTS) == warm, \
        "enabling tracing retraced a warmed signature"
    assert np.array_equal(np.asarray(traced.portfolio_cost),
                          np.asarray(baseline.portfolio_cost))
    assert np.array_equal(np.asarray(traced.sku_unit_total),
                          np.asarray(baseline.sku_unit_total))


def test_trace_counts_global_mirrors_registry():
    from repro.core.engine import TRACE_COUNTS
    assert isinstance(TRACE_COUNTS, TraceCounts)
    for key, n in TRACE_COUNTS.items():
        m = REGISTRY.get(f"trace_{key}")
        assert m is not None, f"trace_{key} not mirrored"
        assert m.get() >= 1 if n else True
