"""RE (recurring engineering) cost model — paper Sec. 3.2, Eqs. (4)-(5).

The total RE cost of a system is broken into the paper's five itemized
components:

  1. cost of raw chips,
  2. cost of chip defects,
  3. cost of raw packages (substrate + interposer/RDL + bonding + assembly),
  4. cost of package defects,
  5. cost of wasted known-good-dies (KGDs) destroyed by packaging defects.

Bumping / wafer sort / package test are folded into the raw-chip and
raw-package terms (the paper includes but does not itemize them).

Two packaging flows (Eq. 5) are modeled; chip-last is the default, as in
the paper's experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from .system import Chip, System
from .technology import IntegrationTech, node, tech
from .yield_model import (dies_per_wafer, raw_die_cost,
                          yield_negative_binomial)


@dataclasses.dataclass
class REBreakdown:
    """Itemized RE cost of one unit of a system (USD)."""

    raw_chips: float
    chip_defects: float
    raw_package: float
    package_defects: float
    wasted_kgd: float

    @property
    def total(self) -> float:
        return (self.raw_chips + self.chip_defects + self.raw_package
                + self.package_defects + self.wasted_kgd)

    @property
    def die_cost(self) -> float:
        """Cost attributable to silicon (what AMD's Fig. 5 compares)."""
        return self.raw_chips + self.chip_defects

    @property
    def packaging_cost(self) -> float:
        """Footnote 2: raw package + package defects + wasted KGDs."""
        return self.raw_package + self.package_defects + self.wasted_kgd

    def as_dict(self) -> Dict[str, float]:
        return {
            "raw_chips": self.raw_chips,
            "chip_defects": self.chip_defects,
            "raw_package": self.raw_package,
            "package_defects": self.package_defects,
            "wasted_kgd": self.wasted_kgd,
            "total": self.total,
        }

    def scaled(self, f: float) -> "REBreakdown":
        return REBreakdown(*(f * x for x in dataclasses.astuple(self)))


# ---------------------------------------------------------------------------
# Per-chip silicon cost
# ---------------------------------------------------------------------------


def chip_costs(chip: Chip) -> Dict[str, float]:
    """Raw die cost, defect overhead and KGD cost for one die."""
    n = chip.node
    area = chip.area_mm2
    raw = float(raw_die_cost(area, n.wafer_cost))
    # sort + bump folded into the raw die (not itemized, per the paper)
    raw += n.wafer_sort_cost / float(dies_per_wafer(area))
    raw += n.bump_cost_per_mm2 * area
    y_die = float(yield_negative_binomial(area, chip.defect_density,
                                          n.cluster_param)) * n.wafer_yield
    kgd = raw / y_die
    return {"raw": raw, "defect": kgd - raw, "kgd": kgd, "yield": y_die}


# ---------------------------------------------------------------------------
# Package-level model
# ---------------------------------------------------------------------------


def _interposer_cost(system: System) -> tuple[float, float]:
    """(raw interposer cost, interposer yield y1) for InFO/2.5D, else (0,1).

    When a package design is reused (``package_area_mm2`` forced), the
    interposer is sized for the *design's* silicon capacity, not for the
    chips actually bonded — Sec. 5.1: reusing a 4x interposer in a 1x
    system pays the full 4x interposer.
    """
    t = system.tech
    if t.interposer_area_factor <= 0.0:
        return 0.0, 1.0
    design_silicon = system.package_area / t.package_area_factor
    area = design_silicon * t.interposer_area_factor
    inode = node(t.interposer_node)
    raw = area * t.interposer_cost_per_mm2
    y1 = float(yield_negative_binomial(area, t.interposer_defect_density,
                                       inode.cluster_param))
    return raw, y1


def _substrate_cost(system: System) -> float:
    t = system.tech
    return (system.package_area * t.substrate_cost_per_mm2
            * t.substrate_layer_factor)


def re_cost(system: System, flow: str = "chip-last") -> REBreakdown:
    """Full Eq. (4)/(5) RE breakdown for one unit of ``system``.

    flow: 'chip-last' (default, paper's choice) or 'chip-first'.
    """
    t: IntegrationTech = system.tech
    n_chips = system.n_chips

    per_chip = [chip_costs(c) for c in system.chips]
    raw_chips = sum(c["raw"] for c in per_chip)
    chip_defects = sum(c["defect"] for c in per_chip)
    kgd_total = sum(c["kgd"] for c in per_chip)

    c_interposer, y1 = _interposer_cost(system)
    c_substrate = _substrate_cost(system)
    c_bond = t.bond_cost_per_chip * n_chips

    y2n = t.y2_chip_bond ** n_chips
    y3 = t.y3_substrate_bond * t.assembly_yield

    if flow == "chip-last":
        # Eq. (4): the interposer/RDL ("package") is fabricated and yielded
        # first, then KGDs are bonded (y2 each), then the assembly is mated
        # to the substrate (y3).
        raw_package = c_interposer + c_substrate + c_bond
        package_defects = (c_interposer * (1.0 / (y1 * y2n * y3) - 1.0)
                           + (c_substrate + c_bond) * (1.0 / y3 - 1.0))
        wasted_kgd = kgd_total * (1.0 / (y2n * y3) - 1.0)
    elif flow == "chip-first":
        # Eq. (5) top: everything rides through the whole flow; KGDs are
        # exposed to interposer-fab losses as well.
        y_all = y1 * y2n * y3
        raw_package = c_interposer + c_substrate + c_bond
        package_defects = raw_package * (1.0 / y_all - 1.0)
        wasted_kgd = kgd_total * (1.0 / y_all - 1.0)
    else:
        raise ValueError(f"unknown flow {flow!r}")

    return REBreakdown(
        raw_chips=raw_chips,
        chip_defects=chip_defects,
        raw_package=raw_package,
        package_defects=package_defects,
        wasted_kgd=wasted_kgd,
    )


# ---------------------------------------------------------------------------
# Deprecated shim — the old homogeneous-even-split jnp kernel.  Its math now
# lives in engine.re_split_relaxed (shared primitives with CostEngine), which
# also fixed the hardcoded 0.99 wafer yield: pass the node's real value.
# ---------------------------------------------------------------------------


def re_cost_split(module_area_mm2, n_chiplets, *, wafer_cost, defect_density,
                  cluster, tech_params, d2d_overhead=None, wafer_yield=0.99):
    """Deprecated: use :class:`repro.core.engine.CostEngine` on a
    :class:`repro.core.batch.SystemBatch` (heterogeneous, batched), or
    :func:`repro.core.engine.re_split_relaxed` for the continuous-n
    relaxation.

    Kept as a thin wrapper for backward compatibility; ``wafer_yield``
    (previously hardcoded to 0.99) is now a parameter so callers can
    thread the per-node value.
    """
    import warnings

    from .engine import re_split_relaxed

    warnings.warn(
        "re_cost_split is deprecated; use CostEngine on a SystemBatch or "
        "engine.re_split_relaxed", DeprecationWarning, stacklevel=2)
    return re_split_relaxed(
        module_area_mm2, n_chiplets, wafer_cost=wafer_cost,
        defect_density=defect_density, cluster=cluster,
        tech_params=tech_params, wafer_yield=wafer_yield,
        interposer_cluster=cluster, d2d_overhead=d2d_overhead)
