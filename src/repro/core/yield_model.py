"""Yield models (paper Sec. 2.2, Eq. (1)) and wafer geometry.

All functions are pure ``jnp`` so they can be ``jit``/``vmap``/``grad``-ed
for design-space sweeps and the differentiable partitioner.

Conventions: die area ``s`` in mm^2, defect density ``d0`` in defects/cm^2
(hence the /100 conversion), wafer diameter in mm.
"""
from __future__ import annotations

import jax.numpy as jnp

MM2_PER_CM2 = 100.0


def yield_negative_binomial(area_mm2, d0_per_cm2, cluster=3.0):
    """Eq. (1): Y = (1 + D*S/c)^(-c) — Seeds / negative-binomial model."""
    area_cm2 = jnp.asarray(area_mm2) / MM2_PER_CM2
    return (1.0 + d0_per_cm2 * area_cm2 / cluster) ** (-cluster)


def yield_poisson(area_mm2, d0_per_cm2):
    """Poisson yield Y = exp(-D*S); the c -> inf limit of Eq. (1)."""
    area_cm2 = jnp.asarray(area_mm2) / MM2_PER_CM2
    return jnp.exp(-d0_per_cm2 * area_cm2)


def yield_murphy(area_mm2, d0_per_cm2):
    """Murphy's model Y = ((1 - e^-DS)/DS)^2 — kept for cross-checking.

    Uses expm1 to avoid the 1-exp(-x) cancellation blowing past 1.0 for
    tiny DS in float32.
    """
    ds = jnp.asarray(area_mm2) / MM2_PER_CM2 * d0_per_cm2
    ds = jnp.maximum(ds, 1e-12)
    return jnp.minimum((-jnp.expm1(-ds) / ds) ** 2, 1.0)


def dies_per_wafer(area_mm2, wafer_diameter_mm=300.0, edge_exclusion_mm=3.0,
                   scribe_mm=0.1):
    """Standard die-per-wafer estimate with edge loss correction.

    DPW = pi*(d/2)^2/S - pi*d/sqrt(2*S), with the diameter shrunk by the
    edge exclusion and the die grown by the scribe lane.
    """
    d = wafer_diameter_mm - 2.0 * edge_exclusion_mm
    s = jnp.asarray(area_mm2)
    # Grow die by scribe lane on each side (approx: sqrt area + scribe)^2.
    s = (jnp.sqrt(s) + scribe_mm) ** 2
    dpw = jnp.pi * (d / 2.0) ** 2 / s - jnp.pi * d / jnp.sqrt(2.0 * s)
    return jnp.maximum(dpw, 1.0)


def raw_die_cost(area_mm2, wafer_cost, wafer_diameter_mm=300.0):
    """Cost of an un-yielded die: wafer price / dies-per-wafer."""
    return wafer_cost / dies_per_wafer(area_mm2, wafer_diameter_mm)


def good_die_cost(area_mm2, wafer_cost, d0_per_cm2, cluster=3.0,
                  wafer_yield=0.99):
    """Cost of a known-good die (raw cost inflated by die + wafer yield)."""
    y = yield_negative_binomial(area_mm2, d0_per_cm2, cluster) * wafer_yield
    return raw_die_cost(area_mm2, wafer_cost) / y
