"""Hardware/workload co-design: price TPU-class accelerator packagings
with the faithful Chiplet Actuary model and fold the dry-run rooflines
into $/step per assigned architecture — the paper's decision method
applied to this framework's own hardware.

  PYTHONPATH=src python examples/codesign.py
"""
import json
from pathlib import Path

from repro.core import AcceleratorSpec, cost_per_step, price_accelerators

RESULTS = Path(__file__).resolve().parents[1] / "results" / \
    "dryrun_optimized.json"
FALLBACK = Path(__file__).resolve().parents[1] / "results" / "dryrun.json"


def main():
    spec = AcceleratorSpec(name="tpu_v5e_class", compute_area=300.0,
                           uncore_area=60.0, phy_area=80.0,
                           process="5nm", phy_process="14nm")
    print("accelerator packaging candidates (1M units):")
    prices = price_accelerators(spec)
    for label, p in prices.items():
        print(f"  {label:12s} unit ${p['unit_cost']:7.0f}  "
              f"die ${p['die_cost']:7.0f}  pkg ${p['packaging_cost']:6.0f}"
              f"  ${p['usd_per_pflops']:.0f}/PFLOPs")
    best = min(prices.items(), key=lambda kv: kv[1]["unit_cost"])
    print(f"cheapest: {best[0]} — the paper's OCME/heterogeneity insight "
          f"priced for this accelerator class\n")

    path = RESULTS if RESULTS.exists() else FALLBACK
    if not path.exists():
        print("run the dry-run first for $/step numbers")
        return
    results = json.loads(path.read_text())
    print(f"cost per training/serving step ({best[0]} packaging):")
    for key, v in sorted(results.items()):
        if v.get("status") != "ok" or v.get("mesh") != "16x16":
            continue
        if len(key.split("|")) != 3:
            continue
        r = v["roofline"]
        cell = {"t_compute": r["t_compute"], "t_memory": r["t_memory"],
                "t_collective": r["t_collective"],
                "hlo_flops": r["flops_per_device"] * r["chips"]}
        cps = cost_per_step(cell, best[1]["unit_cost"], r["chips"])
        print(f"  {key:45s} ${cps['usd_per_step']:8.4f}/step  "
              f"bound {r['bound']}")


if __name__ == "__main__":
    main()
