"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Deliberately naive implementations — quadratic attention, materialized
decay matrices, per-expert loops — independent of the model-zoo code so
kernel bugs cannot hide behind shared helpers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """q:(B,H,S,D) k/v:(B,Hkv,T,D) -> (B,H,S,Dv); GQA by head repeat."""
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    scale = d ** -0.5 if scale is None else scale
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=1)
        v = jnp.repeat(v, h // hkv, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool), t - s)
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_ref(q, k, v, kv_len=None, scale=None):
    """q:(B,H,D) k/v:(B,Hkv,T,D) -> (B,H,Dv)."""
    b, h, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    scale = d ** -0.5 if scale is None else scale
    if hkv != h:
        k = jnp.repeat(k, h // hkv, axis=1)
        v = jnp.repeat(v, h // hkv, axis=1)
    logits = jnp.einsum("bhd,bhtd->bht", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if kv_len is not None:
        mask = jnp.arange(t)[None, None, :] < kv_len[:, None, None]
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bht,bhtd->bhd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(xh, dt, a_log, bm, cm):
    """Sequential-recurrence oracle for the chunked SSD kernel.

    xh:(B,S,H,P) dt:(B,S,H) a_log:(H,) bm/cm:(B,S,N) -> (B,S,H,P), final
    state (B,H,N,P).  Direct h_t = exp(dt*A) h_{t-1} + dt*B x recurrence.
    """
    b, s, h, p = xh.shape
    n = bm.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t * a[None, :])[..., None, None]  # (B,H,1,1)
        upd = jnp.einsum("bn,bh,bhp->bhnp", b_t.astype(jnp.float32),
                         dt_t.astype(jnp.float32), x_t.astype(jnp.float32))
        state = state * decay + upd
        y = jnp.einsum("bn,bhnp->bhp", c_t.astype(jnp.float32), state)
        return state, y

    state0 = jnp.zeros((b, h, n, p), jnp.float32)
    state, ys = jax.lax.scan(
        step, state0,
        (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
         jnp.moveaxis(bm, 1, 0), jnp.moveaxis(cm, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype), state


def gmm_ref(x, w):
    """Grouped matmul oracle: (E,C,D) @ (E,D,F) -> (E,C,F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-5):
    """(N,D),(D,) -> (N,D)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def slstm_seq_ref(xg, r, bias):
    """Sequential sLSTM oracle. xg:(B,S,4,H,Dh) r:(4,H,Dh,Dh)."""
    b, s, _, h, dh = xg.shape
    state = {k: jnp.zeros((b, h, dh), jnp.float32)
             for k in ("c", "n", "h", "m")}

    def step(st, xg_t):
        rec = jnp.einsum("bhd,ghde->bghe", st["h"], r.astype(jnp.float32))
        g = xg_t.astype(jnp.float32) + rec + bias.astype(jnp.float32)[None]
        zt = jnp.tanh(g[:, 0])
        it = g[:, 1]
        ft = jax.nn.log_sigmoid(g[:, 2])
        ot = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(ft + st["m"], it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + st["m"] - m_new)
        c_new = f_ * st["c"] + i_ * zt
        n_new = f_ * st["n"] + i_
        h_new = ot * c_new / jnp.maximum(n_new, 1.0)
        return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new

    _, hs = jax.lax.scan(step, state, jnp.moveaxis(xg, 1, 0))
    return jnp.moveaxis(hs, 0, 1).astype(xg.dtype)
