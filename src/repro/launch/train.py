"""Training launcher: the end-to-end driver (runs for real on CPU with
reduced configs; the same code path is what the dry-run lowers at
production scale).

Features wired here (the fault-tolerance story):
  * deterministic data with O(1) skip-ahead  -> restarts never replay
  * async sharded checkpoints + auto-resume from the newest valid step
  * elastic restore (checkpoint written on one mesh restores on another)
  * per-step metrics log (jsonl) + heartbeat file for external watchdogs

Usage (CPU example — ~100M-param model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch xlstm_125m \
      --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
  (add --smoke for the reduced config)
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, CheckpointManager
from repro.configs.base import ARCH_IDS, get_config
from repro.data import DataConfig, synthetic_batch
from repro.launch.mesh import make_mesh
from repro.parallel import sharding as shd
from repro.parallel import steps as st


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_IDS, default="xlstm_125m")
    p.add_argument("--smoke", action="store_true",
                   help="use the reduced config (CPU-friendly)")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--accum", type=int, default=1)
    p.add_argument("--ckpt-dir", type=Path, default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log", type=Path, default=None)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    cfg = cfg.replace(dtype="float32")     # CPU numerics

    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev, 1), ("data", "model")) if n_dev > 1 else None
    rules = shd.default_rules() if mesh else None

    key = jax.random.PRNGKey(args.seed)
    state = st.init_train_state(cfg, key)
    step_fn = jax.jit(st.make_train_step(
        cfg, base_lr=args.lr, warmup=min(20, args.steps // 10 + 1),
        total_steps=args.steps, accum=args.accum), donate_argnums=(0,))

    dc = DataConfig(seq_len=args.seq, global_batch=args.batch,
                    vocab=cfg.vocab, seed=args.seed)

    start = 0
    ckpt = None
    writer = None
    if args.ckpt_dir:
        args.ckpt_dir.mkdir(parents=True, exist_ok=True)
        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        latest = ckpt.latest()
        if latest is not None:
            _, state = ckpt.restore_latest(state)
            start = latest
            print(f"[resume] restored step {start} from {args.ckpt_dir}")
        writer = AsyncCheckpointer(ckpt)

    logf = open(args.log, "a") if args.log else None
    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = synthetic_batch(dc, step)
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        if args.accum > 1:
            jb = {k: v.reshape((args.accum, v.shape[0] // args.accum)
                               + v.shape[1:]) for k, v in jb.items()}
        state, metrics = step_fn(state, jb)
        loss = float(metrics["loss"])
        losses.append(loss)
        if logf:
            logf.write(json.dumps({"step": step + 1, "loss": loss,
                                   "lr": float(metrics["lr"]),
                                   "t": time.time() - t0}) + "\n")
            logf.flush()
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step+1:5d}  loss {loss:.4f}  "
                  f"({(time.time()-t0)/(step-start+1):.3f}s/step)")
        if writer and (step + 1) % args.ckpt_every == 0:
            writer.submit(step + 1, state)
        if args.ckpt_dir:
            (args.ckpt_dir / "heartbeat").write_text(str(time.time()))
    if writer:
        writer.submit(args.steps, state)
        writer.wait()
        writer.close()
    if logf:
        logf.close()
    first, last = losses[0], float(np.mean(losses[-10:]))
    floor = float(np.log(cfg.vocab))     # random-stream entropy floor
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"(uniform-token floor ~{floor:.3f})")
    # success = finite and not diverging; synthetic random tokens sit AT
    # the entropy floor, so "improvement" is only meaningful vs blow-up
    ok = np.isfinite(last) and last < max(first * 1.05, floor * 1.1)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
