"""Grouped-query attention: full, chunked (online-softmax) and decode paths.

Three execution paths share one math definition:

* ``full``    — materializes the (S, S) score matrix; reference/smoke path.
* ``chunked`` — ``lax.scan`` over KV chunks with a running (max, sum)
  accumulator: flash-attention dataflow expressed in pure ``lax`` so the
  multi-pod dry-run lowers it on any backend with O(S·chunk) memory.
* ``decode``  — one query token against a KV cache (linear in cache len).

The Pallas TPU kernel (kernels/flash_attention.py) implements the same
contract; ``ops.attention`` dispatches on ``impl={"xla","pallas"}``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ParamSpec, apply_rope, dense_spec

NEG_INF = -1e30


def gqa_spec(d_model: int, n_heads: int, n_kv: int, head_dim: int,
             qk_head_dim: Optional[int] = None) -> Dict[str, ParamSpec]:
    qk = qk_head_dim or head_dim
    return {
        "wq": ParamSpec((d_model, n_heads, qk), ("embed", "heads", None)),
        "wk": ParamSpec((d_model, n_kv, qk), ("embed", "kv", None)),
        "wv": ParamSpec((d_model, n_kv, head_dim), ("embed", "kv", None)),
        "wo": ParamSpec((n_heads, head_dim, d_model), ("heads", None, "embed")),
    }


def _group(q, n_kv):
    """(B,S,H,D) -> (B,S,Hkv,G,D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def _repeat_kv(k, n_heads):
    """Duplicate KV heads up to n_heads.

    Under tensor parallelism the (H -> Hkv x G) head-split reshape defeats
    GSPMD sharding propagation (e.g. 96 heads @16-way cannot split into
    (8, 12)), forcing q all-gathers.  Repeating KV keeps every einsum's
    head axis = the sharded q head axis; the repeat itself is sharded the
    same way.  The Pallas kernel path keeps true GQA indexing instead.
    """
    n_kv = k.shape[2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=2)


def attend_full(q, k, v, *, causal: bool = True,
                q_offset: int = 0, scale: Optional[float] = None):
    """Reference attention. q:(B,Sq,H,Dq) k:(B,Sk,Hkv,Dq) v:(B,Sk,Hkv,Dv)."""
    b, sq, h, dq = q.shape
    scale = scale if scale is not None else dq ** -0.5
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    # operands stay in model dtype; the MXU accumulates in f32
    logits = jnp.einsum("bshd,bthd->bhst", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(k.shape[1])
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def attend_chunked(q, k, v, *, causal: bool = True, chunk: int = 1024,
                   q_offset: int = 0, scale: Optional[float] = None):
    """Online-softmax attention, scanning KV in chunks (flash dataflow)."""
    b, sq, h, dq = q.shape
    sk = k.shape[1]
    dv = v.shape[-1]
    scale = scale if scale is not None else dq ** -0.5
    k = _repeat_kv(k, h)
    v = _repeat_kv(v, h)
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kvalid = jnp.arange(sk + pad) < sk
        sk_p = sk + pad
    else:
        kvalid = jnp.ones((sk,), bool)
        sk_p = sk
    n_chunks = sk_p // chunk
    qf = q                                            # (B,Sq,H,D)
    kc = k.reshape(b, n_chunks, chunk, h, dq)
    vc = v.reshape(b, n_chunks, chunk, h, dv)
    valc = kvalid.reshape(n_chunks, chunk)
    qpos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry                             # running max/sum/out
        kb, vb, val, ci = inp
        kpos = ci * chunk + jnp.arange(chunk)
        logits = jnp.einsum("bshd,bthd->bhst", qf, kb,
                            preferred_element_type=jnp.float32) * scale
        mask = val[None, None, None, :]
        if causal:
            mask = mask & (qpos[:, None] >= kpos[None, :])[None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
         valc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,H,Sq,Dv)
    out = jnp.einsum("bhsd->bshd", out)
    return out.astype(q.dtype)


def attend_decode(q, k_cache, v_cache, kv_len=None,
                  scale: Optional[float] = None):
    """One-step decode: q (B,1,H,Dq) vs cache (B,T,Hkv,D*).

    ``kv_len`` (B,) masks the still-empty tail of the cache.  When the
    cache's T axis is sharded, XLA turns the max/sum reductions into
    partial reductions + all-reduce — the flash-decode pattern.
    """
    b, _, h, dq = q.shape
    t, n_kv = k_cache.shape[1], k_cache.shape[2]
    scale = scale if scale is not None else dq ** -0.5
    qg = _group(q, n_kv)[:, 0]                        # (B,N,G,D)
    logits = jnp.einsum("bngd,btnd->bngt", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if kv_len is not None:
        mask = jnp.arange(t)[None] < kv_len[:, None]  # (B,T)
        logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngt,btnd->bngd", w.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full GQA layer (projections + rope + attention + output)
# ---------------------------------------------------------------------------


def gqa_project_qkv(params, x, positions, rope_theta: float = 10000.0):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, params["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, params["wv"])
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def gqa_output(params, attn_out):
    return jnp.einsum("bshd,hdm->bsm", attn_out, params["wo"])


def gqa_layer(params, x, positions, *, impl: str = "chunked",
              rope_theta: float = 10000.0, chunk: int = 1024):
    q, k, v = gqa_project_qkv(params, x, positions, rope_theta)
    if impl == "full":
        o = attend_full(q, k, v)
    elif impl == "chunked":
        o = attend_chunked(q, k, v, chunk=chunk)
    elif impl == "pallas":
        from ..kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=True)
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    return gqa_output(params, o)


def gqa_decode_layer(params, x, cache_k, cache_v, position, kv_len,
                     rope_theta: float = 10000.0):
    """Single-token decode; returns (out, new_k, new_v) cache slices."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dnk->bsnk", x, params["wk"])
    v = jnp.einsum("bsd,dnk->bsnk", x, params["wv"])
    pos = position[:, None] if position.ndim == 1 else position
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)
    ck = _scatter_kv(cache_k, k, kv_len)
    cv = _scatter_kv(cache_v, v, kv_len)
    o = attend_decode(q, ck, cv, kv_len=kv_len + 1)
    return gqa_output(params, o), ck, cv


def _scatter_kv(cache, new, kv_len):
    """Insert (B,1,N,D) `new` at per-batch position kv_len into (B,T,N,D).

    In-place scatter (buffer-aliased under jit donation): HBM traffic is
    the written slice, not a full cache rewrite — the jnp.where
    formulation costs a full cache read+write per layer per token (~100x
    the useful decode traffic at 32k).
    """
    b = cache.shape[0]
    return cache.at[jnp.arange(b), kv_len].set(
        new[:, 0].astype(cache.dtype), mode="drop")
